#!/usr/bin/env python3
"""Validate a `mma bench hotpath --json` report against the committed
baseline (`BENCH_0006_hotpath.json`).

Two duties, split by baseline provenance (see docs/PERF.md):

1. Schema validation — always. The fresh report must be the
   `mma-bench-hotpath/1` document shape, its replay must be flagged
   deterministic, and the incremental allocator must have done zero full
   re-solves while the reference did at least one.
2. Regression gate — only when the baseline's `provenance` is
   `"measured"`. CI machines are noisy, so the gate is deliberately
   loose: fail only if any events/sec figure fell below HALF the
   baseline (a >2x regression). A `"desk-estimated"` baseline skips the
   gate entirely (the numbers were never measured on comparable
   hardware). Set MMA_BENCH_SKIP_REGRESSION=1 to skip the gate on a
   machine known to be slow.

Usage: check_bench.py <fresh-report.json> [baseline.json]
"""

import json
import os
import sys

BASELINE = "BENCH_0006_hotpath.json"
SCHEMA = "mma-bench-hotpath/1"
# Events/sec may drop to 1/REGRESSION_FACTOR of baseline before failing.
REGRESSION_FACTOR = 2.0

EVENTS_KEYS = ("timer_wheel", "binary_heap", "fabric_flow_cycle")
LEG_KEYS = ("wall_s", "recomputes", "full_solves", "component_solves", "flows_solved")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")
        raise  # unreachable


def check_schema(doc: dict, path: str) -> None:
    if doc.get("schema") != SCHEMA:
        fail(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    if doc.get("provenance") not in ("measured", "desk-estimated"):
        fail(f"{path}: bad provenance {doc.get('provenance')!r}")
    eps = doc.get("events_per_sec")
    if not isinstance(eps, dict):
        fail(f"{path}: missing events_per_sec object")
    for k in EVENTS_KEYS:
        v = eps.get(k)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"{path}: events_per_sec.{k} = {v!r} (want a positive number)")
    replay = doc.get("replay")
    if not isinstance(replay, dict):
        fail(f"{path}: missing replay object")
    if replay.get("deterministic") is not True:
        fail(f"{path}: replay.deterministic is {replay.get('deterministic')!r}")
    if not isinstance(replay.get("requests"), int) or replay["requests"] <= 0:
        fail(f"{path}: replay.requests = {replay.get('requests')!r}")
    w = replay.get("wall_per_1m_requests_s")
    if not isinstance(w, (int, float)) or w <= 0:
        fail(f"{path}: replay.wall_per_1m_requests_s = {w!r}")
    for leg in ("incremental", "full"):
        obj = replay.get(leg)
        if not isinstance(obj, dict):
            fail(f"{path}: missing replay.{leg} object")
        for k in LEG_KEYS:
            v = obj.get(k)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{path}: replay.{leg}.{k} = {v!r}")
    # The tentpole's acceptance criterion, checked on every fresh report:
    # incremental does strictly fewer full re-solves than the reference.
    inc, full = replay["incremental"], replay["full"]
    if inc["full_solves"] >= full["full_solves"] or full["full_solves"] == 0:
        fail(
            f"{path}: incremental full_solves {inc['full_solves']} must be "
            f"strictly below reference full_solves {full['full_solves']} (> 0)"
        )


def main() -> None:
    if len(sys.argv) < 2:
        fail("usage: check_bench.py <fresh-report.json> [baseline.json]")
    fresh_path = sys.argv[1]
    base_path = sys.argv[2] if len(sys.argv) > 2 else BASELINE

    fresh = load(fresh_path)
    check_schema(fresh, fresh_path)
    base = load(base_path)
    check_schema(base, base_path)
    print(f"check_bench: schema ok ({fresh_path}, baseline {base_path})")

    if base.get("provenance") != "measured":
        print(
            f"check_bench: baseline provenance is "
            f"{base.get('provenance')!r}; regression gate skipped"
        )
        return
    if os.environ.get("MMA_BENCH_SKIP_REGRESSION"):
        print("check_bench: MMA_BENCH_SKIP_REGRESSION set; regression gate skipped")
        return

    worst = []
    for k in EVENTS_KEYS:
        got = fresh["events_per_sec"][k]
        want = base["events_per_sec"][k]
        ratio = got / want
        print(f"check_bench: events_per_sec.{k}: {got:.0f} vs baseline {want:.0f} ({ratio:.2f}x)")
        if ratio < 1.0 / REGRESSION_FACTOR:
            worst.append((k, ratio))
    if worst:
        detail = ", ".join(f"{k} at {r:.2f}x" for k, r in worst)
        fail(
            f"events/sec regression beyond {REGRESSION_FACTOR}x tolerance: {detail} "
            f"(set MMA_BENCH_SKIP_REGRESSION=1 to skip on known-slow machines)"
        )
    print("check_bench: regression gate ok")


if __name__ == "__main__":
    main()
