#!/usr/bin/env python3
"""Validate a bench JSON report against its committed baseline.

Handles both bench documents the `mma bench hotpath` invocation emits
(dispatch is on the report's `schema` key; see docs/PERF.md):

* `mma-bench-hotpath/1` — the BENCH_0006 hotpath harness
  (baseline `BENCH_0006_hotpath.json`)
* `mma-bench-engine/1` — the BENCH_0007 allocation-free engine leg
  (baseline `BENCH_0007_engine.json`, written via `--out-engine`)
* `mma-bench-serving/1` — the BENCH_0008 serving-cycle leg: LRU
  prefix-tier churn, streaming-histogram record rate, and the
  bounded-window streamed replay path
  (baseline `BENCH_0008_serving.json`, written via `--out-serving`)
* `mma-bench-fabric/1` — the BENCH_0009 O(due) fabric event loop:
  chunked-churn events/s, the solves-per-event ratio (coalescing must
  keep it below 1.0), the zero-flow-start-allocs invariant, and the
  coalesced-vs-eager completion-stream identity
  (baseline `BENCH_0009_fabric.json`, written via `--out-fabric`)
* `mma-bench-batching/1` — the BENCH_0010 continuous-batching step
  loop: fused steps/s under roofline costs, the memory-wall invariant
  (decode step time strictly increasing with aggregate batch KV
  bytes), and the legacy-identity flag (batch-1 + chunking-off
  batching renders byte-identically to the per-request scheduler)
  (baseline `BENCH_0010_batching.json`, written via `--out-batching`)

Two duties, split by baseline provenance:

1. Schema validation — always. The fresh report must match its schema's
   document shape, its replay must be flagged deterministic, the
   incremental allocator must have done zero full re-solves while the
   reference did at least one, (engine schema) the engine's steady
   state must have allocated nothing, and (serving schema) the streamed
   replay must have rendered identically to the materialized oracle
   without spilling.
2. Regression gate — only when the baseline's `provenance` is
   `"measured"`. CI machines are noisy, so the gate is deliberately
   loose: fail only if a throughput figure fell below HALF the baseline
   (a >2x regression). A `"desk-estimated"` baseline skips the gate
   entirely (the numbers were never measured on comparable hardware).
   Set MMA_BENCH_SKIP_REGRESSION=1 to skip the gate on a machine known
   to be slow.

Usage: check_bench.py <fresh-report.json> [baseline.json]
"""

import json
import os
import sys

SCHEMA_HOTPATH = "mma-bench-hotpath/1"
SCHEMA_ENGINE = "mma-bench-engine/1"
SCHEMA_SERVING = "mma-bench-serving/1"
SCHEMA_FABRIC = "mma-bench-fabric/1"
SCHEMA_BATCHING = "mma-bench-batching/1"
DEFAULT_BASELINES = {
    SCHEMA_HOTPATH: "BENCH_0006_hotpath.json",
    SCHEMA_ENGINE: "BENCH_0007_engine.json",
    SCHEMA_SERVING: "BENCH_0008_serving.json",
    SCHEMA_FABRIC: "BENCH_0009_fabric.json",
    SCHEMA_BATCHING: "BENCH_0010_batching.json",
}
# Throughput may drop to 1/REGRESSION_FACTOR of baseline before failing.
REGRESSION_FACTOR = 2.0

EVENTS_KEYS = ("timer_wheel", "binary_heap", "fabric_flow_cycle")
LEG_KEYS = ("wall_s", "recomputes", "full_solves", "component_solves", "flows_solved")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")
        raise  # unreachable


def check_replay(doc: dict, path: str) -> None:
    replay = doc.get("replay")
    if not isinstance(replay, dict):
        fail(f"{path}: missing replay object")
    if replay.get("deterministic") is not True:
        fail(f"{path}: replay.deterministic is {replay.get('deterministic')!r}")
    if not isinstance(replay.get("requests"), int) or replay["requests"] <= 0:
        fail(f"{path}: replay.requests = {replay.get('requests')!r}")
    w = replay.get("wall_per_1m_requests_s")
    if not isinstance(w, (int, float)) or w <= 0:
        fail(f"{path}: replay.wall_per_1m_requests_s = {w!r}")
    for leg in ("incremental", "full"):
        obj = replay.get(leg)
        if not isinstance(obj, dict):
            fail(f"{path}: missing replay.{leg} object")
        for k in LEG_KEYS:
            v = obj.get(k)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{path}: replay.{leg}.{k} = {v!r}")
    # The BENCH_0006 acceptance criterion, checked on every fresh report:
    # incremental does strictly fewer full re-solves than the reference.
    inc, full = replay["incremental"], replay["full"]
    if inc["full_solves"] >= full["full_solves"] or full["full_solves"] == 0:
        fail(
            f"{path}: incremental full_solves {inc['full_solves']} must be "
            f"strictly below reference full_solves {full['full_solves']} (> 0)"
        )


def check_hotpath_schema(doc: dict, path: str) -> None:
    eps = doc.get("events_per_sec")
    if not isinstance(eps, dict):
        fail(f"{path}: missing events_per_sec object")
    for k in EVENTS_KEYS:
        v = eps.get(k)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"{path}: events_per_sec.{k} = {v!r} (want a positive number)")
    check_replay(doc, path)


def check_engine_schema(doc: dict, path: str) -> None:
    eng = doc.get("engine")
    if not isinstance(eng, dict):
        fail(f"{path}: missing engine object")
    for k in ("chunks_per_sec", "actions_per_alloc"):
        v = eng.get(k)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"{path}: engine.{k} = {v!r} (want a positive number)")
    if not isinstance(eng.get("actions_total"), int) or eng["actions_total"] <= 0:
        fail(f"{path}: engine.actions_total = {eng.get('actions_total')!r}")
    # The BENCH_0007 acceptance criterion, on every report regardless of
    # provenance: the engine's steady state must never allocate.
    if eng.get("steady_state_allocs") != 0:
        fail(
            f"{path}: engine.steady_state_allocs = "
            f"{eng.get('steady_state_allocs')!r} (the zero-alloc bar is 0)"
        )
    check_replay(doc, path)


def check_serving_schema(doc: dict, path: str) -> None:
    srv = doc.get("serving")
    if not isinstance(srv, dict):
        fail(f"{path}: missing serving object")
    for k in ("lru_ops_per_sec", "hist_records_per_sec", "requests_per_sec"):
        v = srv.get(k)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"{path}: serving.{k} = {v!r} (want a positive number)")
    for k in ("hist_bins", "requests", "peak_tracked_bytes"):
        if not isinstance(srv.get(k), int) or srv[k] <= 0:
            fail(f"{path}: serving.{k} = {srv.get(k)!r} (want a positive int)")
    # The BENCH_0008 acceptance criteria, on every report regardless of
    # provenance: the streamed replay renders byte-identically to the
    # materialized oracle and never spills on the sorted bench trace.
    if srv.get("streaming_identical") is not True:
        fail(f"{path}: serving.streaming_identical is {srv.get('streaming_identical')!r}")
    if srv.get("spilled") is not False:
        fail(f"{path}: serving.spilled is {srv.get('spilled')!r} (must be false)")


def check_fabric_schema(doc: dict, path: str) -> None:
    fab = doc.get("fabric")
    if not isinstance(fab, dict):
        fail(f"{path}: missing fabric object")
    v = fab.get("events_per_sec")
    if not isinstance(v, (int, float)) or v <= 0:
        fail(f"{path}: fabric.events_per_sec = {v!r} (want a positive number)")
    for k in ("events_total", "solves", "deferred_solves", "cascade_events"):
        if not isinstance(fab.get(k), int) or fab[k] <= 0:
            fail(f"{path}: fabric.{k} = {fab.get(k)!r} (want a positive int)")
    # The BENCH_0009 acceptance criteria, on every report regardless of
    # provenance: coalescing demonstrably collapses same-timestamp
    # cascades, steady-state flow starts never allocate, and the
    # coalesced run matches eager solving exactly.
    spe = fab.get("solves_per_event")
    if not isinstance(spe, (int, float)) or not 0 < spe < 1.0:
        fail(f"{path}: fabric.solves_per_event = {spe!r} (must be in (0, 1))")
    if fab.get("alloc_growth") != 0:
        fail(
            f"{path}: fabric.alloc_growth = {fab.get('alloc_growth')!r} "
            f"(the zero-alloc bar is 0)"
        )
    if fab.get("coalesced_identical") is not True:
        fail(
            f"{path}: fabric.coalesced_identical is "
            f"{fab.get('coalesced_identical')!r}"
        )


def check_batching_schema(doc: dict, path: str) -> None:
    bat = doc.get("batching")
    if not isinstance(bat, dict):
        fail(f"{path}: missing batching object")
    for k in ("steps_per_sec", "prefill_us_per_token"):
        v = bat.get(k)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"{path}: batching.{k} = {v!r} (want a positive number)")
    for k in ("steps_total", "decode_steps", "peak_kv_bytes"):
        if not isinstance(bat.get(k), int) or bat[k] <= 0:
            fail(f"{path}: batching.{k} = {bat.get(k)!r} (want a positive int)")
    # The BENCH_0010 acceptance criteria, on every report regardless of
    # provenance: decode step time must grow with the batch's aggregate
    # KV bytes (the memory wall), and batch-1 + chunking-off continuous
    # batching must render byte-identically to the per-request seed
    # scheduler under legacy costs.
    if bat.get("decode_kv_monotone") is not True:
        fail(
            f"{path}: batching.decode_kv_monotone is "
            f"{bat.get('decode_kv_monotone')!r}"
        )
    if bat.get("legacy_identical") is not True:
        fail(f"{path}: batching.legacy_identical is {bat.get('legacy_identical')!r}")


def check_schema(doc: dict, path: str, schema: str) -> None:
    if doc.get("schema") != schema:
        fail(f"{path}: schema {doc.get('schema')!r} != {schema!r}")
    if doc.get("provenance") not in ("measured", "desk-estimated"):
        fail(f"{path}: bad provenance {doc.get('provenance')!r}")
    if schema == SCHEMA_HOTPATH:
        check_hotpath_schema(doc, path)
    elif schema == SCHEMA_SERVING:
        check_serving_schema(doc, path)
    elif schema == SCHEMA_FABRIC:
        check_fabric_schema(doc, path)
    elif schema == SCHEMA_BATCHING:
        check_batching_schema(doc, path)
    else:
        check_engine_schema(doc, path)


def throughput_figures(doc: dict, schema: str) -> dict:
    if schema == SCHEMA_HOTPATH:
        return {f"events_per_sec.{k}": doc["events_per_sec"][k] for k in EVENTS_KEYS}
    if schema == SCHEMA_SERVING:
        return {
            f"serving.{k}": doc["serving"][k]
            for k in ("lru_ops_per_sec", "hist_records_per_sec", "requests_per_sec")
        }
    if schema == SCHEMA_FABRIC:
        return {"fabric.events_per_sec": doc["fabric"]["events_per_sec"]}
    if schema == SCHEMA_BATCHING:
        return {"batching.steps_per_sec": doc["batching"]["steps_per_sec"]}
    return {"engine.chunks_per_sec": doc["engine"]["chunks_per_sec"]}


def main() -> None:
    if len(sys.argv) < 2:
        fail("usage: check_bench.py <fresh-report.json> [baseline.json]")
    fresh_path = sys.argv[1]
    fresh = load(fresh_path)
    schema = fresh.get("schema")
    if schema not in DEFAULT_BASELINES:
        fail(f"{fresh_path}: unknown schema {schema!r}")
    base_path = sys.argv[2] if len(sys.argv) > 2 else DEFAULT_BASELINES[schema]

    check_schema(fresh, fresh_path, schema)
    base = load(base_path)
    check_schema(base, base_path, schema)
    print(f"check_bench: schema ok ({fresh_path}, baseline {base_path})")

    if base.get("provenance") != "measured":
        print(
            f"check_bench: baseline provenance is "
            f"{base.get('provenance')!r}; regression gate skipped"
        )
        return
    if os.environ.get("MMA_BENCH_SKIP_REGRESSION"):
        print("check_bench: MMA_BENCH_SKIP_REGRESSION set; regression gate skipped")
        return

    fresh_figs = throughput_figures(fresh, schema)
    base_figs = throughput_figures(base, schema)
    worst = []
    for k, got in fresh_figs.items():
        want = base_figs[k]
        ratio = got / want
        print(f"check_bench: {k}: {got:.0f} vs baseline {want:.0f} ({ratio:.2f}x)")
        if ratio < 1.0 / REGRESSION_FACTOR:
            worst.append((k, ratio))
    if worst:
        detail = ", ".join(f"{k} at {r:.2f}x" for k, r in worst)
        fail(
            f"throughput regression beyond {REGRESSION_FACTOR}x tolerance: {detail} "
            f"(set MMA_BENCH_SKIP_REGRESSION=1 to skip on known-slow machines)"
        )
    print("check_bench: regression gate ok")


if __name__ == "__main__":
    main()
