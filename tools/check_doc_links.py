#!/usr/bin/env python3
"""Fail on dangling relative links in the repo's markdown docs.

Scans the maintained docs (docs/, rust/, configs/, examples/) for
markdown links `[text](target)` and verifies that every relative target
(optionally with a #fragment) exists on disk. External
(http/https/mailto) links and pure #anchors are skipped, as are the
repo-root retrieval artifacts (PAPERS.md etc.), which are generated.
Zero dependencies; run from the repo root:

    python3 tools/check_doc_links.py
"""

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files(root):
    for sub in ("docs", "rust", "configs", "examples"):
        top = os.path.join(root, sub)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames if d not in ("target", ".git")]
            for name in sorted(filenames):
                if name.endswith(".md"):
                    yield os.path.join(dirpath, name)


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    bad = []
    checked = 0
    for path in md_files(root):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                bad.append((os.path.relpath(path, root), target))
    if bad:
        print("dangling relative links:")
        for src, target in bad:
            print(f"  {src}: {target}")
        sys.exit(1)
    print(f"doc links ok ({checked} relative links checked)")


if __name__ == "__main__":
    main()
