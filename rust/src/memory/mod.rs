//! Memory substrates: a pinned host-buffer pool (what `cudaHostAlloc`
//! hands out, NUMA-placed) and a per-GPU HBM allocator (block-granular,
//! what the serving layer carves KV pages and weight buffers from).
//!
//! The simulation never stores payload bytes — allocations track *placement
//! and capacity*, which is what routing and admission decisions depend on.

use crate::topology::{GpuId, NumaId};

/// Handle to a pinned host allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct HostAlloc(pub u32);

/// Handle to a device (HBM) allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DevAlloc(pub u32);

#[derive(Debug, Clone)]
struct Region {
    bytes: u64,
    live: bool,
}

/// Pinned host memory pool with per-NUMA capacity accounting.
#[derive(Debug)]
pub struct HostPool {
    capacity: Vec<u64>,
    used: Vec<u64>,
    regions: Vec<(NumaId, Region)>,
    free_slots: Vec<u32>,
}

impl HostPool {
    /// Pool with `capacity_per_numa` bytes on each of `numa_count` nodes.
    pub fn new(numa_count: u8, capacity_per_numa: u64) -> HostPool {
        HostPool {
            capacity: vec![capacity_per_numa; numa_count as usize],
            used: vec![0; numa_count as usize],
            regions: Vec::new(),
            free_slots: Vec::new(),
        }
    }

    /// Allocate pinned bytes on a NUMA node. Fails if it would exceed
    /// capacity (host DRAM is finite — the serving layer's offload tier
    /// sizing depends on this signal).
    pub fn alloc(&mut self, numa: NumaId, bytes: u64) -> Option<HostAlloc> {
        let n = numa.0 as usize;
        if self.used[n] + bytes > self.capacity[n] {
            return None;
        }
        self.used[n] += bytes;
        let region = (numa, Region { bytes, live: true });
        let id = match self.free_slots.pop() {
            Some(i) => {
                self.regions[i as usize] = region;
                i
            }
            None => {
                self.regions.push(region);
                (self.regions.len() - 1) as u32
            }
        };
        Some(HostAlloc(id))
    }

    /// Free an allocation (idempotent-hostile: double free panics).
    pub fn free(&mut self, a: HostAlloc) {
        let (numa, region) = &mut self.regions[a.0 as usize];
        assert!(region.live, "double free of {a:?}");
        region.live = false;
        self.used[numa.0 as usize] -= region.bytes;
        self.free_slots.push(a.0);
    }

    /// NUMA node of an allocation.
    pub fn numa_of(&self, a: HostAlloc) -> NumaId {
        self.regions[a.0 as usize].0
    }

    /// Bytes of an allocation.
    pub fn bytes_of(&self, a: HostAlloc) -> u64 {
        self.regions[a.0 as usize].1.bytes
    }

    /// Used bytes on a node.
    pub fn used(&self, numa: NumaId) -> u64 {
        self.used[numa.0 as usize]
    }

    /// Free bytes on a node.
    pub fn available(&self, numa: NumaId) -> u64 {
        self.capacity[numa.0 as usize] - self.used[numa.0 as usize]
    }
}

/// Per-GPU HBM allocator with bump+freelist semantics at byte granularity.
#[derive(Debug)]
pub struct HbmAllocator {
    capacity: Vec<u64>,
    used: Vec<u64>,
    regions: Vec<(GpuId, Region)>,
    free_slots: Vec<u32>,
}

impl HbmAllocator {
    /// `capacity` bytes on each of `gpu_count` GPUs (H20: 96 GB).
    pub fn new(gpu_count: usize, capacity: u64) -> HbmAllocator {
        HbmAllocator {
            capacity: vec![capacity; gpu_count],
            used: vec![0; gpu_count],
            regions: Vec::new(),
            free_slots: Vec::new(),
        }
    }

    /// Allocate on a GPU; `None` when HBM is exhausted (triggers KV
    /// eviction / refuses model wake-up upstream).
    pub fn alloc(&mut self, gpu: GpuId, bytes: u64) -> Option<DevAlloc> {
        let g = gpu.0 as usize;
        if self.used[g] + bytes > self.capacity[g] {
            return None;
        }
        self.used[g] += bytes;
        let region = (gpu, Region { bytes, live: true });
        let id = match self.free_slots.pop() {
            Some(i) => {
                self.regions[i as usize] = region;
                i
            }
            None => {
                self.regions.push(region);
                (self.regions.len() - 1) as u32
            }
        };
        Some(DevAlloc(id))
    }

    /// Free an allocation.
    pub fn free(&mut self, a: DevAlloc) {
        let (gpu, region) = &mut self.regions[a.0 as usize];
        assert!(region.live, "double free of {a:?}");
        region.live = false;
        self.used[gpu.0 as usize] -= region.bytes;
        self.free_slots.push(a.0);
    }

    /// Used bytes on a GPU.
    pub fn used(&self, gpu: GpuId) -> u64 {
        self.used[gpu.0 as usize]
    }

    /// Free bytes on a GPU.
    pub fn available(&self, gpu: GpuId) -> u64 {
        self.capacity[gpu.0 as usize] - self.used[gpu.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn host_pool_capacity_enforced() {
        let mut p = HostPool::new(2, 100);
        let a = p.alloc(NumaId(0), 60).unwrap();
        assert!(p.alloc(NumaId(0), 50).is_none(), "over capacity");
        assert!(p.alloc(NumaId(1), 50).is_some(), "other node unaffected");
        assert_eq!(p.used(NumaId(0)), 60);
        p.free(a);
        assert_eq!(p.used(NumaId(0)), 0);
        assert!(p.alloc(NumaId(0), 100).is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn host_double_free_panics() {
        let mut p = HostPool::new(1, 100);
        let a = p.alloc(NumaId(0), 10).unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    fn hbm_alloc_free_cycles() {
        let mut h = HbmAllocator::new(2, 1000);
        let a = h.alloc(GpuId(0), 400).unwrap();
        let b = h.alloc(GpuId(0), 600).unwrap();
        assert!(h.alloc(GpuId(0), 1).is_none());
        assert_eq!(h.available(GpuId(1)), 1000);
        h.free(a);
        assert_eq!(h.available(GpuId(0)), 400);
        h.free(b);
        assert_eq!(h.used(GpuId(0)), 0);
    }

    #[test]
    fn accounting_invariant_under_random_ops() {
        testkit::check("memory-accounting", |rng| {
            let mut h = HbmAllocator::new(4, 1 << 20);
            let mut live: Vec<(DevAlloc, GpuId, u64)> = Vec::new();
            let mut expect = [0u64; 4];
            for _ in 0..200 {
                if live.is_empty() || rng.bool(0.6) {
                    let g = GpuId(rng.range_u64(0, 4) as u8);
                    let b = rng.range_u64(1, 1 << 16);
                    if let Some(a) = h.alloc(g, b) {
                        live.push((a, g, b));
                        expect[g.0 as usize] += b;
                    }
                } else {
                    let i = rng.range_usize(0, live.len());
                    let (a, g, b) = live.swap_remove(i);
                    h.free(a);
                    expect[g.0 as usize] -= b;
                }
                for g in 0..4u8 {
                    assert_eq!(h.used(GpuId(g)), expect[g as usize]);
                    assert!(h.used(GpuId(g)) <= 1 << 20);
                }
            }
        });
    }

    #[test]
    fn placement_queries() {
        let mut p = HostPool::new(2, 1000);
        let a = p.alloc(NumaId(1), 123).unwrap();
        assert_eq!(p.numa_of(a), NumaId(1));
        assert_eq!(p.bytes_of(a), 123);
        assert_eq!(p.available(NumaId(1)), 877);
    }
}
