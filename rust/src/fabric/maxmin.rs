//! Max-min fair rate allocation (progressive filling / water-filling),
//! generalized to *weighted* max-min with optional per-flow rate caps.
//!
//! Given link capacities and one path (set of link indices) per flow,
//! compute the unique weighted max-min fair rate vector: repeatedly find
//! the most constrained link (minimum fair share per unit weight,
//! `cap/weight_sum`), freeze its flows at `weight * share`, subtract, and
//! continue. A flow whose rate cap binds before the link share is frozen
//! at its cap instead (QoS bulk throttling). With all weights equal and no
//! caps this degenerates to classic unweighted max-min — bit-identical to
//! the historical allocator, which is what keeps every pre-QoS figure and
//! bench reproducible.

use crate::topology::LinkId;

/// Compute unweighted max-min fair rates: the degenerate case of
/// [`max_min_rates_weighted`] with every weight 1 and no caps.
pub fn max_min_rates(capacity: &[f64], paths: &[&[LinkId]]) -> Vec<f64> {
    let ones = vec![1.0; paths.len()];
    let caps = vec![f64::INFINITY; paths.len()];
    max_min_rates_weighted(capacity, paths, &ones, &caps)
}

/// Compute weighted max-min fair rates. `capacity[l]` is bytes/sec of link
/// `l`; `paths[f]` lists the links flow `f` traverses (duplicates allowed
/// but wasteful); `weights[f]` is flow `f`'s share weight (> 0) and
/// `caps[f]` an absolute rate ceiling (`f64::INFINITY` = uncapped).
/// Returns one rate per flow. O(L·F) per bottleneck round,
/// O(L·F·min(L,F)) worst case — tiny for the fleet sizes simulated here.
pub fn max_min_rates_weighted(
    capacity: &[f64],
    paths: &[&[LinkId]],
    weights: &[f64],
    caps: &[f64],
) -> Vec<f64> {
    let nf = paths.len();
    assert_eq!(weights.len(), nf, "one weight per flow");
    assert_eq!(caps.len(), nf, "one rate cap per flow");
    if nf == 0 {
        return Vec::new();
    }
    debug_assert!(weights.iter().all(|w| *w > 0.0 && w.is_finite()));
    debug_assert!(caps.iter().all(|c| *c > 0.0));
    let nl = capacity.len();
    let mut cap: Vec<f64> = capacity.to_vec();
    // Exact integer count of unassigned flows per link alongside the
    // float weight sum: the count decides whether a link is still a
    // bottleneck candidate, so float residue in `wsum` (non-dyadic
    // weights) can never keep a fully-drained link in play and stall the
    // filling loop.
    let mut active: Vec<u32> = vec![0; nl];
    let mut wsum: Vec<f64> = vec![0.0; nl];
    // Only consider links actually used: iterate a dense used-link list
    // instead of every link in the topology (~4x fewer candidates per
    // bottleneck round at fleet scale — see EXPERIMENTS.md §Perf).
    let mut used: Vec<u32> = Vec::with_capacity(nf * 4);
    for (f, p) in paths.iter().enumerate() {
        for &l in *p {
            if active[l.0 as usize] == 0 {
                used.push(l.0 as u32);
            }
            active[l.0 as usize] += 1;
            wsum[l.0 as usize] += weights[f];
        }
    }
    let mut rate = vec![f64::INFINITY; nf];
    let mut unassigned = nf;

    while unassigned > 0 {
        // Bottleneck link: min cap per unit weight over links still
        // carrying unassigned flows.
        let mut best_link = usize::MAX;
        let mut best_share = f64::INFINITY;
        for &lu in &used {
            let l = lu as usize;
            if active[l] > 0 {
                let share = cap[l].max(0.0) / wsum[l].max(1e-300);
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
        }
        // Rate caps that bind before the link share: freeze those flows at
        // their cap and redistribute the freed bandwidth next round.
        let mut any_capped = false;
        for (f, p) in paths.iter().enumerate() {
            if rate[f].is_finite() || caps[f] >= best_share * weights[f] {
                continue;
            }
            rate[f] = caps[f];
            unassigned -= 1;
            any_capped = true;
            for &l in *p {
                let li = l.0 as usize;
                cap[li] -= caps[f];
                active[li] -= 1;
                wsum[li] -= weights[f];
            }
        }
        if any_capped {
            continue;
        }
        if best_link == usize::MAX {
            // No constrained links left (shouldn't happen with finite caps).
            for r in rate.iter_mut() {
                if r.is_infinite() {
                    *r = 0.0;
                }
            }
            break;
        }
        // Freeze every unassigned flow crossing the bottleneck.
        for (f, p) in paths.iter().enumerate() {
            if rate[f].is_finite() {
                continue;
            }
            if p.iter().any(|&l| l.0 as usize == best_link) {
                let r = best_share * weights[f];
                rate[f] = r;
                unassigned -= 1;
                for &l in *p {
                    let li = l.0 as usize;
                    cap[li] -= r;
                    active[li] -= 1;
                    wsum[li] -= weights[f];
                }
            }
        }
        // Numerical hygiene: the bottleneck is now fully allocated.
        cap[best_link] = cap[best_link].max(0.0);
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn l(i: u16) -> LinkId {
        LinkId(i)
    }

    #[test]
    fn single_flow_gets_capacity() {
        let caps = [100.0, 50.0];
        let p0: &[LinkId] = &[l(0), l(1)];
        let r = max_min_rates(&caps, &[p0]);
        assert_eq!(r, vec![50.0]);
    }

    #[test]
    fn equal_split_on_shared_bottleneck() {
        let caps = [90.0];
        let p: &[LinkId] = &[l(0)];
        let r = max_min_rates(&caps, &[p, p, p]);
        assert_eq!(r, vec![30.0, 30.0, 30.0]);
    }

    #[test]
    fn classic_three_flow_example() {
        // Link0 cap 10 shared by f0,f1; link1 cap 4 used by f1 only.
        // Max-min: f1 limited to 4 by link1; f0 then gets 6.
        let caps = [10.0, 4.0];
        let p0: &[LinkId] = &[l(0)];
        let p1: &[LinkId] = &[l(0), l(1)];
        let r = max_min_rates(&caps, &[p0, p1]);
        assert!((r[0] - 6.0).abs() < 1e-9);
        assert!((r[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn parking_lot_topology() {
        // Chain of 3 links cap 1 each; one long flow over all, one short
        // flow per link. Fair: long flow 0.5, shorts 0.5 each.
        let caps = [1.0, 1.0, 1.0];
        let long: &[LinkId] = &[l(0), l(1), l(2)];
        let s0: &[LinkId] = &[l(0)];
        let s1: &[LinkId] = &[l(1)];
        let s2: &[LinkId] = &[l(2)];
        let r = max_min_rates(&caps, &[long, s0, s1, s2]);
        for x in &r {
            assert!((x - 0.5).abs() < 1e-9, "{r:?}");
        }
    }

    #[test]
    fn empty_inputs() {
        assert!(max_min_rates(&[1.0], &[]).is_empty());
    }

    /// Load of link `li` under `rates`.
    fn link_load(paths: &[Vec<LinkId>], rates: &[f64], li: usize) -> f64 {
        paths
            .iter()
            .zip(rates)
            .filter(|(p, _)| p.iter().any(|&x| x.0 as usize == li))
            .map(|(_, r)| r)
            .sum()
    }

    #[test]
    fn conservation_on_shared_bottleneck() {
        // Conservation: everything the bottleneck can carry is handed out —
        // no bandwidth lost to the allocator, none invented.
        let caps = [120.0, 1000.0, 1000.0];
        let p0: Vec<LinkId> = vec![l(0), l(1)];
        let p1: Vec<LinkId> = vec![l(0), l(2)];
        let p2: Vec<LinkId> = vec![l(0)];
        let paths = [p0, p1, p2];
        let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
        let rates = max_min_rates(&caps, &refs);
        let total: f64 = rates.iter().sum();
        assert!((total - 120.0).abs() < 1e-9, "allocated {total} of 120");
        assert!((link_load(&paths, &rates, 0) - 120.0).abs() < 1e-9);
    }

    #[test]
    fn property_conservation_every_flow_bounded_by_its_links() {
        // No flow exceeds any link it crosses, and per-link loads never
        // exceed capacity: bytes are conserved end to end.
        testkit::check("maxmin-conservation", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            for (p, r) in paths.iter().zip(&rates) {
                for &x in p {
                    assert!(
                        *r <= caps[x.0 as usize] * (1.0 + 1e-9) + 1e-9,
                        "flow rate {r} exceeds link {x:?} cap {}",
                        caps[x.0 as usize]
                    );
                }
            }
            for li in 0..nl {
                let load = link_load(&paths, &rates, li);
                assert!(load <= caps[li] * (1.0 + 1e-9) + 1e-9);
            }
        });
    }

    #[test]
    fn property_bottleneck_saturation() {
        // The globally most-constrained link is always driven to exactly
        // its capacity — the allocator never leaves the bottleneck idle.
        testkit::check("maxmin-bottleneck-saturation", |rng| {
            let nl = rng.range_usize(1, 8);
            let nf = rng.range_usize(1, 16);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            // The first-round bottleneck: min cap/active over used links.
            let mut active = vec![0u32; nl];
            for p in &paths {
                for &x in p {
                    active[x.0 as usize] += 1;
                }
            }
            let bottleneck = (0..nl)
                .filter(|&li| active[li] > 0)
                .min_by(|&a, &b| {
                    let sa = caps[a] / active[a] as f64;
                    let sb = caps[b] / active[b] as f64;
                    sa.partial_cmp(&sb).unwrap()
                });
            if let Some(li) = bottleneck {
                let load = link_load(&paths, &rates, li);
                assert!(
                    (load - caps[li]).abs() <= caps[li] * 1e-9 + 1e-9,
                    "bottleneck link {li} not saturated: {load} vs {}",
                    caps[li]
                );
            }
        });
    }

    #[test]
    fn property_maxmin_dominance() {
        // The max-min witness: every flow crosses a saturated link on which
        // its rate is at least every other crossing flow's rate. (If not,
        // the flow could be raised by lowering a *larger* flow — the
        // allocation would not be max-min fair.)
        testkit::check("maxmin-dominance", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 300.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(5));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            for (f, p) in paths.iter().enumerate() {
                let witness = p.iter().any(|&x| {
                    let li = x.0 as usize;
                    let load = link_load(&paths, &rates, li);
                    let saturated = load >= caps[li] * (1.0 - 1e-9) - 1e-9;
                    let dominant = paths.iter().zip(&rates).all(|(q, r)| {
                        !q.iter().any(|&y| y.0 as usize == li)
                            || rates[f] >= *r - 1e-9 - *r * 1e-9
                    });
                    saturated && dominant
                });
                assert!(
                    witness,
                    "flow {f} (rate {}) has no saturated link it dominates",
                    rates[f]
                );
            }
        });
    }

    #[test]
    fn weighted_split_is_weight_proportional_on_shared_bottleneck() {
        // One link of 90 shared by weights 8:1 → 80 and 10.
        let caps = [90.0];
        let p: &[LinkId] = &[l(0)];
        let w = [8.0, 1.0];
        let rc = [f64::INFINITY; 2];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert!((r[0] - 80.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 10.0).abs() < 1e-9, "{r:?}");
        // Three-way 2:1:1 on the same link.
        let w = [2.0, 1.0, 1.0];
        let rc = [f64::INFINITY; 3];
        let r = max_min_rates_weighted(&caps, &[p, p, p], &w, &rc);
        assert!((r[0] - 45.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 22.5).abs() < 1e-9, "{r:?}");
        assert!((r[2] - 22.5).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn weighted_flow_still_bounded_by_private_bottleneck() {
        // High weight cannot push a flow past its own narrow link: f1 (w=8)
        // is clamped to link1's 4; f0 (w=1) then takes the rest of link0.
        let caps = [10.0, 4.0];
        let p0: &[LinkId] = &[l(0)];
        let p1: &[LinkId] = &[l(0), l(1)];
        let w = [1.0, 8.0];
        let rc = [f64::INFINITY; 2];
        let r = max_min_rates_weighted(&caps, &[p0, p1], &w, &rc);
        assert!((r[1] - 4.0).abs() < 1e-9, "{r:?}");
        assert!((r[0] - 6.0).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn rate_cap_binds_before_fair_share() {
        // Equal weights on a 100-link, but f0 is capped at 10: it freezes
        // at the cap and f1 absorbs the remainder.
        let caps = [100.0];
        let p: &[LinkId] = &[l(0)];
        let w = [1.0, 1.0];
        let rc = [10.0, f64::INFINITY];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert!((r[0] - 10.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 90.0).abs() < 1e-9, "{r:?}");
        // A cap above the fair share changes nothing.
        let rc = [60.0, f64::INFINITY];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert_eq!(r, vec![50.0, 50.0]);
    }

    #[test]
    fn property_equal_weights_match_unweighted_exactly() {
        // The acceptance gate of the QoS refactor: with all weights equal
        // and no caps, the weighted allocator IS the old unweighted one —
        // bit-identical rates on random instances.
        testkit::check("maxmin-equal-weights-degenerate", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let unweighted = max_min_rates(&caps, &refs);
            let w = vec![3.0; nf]; // equal but ≠ 1: only ratios matter
            let rc = vec![f64::INFINITY; nf];
            let weighted = max_min_rates_weighted(&caps, &refs, &w, &rc);
            for (a, b) in unweighted.iter().zip(&weighted) {
                assert!(
                    (a - b).abs() <= a.abs() * 1e-9 + 1e-9,
                    "equal-weight allocation diverged: {unweighted:?} vs {weighted:?}"
                );
            }
        });
    }

    #[test]
    fn property_weighted_conservation_and_feasibility() {
        // Weighted allocations conserve bytes: no link oversubscribed, no
        // flow past its cap, and every flow hits a saturated link or its
        // own rate cap (the weighted max-min optimality witness).
        testkit::check("maxmin-weighted-conservation", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let w: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.5, 8.0)).collect();
            let rc: Vec<f64> = (0..nf)
                .map(|_| {
                    if rng.bool(0.3) {
                        rng.range_f64(1.0, 100.0)
                    } else {
                        f64::INFINITY
                    }
                })
                .collect();
            let rates = max_min_rates_weighted(&caps, &refs, &w, &rc);
            for li in 0..nl {
                let load = link_load(&paths, &rates, li);
                assert!(
                    load <= caps[li] * (1.0 + 1e-9) + 1e-9,
                    "link {li} overloaded: {load} > {}",
                    caps[li]
                );
            }
            for (f, r) in rates.iter().enumerate() {
                assert!(*r > 0.0, "starved flow with positive caps");
                assert!(*r <= rc[f] * (1.0 + 1e-9), "flow {f} beyond cap");
                let capped = rc[f].is_finite() && *r >= rc[f] * (1.0 - 1e-9);
                let has_tight = paths[f].iter().any(|&x| {
                    let li = x.0 as usize;
                    link_load(&paths, &rates, li) >= caps[li] * (1.0 - 1e-9) - 1e-9
                });
                assert!(capped || has_tight, "flow {f} ({r}) neither capped nor tight");
            }
        });
    }

    #[test]
    fn property_feasible_and_saturating() {
        testkit::check("maxmin-feasible", |rng| {
            let nl = rng.range_usize(1, 12);
            let nf = rng.range_usize(1, 24);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let paths_owned: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(5));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let paths: Vec<&[LinkId]> = paths_owned.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &paths);

            // (1) Feasibility: no link oversubscribed.
            for li in 0..nl {
                let load: f64 = paths_owned
                    .iter()
                    .zip(&rates)
                    .filter(|(p, _)| p.iter().any(|&x| x.0 as usize == li))
                    .map(|(_, r)| r)
                    .sum();
                assert!(
                    load <= caps[li] * (1.0 + 1e-9) + 1e-9,
                    "link {li} overloaded: {load} > {}",
                    caps[li]
                );
            }
            // (2) Every flow has a saturated link (max-min optimality
            //     witness): cannot raise any flow without exceeding a cap.
            for (p, r) in paths_owned.iter().zip(&rates) {
                assert!(*r > 0.0, "starved flow with positive caps");
                let has_tight = p.iter().any(|&x| {
                    let li = x.0 as usize;
                    let load: f64 = paths_owned
                        .iter()
                        .zip(&rates)
                        .filter(|(q, _)| q.iter().any(|&y| y.0 as usize == li))
                        .map(|(_, rr)| rr)
                        .sum();
                    load >= caps[li] * (1.0 - 1e-9) - 1e-9
                });
                assert!(has_tight, "flow rate {r} has no saturated link");
            }
        });
    }
}
