//! Max-min fair rate allocation (progressive filling / water-filling),
//! generalized to *weighted* max-min with optional per-flow rate caps.
//!
//! Given link capacities and one path (set of link indices) per flow,
//! compute the unique weighted max-min fair rate vector: repeatedly find
//! the most constrained link (minimum fair share per unit weight,
//! `cap/weight_sum`), freeze its flows at `weight * share`, subtract, and
//! continue. A flow whose rate cap binds before the link share is frozen
//! at its cap instead (QoS bulk throttling). With all weights equal and no
//! caps this degenerates to classic unweighted max-min.
//!
//! ## Structure
//!
//! The allocation problem decomposes exactly over *connected components*
//! of the links↔flows bipartite graph: flows that share no link (directly
//! or transitively) cannot influence each other's rates. Both entry
//! points exploit this:
//!
//! * [`max_min_rates_weighted`] — the pure-function reference oracle.
//!   Decomposes its input into components (ordered by lowest flow index,
//!   flows in index order within each) and water-fills each one.
//! * [`ComponentSolver`] — the scratch-buffer solver the fabric uses on
//!   its hot path. It discovers components via stamped BFS over the
//!   fabric's live link→flow adjacency and runs the *same*
//!   [`water_fill`] kernel, so an incremental re-solve of one touched
//!   component is bit-identical to the slice of a full oracle re-solve —
//!   the floating-point operation sequence per component is the same in
//!   both paths by construction.

use crate::topology::LinkId;

/// Compute unweighted max-min fair rates: the degenerate case of
/// [`max_min_rates_weighted`] with every weight 1 and no caps.
pub fn max_min_rates(capacity: &[f64], paths: &[&[LinkId]]) -> Vec<f64> {
    let ones = vec![1.0; paths.len()];
    let caps = vec![f64::INFINITY; paths.len()];
    max_min_rates_weighted(capacity, paths, &ones, &caps)
}

/// Compute weighted max-min fair rates. `capacity[l]` is bytes/sec of link
/// `l`; `paths[f]` lists the links flow `f` traverses (duplicates allowed
/// but wasteful); `weights[f]` is flow `f`'s share weight (> 0) and
/// `caps[f]` an absolute rate ceiling (`f64::INFINITY` = uncapped).
/// Returns one rate per flow. Solves each connected component of the
/// links↔flows graph independently: O(L·F·min(L,F)) worst case within a
/// component, but typical fleet workloads split into many small
/// components. This is the reference oracle for the fabric's incremental
/// [`ComponentSolver`]: per-component results are bit-identical.
pub fn max_min_rates_weighted(
    capacity: &[f64],
    paths: &[&[LinkId]],
    weights: &[f64],
    caps: &[f64],
) -> Vec<f64> {
    let nf = paths.len();
    assert_eq!(weights.len(), nf, "one weight per flow");
    assert_eq!(caps.len(), nf, "one rate cap per flow");
    if nf == 0 {
        return Vec::new();
    }
    debug_assert!(weights.iter().all(|w| *w > 0.0 && w.is_finite()));
    debug_assert!(caps.iter().all(|c| *c > 0.0));
    let nl = capacity.len();
    let mut rate = vec![0.0; nf];
    let mut lk = LinkScratch::default();
    lk.ensure(nl);

    // Link→flow adjacency for component discovery.
    let mut link_users: Vec<Vec<u32>> = vec![Vec::new(); nl];
    for (f, p) in paths.iter().enumerate() {
        for &l in *p {
            link_users[l.0 as usize].push(f as u32);
        }
    }
    let mut flow_seen = vec![false; nf];
    let mut link_seen = vec![false; nl];
    let mut stack: Vec<u32> = Vec::new();
    let mut comp: Vec<u32> = Vec::new();
    let mut comp_w: Vec<f64> = Vec::new();
    let mut comp_c: Vec<f64> = Vec::new();
    let mut comp_r: Vec<f64> = Vec::new();
    for f0 in 0..nf {
        if flow_seen[f0] {
            continue;
        }
        comp.clear();
        flow_seen[f0] = true;
        stack.push(f0 as u32);
        while let Some(f) = stack.pop() {
            comp.push(f);
            for &l in paths[f as usize] {
                let li = l.0 as usize;
                if !link_seen[li] {
                    link_seen[li] = true;
                    for &g in &link_users[li] {
                        if !flow_seen[g as usize] {
                            flow_seen[g as usize] = true;
                            stack.push(g);
                        }
                    }
                }
            }
        }
        // Canonical flow order within the component: ascending index.
        comp.sort_unstable();
        comp_w.clear();
        comp_w.extend(comp.iter().map(|&f| weights[f as usize]));
        comp_c.clear();
        comp_c.extend(comp.iter().map(|&f| caps[f as usize]));
        comp_r.clear();
        comp_r.resize(comp.len(), f64::INFINITY);
        water_fill(
            capacity,
            comp.len(),
            |i| paths[comp[i] as usize],
            &comp_w,
            &comp_c,
            &mut comp_r,
            &mut lk,
        );
        for (k, &f) in comp.iter().enumerate() {
            rate[f as usize] = comp_r[k];
        }
    }
    rate
}

/// Per-link working state for one [`water_fill`] pass, reusable across
/// components and across solves: only links actually touched by the
/// current component are initialized (and reset on exit), so a solve
/// costs O(component), not O(topology).
#[derive(Default)]
struct LinkScratch {
    /// Residual capacity per link (valid only for `used` entries).
    cap: Vec<f64>,
    /// Unassigned-flow count per link. Invariant: all zeros between calls.
    active: Vec<u32>,
    /// Unassigned weight sum per link. Invariant: all zeros between calls.
    wsum: Vec<f64>,
    /// Dense list of links the current component touches.
    used: Vec<u32>,
}

impl LinkScratch {
    fn ensure(&mut self, n_links: usize) {
        if self.cap.len() < n_links {
            self.cap.resize(n_links, 0.0);
            self.active.resize(n_links, 0);
            self.wsum.resize(n_links, 0.0);
        }
    }
}

/// Progressive filling over one connected component of `n` flows.
///
/// Flows are addressed positionally (`0..n`); `path_of(i)` yields flow
/// `i`'s links, `weights`/`caps` are parallel positional slices, and the
/// result lands in `rate[..n]` (pre-filled with `INFINITY` by the
/// caller). This is the single shared kernel behind both the reference
/// oracle and the fabric's incremental solver — keeping one
/// floating-point operation sequence is what makes the two bit-identical.
fn water_fill<'a, P>(
    capacity: &[f64],
    n: usize,
    path_of: P,
    weights: &[f64],
    caps: &[f64],
    rate: &mut [f64],
    lk: &mut LinkScratch,
) where
    P: Fn(usize) -> &'a [LinkId],
{
    // Exact integer count of unassigned flows per link alongside the
    // float weight sum: the count decides whether a link is still a
    // bottleneck candidate, so float residue in `wsum` (non-dyadic
    // weights) can never keep a fully-drained link in play and stall the
    // filling loop.
    lk.used.clear();
    for f in 0..n {
        for &l in path_of(f) {
            let li = l.0 as usize;
            if lk.active[li] == 0 {
                lk.used.push(li as u32);
                lk.cap[li] = capacity[li];
                lk.wsum[li] = 0.0;
            }
            lk.active[li] += 1;
            lk.wsum[li] += weights[f];
        }
    }
    let mut unassigned = n;

    while unassigned > 0 {
        // Bottleneck link: min cap per unit weight over links still
        // carrying unassigned flows.
        let mut best_link = usize::MAX;
        let mut best_share = f64::INFINITY;
        for &lu in &lk.used {
            let l = lu as usize;
            if lk.active[l] > 0 {
                let share = lk.cap[l].max(0.0) / lk.wsum[l].max(1e-300);
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
        }
        // Rate caps that bind before the link share: freeze those flows at
        // their cap and redistribute the freed bandwidth next round.
        let mut any_capped = false;
        for f in 0..n {
            if rate[f].is_finite() || caps[f] >= best_share * weights[f] {
                continue;
            }
            rate[f] = caps[f];
            unassigned -= 1;
            any_capped = true;
            for &l in path_of(f) {
                let li = l.0 as usize;
                lk.cap[li] -= caps[f];
                lk.active[li] -= 1;
                lk.wsum[li] -= weights[f];
            }
        }
        if any_capped {
            continue;
        }
        if best_link == usize::MAX {
            // No constrained links left (empty-path flows only).
            for r in rate[..n].iter_mut() {
                if r.is_infinite() {
                    *r = 0.0;
                }
            }
            break;
        }
        // Freeze every unassigned flow crossing the bottleneck.
        for f in 0..n {
            if rate[f].is_finite() {
                continue;
            }
            let p = path_of(f);
            if p.iter().any(|&l| l.0 as usize == best_link) {
                let r = best_share * weights[f];
                rate[f] = r;
                unassigned -= 1;
                for &l in p {
                    let li = l.0 as usize;
                    lk.cap[li] -= r;
                    lk.active[li] -= 1;
                    lk.wsum[li] -= weights[f];
                }
            }
        }
        // Numerical hygiene: the bottleneck is now fully allocated.
        lk.cap[best_link] = lk.cap[best_link].max(0.0);
    }
    // Restore the between-calls invariant (active is structurally zero
    // here; wsum carries float residue from the subtractions).
    for &lu in &lk.used {
        let l = lu as usize;
        lk.active[l] = 0;
        lk.wsum[l] = 0.0;
    }
}

/// Scratch-buffer connected-component solver for the fabric hot path.
///
/// A solve *round* (one [`begin`](Self::begin)) corresponds to one rate
/// recomputation event. Within a round the caller collects one or more
/// components — seeded from flows that joined or links that lost a flow
/// — and solves each; generation stamps deduplicate overlapping seeds so
/// every component is solved at most once per round. All buffers are
/// reused across rounds: steady-state solves allocate nothing.
#[derive(Default)]
pub struct ComponentSolver {
    lk: LinkScratch,
    /// Visited stamp per flow slot (== `stamp` ⇒ claimed this round).
    flow_stamp: Vec<u32>,
    /// Visited stamp per link (== `stamp` ⇒ expanded this round).
    link_stamp: Vec<u32>,
    stamp: u32,
    stack: Vec<u32>,
    comp: Vec<u32>,
    comp_w: Vec<f64>,
    comp_c: Vec<f64>,
    comp_r: Vec<f64>,
}

impl ComponentSolver {
    /// Open a fresh solve round over `n_links` links and `n_slots` flow
    /// slots: sizes the scratch arrays and invalidates previous stamps.
    pub fn begin(&mut self, n_links: usize, n_slots: usize) {
        if self.link_stamp.len() < n_links {
            self.link_stamp.resize(n_links, 0);
        }
        if self.flow_stamp.len() < n_slots {
            self.flow_stamp.resize(n_slots, 0);
        }
        self.lk.ensure(n_links);
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            // u32 generation wrapped: scrub stale stamps that would
            // otherwise collide with the restarted counter.
            self.link_stamp.iter_mut().for_each(|s| *s = 0);
            self.flow_stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp = 1;
        }
    }

    /// Whether `flow` was already claimed by a component this round.
    pub fn claimed(&self, flow: u32) -> bool {
        self.flow_stamp[flow as usize] == self.stamp
    }

    /// Collect the connected component containing `seed` (a flow slot)
    /// by BFS over the live link→flow adjacency, leaving the component's
    /// flow slots sorted ascending in the internal buffer. The caller
    /// must check [`claimed`](Self::claimed) first.
    pub fn collect<'a>(
        &mut self,
        seed: u32,
        link_flows: &[Vec<u32>],
        path_of: impl Fn(u32) -> &'a [LinkId],
    ) {
        debug_assert!(!self.claimed(seed));
        self.comp.clear();
        self.stack.clear();
        self.flow_stamp[seed as usize] = self.stamp;
        self.stack.push(seed);
        while let Some(f) = self.stack.pop() {
            self.comp.push(f);
            for &l in path_of(f) {
                let li = l.0 as usize;
                if self.link_stamp[li] != self.stamp {
                    self.link_stamp[li] = self.stamp;
                    for &g in &link_flows[li] {
                        if self.flow_stamp[g as usize] != self.stamp {
                            self.flow_stamp[g as usize] = self.stamp;
                            self.stack.push(g);
                        }
                    }
                }
            }
        }
        // Canonical order: the kernel must see flows in ascending slot
        // order, exactly as the reference oracle does.
        self.comp.sort_unstable();
    }

    /// Water-fill the collected component. Rates are retrieved via
    /// [`result`](Self::result); they are bit-identical to the
    /// corresponding entries of a full [`max_min_rates_weighted`] solve
    /// over the same live flow set.
    ///
    /// The kernel is *memoryless*: rates depend only on capacities and
    /// the component's membership (canonicalized to ascending slot
    /// order by [`collect`](Self::collect)), never on previously
    /// assigned rates. That property is what lets the fabric coalesce a
    /// whole same-timestamp join/leave cascade into one solve — the
    /// merged batch yields the same bits as solving each sub-batch in
    /// sequence, because the intermediate rates leave no trace.
    pub fn solve_collected<'a>(
        &mut self,
        capacity: &[f64],
        path_of: impl Fn(u32) -> &'a [LinkId],
        weight_of: impl Fn(u32) -> f64,
        cap_of: impl Fn(u32) -> f64,
    ) {
        let ComponentSolver {
            lk,
            comp,
            comp_w,
            comp_c,
            comp_r,
            ..
        } = self;
        comp_w.clear();
        comp_w.extend(comp.iter().map(|&f| weight_of(f)));
        comp_c.clear();
        comp_c.extend(comp.iter().map(|&f| cap_of(f)));
        comp_r.clear();
        comp_r.resize(comp.len(), f64::INFINITY);
        water_fill(
            capacity,
            comp.len(),
            |i| path_of(comp[i]),
            comp_w,
            comp_c,
            comp_r,
            lk,
        );
    }

    /// The last solved component: parallel (flow slots, rates).
    pub fn result(&self) -> (&[u32], &[f64]) {
        (&self.comp, &self.comp_r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn l(i: u16) -> LinkId {
        LinkId(i)
    }

    #[test]
    fn single_flow_gets_capacity() {
        let caps = [100.0, 50.0];
        let p0: &[LinkId] = &[l(0), l(1)];
        let r = max_min_rates(&caps, &[p0]);
        assert_eq!(r, vec![50.0]);
    }

    #[test]
    fn equal_split_on_shared_bottleneck() {
        let caps = [90.0];
        let p: &[LinkId] = &[l(0)];
        let r = max_min_rates(&caps, &[p, p, p]);
        assert_eq!(r, vec![30.0, 30.0, 30.0]);
    }

    #[test]
    fn classic_three_flow_example() {
        // Link0 cap 10 shared by f0,f1; link1 cap 4 used by f1 only.
        // Max-min: f1 limited to 4 by link1; f0 then gets 6.
        let caps = [10.0, 4.0];
        let p0: &[LinkId] = &[l(0)];
        let p1: &[LinkId] = &[l(0), l(1)];
        let r = max_min_rates(&caps, &[p0, p1]);
        assert!((r[0] - 6.0).abs() < 1e-9);
        assert!((r[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn parking_lot_topology() {
        // Chain of 3 links cap 1 each; one long flow over all, one short
        // flow per link. Fair: long flow 0.5, shorts 0.5 each.
        let caps = [1.0, 1.0, 1.0];
        let long: &[LinkId] = &[l(0), l(1), l(2)];
        let s0: &[LinkId] = &[l(0)];
        let s1: &[LinkId] = &[l(1)];
        let s2: &[LinkId] = &[l(2)];
        let r = max_min_rates(&caps, &[long, s0, s1, s2]);
        for x in &r {
            assert!((x - 0.5).abs() < 1e-9, "{r:?}");
        }
    }

    #[test]
    fn empty_inputs() {
        assert!(max_min_rates(&[1.0], &[]).is_empty());
    }

    #[test]
    fn disjoint_components_solve_independently() {
        // Two flows on link 0, one flow on link 1: two components.
        let caps = [60.0, 10.0];
        let p0: &[LinkId] = &[l(0)];
        let p1: &[LinkId] = &[l(1)];
        let r = max_min_rates(&caps, &[p0, p0, p1]);
        assert_eq!(r, vec![30.0, 30.0, 10.0]);
    }

    #[test]
    fn property_component_solver_matches_oracle_bitwise() {
        // The incremental-allocator contract: solving any single
        // component via ComponentSolver reproduces the oracle's rates for
        // that component's flows bit-for-bit.
        testkit::check("maxmin-component-vs-oracle", |rng| {
            let nl = rng.range_usize(2, 12);
            let nf = rng.range_usize(1, 24);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    // Short paths over few links → several components.
                    let len = rng.range_usize(1, 3);
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let w: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.5, 8.0)).collect();
            let rc: Vec<f64> = (0..nf)
                .map(|_| {
                    if rng.bool(0.3) {
                        rng.range_f64(1.0, 100.0)
                    } else {
                        f64::INFINITY
                    }
                })
                .collect();
            let oracle = max_min_rates_weighted(&caps, &refs, &w, &rc);

            // Live adjacency, as the fabric would maintain it.
            let mut link_flows: Vec<Vec<u32>> = vec![Vec::new(); nl];
            for (f, p) in paths.iter().enumerate() {
                for &x in p {
                    link_flows[x.0 as usize].push(f as u32);
                }
            }
            let mut solver = ComponentSolver::default();
            solver.begin(nl, nf);
            for f0 in 0..nf as u32 {
                if solver.claimed(f0) {
                    continue;
                }
                solver.collect(f0, &link_flows, |f| refs[f as usize]);
                solver.solve_collected(
                    &caps,
                    |f| refs[f as usize],
                    |f| w[f as usize],
                    |f| rc[f as usize],
                );
                let (slots, rates) = solver.result();
                for (&s, &r) in slots.iter().zip(rates) {
                    assert_eq!(
                        r.to_bits(),
                        oracle[s as usize].to_bits(),
                        "flow {s}: component rate {r} != oracle {}",
                        oracle[s as usize]
                    );
                }
            }
        });
    }

    /// Load of link `li` under `rates`.
    fn link_load(paths: &[Vec<LinkId>], rates: &[f64], li: usize) -> f64 {
        paths
            .iter()
            .zip(rates)
            .filter(|(p, _)| p.iter().any(|&x| x.0 as usize == li))
            .map(|(_, r)| r)
            .sum()
    }

    #[test]
    fn conservation_on_shared_bottleneck() {
        // Conservation: everything the bottleneck can carry is handed out —
        // no bandwidth lost to the allocator, none invented.
        let caps = [120.0, 1000.0, 1000.0];
        let p0: Vec<LinkId> = vec![l(0), l(1)];
        let p1: Vec<LinkId> = vec![l(0), l(2)];
        let p2: Vec<LinkId> = vec![l(0)];
        let paths = [p0, p1, p2];
        let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
        let rates = max_min_rates(&caps, &refs);
        let total: f64 = rates.iter().sum();
        assert!((total - 120.0).abs() < 1e-9, "allocated {total} of 120");
        assert!((link_load(&paths, &rates, 0) - 120.0).abs() < 1e-9);
    }

    #[test]
    fn property_conservation_every_flow_bounded_by_its_links() {
        // No flow exceeds any link it crosses, and per-link loads never
        // exceed capacity: bytes are conserved end to end.
        testkit::check("maxmin-conservation", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            for (p, r) in paths.iter().zip(&rates) {
                for &x in p {
                    assert!(
                        *r <= caps[x.0 as usize] * (1.0 + 1e-9) + 1e-9,
                        "flow rate {r} exceeds link {x:?} cap {}",
                        caps[x.0 as usize]
                    );
                }
            }
            for li in 0..nl {
                let load = link_load(&paths, &rates, li);
                assert!(load <= caps[li] * (1.0 + 1e-9) + 1e-9);
            }
        });
    }

    #[test]
    fn property_bottleneck_saturation() {
        // The globally most-constrained link is always driven to exactly
        // its capacity — the allocator never leaves the bottleneck idle.
        testkit::check("maxmin-bottleneck-saturation", |rng| {
            let nl = rng.range_usize(1, 8);
            let nf = rng.range_usize(1, 16);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            // The first-round bottleneck: min cap/active over used links.
            let mut active = vec![0u32; nl];
            for p in &paths {
                for &x in p {
                    active[x.0 as usize] += 1;
                }
            }
            let bottleneck = (0..nl)
                .filter(|&li| active[li] > 0)
                .min_by(|&a, &b| {
                    let sa = caps[a] / active[a] as f64;
                    let sb = caps[b] / active[b] as f64;
                    sa.partial_cmp(&sb).unwrap()
                });
            if let Some(li) = bottleneck {
                let load = link_load(&paths, &rates, li);
                assert!(
                    (load - caps[li]).abs() <= caps[li] * 1e-9 + 1e-9,
                    "bottleneck link {li} not saturated: {load} vs {}",
                    caps[li]
                );
            }
        });
    }

    #[test]
    fn property_maxmin_dominance() {
        // The max-min witness: every flow crosses a saturated link on which
        // its rate is at least every other crossing flow's rate. (If not,
        // the flow could be raised by lowering a *larger* flow — the
        // allocation would not be max-min fair.)
        testkit::check("maxmin-dominance", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 300.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(5));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &refs);
            for (f, p) in paths.iter().enumerate() {
                let witness = p.iter().any(|&x| {
                    let li = x.0 as usize;
                    let load = link_load(&paths, &rates, li);
                    let saturated = load >= caps[li] * (1.0 - 1e-9) - 1e-9;
                    let dominant = paths.iter().zip(&rates).all(|(q, r)| {
                        !q.iter().any(|&y| y.0 as usize == li)
                            || rates[f] >= *r - 1e-9 - *r * 1e-9
                    });
                    saturated && dominant
                });
                assert!(
                    witness,
                    "flow {f} (rate {}) has no saturated link it dominates",
                    rates[f]
                );
            }
        });
    }

    #[test]
    fn weighted_split_is_weight_proportional_on_shared_bottleneck() {
        // One link of 90 shared by weights 8:1 → 80 and 10.
        let caps = [90.0];
        let p: &[LinkId] = &[l(0)];
        let w = [8.0, 1.0];
        let rc = [f64::INFINITY; 2];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert!((r[0] - 80.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 10.0).abs() < 1e-9, "{r:?}");
        // Three-way 2:1:1 on the same link.
        let w = [2.0, 1.0, 1.0];
        let rc = [f64::INFINITY; 3];
        let r = max_min_rates_weighted(&caps, &[p, p, p], &w, &rc);
        assert!((r[0] - 45.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 22.5).abs() < 1e-9, "{r:?}");
        assert!((r[2] - 22.5).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn weighted_flow_still_bounded_by_private_bottleneck() {
        // High weight cannot push a flow past its own narrow link: f1 (w=8)
        // is clamped to link1's 4; f0 (w=1) then takes the rest of link0.
        let caps = [10.0, 4.0];
        let p0: &[LinkId] = &[l(0)];
        let p1: &[LinkId] = &[l(0), l(1)];
        let w = [1.0, 8.0];
        let rc = [f64::INFINITY; 2];
        let r = max_min_rates_weighted(&caps, &[p0, p1], &w, &rc);
        assert!((r[1] - 4.0).abs() < 1e-9, "{r:?}");
        assert!((r[0] - 6.0).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn rate_cap_binds_before_fair_share() {
        // Equal weights on a 100-link, but f0 is capped at 10: it freezes
        // at the cap and f1 absorbs the remainder.
        let caps = [100.0];
        let p: &[LinkId] = &[l(0)];
        let w = [1.0, 1.0];
        let rc = [10.0, f64::INFINITY];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert!((r[0] - 10.0).abs() < 1e-9, "{r:?}");
        assert!((r[1] - 90.0).abs() < 1e-9, "{r:?}");
        // A cap above the fair share changes nothing.
        let rc = [60.0, f64::INFINITY];
        let r = max_min_rates_weighted(&caps, &[p, p], &w, &rc);
        assert_eq!(r, vec![50.0, 50.0]);
    }

    #[test]
    fn property_equal_weights_match_unweighted_exactly() {
        // The acceptance gate of the QoS refactor: with all weights equal
        // and no caps, the weighted allocator IS the old unweighted one —
        // bit-identical rates on random instances.
        testkit::check("maxmin-equal-weights-degenerate", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let unweighted = max_min_rates(&caps, &refs);
            let w = vec![3.0; nf]; // equal but ≠ 1: only ratios matter
            let rc = vec![f64::INFINITY; nf];
            let weighted = max_min_rates_weighted(&caps, &refs, &w, &rc);
            for (a, b) in unweighted.iter().zip(&weighted) {
                assert!(
                    (a - b).abs() <= a.abs() * 1e-9 + 1e-9,
                    "equal-weight allocation diverged: {unweighted:?} vs {weighted:?}"
                );
            }
        });
    }

    #[test]
    fn property_weighted_conservation_and_feasibility() {
        // Weighted allocations conserve bytes: no link oversubscribed, no
        // flow past its cap, and every flow hits a saturated link or its
        // own rate cap (the weighted max-min optimality witness).
        testkit::check("maxmin-weighted-conservation", |rng| {
            let nl = rng.range_usize(1, 10);
            let nf = rng.range_usize(1, 20);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 500.0)).collect();
            let paths: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(4));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let refs: Vec<&[LinkId]> = paths.iter().map(|p| p.as_slice()).collect();
            let w: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.5, 8.0)).collect();
            let rc: Vec<f64> = (0..nf)
                .map(|_| {
                    if rng.bool(0.3) {
                        rng.range_f64(1.0, 100.0)
                    } else {
                        f64::INFINITY
                    }
                })
                .collect();
            let rates = max_min_rates_weighted(&caps, &refs, &w, &rc);
            for li in 0..nl {
                let load = link_load(&paths, &rates, li);
                assert!(
                    load <= caps[li] * (1.0 + 1e-9) + 1e-9,
                    "link {li} overloaded: {load} > {}",
                    caps[li]
                );
            }
            for (f, r) in rates.iter().enumerate() {
                assert!(*r > 0.0, "starved flow with positive caps");
                assert!(*r <= rc[f] * (1.0 + 1e-9), "flow {f} beyond cap");
                let capped = rc[f].is_finite() && *r >= rc[f] * (1.0 - 1e-9);
                let has_tight = paths[f].iter().any(|&x| {
                    let li = x.0 as usize;
                    link_load(&paths, &rates, li) >= caps[li] * (1.0 - 1e-9) - 1e-9
                });
                assert!(capped || has_tight, "flow {f} ({r}) neither capped nor tight");
            }
        });
    }

    #[test]
    fn property_feasible_and_saturating() {
        testkit::check("maxmin-feasible", |rng| {
            let nl = rng.range_usize(1, 12);
            let nf = rng.range_usize(1, 24);
            let caps: Vec<f64> = (0..nl).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let paths_owned: Vec<Vec<LinkId>> = (0..nf)
                .map(|_| {
                    let len = rng.range_usize(1, (nl + 1).min(5));
                    let mut links: Vec<u16> = (0..nl as u16).collect();
                    rng.shuffle(&mut links);
                    links.truncate(len);
                    links.into_iter().map(LinkId).collect()
                })
                .collect();
            let paths: Vec<&[LinkId]> = paths_owned.iter().map(|p| p.as_slice()).collect();
            let rates = max_min_rates(&caps, &paths);

            // (1) Feasibility: no link oversubscribed.
            for li in 0..nl {
                let load: f64 = paths_owned
                    .iter()
                    .zip(&rates)
                    .filter(|(p, _)| p.iter().any(|&x| x.0 as usize == li))
                    .map(|(_, r)| r)
                    .sum();
                assert!(
                    load <= caps[li] * (1.0 + 1e-9) + 1e-9,
                    "link {li} overloaded: {load} > {}",
                    caps[li]
                );
            }
            // (2) Every flow has a saturated link (max-min optimality
            //     witness): cannot raise any flow without exceeding a cap.
            for (p, r) in paths_owned.iter().zip(&rates) {
                assert!(*r > 0.0, "starved flow with positive caps");
                let has_tight = p.iter().any(|&x| {
                    let li = x.0 as usize;
                    let load: f64 = paths_owned
                        .iter()
                        .zip(&rates)
                        .filter(|(q, _)| q.iter().any(|&y| y.0 as usize == li))
                        .map(|(_, rr)| rr)
                        .sum();
                    load >= caps[li] * (1.0 - 1e-9) - 1e-9
                });
                assert!(has_tight, "flow rate {r} has no saturated link");
            }
        });
    }
}
