//! Flow-level interconnect bandwidth simulator.
//!
//! Each in-flight DMA operation is a *flow* across a path of directional
//! links (see [`crate::topology`]). Concurrent flows share every link
//! **max-min fairly** — the fluid-model analogue of PCIe's credit-based
//! flow control and the NVSwitch's per-port arbitration, which the paper
//! leans on ("PCIe's internal flow control arbitrates bandwidth between
//! co-running traffic sources", §5.1.2).
//!
//! A flow has a fixed latency phase (DMA setup: the flow consumes no
//! bandwidth) followed by a transfer phase at the fair-share rate. The
//! fabric is advanced lazily: callers `poll(now)` to collect completions
//! and `next_event_time()` to know when the state next changes.
//!
//! ## Hot-path layout
//!
//! Every per-event operation scales with *due* work, never with the
//! live-flow or lifetime slot count: dense `active`/`pending` index sets
//! (swap-remove with back-pointers) drive `advance_to` and `class_rate`;
//! each active flow carries an absolute `done_at` completion time fixed
//! when its rate is assigned, and both due harvesting and the
//! next-internal-event query pop lazy min-heaps over those times instead
//! of scanning every live flow per step.
//!
//! Rate allocation is *incremental* by default: when flows join or leave,
//! only the connected components of the links↔flows graph that contain a
//! touched flow are re-solved ([`ComponentSolver`]); everything else
//! keeps its rate and completion time bit-for-bit. The full re-solve
//! (the pre-existing reference path) remains available via
//! [`Fabric::set_incremental`]`(false)` and produces byte-identical
//! simulations — the per-component water-filling kernel is shared, so
//! the float operation sequence per component is the same either way.
//!
//! ## O(due) event processing
//!
//! Due-event harvesting is driven by two generation-stamped lazy
//! min-heaps (one over pending `active_at`s, one over active `done_at`s)
//! instead of per-step scans over every live flow: an entry is pushed
//! when the time it snapshots is set and validated against current flow
//! state on pop, so stale entries (rate changes, cancels, slot reuse)
//! are discarded lazily. Pop order reproduces the retired scans'
//! ascending-slot tie-break exactly (the scans survive as the
//! debug-asserted harvest oracle), so replay stays byte-identical.
//!
//! Rate solves *coalesce* within a virtual timestamp when
//! [`Fabric::set_coalesce`] is on (`[mma] coalesce_solves`, the
//! default): a join/leave batch defers its recompute until the next time
//! advance or rate observation, so a completion → engine action →
//! replacement-activation cascade at one instant settles under a single
//! component solve. Zero time elapses between the folded batches and the
//! water-fill is memoryless in prior rates, so the settled state — and
//! hence the simulation output — is byte-identical to eager solving
//! ([`FabricStats::deferred_solves`]/[`FabricStats::cascade_events`]
//! make the reduction observable).
//!
//! Flow paths are interned ([`PathTable`]/[`PathId`]): paths come from a
//! small static route set, so a [`Flow`] stores a 4-byte id instead of a
//! heap `Vec<LinkId>` and a steady-state flow start allocates nothing
//! ([`Fabric::start_alloc_growth`] is the bench-enforced invariant).

mod maxmin;

pub use maxmin::{max_min_rates, max_min_rates_weighted, ComponentSolver};

use crate::sim::Time;
use crate::topology::{LinkId, Topology};
use crate::util::fxmap::FxHashMap;
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Handle to an in-flight flow.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FlowId(pub u32);

/// Opaque tag the caller attaches to a flow to route its completion.
pub type FlowTag = u64;

/// Handle to an interned flow path (an index into a [`PathTable`]).
///
/// Paths come from a small static route set (topology presets, engine
/// relay stages, background loops), so callers intern once via
/// [`Fabric::intern_path`] and start flows by id — the per-flow
/// `Vec<LinkId>` clone the slice-based entry points used to pay is gone.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PathId(u32);

/// Interner for flow paths: each distinct link sequence is stored once
/// in a shared arena and referenced by [`PathId`]. `intern` of an
/// already-known path is a hash lookup with no allocation, which is what
/// makes steady-state flow starts allocation-free.
#[derive(Default)]
pub struct PathTable {
    /// Concatenated link sequences of every interned path.
    arena: Vec<LinkId>,
    /// `(offset, len)` span of each path in `arena`, indexed by id.
    spans: Vec<(u32, u32)>,
    /// Dedup index: link sequence → id.
    index: FxHashMap<Vec<LinkId>, u32>,
}

impl PathTable {
    /// Intern `path`, returning its id (existing id if already known).
    pub fn intern(&mut self, path: &[LinkId]) -> PathId {
        if let Some(&i) = self.index.get(path) {
            return PathId(i);
        }
        let id = self.spans.len() as u32;
        let off = self.arena.len() as u32;
        self.arena.extend_from_slice(path);
        self.spans.push((off, path.len() as u32));
        self.index.insert(path.to_vec(), id);
        PathId(id)
    }

    /// The link sequence of an interned path.
    pub fn get(&self, id: PathId) -> &[LinkId] {
        let (off, len) = self.spans[id.0 as usize];
        &self.arena[off as usize..(off + len) as usize]
    }

    /// Number of distinct interned paths.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// Whether no path has been interned yet.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }
}

/// A lazy-deletion due heap entry: `(when, slot, generation)`. Min-order
/// on `(when, slot)` reproduces the retired scans' ascending-slot
/// tie-break at equal timestamps; the generation stamp invalidates
/// entries that outlive their flow (slot reuse).
type DueEntry = Reverse<(Time, u32, u32)>;
type DueHeap = RefCell<BinaryHeap<DueEntry>>;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// DMA setup: becomes active at the stored time.
    Pending { active_at: Time },
    /// Transferring at `rate`.
    Active,
    /// Finished (slot free after harvest).
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    path: PathId,
    remaining: f64, // bytes
    total: u64,     // original payload size
    rate: f64,      // bytes/sec, valid while Active
    /// QoS share weight: rate allocation is weighted max-min (1.0 = the
    /// classic unweighted share).
    weight: f64,
    /// Absolute rate ceiling (QoS bulk throttle); `INFINITY` = uncapped.
    cap: f64,
    phase: Phase,
    tag: FlowTag,
    started: Time,
    live: bool,
    /// Absolute completion time, fixed whenever `rate` changes
    /// (`Time::NEVER` while pending or starved).
    done_at: Time,
    /// Back-pointer: position in `pending` (while Pending) or `active`
    /// (while Active), for O(1) swap-removal.
    set_pos: u32,
    /// Slot generation, bumped on reuse: due-heap entries carry the
    /// generation they were pushed under and die with it.
    gen: u32,
    /// Snapshot of `(rate, done_at)` as of the first rate write at
    /// `prev_at` — makes `done_at` a function of the *net* rate change
    /// across a virtual instant, not of how many intermediate solves
    /// observed it. Without this, an eager double-solve that restores a
    /// rate's bits would recompute `done_at` with fresh rounding while a
    /// coalesced single solve kept the old value (see `solve_component`).
    prev_rate: f64,
    prev_done_at: Time,
    prev_at: Time,
}

/// Cumulative per-flow accounting returned on completion.
#[derive(Debug, Clone, Copy)]
pub struct FlowDone {
    /// The completed flow.
    pub id: FlowId,
    /// Caller's tag.
    pub tag: FlowTag,
    /// Total payload bytes the flow carried.
    pub bytes: u64,
    /// When the flow was started (including setup latency).
    pub started: Time,
    /// When it finished.
    pub finished: Time,
}

/// Allocator work counters, for perf introspection and the hotpath bench
/// (`BENCH_0006_hotpath.json` reports these for the incremental vs the
/// reference path).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Rate recomputation events (any flow join/leave batch).
    pub recomputes: u64,
    /// Whole-flow-set re-solves: one per recompute on the reference path,
    /// zero on the incremental path.
    pub full_solves: u64,
    /// Connected-component water-fill passes.
    pub component_solves: u64,
    /// Total flow-rate assignments across all component solves — the
    /// actual allocator work done.
    pub flows_solved: u64,
    /// Recompute requests deferred by timestamp coalescing
    /// ([`Fabric::set_coalesce`]) instead of solved eagerly.
    pub deferred_solves: u64,
    /// Deferred batches folded into an already-pending solve — each one
    /// is a same-timestamp cascade step and a whole solve saved.
    pub cascade_events: u64,
}

/// The fabric simulator.
pub struct Fabric {
    capacity: Vec<f64>,
    flows: Vec<Flow>,
    free: Vec<u32>,
    /// Active flow ids per link (dense, rebuilt incrementally).
    link_flows: Vec<Vec<u32>>,
    /// Dense set of Active flow slots (unordered; back-pointers in flows).
    active: Vec<u32>,
    /// Dense set of Pending flow slots (unordered; back-pointers in flows).
    pending: Vec<u32>,
    last_advance: Time,
    /// Lazy min-heap over pending activations: `(active_at, slot, gen)`.
    /// Interior-mutable so `next_event(&self)` can prune stale tops.
    pending_heap: DueHeap,
    /// Lazy min-heap over active completions: `(done_at, slot, gen)`.
    /// Re-pushed only when a solve actually changes a flow's `done_at`
    /// bits; superseded entries are discarded on pop.
    done_heap: DueHeap,
    /// Interned flow paths (see [`PathTable`]).
    paths: PathTable,
    /// Incremental (component-scoped) rate allocation; false = reference
    /// full re-solve per event.
    incremental: bool,
    /// Defer join/leave rate solves until the next time advance or rate
    /// observation, folding same-timestamp cascades into one solve.
    coalesce: bool,
    /// A deferred join/leave batch awaits its solve (`coalesce` mode).
    solve_dirty: bool,
    /// Allocation-growth events on the flow-start path (new path
    /// interns, flow-slab growth, due-heap capacity growth).
    alloc_growth: u64,
    solver: ComponentSolver,
    /// Flow slots that joined the active set since the last recompute.
    seed_flows: Vec<u32>,
    /// Links that lost a flow since the last recompute.
    seed_links: Vec<u32>,
    /// Scratch for due-event gathering in `poll_into`.
    due_scratch: Vec<u32>,
    /// Scratch for full-mode solve ordering.
    solve_scratch: Vec<u32>,
    stats: FabricStats,
    /// Total bytes completed per tag-class is left to callers; the fabric
    /// tracks aggregate delivered bytes for utilization reports.
    pub delivered_bytes: f64,
}

impl Fabric {
    /// Build over a topology's links (incremental allocation and solve
    /// coalescing on).
    pub fn new(topo: &Topology) -> Fabric {
        Fabric {
            capacity: topo.links.iter().map(|l| l.capacity_bps).collect(),
            flows: Vec::new(),
            free: Vec::new(),
            link_flows: vec![Vec::new(); topo.links.len()],
            active: Vec::new(),
            pending: Vec::new(),
            last_advance: Time::ZERO,
            pending_heap: RefCell::new(BinaryHeap::new()),
            done_heap: RefCell::new(BinaryHeap::new()),
            paths: PathTable::default(),
            incremental: true,
            coalesce: true,
            solve_dirty: false,
            alloc_growth: 0,
            solver: ComponentSolver::default(),
            seed_flows: Vec::new(),
            seed_links: Vec::new(),
            due_scratch: Vec::new(),
            solve_scratch: Vec::new(),
            stats: FabricStats::default(),
            delivered_bytes: 0.0,
        }
    }

    /// Builder-style allocator mode selection (see
    /// [`set_incremental`](Self::set_incremental)).
    pub fn with_incremental(mut self, on: bool) -> Fabric {
        self.set_incremental(on);
        self
    }

    /// Choose between incremental (component-scoped, the default) and
    /// reference (full re-solve per event) rate allocation. Both produce
    /// bit-identical simulations; the reference path exists as the
    /// equivalence oracle and baseline. Switching with live flows forces
    /// one re-solve so rates stay consistent.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
        if !self.active.is_empty() {
            let mut seeds: Vec<u32> = self.active.clone();
            seeds.sort_unstable();
            self.seed_flows.extend(seeds);
            self.request_recompute();
        }
    }

    /// Whether incremental allocation is enabled.
    pub fn is_incremental(&self) -> bool {
        self.incremental
    }

    /// Builder-style solve-coalescing selection (see
    /// [`set_coalesce`](Self::set_coalesce)).
    pub fn with_coalesce(mut self, on: bool) -> Fabric {
        self.set_coalesce(on);
        self
    }

    /// Choose between deferred (timestamp-coalesced, the default) and
    /// eager rate solving. Deferred mode batches every join/leave at one
    /// virtual instant under a single solve, settled before any time
    /// advance or rate observation; since zero time elapses between the
    /// folded batches and the water-fill is memoryless, simulation
    /// output is byte-identical either way. Switching off settles any
    /// pending batch immediately.
    pub fn set_coalesce(&mut self, on: bool) {
        self.coalesce = on;
        if !on {
            self.flush_solve();
        }
    }

    /// Whether solve coalescing is enabled.
    pub fn is_coalescing(&self) -> bool {
        self.coalesce
    }

    /// Intern a path for [`start_flow_path`](Self::start_flow_path),
    /// returning the existing id when the link sequence is known.
    pub fn intern_path(&mut self, path: &[LinkId]) -> PathId {
        let before = self.paths.len();
        let id = self.paths.intern(path);
        if self.paths.len() > before {
            self.alloc_growth += 1;
        }
        id
    }

    /// Links of an interned path.
    pub fn path_links(&self, id: PathId) -> &[LinkId] {
        self.paths.get(id)
    }

    /// Allocation-growth events on the flow-start path since
    /// construction: new path interns, flow-slab growth and due-heap
    /// capacity growth. After warm-up this counter must not move — the
    /// BENCH_0009 zero-flow-start-allocs invariant.
    pub fn start_alloc_growth(&self) -> u64 {
        self.alloc_growth
    }

    /// Settle any deferred rate solve (no-op when none is pending or
    /// coalescing is off). Time advances and rate observations settle
    /// implicitly; this exists for callers that want fresh state at a
    /// known point.
    pub fn settle(&mut self) {
        self.flush_solve();
    }

    /// Allocator work counters since construction.
    pub fn stats(&self) -> FabricStats {
        self.stats
    }

    /// Number of currently live (pending or active) flows.
    pub fn live_flows(&self) -> usize {
        self.active.len() + self.pending.len()
    }

    /// Start a flow of `bytes` over `path` with a setup `latency` before it
    /// occupies any bandwidth, at the default QoS parameters (weight 1,
    /// uncapped). Returns its id. Call `poll(now)` afterwards (mutations
    /// are lazy).
    pub fn start_flow(
        &mut self,
        now: Time,
        path: &[LinkId],
        bytes: u64,
        latency: Time,
        tag: FlowTag,
    ) -> FlowId {
        self.start_flow_qos(now, path, bytes, latency, tag, 1.0, f64::INFINITY)
    }

    /// Start a flow carrying explicit QoS parameters: `weight` is its
    /// weighted max-min share weight (> 0), `cap` an absolute rate ceiling
    /// in bytes/sec (`f64::INFINITY` = uncapped). With every live flow at
    /// weight 1 / uncapped, allocation is identical to classic unweighted
    /// max-min.
    #[allow(clippy::too_many_arguments)]
    pub fn start_flow_qos(
        &mut self,
        now: Time,
        path: &[LinkId],
        bytes: u64,
        latency: Time,
        tag: FlowTag,
        weight: f64,
        cap: f64,
    ) -> FlowId {
        let pid = self.intern_path(path);
        self.start_flow_path(now, pid, bytes, latency, tag, weight, cap)
    }

    /// [`start_flow_qos`](Self::start_flow_qos) by interned path id —
    /// the allocation-free core every flow start funnels through.
    #[allow(clippy::too_many_arguments)]
    pub fn start_flow_path(
        &mut self,
        now: Time,
        path: PathId,
        bytes: u64,
        latency: Time,
        tag: FlowTag,
        weight: f64,
        cap: f64,
    ) -> FlowId {
        debug_assert!(!self.paths.get(path).is_empty());
        debug_assert!(weight > 0.0 && weight.is_finite(), "flow weight {weight}");
        debug_assert!(cap > 0.0, "flow cap {cap}");
        self.advance_to(now);
        let active_at = now + latency;
        let flow = Flow {
            path,
            remaining: bytes.max(1) as f64,
            total: bytes.max(1),
            rate: 0.0,
            weight,
            cap,
            phase: Phase::Pending { active_at },
            tag,
            started: now,
            live: true,
            done_at: Time::NEVER,
            set_pos: 0,
            gen: 0,
            prev_rate: 0.0,
            prev_done_at: Time::NEVER,
            prev_at: Time::NEVER,
        };
        let id = match self.free.pop() {
            Some(i) => {
                let gen = self.flows[i as usize].gen.wrapping_add(1);
                self.flows[i as usize] = flow;
                self.flows[i as usize].gen = gen;
                i
            }
            None => {
                if self.flows.len() == self.flows.capacity() {
                    self.alloc_growth += 1;
                }
                self.flows.push(flow);
                (self.flows.len() - 1) as u32
            }
        };
        self.flows[id as usize].set_pos = self.pending.len() as u32;
        if self.pending.len() == self.pending.capacity() {
            self.alloc_growth += 1;
        }
        self.pending.push(id);
        let gen = self.flows[id as usize].gen;
        Self::heap_push(&self.pending_heap, &mut self.alloc_growth, (active_at, id, gen));
        FlowId(id)
    }

    /// Cancel a live flow (used by failure-injection tests).
    pub fn cancel(&mut self, now: Time, id: FlowId) {
        self.advance_to(now);
        let f = &mut self.flows[id.0 as usize];
        if !f.live {
            return;
        }
        let was_active = f.phase == Phase::Active;
        // Mark dead *before* recomputing, or the rate allocation would
        // still count the cancelled flow. Its due-heap entries go stale
        // and are discarded on pop.
        f.live = false;
        f.phase = Phase::Done;
        if was_active {
            self.active_remove(id.0);
            self.detach(id.0);
            self.request_recompute();
        } else {
            self.pending_remove(id.0);
        }
        self.free.push(id.0);
    }

    /// Advance to `now`, activating due pending flows and harvesting
    /// completions. Returns completion records in deterministic order.
    /// Allocation-free callers should prefer [`poll_into`](Self::poll_into).
    pub fn poll(&mut self, now: Time) -> Vec<FlowDone> {
        let mut done = Vec::new();
        self.poll_into(now, &mut done);
        done
    }

    /// [`poll`](Self::poll) into a caller-owned buffer (appended, not
    /// cleared), so steady-state polling allocates nothing.
    pub fn poll_into(&mut self, now: Time, done: &mut Vec<FlowDone>) {
        // Process piecewise: there may be several internal events (an
        // activation changes rates, which changes completion times) between
        // last_advance and now.
        let mut due = std::mem::take(&mut self.due_scratch);
        loop {
            let next = self.next_event();
            let step_to = match next {
                Some(t) if t <= now => t,
                _ => now,
            };
            // Flushes any deferred solve first when time actually elapses,
            // so bytes integrate at the rates that were in force.
            self.advance_to(step_to);
            let mut changed = false;
            // Activations due, in ascending slot order (the order fixes
            // link_flows layout and hence float summation order). The heap
            // drain plus sort reproduces the retired O(live) scan exactly
            // (debug-asserted against it below).
            due.clear();
            self.drain_due_pending(step_to, &mut due);
            due.sort_unstable();
            debug_assert_eq!(
                due,
                self.scan_due_pending(step_to),
                "pending due-heap diverged from the scan oracle"
            );
            for &s in &due {
                self.pending_remove(s);
                self.active_insert(s);
                let Fabric {
                    flows,
                    link_flows,
                    paths,
                    alloc_growth,
                    ..
                } = self;
                let f = &mut flows[s as usize];
                f.phase = Phase::Active;
                f.rate = 0.0;
                f.done_at = Time::NEVER;
                for &l in paths.get(f.path) {
                    let v = &mut link_flows[l.0 as usize];
                    if v.len() == v.capacity() {
                        *alloc_growth += 1;
                    }
                    v.push(s);
                }
                self.seed_flows.push(s);
                changed = true;
            }
            // Completions due, in ascending slot order. Sound even with a
            // solve deferred at this instant: a pending batch only moves
            // completion times strictly later than `step_to`, so the due
            // set is exactly what eager solving would harvest.
            due.clear();
            self.drain_due_done(step_to, &mut due);
            due.sort_unstable();
            // A completion can carry two valid entries with identical
            // (time, slot, gen): a restored `done_at` is re-pushed even
            // though the original entry may still be queued.
            due.dedup();
            debug_assert_eq!(
                due,
                self.scan_due_active(step_to),
                "done due-heap diverged from the scan oracle"
            );
            for &s in &due {
                let f = &self.flows[s as usize];
                done.push(FlowDone {
                    id: FlowId(s),
                    tag: f.tag,
                    bytes: f.total,
                    started: f.started,
                    finished: step_to,
                });
                self.active_remove(s);
                self.detach(s);
                let f = &mut self.flows[s as usize];
                f.live = false;
                f.phase = Phase::Done;
                self.free.push(s);
                changed = true;
            }
            if changed {
                self.request_recompute();
            } else {
                // Nothing due at this instant: a deferred solve parked
                // next_event here. Settle it so the true next event (and
                // quiescence) is reachable; settled completion times are
                // all strictly in the future, so nothing new comes due.
                self.flush_solve();
            }
            if step_to >= now {
                break;
            }
        }
        self.due_scratch = due;
    }

    /// Earliest future time at which fabric state changes (activation or
    /// completion), or `None` if fully idle. While a deferred solve is
    /// pending (see [`set_coalesce`](Self::set_coalesce)) this returns
    /// the current instant — rates change the moment the batch settles —
    /// which is what re-arms the driver to poll, settle and merge
    /// same-timestamp cascades.
    pub fn next_event_time(&self) -> Option<Time> {
        self.next_event()
    }

    /// Instantaneous rate of a live flow (bytes/sec; 0 while pending).
    /// Settles any deferred solve first, hence `&mut`.
    pub fn flow_rate(&mut self, id: FlowId) -> f64 {
        self.flush_solve();
        let f = &self.flows[id.0 as usize];
        if f.live && f.phase == Phase::Active {
            f.rate
        } else {
            0.0
        }
    }

    /// Instantaneous utilization of a link: sum of active flow rates (B/s).
    /// Settles any deferred solve first, hence `&mut`.
    pub fn link_rate(&mut self, link: LinkId) -> f64 {
        self.flush_solve();
        self.link_flows[link.0 as usize]
            .iter()
            .map(|&i| self.flows[i as usize].rate)
            .sum()
    }

    /// Sum of instantaneous rates of all live flows whose tag satisfies the
    /// predicate — the figure harnesses use this to plot per-class
    /// bandwidth over time (Fig 9). O(active flows). Settles any deferred
    /// solve first, hence `&mut`.
    pub fn class_rate(&mut self, pred: impl Fn(FlowTag) -> bool) -> f64 {
        self.flush_solve();
        self.active
            .iter()
            .map(|&s| &self.flows[s as usize])
            .filter(|f| pred(f.tag))
            .map(|f| f.rate)
            .sum()
    }

    // ----- internals -------------------------------------------------

    /// Earliest internal event: the min over the two due heaps' valid
    /// tops, pruning stale entries on sight — O(1) amortized (every
    /// discard is paid for by the push that created it). A pending
    /// deferred solve parks the estimate at the current instant.
    fn next_event(&self) -> Option<Time> {
        if self.solve_dirty {
            return Some(self.last_advance);
        }
        let p = self.prune_peek(&self.pending_heap, false);
        let d = self.prune_peek(&self.done_heap, true);
        match (p, d) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Peek the earliest valid entry of a due heap, popping stale ones.
    fn prune_peek(&self, heap: &DueHeap, completions: bool) -> Option<Time> {
        let mut h = heap.borrow_mut();
        while let Some(&Reverse((t, s, g))) = h.peek() {
            if self.due_entry_valid(t, s, g, completions) {
                return Some(t);
            }
            h.pop();
        }
        None
    }

    /// Whether a due-heap entry still describes its flow: generation,
    /// liveness, phase and the snapshotted time must all match.
    fn due_entry_valid(&self, t: Time, s: u32, g: u32, completions: bool) -> bool {
        let f = &self.flows[s as usize];
        if f.gen != g || !f.live {
            return false;
        }
        if completions {
            f.phase == Phase::Active && f.done_at == t
        } else {
            matches!(f.phase, Phase::Pending { active_at } if active_at == t)
        }
    }

    /// Drain valid pending-activation entries due at or before `step_to`.
    fn drain_due_pending(&mut self, step_to: Time, due: &mut Vec<u32>) {
        let mut h = self.pending_heap.borrow_mut();
        while let Some(&Reverse((t, s, g))) = h.peek() {
            if t > step_to {
                break;
            }
            h.pop();
            let f = &self.flows[s as usize];
            if f.gen == g
                && f.live
                && matches!(f.phase, Phase::Pending { active_at } if active_at == t)
            {
                due.push(s);
            }
        }
    }

    /// Drain valid completion entries due at or before `step_to`.
    fn drain_due_done(&mut self, step_to: Time, due: &mut Vec<u32>) {
        let mut h = self.done_heap.borrow_mut();
        while let Some(&Reverse((t, s, g))) = h.peek() {
            if t > step_to {
                break;
            }
            h.pop();
            let f = &self.flows[s as usize];
            if f.gen == g && f.live && f.phase == Phase::Active && f.done_at == t {
                due.push(s);
            }
        }
    }

    /// The retired O(live) activation scan, kept as the harvest oracle
    /// the heap drain is debug-asserted against (ascending slot order).
    #[cfg_attr(not(debug_assertions), allow(dead_code))]
    fn scan_due_pending(&self, step_to: Time) -> Vec<u32> {
        let mut due: Vec<u32> = self
            .pending
            .iter()
            .copied()
            .filter(|&s| {
                matches!(self.flows[s as usize].phase,
                    Phase::Pending { active_at } if active_at <= step_to)
            })
            .collect();
        due.sort_unstable();
        due
    }

    /// The retired O(live) completion scan, kept as the harvest oracle
    /// the heap drain is debug-asserted against (ascending slot order).
    #[cfg_attr(not(debug_assertions), allow(dead_code))]
    fn scan_due_active(&self, step_to: Time) -> Vec<u32> {
        let mut due: Vec<u32> = self
            .active
            .iter()
            .copied()
            .filter(|&s| self.flows[s as usize].done_at <= step_to)
            .collect();
        due.sort_unstable();
        due
    }

    /// Test probe: what the heap drain *would* harvest up to `horizon`,
    /// computed on cloned heaps so fabric state is untouched. Compared
    /// against the scan oracles at arbitrary future horizons.
    #[cfg(test)]
    fn heap_due_snapshot(&self, horizon: Time, completions: bool) -> Vec<u32> {
        let heap = if completions {
            &self.done_heap
        } else {
            &self.pending_heap
        };
        let mut h = heap.borrow().clone();
        let mut out = Vec::new();
        while let Some(Reverse((t, s, g))) = h.pop() {
            if t > horizon {
                break;
            }
            if self.due_entry_valid(t, s, g, completions) {
                out.push(s);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Push a due-heap entry, counting capacity growth against the
    /// zero-flow-start-allocs invariant.
    fn heap_push(heap: &DueHeap, alloc_growth: &mut u64, entry: (Time, u32, u32)) {
        let mut h = heap.borrow_mut();
        if h.len() == h.capacity() {
            *alloc_growth += 1;
        }
        h.push(Reverse(entry));
    }

    /// Run a requested rate recompute now, or defer it to the next time
    /// advance / observation when coalescing (folding same-timestamp
    /// batches into one solve).
    fn request_recompute(&mut self) {
        if self.coalesce {
            self.stats.deferred_solves += 1;
            if self.solve_dirty {
                // Folded into the already-pending batch: a cascade step
                // at this instant, and a whole solve saved.
                self.stats.cascade_events += 1;
            }
            self.solve_dirty = true;
        } else {
            self.recompute();
        }
    }

    /// Settle a deferred solve batch, if one is pending.
    fn flush_solve(&mut self) {
        if self.solve_dirty {
            self.solve_dirty = false;
            self.recompute();
        }
    }

    fn advance_to(&mut self, now: Time) {
        if now <= self.last_advance {
            return;
        }
        // Time is about to elapse: settle any deferred solve first so
        // bytes integrate at the rates that were actually in force.
        self.flush_solve();
        let dt = (now - self.last_advance).as_secs_f64();
        let Fabric {
            active,
            flows,
            delivered_bytes,
            ..
        } = self;
        for &s in active.iter() {
            let f = &mut flows[s as usize];
            if f.rate > 0.0 {
                let moved = f.rate * dt;
                let used = moved.min(f.remaining);
                f.remaining -= used;
                *delivered_bytes += used;
                if f.remaining < 0.25 {
                    f.remaining = 0.0;
                }
            }
        }
        self.last_advance = now;
    }

    fn pending_remove(&mut self, s: u32) {
        let pos = self.flows[s as usize].set_pos as usize;
        debug_assert_eq!(self.pending[pos], s);
        self.pending.swap_remove(pos);
        if let Some(&moved) = self.pending.get(pos) {
            self.flows[moved as usize].set_pos = pos as u32;
        }
    }

    fn active_insert(&mut self, s: u32) {
        self.flows[s as usize].set_pos = self.active.len() as u32;
        if self.active.len() == self.active.capacity() {
            self.alloc_growth += 1;
        }
        self.active.push(s);
    }

    fn active_remove(&mut self, s: u32) {
        let pos = self.flows[s as usize].set_pos as usize;
        debug_assert_eq!(self.active[pos], s);
        self.active.swap_remove(pos);
        if let Some(&moved) = self.active.get(pos) {
            self.flows[moved as usize].set_pos = pos as u32;
        }
    }

    /// Unlink a flow from every link it crosses, recording the links as
    /// component seeds for the next incremental re-solve.
    fn detach(&mut self, idx: u32) {
        let Fabric {
            flows,
            link_flows,
            seed_links,
            paths,
            ..
        } = self;
        for &l in paths.get(flows[idx as usize].path) {
            let v = &mut link_flows[l.0 as usize];
            if let Some(p) = v.iter().position(|&x| x == idx) {
                v.swap_remove(p);
            }
            seed_links.push(l.0 as u32);
        }
    }

    /// Re-solve rate allocation after a flow join/leave batch. The
    /// incremental path re-solves only components seeded by the batch;
    /// the reference path re-solves every live component. Either way each
    /// component runs the same water-fill kernel, so a flow's rate (and
    /// its `done_at`) changes bits only when its allocation truly changed.
    fn recompute(&mut self) {
        debug_assert!(!self.solve_dirty, "recompute with an unsettled deferred batch");
        self.stats.recomputes += 1;
        let mut solver = std::mem::take(&mut self.solver);
        let mut seed_flows = std::mem::take(&mut self.seed_flows);
        let mut seed_links = std::mem::take(&mut self.seed_links);
        solver.begin(self.capacity.len(), self.flows.len());
        if self.incremental {
            for &s in &seed_flows {
                let f = &self.flows[s as usize];
                if f.live && f.phase == Phase::Active && !solver.claimed(s) {
                    self.solve_component(&mut solver, s);
                }
            }
            for &l in &seed_links {
                let mut k = 0;
                while k < self.link_flows[l as usize].len() {
                    let g = self.link_flows[l as usize][k];
                    if !solver.claimed(g) {
                        self.solve_component(&mut solver, g);
                    }
                    k += 1;
                }
            }
        } else {
            self.stats.full_solves += 1;
            let mut all = std::mem::take(&mut self.solve_scratch);
            all.clear();
            all.extend_from_slice(&self.active);
            all.sort_unstable();
            for &s in &all {
                if !solver.claimed(s) {
                    self.solve_component(&mut solver, s);
                }
            }
            self.solve_scratch = all;
        }
        seed_flows.clear();
        seed_links.clear();
        self.seed_flows = seed_flows;
        self.seed_links = seed_links;
        self.solver = solver;
    }

    /// Solve the component containing `seed` and apply its rates,
    /// refreshing `done_at` only for flows whose rate actually changed
    /// (bit comparison) — unchanged flows keep their exact completion
    /// times, which is what makes incremental and full allocation
    /// byte-identical in simulation output.
    fn solve_component(&mut self, solver: &mut ComponentSolver, seed: u32) {
        solver.collect(seed, &self.link_flows, |f| {
            self.paths.get(self.flows[f as usize].path)
        });
        solver.solve_collected(
            &self.capacity,
            |f| self.paths.get(self.flows[f as usize].path),
            |f| self.flows[f as usize].weight,
            |f| self.flows[f as usize].cap,
        );
        self.stats.component_solves += 1;
        let (slots, rates) = solver.result();
        self.stats.flows_solved += slots.len() as u64;
        let at = self.last_advance;
        for (&s, &r) in slots.iter().zip(rates) {
            let f = &mut self.flows[s as usize];
            if f.rate.to_bits() != r.to_bits() {
                // First rate write at this instant: snapshot the incoming
                // state. If a later solve at the *same* instant restores
                // the rate's bits (eager mode solving a completion and its
                // same-timestamp replacement separately), restore the
                // snapshotted `done_at` instead of recomputing it — the
                // fresh ceil would round differently and diverge from the
                // coalesced single solve, which never saw the intermediate
                // rate. `done_at` thus depends only on the net rate change
                // across the instant, never on how many solves observed it.
                if f.prev_at != at {
                    f.prev_at = at;
                    f.prev_rate = f.rate;
                    f.prev_done_at = f.done_at;
                }
                let done_at = if r.to_bits() == f.prev_rate.to_bits() {
                    f.prev_done_at
                } else if r > 0.0 {
                    // Ceil to a whole nanosecond and always make progress:
                    // a sub-ns rounding to zero would stall the poll loop.
                    at + Time((f.remaining / r * 1e9).ceil().max(1.0) as u64)
                } else {
                    Time::NEVER
                };
                f.rate = r;
                f.done_at = done_at;
                let gen = f.gen;
                // Only flows whose completion time actually moved get a
                // fresh heap entry; the superseded one dies lazily. A
                // restored `done_at` is re-pushed too: its original entry
                // may have been pruned as stale while the intermediate
                // rate was in force.
                if done_at != Time::NEVER {
                    Self::heap_push(&self.done_heap, &mut self.alloc_growth, (done_at, s, gen));
                }
            }
        }
    }
}

/// Convenience: run a closed set of flows to completion and return each
/// flow's completion time. Used heavily in tests.
pub fn run_to_completion(fabric: &mut Fabric, mut now: Time) -> HashMap<FlowTag, Time> {
    let mut out = HashMap::new();
    loop {
        for d in fabric.poll(now) {
            out.insert(d.tag, d.finished);
        }
        match fabric.next_event_time() {
            Some(t) => now = t,
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::topology::{h20x8, Direction, GpuId, NumaId};

    fn topo() -> Topology {
        h20x8()
    }

    #[test]
    fn single_flow_runs_at_link_capacity() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let bytes = 1_000_000_000u64; // 1 GB
        f.start_flow(Time::ZERO, &path, bytes, Time::from_us(10), 1);
        let done = run_to_completion(&mut f, Time::ZERO);
        let finish = done[&1];
        let expect = 10e-6 + bytes as f64 / t.pcie_capacity(GpuId(0), Direction::H2D);
        let got = finish.as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 1e-6,
            "got {got}, expect {expect}"
        );
    }

    #[test]
    fn two_flows_share_one_link_fairly() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let b = 1_000_000_000u64;
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        assert!((f.flow_rate(FlowId(0)) - cap / 2.0).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap / 2.0).abs() < 1.0);
        let done = run_to_completion(&mut f, Time::ZERO);
        // Both finish together at 2x the solo time.
        let solo = b as f64 / cap;
        assert!((done[&1].as_secs_f64() - 2.0 * solo).abs() / solo < 1e-6);
        assert!((done[&2].as_secs_f64() - 2.0 * solo).abs() / solo < 1e-6);
    }

    #[test]
    fn disjoint_paths_do_not_interfere() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let b = 500_000_000u64;
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(2)), b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        // GPUs 0 and 2 are behind different switches; only DRAM is shared
        // (380 GB/s >> 2x53.6) so both run at full lane rate.
        assert!((f.flow_rate(FlowId(0)) - cap).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap).abs() < 1.0);
    }

    #[test]
    fn switch_uplink_contends_two_gpus() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let b = 1_000_000_000u64;
        // GPUs 0 and 1 share switch 0 (uplink 100 GB/s < 2x53.6).
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(1)), b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let r0 = f.flow_rate(FlowId(0));
        let r1 = f.flow_rate(FlowId(1));
        assert!((r0 - 50e9).abs() < 1e6, "r0={r0}");
        assert!((r1 - 50e9).abs() < 1e6, "r1={r1}");
    }

    #[test]
    fn early_completion_releases_bandwidth() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        // Flow 1 is half the size of flow 2; once it finishes, flow 2
        // should speed up to full capacity.
        f.start_flow(Time::ZERO, &path, 500_000_000, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 2);
        let done = run_to_completion(&mut f, Time::ZERO);
        // flow1: 0.5GB at cap/2 -> 1/ cap *1e9 secs. flow2: 0.5GB at cap/2 + 0.5GB at cap.
        let t1 = 0.5e9 / (cap / 2.0);
        let t2 = t1 + 0.5e9 / cap;
        assert!((done[&1].as_secs_f64() - t1).abs() / t1 < 1e-6);
        assert!((done[&2].as_secs_f64() - t2).abs() / t2 < 1e-6);
    }

    #[test]
    fn pending_latency_consumes_no_bandwidth() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        f.start_flow(Time::ZERO, &path, 1_000_000, Time::from_us(100), 1);
        f.poll(Time::from_us(50));
        assert_eq!(f.flow_rate(FlowId(0)), 0.0);
        assert_eq!(f.link_rate(path[0]), 0.0);
        f.poll(Time::from_us(101));
        assert!(f.flow_rate(FlowId(0)) > 0.0);
    }

    #[test]
    fn cancel_frees_capacity() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let a = f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let half = f.flow_rate(FlowId(1));
        f.cancel(Time::from_ms(1), a);
        f.poll(Time::from_ms(1));
        assert!(f.flow_rate(FlowId(1)) > 1.9 * half);
    }

    #[test]
    fn cancel_pending_flow_never_activates() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let a = f.start_flow(Time::ZERO, &path, 1_000_000, Time::from_us(100), 1);
        f.cancel(Time::from_us(10), a);
        assert_eq!(f.live_flows(), 0);
        assert!(f.poll(Time::from_ms(5)).is_empty());
        assert_eq!(f.next_event_time(), None);
    }

    #[test]
    fn class_rate_sums_by_tag() {
        let t = topo();
        let mut f = Fabric::new(&t);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), 1 << 30, Time::ZERO, 10);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(2)), 1 << 30, Time::ZERO, 20);
        f.poll(Time::ZERO);
        let all = f.class_rate(|_| true);
        let tens = f.class_rate(|t| t == 10);
        assert!(tens > 0.0 && tens < all);
    }

    #[test]
    fn p2p_alone_matches_table2() {
        // Table 2: P2P_alone = 367.60 GB/s.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.p2p(GpuId(0), GpuId(1));
        let b = 4u64 << 30;
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 1);
        let done = run_to_completion(&mut f, Time::ZERO);
        let bw = b as f64 / done[&1].as_secs_f64() / 1e9;
        assert!((bw - 368.0).abs() < 2.0, "p2p alone bw {bw}");
    }

    #[test]
    fn weighted_flows_split_a_shared_lane_by_weight() {
        // The QoS regression anchor: a Bulk wake (weight 1) co-running with
        // a LatencyCritical fetch (weight 8) on one PCIe lane leaves the
        // fetch ≥ its 8/9 weighted share while both are live.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 1, 8.0, f64::INFINITY);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 2, 1.0, f64::INFINITY);
        f.poll(Time::ZERO);
        let crit = f.flow_rate(FlowId(0));
        let bulk = f.flow_rate(FlowId(1));
        assert!((crit - cap * 8.0 / 9.0).abs() < 1.0, "critical {crit}");
        assert!((bulk - cap / 9.0).abs() < 1.0, "bulk {bulk}");
        // Weighting redistributes, never destroys, bandwidth.
        assert!((crit + bulk - cap).abs() < 1.0);
    }

    #[test]
    fn capped_flow_leaves_headroom_even_alone() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap_bps = 10e9;
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 1, 1.0, cap_bps);
        f.poll(Time::ZERO);
        let r = f.flow_rate(FlowId(0));
        assert!((r - cap_bps).abs() < 1.0, "capped solo rate {r}");
        let done = run_to_completion(&mut f, Time::ZERO);
        let want = (1u64 << 30) as f64 / cap_bps;
        let got = done[&1].as_secs_f64();
        assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn unit_weight_flows_match_legacy_fair_sharing() {
        // start_flow (no QoS parameters) must behave exactly as before the
        // weighted refactor: equal split on a shared lane.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        f.start_flow(Time::ZERO, &path, 1 << 30, Time::ZERO, 1);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 2, 1.0, f64::INFINITY);
        f.poll(Time::ZERO);
        assert!((f.flow_rate(FlowId(0)) - cap / 2.0).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap / 2.0).abs() < 1.0);
    }

    #[test]
    fn reuses_freed_slots() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        for _ in 0..100 {
            let now = f.last_advance;
            f.start_flow(now, &path, 1_000_000, Time::ZERO, 7);
            run_to_completion(&mut f, now);
        }
        assert!(f.flows.len() <= 2, "slab grew: {}", f.flows.len());
    }

    #[test]
    fn incremental_skips_untouched_components() {
        // Two flows on truly disjoint paths (distinct NVLink P2P pairs —
        // H2D paths from one socket always share the DRAM-read link):
        // starting the second must not re-solve the first's component.
        let t = topo();
        let mut f = Fabric::new(&t);
        f.start_flow(Time::ZERO, &t.p2p(GpuId(0), GpuId(1)), 1 << 30, Time::ZERO, 1);
        f.poll(Time::ZERO);
        let after_first = f.stats();
        f.start_flow(Time::ZERO, &t.p2p(GpuId(2), GpuId(3)), 1 << 30, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let after_second = f.stats();
        assert_eq!(after_second.full_solves, 0);
        // The second activation solved exactly one component of one flow.
        assert_eq!(
            after_second.flows_solved - after_first.flows_solved,
            1,
            "disjoint activation re-solved a foreign component: {after_second:?}"
        );
    }

    #[test]
    fn reference_mode_full_solves_every_event() {
        let t = topo();
        let mut f = Fabric::new(&t).with_incremental(false);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), 1 << 20, Time::ZERO, 1);
        run_to_completion(&mut f, Time::ZERO);
        let s = f.stats();
        assert_eq!(s.full_solves, s.recomputes);
        assert!(s.recomputes >= 2, "{s:?}"); // activation + completion
    }

    /// Drive two fabrics through an identical random churn of starts,
    /// cancels and polls, asserting lock-step equality of completions and
    /// rates; also pin the incremental fabric's live rates to the oracle.
    #[test]
    fn property_incremental_churn_matches_reference_and_oracle() {
        testkit::check("fabric-incremental-churn", |rng| {
            let t = topo();
            let mut inc = Fabric::new(&t); // incremental (default)
            let mut full = Fabric::new(&t).with_incremental(false);
            let mut now = Time::ZERO;
            let mut live: Vec<FlowId> = Vec::new();
            let mut tag: FlowTag = 0;
            let steps = rng.range_usize(10, 40);
            for _ in 0..steps {
                let start = live.len() < 2 || rng.bool(0.65);
                if start {
                    let path = match rng.range_usize(0, 3) {
                        0 => t.h2d_direct(NumaId(0), GpuId(rng.range_usize(0, 8) as u8)),
                        1 => t.h2d_direct(NumaId(1), GpuId(rng.range_usize(0, 8) as u8)),
                        _ => {
                            let a = rng.range_usize(0, 8) as u8;
                            let b = (a + 1 + rng.range_usize(0, 7) as u8) % 8;
                            t.p2p(GpuId(a), GpuId(b))
                        }
                    };
                    let bytes = rng.range_u64(100_000, 200_000_000);
                    let latency = Time::from_ns(rng.range_u64(0, 20_000));
                    let weight = *rng.choose(&[0.5, 1.0, 4.0, 8.0]);
                    let cap = if rng.bool(0.2) { 10e9 } else { f64::INFINITY };
                    tag += 1;
                    let a = inc.start_flow_qos(now, &path, bytes, latency, tag, weight, cap);
                    let b = full.start_flow_qos(now, &path, bytes, latency, tag, weight, cap);
                    assert_eq!(a, b, "slot allocation diverged");
                    live.push(a);
                } else {
                    let k = rng.range_usize(0, live.len());
                    let id = live.swap_remove(k);
                    inc.cancel(now, id);
                    full.cancel(now, id);
                }
                now = now + Time::from_ns(rng.range_u64(1, 4_000_000));
                let da = inc.poll(now);
                let db = full.poll(now);
                assert_eq!(da.len(), db.len(), "completion count diverged");
                for (x, y) in da.iter().zip(&db) {
                    assert_eq!(x.id, y.id);
                    assert_eq!(x.tag, y.tag);
                    assert_eq!(x.finished, y.finished, "completion time diverged");
                    live.retain(|&f| f != x.id);
                }
                // Lock-step rates, bit for bit.
                for s in 0..inc.flows.len() {
                    let id = FlowId(s as u32);
                    assert_eq!(
                        inc.flow_rate(id).to_bits(),
                        full.flow_rate(id).to_bits(),
                        "rate diverged on slot {s}"
                    );
                }
                assert_eq!(inc.next_event_time(), full.next_event_time());
                // Oracle: the incremental fabric's live rates equal a fresh
                // full water-fill over the same active set, bit for bit.
                let mut slots: Vec<u32> = inc.active.clone();
                slots.sort_unstable();
                let paths: Vec<&[LinkId]> = slots
                    .iter()
                    .map(|&s| inc.paths.get(inc.flows[s as usize].path))
                    .collect();
                let w: Vec<f64> = slots.iter().map(|&s| inc.flows[s as usize].weight).collect();
                let c: Vec<f64> = slots.iter().map(|&s| inc.flows[s as usize].cap).collect();
                let oracle = max_min_rates_weighted(&inc.capacity, &paths, &w, &c);
                for (k, &s) in slots.iter().enumerate() {
                    assert_eq!(
                        inc.flows[s as usize].rate.to_bits(),
                        oracle[k].to_bits(),
                        "incremental rate for slot {s} diverged from oracle"
                    );
                }
            }
            // The whole point: the incremental path never full-solves.
            assert_eq!(inc.stats().full_solves, 0);
            assert_eq!(full.stats().full_solves, full.stats().recomputes);
            assert!(
                inc.stats().flows_solved <= full.stats().flows_solved,
                "incremental did more allocator work than the reference: {:?} vs {:?}",
                inc.stats(),
                full.stats()
            );
        });
    }

    #[test]
    fn path_table_interns_dedup_and_roundtrip() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let p0 = t.h2d_direct(NumaId(0), GpuId(0));
        let p1 = t.h2d_direct(NumaId(0), GpuId(1));
        let a = f.intern_path(&p0);
        let b = f.intern_path(&p1);
        let c = f.intern_path(&p0);
        assert_eq!(a, c, "re-interning an identical path minted a new id");
        assert_ne!(a, b);
        assert_eq!(f.path_links(a), &p0[..]);
        assert_eq!(f.path_links(b), &p1[..]);
        let before = f.start_alloc_growth();
        f.intern_path(&p0);
        f.intern_path(&p1);
        assert_eq!(f.start_alloc_growth(), before, "intern hit allocated");
    }

    #[test]
    fn steady_state_flow_starts_do_not_allocate() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let routes = [
            t.h2d_direct(NumaId(0), GpuId(0)),
            t.h2d_direct(NumaId(0), GpuId(1)),
        ];
        let pids: Vec<PathId> = routes.iter().map(|p| f.intern_path(p)).collect();
        // Warm-up: size the slab, index sets, link lists and due heaps.
        for round in 0..32u64 {
            let now = f.last_advance;
            for (k, &pid) in pids.iter().enumerate() {
                let tag = round * 2 + k as u64;
                f.start_flow_path(now, pid, 1_000_000, Time::ZERO, tag, 1.0, f64::INFINITY);
            }
            run_to_completion(&mut f, now);
        }
        let base = f.start_alloc_growth();
        for round in 0..256u64 {
            let now = f.last_advance;
            for (k, &pid) in pids.iter().enumerate() {
                let tag = round * 2 + k as u64;
                f.start_flow_path(now, pid, 1_000_000, Time::ZERO, tag, 1.0, f64::INFINITY);
            }
            run_to_completion(&mut f, now);
        }
        assert_eq!(
            f.start_alloc_growth(),
            base,
            "steady-state flow starts grew a fabric container"
        );
    }

    /// A chunked transfer's completion → same-instant replacement cascade
    /// must settle under one solve per boundary (solves-per-event < 1,
    /// the BENCH_0009 acceptance bar) and render byte-identically to
    /// eager per-event solving.
    #[test]
    fn chunked_cascades_coalesce_and_match_eager() {
        fn drive(f: &mut Fabric, path: &[LinkId], chunks: u64) -> Vec<(FlowTag, Time)> {
            let mut out = Vec::new();
            let mut started = 1u64;
            let mut now = Time::ZERO;
            f.start_flow(now, path, 5_000_000, Time::ZERO, 1);
            loop {
                for d in f.poll(now) {
                    out.push((d.tag, d.finished));
                    if d.tag != 999 && started < chunks {
                        started += 1;
                        // Zero-latency replacement at the completion
                        // instant: the cascade an engine generates at
                        // every chunk boundary.
                        f.start_flow(now, path, 5_000_000, Time::ZERO, started);
                    }
                }
                match f.next_event_time() {
                    Some(t) => now = now.max(t),
                    None => break,
                }
            }
            out
        }
        let t = topo();
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let sibling = t.h2d_direct(NumaId(0), GpuId(1));
        let mut coal = Fabric::new(&t);
        let mut eager = Fabric::new(&t).with_coalesce(false);
        assert!(coal.is_coalescing() && !eager.is_coalescing());
        for f in [&mut coal, &mut eager] {
            // A long-lived contender on the shared switch uplink, so every
            // chunk boundary re-solves a shared component (and its rate is
            // disturbed and restored within the boundary instant).
            f.start_flow(Time::ZERO, &sibling, 1 << 30, Time::ZERO, 999);
        }
        let chunks = 48u64;
        let a = drive(&mut coal, &path, chunks);
        let b = drive(&mut eager, &path, chunks);
        assert_eq!(a, b, "coalesced and eager completion streams diverged");
        assert_eq!(a.len() as u64, chunks + 1);
        let (sc, se) = (coal.stats(), eager.stats());
        assert!(sc.cascade_events > 0, "no cascade was folded: {sc:?}");
        assert_eq!(se.deferred_solves, 0);
        assert!(
            sc.recomputes < se.recomputes,
            "coalescing saved no solves: {sc:?} vs {se:?}"
        );
        // Every flow contributes one activation and one completion event.
        let events = 2 * (chunks + 1);
        assert!(
            sc.recomputes < events,
            "solves-per-event not < 1 under chunked churn: {sc:?}"
        );
    }

    /// The coalescing analogue of the incremental churn property, run for
    /// both allocator modes (all four `incremental × coalesce` legs):
    /// deferred same-instant batch solving must reproduce eager
    /// per-event solving's completion stream and rate bits exactly.
    #[test]
    fn property_coalesced_vs_eager_byte_identical_all_legs() {
        for &incremental in &[true, false] {
            let name = if incremental {
                "fabric-coalesce-churn-incremental"
            } else {
                "fabric-coalesce-churn-reference"
            };
            testkit::check(name, |rng| {
                let t = topo();
                let mut coal = Fabric::new(&t).with_incremental(incremental);
                let mut eager = Fabric::new(&t)
                    .with_incremental(incremental)
                    .with_coalesce(false);
                let mut now = Time::ZERO;
                let mut live: Vec<FlowId> = Vec::new();
                let mut tag: FlowTag = 0;
                let steps = rng.range_usize(10, 40);
                for _ in 0..steps {
                    // Several same-instant operations per step: zero-latency
                    // starts and cancels are what build solve cascades.
                    let ops = rng.range_usize(1, 4);
                    for _ in 0..ops {
                        let start = live.len() < 2 || rng.bool(0.6);
                        if start {
                            let path = match rng.range_usize(0, 3) {
                                0 => t.h2d_direct(NumaId(0), GpuId(rng.range_usize(0, 8) as u8)),
                                1 => t.h2d_direct(NumaId(1), GpuId(rng.range_usize(0, 8) as u8)),
                                _ => {
                                    let a = rng.range_usize(0, 8) as u8;
                                    let b = (a + 1 + rng.range_usize(0, 7) as u8) % 8;
                                    t.p2p(GpuId(a), GpuId(b))
                                }
                            };
                            let bytes = rng.range_u64(100_000, 200_000_000);
                            let latency = if rng.bool(0.5) {
                                Time::ZERO
                            } else {
                                Time::from_ns(rng.range_u64(1, 20_000))
                            };
                            let weight = *rng.choose(&[0.5, 1.0, 4.0, 8.0]);
                            let cap = if rng.bool(0.2) { 10e9 } else { f64::INFINITY };
                            tag += 1;
                            let a = coal.start_flow_qos(now, &path, bytes, latency, tag, weight, cap);
                            let b = eager.start_flow_qos(now, &path, bytes, latency, tag, weight, cap);
                            assert_eq!(a, b, "slot allocation diverged");
                            live.push(a);
                        } else {
                            let k = rng.range_usize(0, live.len());
                            let id = live.swap_remove(k);
                            coal.cancel(now, id);
                            eager.cancel(now, id);
                        }
                    }
                    // Poll at the mutation instant first (harvesting any
                    // zero-latency activations as a cascade batch), then
                    // again after time advances.
                    for _ in 0..2 {
                        let da = coal.poll(now);
                        let db = eager.poll(now);
                        assert_eq!(da.len(), db.len(), "completion count diverged");
                        for (x, y) in da.iter().zip(&db) {
                            assert_eq!(
                                (x.id, x.tag, x.finished),
                                (y.id, y.tag, y.finished),
                                "completion diverged"
                            );
                            live.retain(|&f| f != x.id);
                        }
                        now = now + Time::from_ns(rng.range_u64(1, 4_000_000));
                    }
                    // Lock-step rates, bit for bit (flow_rate settles any
                    // deferred batch first).
                    for s in 0..coal.flows.len() {
                        let id = FlowId(s as u32);
                        assert_eq!(
                            coal.flow_rate(id).to_bits(),
                            eager.flow_rate(id).to_bits(),
                            "rate diverged on slot {s}"
                        );
                    }
                    assert_eq!(coal.next_event_time(), eager.next_event_time());
                }
                assert!(coal.stats().deferred_solves > 0, "nothing was deferred");
                assert_eq!(eager.stats().deferred_solves, 0);
                assert!(
                    coal.stats().recomputes <= eager.stats().recomputes,
                    "coalescing did extra solves: {:?} vs {:?}",
                    coal.stats(),
                    eager.stats()
                );
            });
        }
    }

    /// The heap harvests must equal the retired scans at *arbitrary*
    /// horizons — not just at poll instants, where `poll_into` already
    /// debug-asserts them on every step.
    #[test]
    fn property_due_heaps_match_scan_oracles() {
        testkit::check("fabric-heap-vs-scan", |rng| {
            let t = topo();
            let mut f = Fabric::new(&t);
            let mut now = Time::ZERO;
            let mut live: Vec<FlowId> = Vec::new();
            let mut tag: FlowTag = 0;
            for _ in 0..rng.range_usize(10, 30) {
                if live.len() < 2 || rng.bool(0.7) {
                    tag += 1;
                    let g = GpuId(rng.range_usize(0, 8) as u8);
                    let path = t.h2d_direct(NumaId(0), g);
                    let bytes = rng.range_u64(100_000, 50_000_000);
                    let lat = Time::from_ns(rng.range_u64(0, 30_000));
                    live.push(f.start_flow(now, &path, bytes, lat, tag));
                } else {
                    let k = rng.range_usize(0, live.len());
                    f.cancel(now, live.swap_remove(k));
                }
                now = now + Time::from_ns(rng.range_u64(1, 2_000_000));
                for d in f.poll(now) {
                    live.retain(|&x| x != d.id);
                }
                f.settle();
                let horizon = now + Time::from_ns(rng.range_u64(0, 3_000_000));
                assert_eq!(
                    f.heap_due_snapshot(horizon, false),
                    f.scan_due_pending(horizon),
                    "pending heap diverged from the scan at a future horizon"
                );
                assert_eq!(
                    f.heap_due_snapshot(horizon, true),
                    f.scan_due_active(horizon),
                    "done heap diverged from the scan at a future horizon"
                );
            }
        });
    }

    #[test]
    fn toggling_coalesce_off_settles_the_pending_batch() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        f.start_flow(Time::ZERO, &path, 1 << 20, Time::ZERO, 1);
        f.poll(Time::ZERO); // activation batch stays deferred
        assert!(f.stats().deferred_solves > 0);
        f.set_coalesce(false);
        assert!(!f.is_coalescing());
        assert!(f.stats().recomputes >= 1, "toggle did not settle the batch");
        assert!(f.flow_rate(FlowId(0)) > 0.0);
        let done = run_to_completion(&mut f, Time::ZERO);
        assert_eq!(done.len(), 1);
    }
}
