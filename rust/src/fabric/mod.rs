//! Flow-level interconnect bandwidth simulator.
//!
//! Each in-flight DMA operation is a *flow* across a path of directional
//! links (see [`crate::topology`]). Concurrent flows share every link
//! **max-min fairly** — the fluid-model analogue of PCIe's credit-based
//! flow control and the NVSwitch's per-port arbitration, which the paper
//! leans on ("PCIe's internal flow control arbitrates bandwidth between
//! co-running traffic sources", §5.1.2).
//!
//! A flow has a fixed latency phase (DMA setup: the flow consumes no
//! bandwidth) followed by a transfer phase at the fair-share rate. The
//! fabric is advanced lazily: callers `poll(now)` to collect completions
//! and `next_event_time()` to know when the state next changes.

mod maxmin;

pub use maxmin::{max_min_rates, max_min_rates_weighted};

use crate::sim::Time;
use crate::topology::{LinkId, Topology};
use std::collections::HashMap;

/// Handle to an in-flight flow.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FlowId(pub u32);

/// Opaque tag the caller attaches to a flow to route its completion.
pub type FlowTag = u64;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// DMA setup: becomes active at the stored time.
    Pending { active_at: Time },
    /// Transferring at `rate` since `since`.
    Active,
    /// Finished (slot free after harvest).
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    path: Vec<LinkId>,
    remaining: f64, // bytes
    total: u64,     // original payload size
    rate: f64,      // bytes/sec, valid while Active
    /// QoS share weight: rate allocation is weighted max-min (1.0 = the
    /// classic unweighted share).
    weight: f64,
    /// Absolute rate ceiling (QoS bulk throttle); `INFINITY` = uncapped.
    cap: f64,
    phase: Phase,
    tag: FlowTag,
    started: Time,
    live: bool,
}

/// Cumulative per-flow accounting returned on completion.
#[derive(Debug, Clone, Copy)]
pub struct FlowDone {
    /// The completed flow.
    pub id: FlowId,
    /// Caller's tag.
    pub tag: FlowTag,
    /// Total payload bytes the flow carried.
    pub bytes: u64,
    /// When the flow was started (including setup latency).
    pub started: Time,
    /// When it finished.
    pub finished: Time,
}

/// The fabric simulator.
pub struct Fabric {
    capacity: Vec<f64>,
    flows: Vec<Flow>,
    free: Vec<u32>,
    /// Active flow ids per link (dense, rebuilt incrementally).
    link_flows: Vec<Vec<u32>>,
    last_advance: Time,
    active_count: usize,
    /// Monotone counter of rate recomputations (perf introspection).
    pub recomputes: u64,
    /// Total bytes completed per tag-class is left to callers; the fabric
    /// tracks aggregate delivered bytes for utilization reports.
    pub delivered_bytes: f64,
}

impl Fabric {
    /// Build over a topology's links.
    pub fn new(topo: &Topology) -> Fabric {
        Fabric {
            capacity: topo.links.iter().map(|l| l.capacity_bps).collect(),
            flows: Vec::new(),
            free: Vec::new(),
            link_flows: vec![Vec::new(); topo.links.len()],
            last_advance: Time::ZERO,
            active_count: 0,
            recomputes: 0,
            delivered_bytes: 0.0,
        }
    }

    /// Number of currently live (pending or active) flows.
    pub fn live_flows(&self) -> usize {
        self.flows.iter().filter(|f| f.live).count()
    }

    /// Start a flow of `bytes` over `path` with a setup `latency` before it
    /// occupies any bandwidth, at the default QoS parameters (weight 1,
    /// uncapped). Returns its id. Call `poll(now)` afterwards (mutations
    /// are lazy).
    pub fn start_flow(
        &mut self,
        now: Time,
        path: &[LinkId],
        bytes: u64,
        latency: Time,
        tag: FlowTag,
    ) -> FlowId {
        self.start_flow_qos(now, path, bytes, latency, tag, 1.0, f64::INFINITY)
    }

    /// Start a flow carrying explicit QoS parameters: `weight` is its
    /// weighted max-min share weight (> 0), `cap` an absolute rate ceiling
    /// in bytes/sec (`f64::INFINITY` = uncapped). With every live flow at
    /// weight 1 / uncapped, allocation is identical to classic unweighted
    /// max-min.
    #[allow(clippy::too_many_arguments)]
    pub fn start_flow_qos(
        &mut self,
        now: Time,
        path: &[LinkId],
        bytes: u64,
        latency: Time,
        tag: FlowTag,
        weight: f64,
        cap: f64,
    ) -> FlowId {
        debug_assert!(!path.is_empty());
        debug_assert!(weight > 0.0 && weight.is_finite(), "flow weight {weight}");
        debug_assert!(cap > 0.0, "flow cap {cap}");
        self.advance_to(now);
        let flow = Flow {
            path: path.to_vec(),
            remaining: bytes.max(1) as f64,
            total: bytes.max(1),
            rate: 0.0,
            weight,
            cap,
            phase: Phase::Pending {
                active_at: now + latency,
            },
            tag,
            started: now,
            live: true,
        };
        let id = match self.free.pop() {
            Some(i) => {
                self.flows[i as usize] = flow;
                i
            }
            None => {
                self.flows.push(flow);
                (self.flows.len() - 1) as u32
            }
        };
        FlowId(id)
    }

    /// Cancel a live flow (used by failure-injection tests).
    pub fn cancel(&mut self, now: Time, id: FlowId) {
        self.advance_to(now);
        let f = &mut self.flows[id.0 as usize];
        if !f.live {
            return;
        }
        let was_active = f.phase == Phase::Active;
        // Mark dead *before* recomputing, or the rate allocation would
        // still count the cancelled flow.
        f.live = false;
        f.phase = Phase::Done;
        if was_active {
            self.detach(id.0);
            self.recompute();
        }
        self.free.push(id.0);
    }

    /// Advance to `now`, activating due pending flows and harvesting
    /// completions. Returns completion records in deterministic order.
    pub fn poll(&mut self, now: Time) -> Vec<FlowDone> {
        let mut done = Vec::new();
        // Process piecewise: there may be several internal events (an
        // activation changes rates, which changes completion times) between
        // last_advance and now.
        loop {
            let next = self.next_internal_event();
            let step_to = match next {
                Some(t) if t <= now => t,
                _ => now,
            };
            self.advance_to(step_to);
            let mut changed = false;
            // Activations due.
            for i in 0..self.flows.len() {
                let f = &mut self.flows[i];
                if f.live {
                    if let Phase::Pending { active_at } = f.phase {
                        if active_at <= step_to {
                            f.phase = Phase::Active;
                            for &l in &self.flows[i].path.clone() {
                                self.link_flows[l.0 as usize].push(i as u32);
                            }
                            self.active_count += 1;
                            changed = true;
                        }
                    }
                }
            }
            // Completions due (remaining hit zero during advance).
            for i in 0..self.flows.len() {
                let f = &self.flows[i];
                if f.live && f.phase == Phase::Active && f.remaining <= 0.25 {
                    let rec = FlowDone {
                        id: FlowId(i as u32),
                        tag: f.tag,
                        bytes: f.total,
                        started: f.started,
                        finished: step_to,
                    };
                    self.detach(i as u32);
                    let f = &mut self.flows[i];
                    f.live = false;
                    f.phase = Phase::Done;
                    self.free.push(i as u32);
                    done.push(rec);
                    changed = true;
                }
            }
            if changed {
                self.recompute();
            }
            if step_to >= now {
                break;
            }
        }
        done
    }

    /// Earliest future time at which fabric state changes (activation or
    /// completion), or `None` if fully idle.
    pub fn next_event_time(&self) -> Option<Time> {
        self.next_internal_event()
    }

    /// Instantaneous rate of a live flow (bytes/sec; 0 while pending).
    pub fn flow_rate(&self, id: FlowId) -> f64 {
        let f = &self.flows[id.0 as usize];
        if f.live && f.phase == Phase::Active {
            f.rate
        } else {
            0.0
        }
    }

    /// Instantaneous utilization of a link: sum of active flow rates (B/s).
    pub fn link_rate(&self, link: LinkId) -> f64 {
        self.link_flows[link.0 as usize]
            .iter()
            .map(|&i| self.flows[i as usize].rate)
            .sum()
    }

    /// Sum of instantaneous rates of all live flows whose tag satisfies the
    /// predicate — the figure harnesses use this to plot per-class
    /// bandwidth over time (Fig 9).
    pub fn class_rate(&self, pred: impl Fn(FlowTag) -> bool) -> f64 {
        self.flows
            .iter()
            .filter(|f| f.live && f.phase == Phase::Active && pred(f.tag))
            .map(|f| f.rate)
            .sum()
    }

    // ----- internals -------------------------------------------------

    fn next_internal_event(&self) -> Option<Time> {
        let mut best: Option<Time> = None;
        for f in &self.flows {
            if !f.live {
                continue;
            }
            let t = match f.phase {
                Phase::Pending { active_at } => active_at,
                Phase::Active => {
                    if f.rate <= 0.0 {
                        continue; // starved; completes only after others free capacity
                    }
                    // Ceil to a whole nanosecond and always make progress:
                    // a sub-ns rounding to zero would stall the poll loop.
                    let ns = (f.remaining / f.rate * 1e9).ceil().max(1.0) as u64;
                    self.last_advance + Time(ns)
                }
                Phase::Done => continue,
            };
            best = Some(match best {
                None => t,
                Some(b) => b.min(t),
            });
        }
        best
    }

    fn advance_to(&mut self, now: Time) {
        if now <= self.last_advance {
            return;
        }
        let dt = (now - self.last_advance).as_secs_f64();
        for f in &mut self.flows {
            if f.live && f.phase == Phase::Active && f.rate > 0.0 {
                let moved = f.rate * dt;
                let used = moved.min(f.remaining);
                f.remaining -= used;
                self.delivered_bytes += used;
                if f.remaining < 0.25 {
                    f.remaining = 0.0;
                }
            }
        }
        self.last_advance = now;
    }

    fn detach(&mut self, idx: u32) {
        for &l in &self.flows[idx as usize].path.clone() {
            let v = &mut self.link_flows[l.0 as usize];
            if let Some(p) = v.iter().position(|&x| x == idx) {
                v.swap_remove(p);
            }
        }
        self.active_count -= 1;
    }

    fn recompute(&mut self) {
        self.recomputes += 1;
        let mut actives: Vec<u32> = Vec::with_capacity(self.active_count);
        for (i, f) in self.flows.iter().enumerate() {
            if f.live && f.phase == Phase::Active {
                actives.push(i as u32);
            }
        }
        let paths: Vec<&[LinkId]> = actives
            .iter()
            .map(|&i| self.flows[i as usize].path.as_slice())
            .collect();
        let weights: Vec<f64> = actives
            .iter()
            .map(|&i| self.flows[i as usize].weight)
            .collect();
        let caps: Vec<f64> = actives
            .iter()
            .map(|&i| self.flows[i as usize].cap)
            .collect();
        let rates = max_min_rates_weighted(&self.capacity, &paths, &weights, &caps);
        for (k, &i) in actives.iter().enumerate() {
            self.flows[i as usize].rate = rates[k];
        }
    }
}

/// Convenience: run a closed set of flows to completion and return each
/// flow's completion time. Used heavily in tests.
pub fn run_to_completion(fabric: &mut Fabric, mut now: Time) -> HashMap<FlowTag, Time> {
    let mut out = HashMap::new();
    loop {
        for d in fabric.poll(now) {
            out.insert(d.tag, d.finished);
        }
        match fabric.next_event_time() {
            Some(t) => now = t,
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{h20x8, Direction, GpuId, NumaId};

    fn topo() -> Topology {
        h20x8()
    }

    #[test]
    fn single_flow_runs_at_link_capacity() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let bytes = 1_000_000_000u64; // 1 GB
        f.start_flow(Time::ZERO, &path, bytes, Time::from_us(10), 1);
        let done = run_to_completion(&mut f, Time::ZERO);
        let finish = done[&1];
        let expect = 10e-6 + bytes as f64 / t.pcie_capacity(GpuId(0), Direction::H2D);
        let got = finish.as_secs_f64();
        assert!(
            (got - expect).abs() / expect < 1e-6,
            "got {got}, expect {expect}"
        );
    }

    #[test]
    fn two_flows_share_one_link_fairly() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let b = 1_000_000_000u64;
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        assert!((f.flow_rate(FlowId(0)) - cap / 2.0).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap / 2.0).abs() < 1.0);
        let done = run_to_completion(&mut f, Time::ZERO);
        // Both finish together at 2x the solo time.
        let solo = b as f64 / cap;
        assert!((done[&1].as_secs_f64() - 2.0 * solo).abs() / solo < 1e-6);
        assert!((done[&2].as_secs_f64() - 2.0 * solo).abs() / solo < 1e-6);
    }

    #[test]
    fn disjoint_paths_do_not_interfere() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let b = 500_000_000u64;
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(2)), b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        // GPUs 0 and 2 are behind different switches; only DRAM is shared
        // (380 GB/s >> 2x53.6) so both run at full lane rate.
        assert!((f.flow_rate(FlowId(0)) - cap).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap).abs() < 1.0);
    }

    #[test]
    fn switch_uplink_contends_two_gpus() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let b = 1_000_000_000u64;
        // GPUs 0 and 1 share switch 0 (uplink 100 GB/s < 2x53.6).
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), b, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(1)), b, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let r0 = f.flow_rate(FlowId(0));
        let r1 = f.flow_rate(FlowId(1));
        assert!((r0 - 50e9).abs() < 1e6, "r0={r0}");
        assert!((r1 - 50e9).abs() < 1e6, "r1={r1}");
    }

    #[test]
    fn early_completion_releases_bandwidth() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        // Flow 1 is half the size of flow 2; once it finishes, flow 2
        // should speed up to full capacity.
        f.start_flow(Time::ZERO, &path, 500_000_000, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 2);
        let done = run_to_completion(&mut f, Time::ZERO);
        // flow1: 0.5GB at cap/2 -> 1/ cap *1e9 secs. flow2: 0.5GB at cap/2 + 0.5GB at cap.
        let t1 = 0.5e9 / (cap / 2.0);
        let t2 = t1 + 0.5e9 / cap;
        assert!((done[&1].as_secs_f64() - t1).abs() / t1 < 1e-6);
        assert!((done[&2].as_secs_f64() - t2).abs() / t2 < 1e-6);
    }

    #[test]
    fn pending_latency_consumes_no_bandwidth() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        f.start_flow(Time::ZERO, &path, 1_000_000, Time::from_us(100), 1);
        f.poll(Time::from_us(50));
        assert_eq!(f.flow_rate(FlowId(0)), 0.0);
        assert_eq!(f.link_rate(path[0]), 0.0);
        f.poll(Time::from_us(101));
        assert!(f.flow_rate(FlowId(0)) > 0.0);
    }

    #[test]
    fn cancel_frees_capacity() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let a = f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 1);
        f.start_flow(Time::ZERO, &path, 1_000_000_000, Time::ZERO, 2);
        f.poll(Time::ZERO);
        let half = f.flow_rate(FlowId(1));
        f.cancel(Time::from_ms(1), a);
        f.poll(Time::from_ms(1));
        assert!(f.flow_rate(FlowId(1)) > 1.9 * half);
    }

    #[test]
    fn class_rate_sums_by_tag() {
        let t = topo();
        let mut f = Fabric::new(&t);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(0)), 1 << 30, Time::ZERO, 10);
        f.start_flow(Time::ZERO, &t.h2d_direct(NumaId(0), GpuId(2)), 1 << 30, Time::ZERO, 20);
        f.poll(Time::ZERO);
        let all = f.class_rate(|_| true);
        let tens = f.class_rate(|t| t == 10);
        assert!(tens > 0.0 && tens < all);
    }

    #[test]
    fn p2p_alone_matches_table2() {
        // Table 2: P2P_alone = 367.60 GB/s.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.p2p(GpuId(0), GpuId(1));
        let b = 4u64 << 30;
        f.start_flow(Time::ZERO, &path, b, Time::ZERO, 1);
        let done = run_to_completion(&mut f, Time::ZERO);
        let bw = b as f64 / done[&1].as_secs_f64() / 1e9;
        assert!((bw - 368.0).abs() < 2.0, "p2p alone bw {bw}");
    }

    #[test]
    fn weighted_flows_split_a_shared_lane_by_weight() {
        // The QoS regression anchor: a Bulk wake (weight 1) co-running with
        // a LatencyCritical fetch (weight 8) on one PCIe lane leaves the
        // fetch ≥ its 8/9 weighted share while both are live.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 1, 8.0, f64::INFINITY);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 2, 1.0, f64::INFINITY);
        f.poll(Time::ZERO);
        let crit = f.flow_rate(FlowId(0));
        let bulk = f.flow_rate(FlowId(1));
        assert!((crit - cap * 8.0 / 9.0).abs() < 1.0, "critical {crit}");
        assert!((bulk - cap / 9.0).abs() < 1.0, "bulk {bulk}");
        // Weighting redistributes, never destroys, bandwidth.
        assert!((crit + bulk - cap).abs() < 1.0);
    }

    #[test]
    fn capped_flow_leaves_headroom_even_alone() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap_bps = 10e9;
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 1, 1.0, cap_bps);
        f.poll(Time::ZERO);
        let r = f.flow_rate(FlowId(0));
        assert!((r - cap_bps).abs() < 1.0, "capped solo rate {r}");
        let done = run_to_completion(&mut f, Time::ZERO);
        let want = (1u64 << 30) as f64 / cap_bps;
        let got = done[&1].as_secs_f64();
        assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn unit_weight_flows_match_legacy_fair_sharing() {
        // start_flow (no QoS parameters) must behave exactly as before the
        // weighted refactor: equal split on a shared lane.
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        let cap = t.pcie_capacity(GpuId(0), Direction::H2D);
        f.start_flow(Time::ZERO, &path, 1 << 30, Time::ZERO, 1);
        f.start_flow_qos(Time::ZERO, &path, 1 << 30, Time::ZERO, 2, 1.0, f64::INFINITY);
        f.poll(Time::ZERO);
        assert!((f.flow_rate(FlowId(0)) - cap / 2.0).abs() < 1.0);
        assert!((f.flow_rate(FlowId(1)) - cap / 2.0).abs() < 1.0);
    }

    #[test]
    fn reuses_freed_slots() {
        let t = topo();
        let mut f = Fabric::new(&t);
        let path = t.h2d_direct(NumaId(0), GpuId(0));
        for _ in 0..100 {
            let now = f.last_advance;
            f.start_flow(now, &path, 1_000_000, Time::ZERO, 7);
            run_to_completion(&mut f, now);
        }
        assert!(f.flows.len() <= 2, "slab grew: {}", f.flows.len());
    }
}
