//! The hotpath performance harness behind `mma bench hotpath` and
//! `rust/benches/hotpath.rs` — the producer of the repo's perf
//! trajectory (`BENCH_*.json` files at the repo root).
//!
//! Three legs, matching the hot paths the simulator spends its time in:
//!
//! 1. **Event queue churn** — pop + reschedule cycles per second on the
//!    hierarchical timer wheel ([`crate::sim::EventQueue`]) vs the
//!    retired `BinaryHeap` ([`crate::sim::HeapEventQueue`]); the wheel's
//!    speedup stays a measured number, not a claim.
//! 2. **Fabric flow cycle** — flow activation + completion events per
//!    second through the max-min fabric.
//! 3. **Workload replay** — wall-clock for a trace replayed through the
//!    full serving fleet, extrapolated to seconds-per-1M-requests, run
//!    with both the incremental allocator and the reference full
//!    re-solve. The harness asserts the two renders byte-identically
//!    (the tentpole's determinism constraint) and reports each side's
//!    [`FabricStats`] so the incremental path's work reduction is
//!    visible in the JSON.
//!
//! [`HotpathReport::to_json`] emits the stable `mma-bench-hotpath/1`
//! schema documented in `docs/PERF.md`; `tools/check_bench.py` validates
//! it in CI against the committed `BENCH_0006_hotpath.json` baseline.
//!
//! Two sibling benches share the harness: the `BENCH_0007` engine cycle
//! ([`run_engine_bench`], `mma-bench-engine/1`) and the `BENCH_0008`
//! serving cycle ([`run_serving_bench`], `mma-bench-serving/1`) — LRU
//! prefix-tier churn, streaming-histogram record rate, and the
//! bounded-window streamed replay path, each cross-checked against its
//! exact/materialized oracle in the same invocation. `BENCH_0009`
//! ([`run_fabric_bench`], `mma-bench-fabric/1`) measures the O(due)
//! fabric event loop under heavy chunked churn: events per second with
//! solve coalescing, the solves-per-event ratio (must stay below 1.0 —
//! cascades demonstrably collapse), the zero-flow-start-allocs
//! invariant on the interned-path fast path, and a coalesced-vs-eager
//! completion-stream identity check. `BENCH_0010`
//! ([`run_batching_bench`], `mma-bench-batching/1`) measures the
//! roofline-grounded continuous-batching step loop: fused steps per
//! second, the memory-wall invariant (decode step time strictly
//! increasing with aggregate batch KV bytes — must hold), and the
//! legacy-oracle identity flag (batch-1 + chunking-off batching renders
//! byte-identically to the per-request scheduler — must hold).

use crate::config::{BatchingConfig, ComputeSource, FleetConfig, ServingConfig};
use crate::fabric::{self, Fabric, FabricStats, FlowDone};
use crate::figures::workload_replay::{replay, replay_serving, replay_streamed, ReplayOptions};
use crate::gpusim::TransferId;
use crate::metrics::LogHistogram;
use crate::mma::{ActionSink, Engine, EngineAction, MmaConfig, TransferDesc};
use crate::models::qwen_7b_chat;
use crate::serving::{GpuPrefixTier, RoutePolicy};
use crate::sim::{EventQueue, HeapEventQueue, Time};
use crate::topology::{h20x8, Direction, GpuId, NumaId, Topology};
use crate::util::bench::black_box;
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, TenantSpec, Trace, TraceGen, TraceReader};
use std::collections::VecDeque;
use std::io::Cursor;
use std::time::{Duration, Instant};

/// Seed for the harness's synthetic workloads (fixed: the bench varies
/// only in wall-clock, never in simulated work).
const BENCH_SEED: u64 = 0xB006;

/// One replay leg: wall time + the allocator work it took.
#[derive(Debug, Clone, Copy)]
pub struct ReplayLeg {
    /// Wall-clock seconds for the replay call.
    pub wall_s: f64,
    /// Fabric allocator counters after the run.
    pub stats: FabricStats,
}

/// Everything `mma bench hotpath` measures.
#[derive(Debug, Clone)]
pub struct HotpathReport {
    /// Fast mode (smaller budgets/workloads; CI smoke).
    pub fast: bool,
    /// Timer-wheel pop+reschedule events per second.
    pub wheel_events_per_sec: f64,
    /// Same churn on the retired `BinaryHeap` queue.
    pub heap_events_per_sec: f64,
    /// Fabric flow events (activation + completion) per second.
    pub fabric_events_per_sec: f64,
    /// Requests in the replay leg's trace.
    pub replay_requests: usize,
    /// Whether the incremental and reference replays rendered
    /// byte-identically (must always be true).
    pub replay_deterministic: bool,
    /// Replay with the incremental (component) allocator — the default.
    pub incremental: ReplayLeg,
    /// Replay with the reference full re-solve allocator.
    pub reference: ReplayLeg,
}

/// Run the full harness. `fast` shrinks budgets and the replay trace for
/// CI smoke runs; numbers stay comparable only within a mode.
pub fn run_hotpath(fast: bool) -> HotpathReport {
    let budget = if fast {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    };
    let requests = if fast { 48 } else { 192 };
    run_hotpath_with(fast, budget, requests)
}

/// [`run_hotpath`] with explicit knobs (tests use tiny budgets).
pub fn run_hotpath_with(fast: bool, budget: Duration, requests: usize) -> HotpathReport {
    let wheel_events_per_sec = churn_wheel(budget);
    let heap_events_per_sec = churn_heap(budget);
    let fabric_events_per_sec = fabric_cycle(budget);

    let trace = replay_trace(requests);
    let (inc_report, incremental) = replay_leg(&trace, true);
    let (ref_report, reference) = replay_leg(&trace, false);
    let replay_deterministic = inc_report == ref_report;

    HotpathReport {
        fast,
        wheel_events_per_sec,
        heap_events_per_sec,
        fabric_events_per_sec,
        replay_requests: requests,
        replay_deterministic,
        incremental,
        reference,
    }
}

/// The engine-cycle leg of `BENCH_0007`: the MMA engine pipeline driven
/// directly (no fabric, synthetic 1 us flow times), so the number
/// isolates the engine's own per-event cost — split, policy pull,
/// dispatch, retire — on the allocation-free sink/slab path.
#[derive(Debug, Clone, Copy)]
pub struct EngineCycle {
    /// Chunks retired per wall-clock second through the full
    /// activate → wake → flow-done → retire cycle.
    pub chunks_per_sec: f64,
    /// Engine actions emitted during the measured window (post-warm-up).
    pub actions_total: u64,
    /// [`ActionSink`] buffer growths observed after the warm-up transfer.
    /// The zero-allocation acceptance bar: must be 0 — every steady-state
    /// event reuses the sink, the slab slots, and the inline paths.
    pub steady_state_allocs: u64,
    /// Actions emitted per sink growth over the whole run (warm-up
    /// included); higher means the one-time warm-up amortizes further.
    pub actions_per_alloc: f64,
}

/// Transfer size of one engine-cycle iteration (10 default chunks).
const ENGINE_XFER_BYTES: u64 = 50_000_000;

/// Run one transfer through the engine to quiescence with the reused
/// sink; returns chunks retired. Mirrors the engine's sink-based test
/// executor: the executor itself stays on the allocation-free path once
/// the `pending` ring is warm.
fn engine_transfer(
    e: &mut Engine,
    topo: &Topology,
    sink: &mut ActionSink,
    pending: &mut VecDeque<EngineAction>,
    tid: u32,
) -> u64 {
    sink.clear();
    let desc = TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), ENGINE_XFER_BYTES);
    e.activate_into(Time::ZERO, TransferId(tid), desc, topo, sink);
    pending.extend(sink.drain());
    let mut now = Time::ZERO;
    let mut retired = 0u64;
    while let Some(act) = pending.pop_front() {
        sink.clear();
        match act {
            EngineAction::StartFlow { key, .. } => {
                now = now + Time::from_us(1);
                e.on_flow_done_into(now, key, topo, sink);
            }
            EngineAction::RetireAt { gpu, key, at } => {
                now = now.max(at);
                retired += 1;
                e.on_retire_into(now, gpu, key, topo, sink);
            }
            EngineAction::WakeAt { gpu, at } => {
                now = now.max(at);
                e.on_wake_into(now, gpu, topo, sink);
            }
            EngineAction::TransferComplete { .. } => {}
        }
        pending.extend(sink.drain());
    }
    retired
}

/// Measure the engine cycle: one warm-up transfer sizes the sink, slabs,
/// and lane queues, then transfers loop under `budget` while the sink's
/// growth counter polices the zero-allocation bar.
pub fn engine_cycle(budget: Duration) -> EngineCycle {
    let topo = h20x8();
    let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), topo.gpu_count());
    let mut sink = ActionSink::new();
    let mut pending = VecDeque::new();
    engine_transfer(&mut e, &topo, &mut sink, &mut pending, 0);
    let warm_grows = sink.grows();
    let warm_pushed = sink.pushed();
    let t0 = Instant::now();
    let mut chunks = 0u64;
    let mut tid = 1u32;
    while t0.elapsed() < budget {
        chunks += engine_transfer(&mut e, &topo, &mut sink, &mut pending, tid);
        tid += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    EngineCycle {
        chunks_per_sec: chunks as f64 / wall.max(1e-9),
        actions_total: sink.pushed() - warm_pushed,
        steady_state_allocs: sink.grows() - warm_grows,
        actions_per_alloc: sink.pushed() as f64 / sink.grows().max(1) as f64,
    }
}

/// Everything the `BENCH_0007` engine bench measures: the engine cycle
/// plus the twin replay legs (the end-to-end view of the same event
/// path, incremental vs reference allocator).
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Fast mode (smaller budgets/workloads; CI smoke).
    pub fast: bool,
    /// The isolated engine-pipeline measurement.
    pub engine: EngineCycle,
    /// Requests in the replay legs' trace.
    pub replay_requests: usize,
    /// Whether the twin replays rendered byte-identically.
    pub replay_deterministic: bool,
    /// Replay with the incremental (component) allocator.
    pub incremental: ReplayLeg,
    /// Replay with the reference full re-solve allocator.
    pub reference: ReplayLeg,
}

/// Run the `BENCH_0007` engine bench (`mma bench hotpath --out-engine`).
pub fn run_engine_bench(fast: bool) -> EngineReport {
    let budget = if fast {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    };
    let requests = if fast { 48 } else { 192 };
    run_engine_bench_with(fast, budget, requests)
}

/// [`run_engine_bench`] with explicit knobs (tests use tiny budgets).
pub fn run_engine_bench_with(fast: bool, budget: Duration, requests: usize) -> EngineReport {
    let engine = engine_cycle(budget);
    let trace = replay_trace(requests);
    let (inc_report, incremental) = replay_leg(&trace, true);
    let (ref_report, reference) = replay_leg(&trace, false);
    EngineReport {
        fast,
        engine,
        replay_requests: requests,
        replay_deterministic: inc_report == ref_report,
        incremental,
        reference,
    }
}

/// The serving-cycle leg of `BENCH_0008`: the three serving-layer hot
/// paths this PR series made O(1)/O(window) — LRU prefix-tier churn,
/// the bounded-memory streaming histogram, and the streamed replay
/// ingestion path — each with its bar encoded in the report.
#[derive(Debug, Clone, Copy)]
pub struct ServingCycle {
    /// Prefix-tier operations (touch-or-insert under constant eviction
    /// pressure) per wall-clock second on the intrusive-LRU tier.
    pub lru_ops_per_sec: f64,
    /// Streaming-histogram samples recorded per wall-clock second.
    pub hist_records_per_sec: f64,
    /// Bins the histogram leg ran with (`[metrics] histogram_bins`).
    pub hist_bins: usize,
    /// Requests in the streamed replay leg's trace.
    pub requests: usize,
    /// Requests replayed per wall-clock second on the streamed path.
    pub requests_per_sec: f64,
    /// Peak ingestion bytes the streamed replay tracked (merge-window
    /// records + line buffer) — the O(window) memory claim, as a number.
    pub peak_tracked_bytes: u64,
    /// Whether the streamed and materialized replays rendered
    /// byte-identically (must always be true).
    pub streaming_identical: bool,
    /// Whether the streamed leg spilled to the materialized path (must
    /// be false: the generated trace is sorted within any window).
    pub spilled: bool,
}

/// Everything the `BENCH_0008` serving bench measures.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Fast mode (smaller budgets/workloads; CI smoke).
    pub fast: bool,
    /// The serving-cycle measurements.
    pub serving: ServingCycle,
}

/// Run the `BENCH_0008` serving bench (`mma bench hotpath --out-serving`).
pub fn run_serving_bench(fast: bool) -> ServingReport {
    run_serving_bench_bins(fast, crate::metrics::hist::DEFAULT_BINS)
}

/// [`run_serving_bench`] with the histogram sized per the resolved
/// `[metrics] histogram_bins` (the CLI passes the config value through).
pub fn run_serving_bench_bins(fast: bool, bins: usize) -> ServingReport {
    let budget = if fast {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    };
    let requests = if fast { 48 } else { 192 };
    run_serving_bench_with(fast, budget, requests, bins)
}

/// [`run_serving_bench`] with explicit knobs (tests use tiny budgets).
pub fn run_serving_bench_with(
    fast: bool,
    budget: Duration,
    requests: usize,
    bins: usize,
) -> ServingReport {
    let lru_ops_per_sec = lru_churn(budget);
    let hist_records_per_sec = hist_churn(budget, bins);
    let (requests_per_sec, peak_tracked_bytes, streaming_identical, spilled) =
        streamed_replay_leg(requests);
    ServingReport {
        fast,
        serving: ServingCycle {
            lru_ops_per_sec,
            hist_records_per_sec,
            hist_bins: bins.max(1),
            requests,
            requests_per_sec,
            peak_tracked_bytes,
            streaming_identical,
            spilled,
        },
    }
}

/// The fabric-event-loop leg of `BENCH_0009`: chunked churn through the
/// O(due) fabric, with every acceptance bar encoded in the report.
#[derive(Debug, Clone, Copy)]
pub struct FabricCycle {
    /// Fabric events (activations + completions) per wall-clock second
    /// on the coalesced churn scenario.
    pub events_per_sec: f64,
    /// Events in one deterministic churn run (`2 × lanes × chunks`).
    pub events_total: u64,
    /// Rate recomputes the coalesced run performed.
    pub solves: u64,
    /// `solves / events_total` — must stay below 1.0: coalescing folds
    /// every completion → same-instant replacement cascade into one
    /// solve.
    pub solves_per_event: f64,
    /// Recompute requests that were deferred instead of solved eagerly.
    pub deferred_solves: u64,
    /// Deferred requests that folded into an already-pending batch — the
    /// solves the cascade actually saved.
    pub cascade_events: u64,
    /// Fabric container growths after warm-up — **must be 0**: the
    /// interned-path flow-start path allocates nothing in steady state.
    pub alloc_growth: u64,
    /// Whether the coalesced and eager runs produced identical
    /// completion streams (tag and time, in order) — **must be true**.
    pub coalesced_identical: bool,
}

/// Everything the `BENCH_0009` fabric bench measures.
#[derive(Debug, Clone)]
pub struct FabricReport {
    /// Fast mode (smaller budgets/workloads; CI smoke).
    pub fast: bool,
    /// The fabric-event-loop measurements.
    pub fabric: FabricCycle,
}

/// Run the `BENCH_0009` fabric bench (`mma bench hotpath --out-fabric`).
pub fn run_fabric_bench(fast: bool) -> FabricReport {
    let budget = if fast {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    };
    let chunks = if fast { 32 } else { 128 };
    run_fabric_bench_with(fast, budget, chunks)
}

/// [`run_fabric_bench`] with explicit knobs (tests use tiny budgets).
pub fn run_fabric_bench_with(fast: bool, budget: Duration, chunks: u64) -> FabricReport {
    let lanes = 8;
    // Deterministic leg: the coalesced and eager twins must produce the
    // same completion stream, and the coalesced run's counters carry the
    // solves-per-event and zero-alloc acceptance bars.
    let coal = fabric_churn(true, lanes, chunks);
    let eager = fabric_churn(false, lanes, chunks);
    let coalesced_identical = coal.completions == eager.completions;
    let events_total = coal.events;
    let solves = coal.stats.recomputes;
    // Timed leg: repeat the coalesced churn within the budget.
    let t0 = Instant::now();
    let mut timed_events = 0u64;
    while t0.elapsed() < budget {
        let run = fabric_churn(true, lanes, chunks);
        timed_events += run.events;
        black_box(run.completions);
    }
    let events_per_sec = timed_events as f64 / t0.elapsed().as_secs_f64();
    FabricReport {
        fast,
        fabric: FabricCycle {
            events_per_sec,
            events_total,
            solves,
            solves_per_event: solves as f64 / events_total.max(1) as f64,
            deferred_solves: coal.stats.deferred_solves,
            cascade_events: coal.stats.cascade_events,
            alloc_growth: coal.alloc_growth,
            coalesced_identical,
        },
    }
}

/// The continuous-batching leg of `BENCH_0010`: one roofline-priced
/// probe cell plus the legacy-oracle identity check, with every
/// acceptance bar encoded in the report.
#[derive(Debug, Clone, Copy)]
pub struct BatchingCycle {
    /// Fused steps simulated per wall-clock second on the probe cell.
    pub steps_per_sec: f64,
    /// Fused steps in one deterministic probe cell.
    pub steps_total: u64,
    /// Full-batch pure-decode steps among them — the samples the
    /// memory-wall signature is read off.
    pub decode_steps: u64,
    /// Decode step time strictly increases with the batch's aggregate KV
    /// bytes over the full-batch decode steps — **must be true**: each
    /// iteration streams `weights + Σ KV(context_i)` over HBM.
    pub decode_kv_monotone: bool,
    /// Largest aggregate decode KV footprint any step carried, bytes.
    pub peak_kv_bytes: u64,
    /// Mean prefill microseconds per token over the probe cell
    /// (compute-bound above the roofline crossover ⇒ roughly flat).
    pub prefill_us_per_token: f64,
    /// Batch-1 + chunking-off continuous batching rendered
    /// byte-identically to the per-request seed scheduler under legacy
    /// costs — **must be true** (the oracle gate).
    pub legacy_identical: bool,
}

/// Everything the `BENCH_0010` batching bench measures.
#[derive(Debug, Clone)]
pub struct BatchingReport {
    /// Fast mode (smaller budgets/workloads; CI smoke).
    pub fast: bool,
    /// The continuous-batching measurements.
    pub batching: BatchingCycle,
}

/// Run the `BENCH_0010` batching bench (`mma bench hotpath
/// --out-batching`).
pub fn run_batching_bench(fast: bool) -> BatchingReport {
    let budget = if fast {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(600)
    };
    let batch = if fast { 8 } else { 16 };
    let requests = if fast { 24 } else { 48 };
    run_batching_bench_with(fast, budget, batch, requests)
}

/// [`run_batching_bench`] with explicit knobs (tests use tiny budgets).
/// `batch` sizes the roofline probe cell; `requests` sizes the trace the
/// legacy-identity oracle replays.
pub fn run_batching_bench_with(
    fast: bool,
    budget: Duration,
    batch: u32,
    requests: usize,
) -> BatchingReport {
    // Deterministic leg 1: the memory-wall probe cell — `batch` cold
    // 16K-context requests under roofline costs, unchunked.
    let cell = crate::figures::batching::batching_cell(batch, 0, 16_384, 16);
    let decode_steps = cell.full_decode_steps(batch).len() as u64;
    let decode_kv_monotone = cell.decode_kv_monotone(batch);
    // Deterministic leg 2: batch-1 + chunking-off continuous batching
    // must render byte-identically to the per-request seed scheduler
    // under legacy costs (same gate the replay oracle test holds).
    let trace = replay_trace(requests);
    let per_request = ServingConfig {
        max_batch_seqs: 1,
        max_concurrency: 1,
        compute: ComputeSource::Legacy,
        ..replay_serving()
    };
    let batched = ServingConfig {
        batching: BatchingConfig {
            enabled: true,
            chunk_tokens: 0,
        },
        ..per_request.clone()
    };
    let fleet = FleetConfig {
        gpus: 2,
        router: RoutePolicy::RoundRobin,
        peer_fetch: true,
        prefix_affinity: false,
    };
    let opts = ReplayOptions::default();
    let model = qwen_7b_chat();
    let base = replay(
        &trace,
        &model,
        MmaConfig::default(),
        per_request,
        fleet.clone(),
        &opts,
    );
    let cb = replay(&trace, &model, MmaConfig::default(), batched, fleet, &opts);
    let legacy_identical = base.render() == cb.render();
    // Timed leg: repeat the probe cell within the budget.
    let t0 = Instant::now();
    let mut timed_steps = 0u64;
    while t0.elapsed() < budget {
        let run = crate::figures::batching::batching_cell(batch, 0, 16_384, 16);
        timed_steps += run.steps.len() as u64;
        black_box(run.mean_tpot);
    }
    let steps_per_sec = timed_steps as f64 / t0.elapsed().as_secs_f64();
    BatchingReport {
        fast,
        batching: BatchingCycle {
            steps_per_sec,
            steps_total: cell.steps.len() as u64,
            decode_steps,
            decode_kv_monotone,
            peak_kv_bytes: cell.peak_kv_bytes(),
            prefill_us_per_token: 1e6 * cell.prefill_secs_per_token(),
            legacy_identical,
        },
    }
}

/// One churn run's observables.
struct ChurnRun {
    completions: Vec<(u64, Time)>,
    stats: FabricStats,
    /// Container growths after the warm-up waves.
    alloc_growth: u64,
    /// Activations + completions over the whole run.
    events: u64,
}

/// The `BENCH_0009` scenario: `lanes` contending H2D lanes (one socket,
/// so every lane shares the DRAM-read link and the switch uplinks),
/// each carrying `chunks` back-to-back copies restarted with zero
/// latency at the completion instant — the completion → replacement
/// cascade an engine generates at every chunk boundary. Chunk sizes are
/// staggered per lane so boundaries disturb (and restore) neighbour
/// rates instead of completing in symmetric lock-step.
fn fabric_churn(coalesce: bool, lanes: usize, chunks: u64) -> ChurnRun {
    let topo = h20x8();
    let mut f = Fabric::new(&topo).with_coalesce(coalesce);
    let pids: Vec<_> = (0..lanes)
        .map(|g| f.intern_path(&topo.h2d_direct(NumaId(0), GpuId((g % 8) as u8))))
        .collect();
    let chunk_bytes = |lane: usize| 5_000_000 + 4096 * lane as u64;
    let warm_done = chunks.min(8) * lanes as u64;
    let mut left = vec![chunks.saturating_sub(1); lanes];
    let mut completions = Vec::new();
    let mut done_buf: Vec<FlowDone> = Vec::new();
    let mut now = Time::ZERO;
    for (lane, &pid) in pids.iter().enumerate() {
        let b = chunk_bytes(lane);
        f.start_flow_path(now, pid, b, Time::ZERO, lane as u64, 1.0, f64::INFINITY);
    }
    let mut alloc_base = None;
    loop {
        done_buf.clear();
        f.poll_into(now, &mut done_buf);
        for k in 0..done_buf.len() {
            let d = done_buf[k];
            completions.push((d.tag, d.finished));
            let lane = (d.tag % lanes as u64) as usize;
            if left[lane] > 0 {
                left[lane] -= 1;
                let (tag, b) = (d.tag + lanes as u64, chunk_bytes(lane));
                f.start_flow_path(now, pids[lane], b, Time::ZERO, tag, 1.0, f64::INFINITY);
            }
        }
        if alloc_base.is_none() && completions.len() as u64 >= warm_done {
            alloc_base = Some(f.start_alloc_growth());
        }
        match f.next_event_time() {
            Some(t) => now = now.max(t),
            None => break,
        }
    }
    ChurnRun {
        completions,
        stats: f.stats(),
        alloc_growth: f.start_alloc_growth() - alloc_base.unwrap_or(0),
        events: 2 * lanes as u64 * chunks,
    }
}

/// Prefix-tier churn: a tier holding 1/16 of the keyspace, so most
/// inserts evict — the worst case for the retired O(n) scan and the
/// steady state of a busy serving instance. Each iteration is the
/// scheduler's access shape: touch the key if resident, insert it
/// otherwise.
fn lru_churn(budget: Duration) -> f64 {
    // 1024 resident blocks of 16 tokens; 4096 keys of 64 tokens each.
    let mut tier = GpuPrefixTier::new(16, 16 * 1024);
    let mut rng = Rng::seed_from_u64(BENCH_SEED);
    let t0 = Instant::now();
    let mut ops = 0u64;
    while t0.elapsed() < budget {
        for _ in 0..1024 {
            let key = rng.range_u64(1, 4096);
            if !tier.touch(key) {
                black_box(tier.insert(key, 64));
            }
            ops += 1;
        }
    }
    ops as f64 / t0.elapsed().as_secs_f64()
}

/// Histogram churn: log-uniform latencies (the TTFT shape) cycled from a
/// precomputed block so the measurement is `record()`, not `powf()`.
fn hist_churn(budget: Duration, bins: usize) -> f64 {
    let mut rng = Rng::seed_from_u64(BENCH_SEED);
    let samples: Vec<f64> = (0..1024)
        .map(|_| 1e-6 * 1e6f64.powf(rng.range_f64(0.0, 1.0)))
        .collect();
    let mut h = LogHistogram::new(bins);
    let t0 = Instant::now();
    let mut ops = 0u64;
    while t0.elapsed() < budget {
        for &v in &samples {
            h.record(v);
        }
        ops += samples.len() as u64;
    }
    black_box(h.percentile(99.0));
    ops as f64 / t0.elapsed().as_secs_f64()
}

/// Replay the bench trace both ways — materialized (the oracle) and
/// streamed through the bounded-window ingestion — timing the streamed
/// side; returns (requests/s, peak tracked bytes, identical, spilled).
fn streamed_replay_leg(requests: usize) -> (f64, u64, bool, bool) {
    let trace = replay_trace(requests);
    let text = trace.render();
    let fleet = || FleetConfig {
        gpus: 2,
        router: RoutePolicy::RoundRobin,
        peer_fetch: true,
        prefix_affinity: false,
    };
    let opts = ReplayOptions::default();
    let oracle = replay(
        &trace,
        &qwen_7b_chat(),
        MmaConfig::default(),
        replay_serving(),
        fleet(),
        &opts,
    );
    let t0 = Instant::now();
    let streamed = replay_streamed(
        || Ok(TraceReader::new(Cursor::new(text.as_bytes()))),
        &qwen_7b_chat(),
        MmaConfig::default(),
        replay_serving(),
        fleet(),
        &opts,
        1024,
    )
    .expect("generated trace streams cleanly");
    let wall_s = t0.elapsed().as_secs_f64();
    (
        requests as f64 / wall_s.max(1e-9),
        streamed.ingest.peak_tracked_bytes,
        oracle.render() == streamed.render(),
        streamed.ingest.spilled,
    )
}

/// Initial backlog + reschedule horizon of the queue churn benches.
const CHURN_BACKLOG: usize = 4096;

fn churn_wheel(budget: Duration) -> f64 {
    let mut q = EventQueue::new();
    let mut rng = Rng::seed_from_u64(BENCH_SEED);
    for i in 0..CHURN_BACKLOG as u32 {
        q.schedule_at(Time(rng.range_u64(0, 1 << 24)), i);
    }
    let t0 = Instant::now();
    let mut ops = 0u64;
    while t0.elapsed() < budget {
        for _ in 0..1024 {
            let (t, ev) = q.pop().expect("churn queue never empties");
            // Mixed-horizon reschedule: near timers dominate, with a tail
            // of far ones — the shape the MMA driver produces.
            let delta = 1 + (ev as u64).wrapping_mul(2_654_435_761) % 1_000_000;
            q.schedule_at(t + Time(delta), ev);
            ops += 2; // one pop + one schedule
        }
    }
    ops as f64 / t0.elapsed().as_secs_f64()
}

fn churn_heap(budget: Duration) -> f64 {
    let mut q = HeapEventQueue::new();
    let mut rng = Rng::seed_from_u64(BENCH_SEED);
    for i in 0..CHURN_BACKLOG as u32 {
        q.schedule_at(Time(rng.range_u64(0, 1 << 24)), i);
    }
    let t0 = Instant::now();
    let mut ops = 0u64;
    while t0.elapsed() < budget {
        for _ in 0..1024 {
            let (t, ev) = q.pop().expect("churn queue never empties");
            let delta = 1 + (ev as u64).wrapping_mul(2_654_435_761) % 1_000_000;
            q.schedule_at(t + Time(delta), ev);
            ops += 2;
        }
    }
    ops as f64 / t0.elapsed().as_secs_f64()
}

/// Flow activation+completion events per second through the fabric.
fn fabric_cycle(budget: Duration) -> f64 {
    let topo = h20x8();
    let path = topo.h2d_direct(NumaId(0), GpuId(0));
    let t0 = Instant::now();
    let mut events = 0u64;
    while t0.elapsed() < budget {
        let mut f = Fabric::new(&topo);
        for i in 0..16 {
            f.start_flow(Time::ZERO, &path, 5_000_000, Time::ZERO, i);
        }
        black_box(fabric::run_to_completion(&mut f, Time::ZERO));
        events += 32; // 16 activations + 16 completions
    }
    events as f64 / t0.elapsed().as_secs_f64()
}

/// The replay leg's trace: two tenants (interactive + bulk) over warm
/// host-tier prefixes on bursty arrivals — the contention-heavy shape
/// the workload-replay figure uses.
fn replay_trace(requests: usize) -> Trace {
    let mut chat = TenantSpec::interactive(1, 4, 8_192);
    chat.share = 2.0;
    chat.warm_start = true;
    let mut bulk = TenantSpec::interactive(2, 4, 8_192);
    bulk.share = 1.0;
    bulk.class = Some(crate::mma::TransferClass::Bulk);
    bulk.warm_start = true;
    let gen = TraceGen {
        arrivals: ArrivalProcess::bursty(20.0, 0.9, 2.0),
        tenants: vec![chat, bulk],
        requests,
    };
    gen.generate(&mut Rng::seed_from_u64(BENCH_SEED))
}

/// Replay `trace` once with the chosen allocator; returns the rendered
/// report (for the determinism cross-check) and the timed leg.
fn replay_leg(trace: &Trace, incremental: bool) -> (String, ReplayLeg) {
    let mma = MmaConfig {
        incremental_alloc: incremental,
        ..MmaConfig::default()
    };
    let fleet = FleetConfig {
        gpus: 2,
        router: RoutePolicy::RoundRobin,
        peer_fetch: true,
        prefix_affinity: false,
    };
    let t0 = Instant::now();
    let report = replay(
        trace,
        &qwen_7b_chat(),
        mma,
        replay_serving(),
        fleet,
        &ReplayOptions::default(),
    );
    let wall_s = t0.elapsed().as_secs_f64();
    (
        report.render(),
        ReplayLeg {
            wall_s,
            stats: report.fabric_stats,
        },
    )
}

/// Format a float for JSON: finite, fixed precision, no NaN/inf tokens.
fn jnum(x: f64, decimals: usize) -> String {
    if x.is_finite() {
        format!("{x:.decimals$}")
    } else {
        "0".to_string()
    }
}

fn stats_json(out: &mut String, leg: &ReplayLeg, indent: &str) {
    out.push_str(&format!(
        "{indent}\"wall_s\": {},\n\
         {indent}\"recomputes\": {},\n\
         {indent}\"full_solves\": {},\n\
         {indent}\"component_solves\": {},\n\
         {indent}\"flows_solved\": {}\n",
        jnum(leg.wall_s, 6),
        leg.stats.recomputes,
        leg.stats.full_solves,
        leg.stats.component_solves,
        leg.stats.flows_solved,
    ));
}

impl HotpathReport {
    /// Seconds to replay one million requests, extrapolated from the
    /// incremental leg.
    pub fn wall_per_1m_requests_s(&self) -> f64 {
        if self.replay_requests == 0 {
            return 0.0;
        }
        self.incremental.wall_s * (1_000_000.0 / self.replay_requests as f64)
    }

    /// The `mma-bench-hotpath/1` JSON document (stable key order; see
    /// `docs/PERF.md` for the schema).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"mma-bench-hotpath/1\",\n");
        s.push_str("  \"bench\": \"BENCH_0006\",\n");
        s.push_str("  \"provenance\": \"measured\",\n");
        s.push_str(&format!("  \"fast\": {},\n", self.fast));
        s.push_str("  \"events_per_sec\": {\n");
        s.push_str(&format!(
            "    \"timer_wheel\": {},\n",
            jnum(self.wheel_events_per_sec, 1)
        ));
        s.push_str(&format!(
            "    \"binary_heap\": {},\n",
            jnum(self.heap_events_per_sec, 1)
        ));
        s.push_str(&format!(
            "    \"fabric_flow_cycle\": {}\n",
            jnum(self.fabric_events_per_sec, 1)
        ));
        s.push_str("  },\n");
        s.push_str("  \"replay\": {\n");
        s.push_str(&format!("    \"requests\": {},\n", self.replay_requests));
        s.push_str(&format!(
            "    \"deterministic\": {},\n",
            self.replay_deterministic
        ));
        s.push_str(&format!(
            "    \"wall_per_1m_requests_s\": {},\n",
            jnum(self.wall_per_1m_requests_s(), 3)
        ));
        s.push_str("    \"incremental\": {\n");
        stats_json(&mut s, &self.incremental, "      ");
        s.push_str("    },\n");
        s.push_str("    \"full\": {\n");
        stats_json(&mut s, &self.reference, "      ");
        s.push_str("    }\n");
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable summary (`mma bench hotpath` without `--json`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "event queue     {:>12.0} events/s (timer wheel) vs {:>12.0} (binary heap), {:.2}x\n",
            self.wheel_events_per_sec,
            self.heap_events_per_sec,
            self.wheel_events_per_sec / self.heap_events_per_sec.max(1.0),
        ));
        s.push_str(&format!(
            "fabric cycle    {:>12.0} flow events/s\n",
            self.fabric_events_per_sec
        ));
        s.push_str(&format!(
            "replay          {} requests in {:.3} s ({:.1} s per 1M requests), deterministic: {}\n",
            self.replay_requests,
            self.incremental.wall_s,
            self.wall_per_1m_requests_s(),
            self.replay_deterministic,
        ));
        s.push_str(&format!(
            "allocator work  incremental: {} recomputes, {} full solves, {} component solves, {} flows\n",
            self.incremental.stats.recomputes,
            self.incremental.stats.full_solves,
            self.incremental.stats.component_solves,
            self.incremental.stats.flows_solved,
        ));
        s.push_str(&format!(
            "                reference:   {} recomputes, {} full solves, {} component solves, {} flows\n",
            self.reference.stats.recomputes,
            self.reference.stats.full_solves,
            self.reference.stats.component_solves,
            self.reference.stats.flows_solved,
        ));
        s
    }
}

impl EngineReport {
    /// Seconds to replay one million requests, extrapolated from the
    /// incremental leg.
    pub fn wall_per_1m_requests_s(&self) -> f64 {
        if self.replay_requests == 0 {
            return 0.0;
        }
        self.incremental.wall_s * (1_000_000.0 / self.replay_requests as f64)
    }

    /// The `mma-bench-engine/1` JSON document (stable key order; see
    /// `docs/PERF.md` for the schema).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"mma-bench-engine/1\",\n");
        s.push_str("  \"bench\": \"BENCH_0007\",\n");
        s.push_str("  \"provenance\": \"measured\",\n");
        s.push_str(&format!("  \"fast\": {},\n", self.fast));
        s.push_str("  \"engine\": {\n");
        s.push_str(&format!(
            "    \"chunks_per_sec\": {},\n",
            jnum(self.engine.chunks_per_sec, 1)
        ));
        s.push_str(&format!(
            "    \"actions_total\": {},\n",
            self.engine.actions_total
        ));
        s.push_str(&format!(
            "    \"actions_per_alloc\": {},\n",
            jnum(self.engine.actions_per_alloc, 1)
        ));
        s.push_str(&format!(
            "    \"steady_state_allocs\": {}\n",
            self.engine.steady_state_allocs
        ));
        s.push_str("  },\n");
        s.push_str("  \"replay\": {\n");
        s.push_str(&format!("    \"requests\": {},\n", self.replay_requests));
        s.push_str(&format!(
            "    \"deterministic\": {},\n",
            self.replay_deterministic
        ));
        s.push_str(&format!(
            "    \"wall_per_1m_requests_s\": {},\n",
            jnum(self.wall_per_1m_requests_s(), 3)
        ));
        s.push_str("    \"incremental\": {\n");
        stats_json(&mut s, &self.incremental, "      ");
        s.push_str("    },\n");
        s.push_str("    \"full\": {\n");
        stats_json(&mut s, &self.reference, "      ");
        s.push_str("    }\n");
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable summary (the engine leg of `mma bench hotpath`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "engine cycle    {:>12.0} chunks/s, {} actions, {:.0} actions/alloc, {} steady-state allocs\n",
            self.engine.chunks_per_sec,
            self.engine.actions_total,
            self.engine.actions_per_alloc,
            self.engine.steady_state_allocs,
        ));
        s.push_str(&format!(
            "engine replay   {} requests in {:.3} s ({:.1} s per 1M requests), deterministic: {}\n",
            self.replay_requests,
            self.incremental.wall_s,
            self.wall_per_1m_requests_s(),
            self.replay_deterministic,
        ));
        s
    }
}

impl ServingReport {
    /// The `mma-bench-serving/1` JSON document (stable key order; see
    /// `docs/PERF.md` for the schema).
    pub fn to_json(&self) -> String {
        let c = &self.serving;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"mma-bench-serving/1\",\n");
        s.push_str("  \"bench\": \"BENCH_0008\",\n");
        s.push_str("  \"provenance\": \"measured\",\n");
        s.push_str(&format!("  \"fast\": {},\n", self.fast));
        s.push_str("  \"serving\": {\n");
        s.push_str(&format!(
            "    \"lru_ops_per_sec\": {},\n",
            jnum(c.lru_ops_per_sec, 1)
        ));
        s.push_str(&format!(
            "    \"hist_records_per_sec\": {},\n",
            jnum(c.hist_records_per_sec, 1)
        ));
        s.push_str(&format!("    \"hist_bins\": {},\n", c.hist_bins));
        s.push_str(&format!("    \"requests\": {},\n", c.requests));
        s.push_str(&format!(
            "    \"requests_per_sec\": {},\n",
            jnum(c.requests_per_sec, 1)
        ));
        s.push_str(&format!(
            "    \"peak_tracked_bytes\": {},\n",
            c.peak_tracked_bytes
        ));
        s.push_str(&format!(
            "    \"streaming_identical\": {},\n",
            c.streaming_identical
        ));
        s.push_str(&format!("    \"spilled\": {}\n", c.spilled));
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable summary (the serving leg of `mma bench hotpath`).
    pub fn render(&self) -> String {
        let c = &self.serving;
        let mut s = String::new();
        s.push_str(&format!(
            "prefix lru      {:>12.0} tier ops/s (touch-or-insert under eviction pressure)\n",
            c.lru_ops_per_sec
        ));
        s.push_str(&format!(
            "histogram       {:>12.0} records/s ({} bins, {} tracked bytes, bounded)\n",
            c.hist_records_per_sec,
            c.hist_bins,
            LogHistogram::new(c.hist_bins).tracked_bytes(),
        ));
        s.push_str(&format!(
            "serving replay  {} requests streamed at {:.0} req/s, peak {} ingest bytes, \
             identical: {}, spilled: {}\n",
            c.requests,
            c.requests_per_sec,
            c.peak_tracked_bytes,
            c.streaming_identical,
            c.spilled,
        ));
        s
    }
}

impl FabricReport {
    /// The `mma-bench-fabric/1` JSON document (stable key order; see
    /// `docs/PERF.md` for the schema).
    pub fn to_json(&self) -> String {
        let c = &self.fabric;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"mma-bench-fabric/1\",\n");
        s.push_str("  \"bench\": \"BENCH_0009\",\n");
        s.push_str("  \"provenance\": \"measured\",\n");
        s.push_str(&format!("  \"fast\": {},\n", self.fast));
        s.push_str("  \"fabric\": {\n");
        s.push_str(&format!(
            "    \"events_per_sec\": {},\n",
            jnum(c.events_per_sec, 1)
        ));
        s.push_str(&format!("    \"events_total\": {},\n", c.events_total));
        s.push_str(&format!("    \"solves\": {},\n", c.solves));
        s.push_str(&format!(
            "    \"solves_per_event\": {},\n",
            jnum(c.solves_per_event, 4)
        ));
        s.push_str(&format!(
            "    \"deferred_solves\": {},\n",
            c.deferred_solves
        ));
        s.push_str(&format!("    \"cascade_events\": {},\n", c.cascade_events));
        s.push_str(&format!("    \"alloc_growth\": {},\n", c.alloc_growth));
        s.push_str(&format!(
            "    \"coalesced_identical\": {}\n",
            c.coalesced_identical
        ));
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable summary (the fabric leg of `mma bench hotpath`).
    pub fn render(&self) -> String {
        let c = &self.fabric;
        format!(
            "fabric churn    {:>12.0} events/s, {:.3} solves/event \
             ({} deferred, {} cascades folded), {} steady-state allocs, \
             coalesced identical: {}\n",
            c.events_per_sec,
            c.solves_per_event,
            c.deferred_solves,
            c.cascade_events,
            c.alloc_growth,
            c.coalesced_identical,
        )
    }
}

impl BatchingReport {
    /// The `mma-bench-batching/1` JSON document (stable key order; see
    /// `docs/PERF.md` for the schema).
    pub fn to_json(&self) -> String {
        let c = &self.batching;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"mma-bench-batching/1\",\n");
        s.push_str("  \"bench\": \"BENCH_0010\",\n");
        s.push_str("  \"provenance\": \"measured\",\n");
        s.push_str(&format!("  \"fast\": {},\n", self.fast));
        s.push_str("  \"batching\": {\n");
        s.push_str(&format!(
            "    \"steps_per_sec\": {},\n",
            jnum(c.steps_per_sec, 1)
        ));
        s.push_str(&format!("    \"steps_total\": {},\n", c.steps_total));
        s.push_str(&format!("    \"decode_steps\": {},\n", c.decode_steps));
        s.push_str(&format!(
            "    \"decode_kv_monotone\": {},\n",
            c.decode_kv_monotone
        ));
        s.push_str(&format!("    \"peak_kv_bytes\": {},\n", c.peak_kv_bytes));
        s.push_str(&format!(
            "    \"prefill_us_per_token\": {},\n",
            jnum(c.prefill_us_per_token, 3)
        ));
        s.push_str(&format!(
            "    \"legacy_identical\": {}\n",
            c.legacy_identical
        ));
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable summary (the batching leg of `mma bench hotpath`).
    pub fn render(&self) -> String {
        let c = &self.batching;
        format!(
            "batching step   {:>12.0} steps/s ({} steps, {} full-batch \
             decode), peak KV {:.2} GB, prefill {:.2} us/tok, \
             kv-monotone: {}, legacy identical: {}\n",
            c.steps_per_sec,
            c.steps_total,
            c.decode_steps,
            c.peak_kv_bytes as f64 / 1e9,
            c.prefill_us_per_token,
            c.decode_kv_monotone,
            c.legacy_identical,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_and_reports_incremental_win() {
        // Tiny budgets: this is a correctness test of the harness, not a
        // measurement. The acceptance-criteria assertions live here: the
        // incremental path must do strictly fewer full re-solves than the
        // reference on the replay bench while rendering identically.
        let r = run_hotpath_with(true, Duration::from_millis(5), 12);
        assert!(r.replay_deterministic, "replay legs diverged");
        assert_eq!(r.incremental.stats.full_solves, 0);
        assert!(
            r.reference.stats.full_solves > 0,
            "reference leg did no full solves: {:?}",
            r.reference.stats
        );
        assert!(
            r.incremental.stats.full_solves < r.reference.stats.full_solves,
            "incremental must full-solve strictly less"
        );
        // Same event sequence ⇒ same number of recompute events.
        assert_eq!(
            r.incremental.stats.recomputes,
            r.reference.stats.recomputes
        );
        assert!(r.wheel_events_per_sec > 0.0);
        assert!(r.heap_events_per_sec > 0.0);
        assert!(r.fabric_events_per_sec > 0.0);
        assert!(r.wall_per_1m_requests_s() > 0.0);
    }

    #[test]
    fn engine_bench_holds_the_zero_alloc_bar() {
        // Tiny budget: correctness of the harness, not a measurement. The
        // acceptance criterion lives here — steady-state engine events
        // must never grow the reused sink.
        let r = run_engine_bench_with(true, Duration::from_millis(5), 12);
        assert_eq!(
            r.engine.steady_state_allocs, 0,
            "engine steady state allocated: {:?}",
            r.engine
        );
        assert!(r.engine.chunks_per_sec > 0.0);
        assert!(r.engine.actions_total > 0);
        assert!(r.engine.actions_per_alloc > 0.0);
        assert!(r.replay_deterministic, "replay legs diverged");
        assert!(
            r.incremental.stats.full_solves < r.reference.stats.full_solves,
            "incremental must full-solve strictly less"
        );
    }

    #[test]
    fn engine_json_has_stable_schema_keys() {
        let r = run_engine_bench_with(true, Duration::from_millis(2), 6);
        let j = r.to_json();
        for key in [
            "\"schema\": \"mma-bench-engine/1\"",
            "\"bench\": \"BENCH_0007\"",
            "\"provenance\": \"measured\"",
            "\"chunks_per_sec\"",
            "\"actions_total\"",
            "\"actions_per_alloc\"",
            "\"steady_state_allocs\"",
            "\"replay\"",
            "\"deterministic\"",
            "\"incremental\"",
            "\"full\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(!j.contains("NaN") && !j.contains("inf"));
        assert!(!r.render().is_empty());
    }

    #[test]
    fn serving_bench_streams_identically() {
        // Tiny budget: harness correctness, not a measurement. The
        // acceptance bars live here — the streamed replay must render
        // byte-identically to the materialized oracle without spilling,
        // and its tracked ingestion memory must be a real bounded number.
        let r = run_serving_bench_with(true, Duration::from_millis(5), 12, 256);
        let c = r.serving;
        assert!(c.streaming_identical, "streamed replay diverged");
        assert!(!c.spilled, "sorted bench trace must not spill");
        assert!(c.lru_ops_per_sec > 0.0);
        assert!(c.hist_records_per_sec > 0.0);
        assert!(c.requests_per_sec > 0.0);
        assert!(c.peak_tracked_bytes > 0, "streamed leg tracked no memory");
        assert_eq!(c.requests, 12);
        assert_eq!(c.hist_bins, 256);
    }

    #[test]
    fn serving_json_has_stable_schema_keys() {
        let r = run_serving_bench_with(true, Duration::from_millis(2), 6, 1024);
        let j = r.to_json();
        for key in [
            "\"schema\": \"mma-bench-serving/1\"",
            "\"bench\": \"BENCH_0008\"",
            "\"provenance\": \"measured\"",
            "\"lru_ops_per_sec\"",
            "\"hist_records_per_sec\"",
            "\"hist_bins\": 1024",
            "\"requests\"",
            "\"requests_per_sec\"",
            "\"peak_tracked_bytes\"",
            "\"streaming_identical\": true",
            "\"spilled\": false",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(!j.contains("NaN") && !j.contains("inf"));
        assert!(!r.render().is_empty());
    }

    #[test]
    fn fabric_bench_holds_the_coalescing_bars() {
        // Tiny budget: harness correctness, not a measurement. The
        // acceptance bars live here — coalescing must fold cascades
        // (solves-per-event < 1), steady-state flow starts must not
        // allocate, and the coalesced run must match eager exactly.
        let r = run_fabric_bench_with(true, Duration::from_millis(5), 24);
        let c = r.fabric;
        assert!(c.coalesced_identical, "coalesced and eager runs diverged");
        assert_eq!(c.alloc_growth, 0, "steady-state flow starts allocated");
        assert!(
            c.solves_per_event < 1.0,
            "cascades did not collapse: {c:?}"
        );
        assert!(c.cascade_events > 0, "no cascade was folded: {c:?}");
        assert!(c.deferred_solves > 0);
        assert!(c.events_per_sec > 0.0);
        assert_eq!(c.events_total, 2 * 8 * 24);
    }

    #[test]
    fn fabric_json_has_stable_schema_keys() {
        let r = run_fabric_bench_with(true, Duration::from_millis(2), 12);
        let j = r.to_json();
        for key in [
            "\"schema\": \"mma-bench-fabric/1\"",
            "\"bench\": \"BENCH_0009\"",
            "\"provenance\": \"measured\"",
            "\"events_per_sec\"",
            "\"events_total\"",
            "\"solves\"",
            "\"solves_per_event\"",
            "\"deferred_solves\"",
            "\"cascade_events\"",
            "\"alloc_growth\": 0",
            "\"coalesced_identical\": true",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(!j.contains("NaN") && !j.contains("inf"));
        assert!(!r.render().is_empty());
    }

    #[test]
    fn batching_bench_holds_the_memory_wall_bars() {
        let r = run_batching_bench_with(true, Duration::from_millis(5), 4, 12);
        let c = &r.batching;
        assert!(
            c.decode_kv_monotone,
            "decode step time must grow with aggregate KV bytes"
        );
        assert!(
            c.legacy_identical,
            "batch-1 + chunking-off must render identically to the seed scheduler"
        );
        assert!(c.steps_per_sec > 0.0);
        assert!(c.steps_total > 0 && c.decode_steps >= 2);
        assert!(c.peak_kv_bytes > 0);
        assert!(c.prefill_us_per_token > 0.0);
    }

    #[test]
    fn batching_json_has_stable_schema_keys() {
        let r = run_batching_bench_with(true, Duration::from_millis(2), 4, 12);
        let j = r.to_json();
        for key in [
            "\"schema\": \"mma-bench-batching/1\"",
            "\"bench\": \"BENCH_0010\"",
            "\"provenance\": \"measured\"",
            "\"steps_per_sec\"",
            "\"steps_total\"",
            "\"decode_steps\"",
            "\"decode_kv_monotone\": true",
            "\"peak_kv_bytes\"",
            "\"prefill_us_per_token\"",
            "\"legacy_identical\": true",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(!j.contains("NaN") && !j.contains("inf"));
        assert!(!r.render().is_empty());
    }

    #[test]
    fn json_has_stable_schema_keys() {
        let r = run_hotpath_with(true, Duration::from_millis(2), 6);
        let j = r.to_json();
        for key in [
            "\"schema\": \"mma-bench-hotpath/1\"",
            "\"bench\": \"BENCH_0006\"",
            "\"provenance\": \"measured\"",
            "\"events_per_sec\"",
            "\"timer_wheel\"",
            "\"binary_heap\"",
            "\"fabric_flow_cycle\"",
            "\"replay\"",
            "\"wall_per_1m_requests_s\"",
            "\"incremental\"",
            "\"full\"",
            "\"full_solves\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        // Structurally sane: balanced braces, no NaN/inf tokens.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(!j.contains("NaN") && !j.contains("inf"));
        assert!(!r.render().is_empty());
    }
}
