//! CUDA-semantics execution model over the virtual clock.
//!
//! Reproduces exactly the properties of the CUDA execution model that the
//! paper's design wrestles with (§2.3):
//!
//! * **Streams are FIFO**: tasks execute strictly in order; a task's
//!   completion releases the next one.
//! * **Enqueue-time binding (C1)**: a `Memcpy` task that enters a stream is
//!   committed — the only pre-dispatch hook is at the API boundary, which
//!   is where [`crate::mma::Interceptor`] interposes.
//! * **Single-task completion (C2)**: downstream work observes only
//!   stream-task completion, so distributed multipath completion must be
//!   funneled through one stream-visible task (the Dummy Task =
//!   [`StreamTask::HostCallback`] + [`StreamTask::SpinKernel`]).
//! * **Events**: `record`/`wait` pairs order work across streams.
//!
//! The model is passive: the driver (see [`crate::mma::driver`]) calls
//! [`GpuSim::try_advance`] when a stream may be able to make progress and
//! acts on the returned [`Action`]s (start a DMA flow, schedule a kernel
//! completion, run a host callback...).

use crate::sim::Time;
use crate::topology::GpuId;
use std::collections::VecDeque;
use std::fmt;

/// Stream index within a device.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub u16);

/// A host-visible copy registered with the runtime (native or intercepted).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TransferId(pub u32);

/// Mapped pinned-host flag a spin kernel polls (`cudaHostAllocMapped`).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlagId(pub u32);

/// Host-callback handle (`cudaLaunchHostFunc`).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct CbId(pub u32);

/// CUDA event handle.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct CudaEventId(pub u32);

impl fmt::Debug for StreamId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}
impl fmt::Debug for TransferId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xfer{}", self.0)
    }
}
impl fmt::Debug for FlagId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "flag{}", self.0)
    }
}
impl fmt::Debug for CbId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cb{}", self.0)
    }
}
impl fmt::Debug for CudaEventId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ev{}", self.0)
    }
}

/// A task in a CUDA stream.
#[derive(Clone, Debug)]
pub enum StreamTask {
    /// Compute kernel with a fixed duration.
    Kernel {
        /// Execution time once scheduled.
        dur: Time,
        /// Debug label.
        label: &'static str,
        /// Caller tag surfaced on completion (0 = untracked). The driver
        /// turns a nonzero tag into a [`crate::mma::Notice::KernelDone`]
        /// so external consumers (the serving layer) can react to kernel
        /// completions without polling streams.
        tag: u64,
    },
    /// A memory copy bound to its path at enqueue time (native semantics).
    /// The driver starts the DMA when the task reaches the stream head and
    /// calls [`GpuSim::complete_head`] when the flow finishes.
    Memcpy {
        /// The registered transfer this task carries.
        transfer: TransferId,
    },
    /// `cudaLaunchHostFunc`: runs on the CPU when it reaches the head;
    /// stream→CPU notification only (cannot block the stream afterwards).
    HostCallback {
        /// Which callback to run.
        cb: CbId,
    },
    /// MMA's spin kernel: occupies the stream until the mapped flag is set
    /// (CPU→stream direction of the bidirectional handshake, §3.3).
    SpinKernel {
        /// Flag to poll with `__ldcg` + `__nanosleep`.
        flag: FlagId,
    },
    /// `cudaEventRecord`: completes instantly, timestamping the event.
    RecordEvent {
        /// Event to record.
        event: CudaEventId,
    },
    /// `cudaStreamWaitEvent`: blocks until the event is recorded.
    WaitEvent {
        /// Event to wait for.
        event: CudaEventId,
    },
}

/// What the driver must do after a stream advanced onto a new head task.
#[derive(Clone, Debug)]
pub enum Action {
    /// A kernel started: schedule `complete_head` after `dur`.
    KernelStarted {
        /// Device/stream that must be completed later.
        dev: GpuId,
        /// Stream.
        stream: StreamId,
        /// Kernel duration.
        dur: Time,
        /// Caller tag from the enqueued task (0 = untracked).
        tag: u64,
    },
    /// A native (non-intercepted) copy reached the head: start its DMA.
    CopyReachedHead {
        /// Device owning the stream.
        dev: GpuId,
        /// Stream.
        stream: StreamId,
        /// The transfer to launch.
        transfer: TransferId,
    },
    /// Run a host callback now (the stream continues past it immediately).
    RunCallback {
        /// Callback id.
        cb: CbId,
    },
    /// The stream parked on a spin kernel whose flag is still unset.
    /// When the flag is set, the driver releases it after a PCIe RTT.
    SpinParked {
        /// Device.
        dev: GpuId,
        /// Stream.
        stream: StreamId,
        /// Flag being polled.
        flag: FlagId,
    },
}

#[derive(Clone, Debug, PartialEq)]
enum HeadState {
    /// Nothing started at the head.
    Idle,
    /// Head task started and waiting for external completion.
    Running,
    /// Parked on a spin kernel / event.
    Blocked,
}

#[derive(Debug, Default)]
struct Stream {
    q: VecDeque<StreamTask>,
    state: HeadState,
    /// Completion count, for idle detection.
    completed: u64,
}

impl Default for HeadState {
    fn default() -> Self {
        HeadState::Idle
    }
}

#[derive(Debug, Default)]
struct Device {
    streams: Vec<Stream>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum FlagState {
    Unset,
    Set,
}

/// The device-side world: all GPUs, their streams, CUDA events, and mapped
/// host flags.
pub struct GpuSim {
    devices: Vec<Device>,
    flags: Vec<FlagState>,
    /// (dev, stream) parked on each flag.
    flag_waiters: Vec<Vec<(GpuId, StreamId)>>,
    events: Vec<Option<Time>>, // recorded at
    event_waiters: Vec<Vec<(GpuId, StreamId)>>,
}

impl GpuSim {
    /// Create with `gpu_count` devices, each starting with zero streams.
    pub fn new(gpu_count: usize) -> GpuSim {
        GpuSim {
            devices: (0..gpu_count).map(|_| Device::default()).collect(),
            flags: Vec::new(),
            flag_waiters: Vec::new(),
            events: Vec::new(),
            event_waiters: Vec::new(),
        }
    }

    /// Create a stream on a device (`cudaStreamCreate`).
    pub fn create_stream(&mut self, dev: GpuId) -> StreamId {
        let d = &mut self.devices[dev.0 as usize];
        d.streams.push(Stream::default());
        StreamId((d.streams.len() - 1) as u16)
    }

    /// Allocate a mapped pinned-host flag (`cudaHostAllocMapped`).
    pub fn alloc_flag(&mut self) -> FlagId {
        self.flags.push(FlagState::Unset);
        self.flag_waiters.push(Vec::new());
        FlagId((self.flags.len() - 1) as u32)
    }

    /// Create a CUDA event (`cudaEventCreate`).
    pub fn create_event(&mut self) -> CudaEventId {
        self.events.push(None);
        self.event_waiters.push(Vec::new());
        CudaEventId((self.events.len() - 1) as u32)
    }

    /// Enqueue a task (`cudaMemcpyAsync` / kernel launch / ...).
    /// Returns the streams that may now advance (just this one).
    pub fn enqueue(&mut self, dev: GpuId, stream: StreamId, task: StreamTask) {
        self.devices[dev.0 as usize].streams[stream.0 as usize]
            .q
            .push_back(task);
    }

    /// True if the stream has no pending tasks.
    pub fn stream_idle(&self, dev: GpuId, stream: StreamId) -> bool {
        self.devices[dev.0 as usize].streams[stream.0 as usize]
            .q
            .is_empty()
    }

    /// Number of tasks this stream has fully retired.
    pub fn stream_completed(&self, dev: GpuId, stream: StreamId) -> u64 {
        self.devices[dev.0 as usize].streams[stream.0 as usize].completed
    }

    /// Whether a CUDA event has been recorded (and when).
    pub fn event_recorded(&self, ev: CudaEventId) -> Option<Time> {
        self.events[ev.0 as usize]
    }

    /// Advance a stream as far as possible. Returns driver actions. Call
    /// whenever the stream may progress (after enqueue, completion, flag
    /// set, or event record).
    pub fn try_advance(&mut self, now: Time, dev: GpuId, stream: StreamId) -> Vec<Action> {
        let mut actions = Vec::new();
        loop {
            let s = &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
            if s.state != HeadState::Idle {
                break; // running or blocked; external completion will resume us
            }
            let Some(head) = s.q.front().cloned() else {
                break;
            };
            match head {
                StreamTask::Kernel { dur, tag, .. } => {
                    s.state = HeadState::Running;
                    actions.push(Action::KernelStarted {
                        dev,
                        stream,
                        dur,
                        tag,
                    });
                    break;
                }
                StreamTask::Memcpy { transfer } => {
                    s.state = HeadState::Running;
                    actions.push(Action::CopyReachedHead {
                        dev,
                        stream,
                        transfer,
                    });
                    break;
                }
                StreamTask::HostCallback { cb } => {
                    // Executes "instantly" on the CPU; stream moves on.
                    s.q.pop_front();
                    s.completed += 1;
                    actions.push(Action::RunCallback { cb });
                }
                StreamTask::SpinKernel { flag } => {
                    match self.flags[flag.0 as usize] {
                        FlagState::Set => {
                            // Flag already set: kernel exits immediately.
                            let s =
                                &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
                            s.q.pop_front();
                            s.completed += 1;
                        }
                        FlagState::Unset => {
                            let s =
                                &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
                            s.state = HeadState::Blocked;
                            self.flag_waiters[flag.0 as usize].push((dev, stream));
                            actions.push(Action::SpinParked { dev, stream, flag });
                            break;
                        }
                    }
                }
                StreamTask::RecordEvent { event } => {
                    s.q.pop_front();
                    s.completed += 1;
                    self.events[event.0 as usize] = Some(now);
                    // Waiters resume; caller must try_advance them. We return
                    // them as RunCallback-free actions? Keep it simple: the
                    // driver re-advances waiters via `take_event_waiters`.
                }
                StreamTask::WaitEvent { event } => {
                    if self.events[event.0 as usize].is_some() {
                        s.q.pop_front();
                        s.completed += 1;
                    } else {
                        s.state = HeadState::Blocked;
                        self.event_waiters[event.0 as usize].push((dev, stream));
                        break;
                    }
                }
            }
        }
        actions
    }

    /// Complete the currently-running head task of a stream (kernel done,
    /// native copy done, intercepted transfer done). The caller then calls
    /// [`Self::try_advance`] again.
    pub fn complete_head(&mut self, dev: GpuId, stream: StreamId) {
        let s = &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
        debug_assert_eq!(s.state, HeadState::Running, "complete_head on non-running");
        s.q.pop_front();
        s.completed += 1;
        s.state = HeadState::Idle;
    }

    /// CPU sets a mapped flag (`*h_flag = 1`). Returns the streams whose
    /// spin kernels observe it; the driver releases each after a PCIe RTT
    /// by calling [`Self::release_spin`].
    pub fn set_flag(&mut self, flag: FlagId) -> Vec<(GpuId, StreamId)> {
        self.flags[flag.0 as usize] = FlagState::Set;
        std::mem::take(&mut self.flag_waiters[flag.0 as usize])
    }

    /// Reset a flag for reuse (MMA pools its mapped flags).
    pub fn reset_flag(&mut self, flag: FlagId) {
        self.flags[flag.0 as usize] = FlagState::Unset;
    }

    /// The spin kernel observed the flag: pop it and unblock the stream.
    pub fn release_spin(&mut self, dev: GpuId, stream: StreamId) {
        let s = &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
        debug_assert_eq!(s.state, HeadState::Blocked);
        debug_assert!(matches!(s.q.front(), Some(StreamTask::SpinKernel { .. })));
        s.q.pop_front();
        s.completed += 1;
        s.state = HeadState::Idle;
    }

    /// Streams parked on an event that has just been recorded. The driver
    /// unblocks (state → Idle) and re-advances each.
    pub fn take_event_waiters(&mut self, ev: CudaEventId) -> Vec<(GpuId, StreamId)> {
        let ws = std::mem::take(&mut self.event_waiters[ev.0 as usize]);
        for &(dev, stream) in &ws {
            let s = &mut self.devices[dev.0 as usize].streams[stream.0 as usize];
            debug_assert_eq!(s.state, HeadState::Blocked);
            s.state = HeadState::Idle;
        }
        ws
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(i: u8) -> GpuId {
        GpuId(i)
    }

    #[test]
    fn fifo_order_kernel_then_copy() {
        let mut sim = GpuSim::new(2);
        let s = sim.create_stream(g(0));
        sim.enqueue(g(0), s, StreamTask::Kernel { dur: Time::from_us(5), label: "k", tag: 0 });
        sim.enqueue(g(0), s, StreamTask::Memcpy { transfer: TransferId(7) });
        let a = sim.try_advance(Time::ZERO, g(0), s);
        assert!(matches!(a[..], [Action::KernelStarted { .. }]));
        // Copy must NOT start while the kernel runs.
        assert!(sim.try_advance(Time::ZERO, g(0), s).is_empty());
        sim.complete_head(g(0), s);
        let a = sim.try_advance(Time::from_us(5), g(0), s);
        assert!(
            matches!(a[..], [Action::CopyReachedHead { transfer: TransferId(7), .. }]),
            "{a:?}"
        );
    }

    #[test]
    fn host_callback_runs_and_stream_continues() {
        let mut sim = GpuSim::new(1);
        let s = sim.create_stream(g(0));
        let cb = CbId(3);
        sim.enqueue(g(0), s, StreamTask::HostCallback { cb });
        sim.enqueue(g(0), s, StreamTask::Kernel { dur: Time::from_us(1), label: "k", tag: 0 });
        let a = sim.try_advance(Time::ZERO, g(0), s);
        // Callback fires AND the next kernel starts in the same advance:
        // host callbacks give stream→CPU notification but cannot block.
        assert_eq!(a.len(), 2);
        assert!(matches!(a[0], Action::RunCallback { cb: CbId(3) }));
        assert!(matches!(a[1], Action::KernelStarted { .. }));
    }

    #[test]
    fn spin_kernel_blocks_until_flag() {
        let mut sim = GpuSim::new(1);
        let s = sim.create_stream(g(0));
        let flag = sim.alloc_flag();
        sim.enqueue(g(0), s, StreamTask::SpinKernel { flag });
        sim.enqueue(g(0), s, StreamTask::Kernel { dur: Time::from_us(1), label: "down", tag: 0 });
        let a = sim.try_advance(Time::ZERO, g(0), s);
        assert!(matches!(a[..], [Action::SpinParked { .. }]));
        // Downstream kernel must not start: C2's stale-read hazard.
        assert!(sim.try_advance(Time::ZERO, g(0), s).is_empty());
        // CPU sets the flag.
        let waiters = sim.set_flag(flag);
        assert_eq!(waiters, vec![(g(0), s)]);
        sim.release_spin(g(0), s);
        let a = sim.try_advance(Time::from_us(2), g(0), s);
        assert!(matches!(a[..], [Action::KernelStarted { .. }]));
    }

    #[test]
    fn spin_kernel_with_preset_flag_passes_through() {
        let mut sim = GpuSim::new(1);
        let s = sim.create_stream(g(0));
        let flag = sim.alloc_flag();
        sim.set_flag(flag);
        sim.enqueue(g(0), s, StreamTask::SpinKernel { flag });
        let a = sim.try_advance(Time::ZERO, g(0), s);
        assert!(a.is_empty());
        assert!(sim.stream_idle(g(0), s));
        assert_eq!(sim.stream_completed(g(0), s), 1);
    }

    #[test]
    fn events_order_across_streams() {
        let mut sim = GpuSim::new(1);
        let s1 = sim.create_stream(g(0));
        let s2 = sim.create_stream(g(0));
        let ev = sim.create_event();
        // s2 waits on ev; s1 records it after a kernel.
        sim.enqueue(g(0), s2, StreamTask::WaitEvent { event: ev });
        sim.enqueue(g(0), s2, StreamTask::Kernel { dur: Time::from_us(1), label: "after", tag: 0 });
        let a = sim.try_advance(Time::ZERO, g(0), s2);
        assert!(a.is_empty(), "s2 must block: {a:?}");

        sim.enqueue(g(0), s1, StreamTask::Kernel { dur: Time::from_us(3), label: "k", tag: 0 });
        sim.enqueue(g(0), s1, StreamTask::RecordEvent { event: ev });
        let a = sim.try_advance(Time::ZERO, g(0), s1);
        assert!(matches!(a[..], [Action::KernelStarted { .. }]));
        sim.complete_head(g(0), s1);
        let a = sim.try_advance(Time::from_us(3), g(0), s1);
        assert!(a.is_empty()); // record is instant
        assert_eq!(sim.event_recorded(ev), Some(Time::from_us(3)));
        let waiters = sim.take_event_waiters(ev);
        assert_eq!(waiters, vec![(g(0), s2)]);
        let a = sim.try_advance(Time::from_us(3), g(0), s2);
        assert!(matches!(a[..], [Action::KernelStarted { .. }]));
    }

    #[test]
    fn wait_on_already_recorded_event_is_instant() {
        let mut sim = GpuSim::new(1);
        let s1 = sim.create_stream(g(0));
        let ev = sim.create_event();
        sim.enqueue(g(0), s1, StreamTask::RecordEvent { event: ev });
        sim.try_advance(Time::ZERO, g(0), s1);
        let s2 = sim.create_stream(g(0));
        sim.enqueue(g(0), s2, StreamTask::WaitEvent { event: ev });
        sim.try_advance(Time::ZERO, g(0), s2);
        assert!(sim.stream_idle(g(0), s2));
    }

    #[test]
    fn flag_reuse_after_reset() {
        let mut sim = GpuSim::new(1);
        let s = sim.create_stream(g(0));
        let flag = sim.alloc_flag();
        sim.set_flag(flag);
        sim.reset_flag(flag);
        sim.enqueue(g(0), s, StreamTask::SpinKernel { flag });
        let a = sim.try_advance(Time::ZERO, g(0), s);
        assert!(matches!(a[..], [Action::SpinParked { .. }]), "reset flag must block");
    }
}
