//! `mma` CLI: the leader entrypoint.
//!
//! ```text
//! mma topo [--preset h20x8]               describe the simulated server
//! mma microbench [--dir h2d] [--size 1GB] [--relays 7] [--policy <name>]
//! mma figure <id|all> [--fast] [--seed N] [--jobs N]
//!                                         regenerate a paper table/figure
//! mma serve [--model qwen-7b] [--ctx 65536] [--docs 4] [--policy <name>]
//!           [--arrival-rate R] [--max-concurrency N] [--fetch-chunks C]
//!           [--gpus N] [--router round-robin|least-loaded]
//!           [--peer-fetch true|false] [--prefix-affinity] [--qos on|off]
//!           [--compute legacy|roofline] [--batching on|off] [--chunk-tokens T]
//! mma switch [--model qwen3-32b] [--policy <name>] [--qos on|off]
//! mma replay [trace.jsonl] [--gpus N] [--policy <name>] [--qos on|off]
//!            [--model qwen-7b] [--sleep-all] [--follow-switches]
//!            [--max N | --fast] [--router ...] [--peer-fetch ...]
//!            [--compute ...] [--batching ...] [--chunk-tokens T]
//!            [--window N]                     streaming reorder window
//! mma trace gen [--out FILE] [--arrivals poisson|bursty|diurnal]
//!               [--rate R] [--burstiness B] [--dwell S] [--period S]
//!               [--requests N] [--tenants K] [--docs D] [--zipf S]
//!               [--ctx T] [--suffix T] [--output-tokens T] [--seed N]
//!               [--warm-start] [--switch-models m1,m2 --phase S]
//! mma bench hotpath [--fast] [--json] [--out FILE] [--out-engine FILE]
//!                   [--out-serving FILE] [--out-fabric FILE]
//!                   [--out-batching FILE]  hot-path perf harness (docs/PERF.md)
//! mma config-check <file.toml>            validate a config file
//! ```
//!
//! Every subcommand accepts `--config <file.toml>`: the file is parsed
//! first, then `MMA_*` env vars, then flags — the same precedence the
//! `[policy]`/`[qos]`/`[workload]` sections document.
//!
//! `mma replay` feeds a JSONL trace (see `docs/CONFIG.md` and
//! `examples/sample_trace.jsonl`) through the serving fleet
//! deterministically: the same trace and configuration print a
//! byte-identical metrics block. The trace is line-streamed through a
//! bounded reorder window (`--window`, `[workload] reorder_window`) so
//! peak ingestion memory is O(window), spilling to whole-trace
//! materialization — same output — only when the trace is more
//! disordered than the window or `--follow-switches` needs the full
//! schedule. With no positional path the `[workload]
//! trace` key (or `MMA_TRACE`) names the input. `mma trace gen`
//! materializes generator output — bursty/diurnal arrivals, multi-tenant
//! Zipf mixes, model-switch schedules — to a file or stdout.
//!
//! `mma figure --jobs N` (also `MMA_JOBS` / `[run] jobs`) fans a sweep's
//! independent cells over N worker threads; results merge in canonical
//! cell order, so output stays byte-identical for any job count.
//!
//! `--policy` selects the transfer policy on any run: `native`,
//! `static-split` (or `static:<gpu>:<w>,...`), `mma-greedy`,
//! `congestion-feedback`, `numa-aware`. The older `--mode mma|native`
//! spelling still works. `--seed N` makes stochastic runners reproducible.
//!
//! `mma serve --arrival-rate R` switches to open-loop mode: `--docs`
//! Poisson arrivals per second of host-tier prefix hits are pushed
//! through the event-driven engine (KV fetches from concurrent requests
//! contend in the fabric); `--max-concurrency` caps admission and
//! `--fetch-chunks` pipelines each fetch with prefill compute.
//!
//! `mma serve --gpus N` (N > 1) runs a serving *fleet*: N per-GPU
//! instances under the event-driven router, all on one SimWorld clock
//! (`[fleet]` TOML section sets the same knobs). `--turns T` repeats each
//! document so later turns exercise peer-NVLink prefix fetches.
//!
//! `--qos on|off` (any run; also the `[qos]` TOML section / `MMA_QOS`)
//! enables the QoS transfer classes: latency-critical prefix fetches
//! outweigh bulk model wakes on every shared link (weighted max-min
//! fabric + class-aware engine issue order). `mma figure qos` reproduces
//! the wake-co-run isolation experiment.
//!
//! `--compute legacy|roofline` (serve/replay; also the `[compute]` TOML
//! section / `MMA_COMPUTE`) selects the kernel-duration source, and
//! `--batching on|off` + `--chunk-tokens T` (the `[batching]` section /
//! `MMA_BATCHING`, `MMA_CHUNK_TOKENS`) enable iteration-level continuous
//! batching with chunked prefill. Both default to legacy/off, which is
//! byte-identical to pre-`[compute]` output; `mma figure batching`
//! sweeps the roofline-priced TTFT/TPOT surface.

use mma::config::RunConfig;
use mma::figures;
use mma::figures::workload_replay::{replay_path, replay_serving_from, ReplayOptions};
use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::models;
use mma::policy::PolicySpec;
use mma::serving::RoutePolicy;
use mma::topology::{Direction, GpuId, NumaId, Preset};
use mma::util::cli::Args;
use mma::util::fmt;
use mma::util::rng::Rng;
use mma::workload::{model_switch_trace, TraceGen};

/// Engine config for a run: start from the resolved run config's
/// `[mma]`/`[policy]`/`[qos]` state (file → env already applied), then
/// let flags override — the documented precedence.
fn mma_cfg(args: &Args, base: &MmaConfig) -> MmaConfig {
    let mut cfg = match args.get("mode") {
        Some("native") => MmaConfig::native(),
        Some(_) => MmaConfig::default(),
        None => base.clone(),
    };
    if let Some(p) = args.get("policy") {
        let spec = PolicySpec::parse(p).unwrap_or_else(|| {
            eprintln!(
                "unknown policy {p:?}; one of native, static-split, \
                 static:<gpu>:<w>[,...], mma-greedy, congestion-feedback, numa-aware"
            );
            std::process::exit(2);
        });
        if let Err(e) = spec.validate(Preset::H20x8.build().gpu_count()) {
            eprintln!("invalid --policy: {e}");
            std::process::exit(2);
        }
        cfg.set_policy(spec);
    }
    if let Some(r) = args.get_as::<usize>("relays") {
        let topo = Preset::H20x8.build();
        cfg.relay_gpus = Some(
            topo.relay_order(GpuId(0), &[])
                .into_iter()
                .take(r)
                .collect(),
        );
    }
    if let Some(q) = args.get("qos") {
        cfg.qos.enabled = match q.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" | "yes" => true,
            "off" | "false" | "0" | "no" => false,
            other => {
                eprintln!("--qos: expected on|off, got {other:?}");
                std::process::exit(2);
            }
        };
    }
    cfg.chunk_bytes = args.size_or("chunk", cfg.chunk_bytes);
    cfg.outstanding_depth = args.or("depth", cfg.outstanding_depth);
    cfg
}

fn model_by_name(name: &str) -> models::ModelSpec {
    models::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown model {name:?}; using qwen-7b-chat");
        models::qwen_7b_chat()
    })
}

/// Fleet config for a run: the resolved `[fleet]` section with the
/// `--gpus`/`--router`/`--peer-fetch`/`--prefix-affinity` flag overrides
/// (shared by the serve-fleet and replay arms so the two cannot drift).
fn fleet_cfg(args: &Args, cfg: &RunConfig) -> mma::config::FleetConfig {
    let router = match args.get("router") {
        Some(r) => RoutePolicy::parse(r).unwrap_or_else(|| {
            eprintln!("unknown router {r:?}; round-robin | least-loaded");
            std::process::exit(2);
        }),
        None => cfg.fleet.router,
    };
    let peer_fetch = match args.get("peer-fetch") {
        Some(v) => matches!(v, "true" | "1" | "yes"),
        None => cfg.fleet.peer_fetch,
    };
    mma::config::FleetConfig {
        gpus: args.or("gpus", cfg.fleet.gpus).max(1),
        router,
        peer_fetch,
        prefix_affinity: args.flag("prefix-affinity") || cfg.fleet.prefix_affinity,
    }
}

/// Apply the `--compute` / `--batching` / `--chunk-tokens` flag
/// overrides to a resolved serving config (file → env already applied;
/// shared by the serve and replay arms so the two cannot drift).
fn serving_overrides(
    args: &Args,
    mut serving: mma::config::ServingConfig,
) -> mma::config::ServingConfig {
    if let Some(v) = args.get("compute") {
        serving.compute = mma::config::ComputeSource::parse(v).unwrap_or_else(|| {
            eprintln!("--compute: expected legacy|roofline, got {v:?}");
            std::process::exit(2);
        });
    }
    if let Some(v) = args.get("batching") {
        serving.batching.enabled = match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" | "yes" => true,
            "off" | "false" | "0" | "no" => false,
            other => {
                eprintln!("--batching: expected on|off, got {other:?}");
                std::process::exit(2);
            }
        };
    }
    serving.batching.chunk_tokens = args.or("chunk-tokens", serving.batching.chunk_tokens);
    serving
}

fn main() {
    let args = Args::from_env();
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("--config {path}: {e}");
                std::process::exit(2);
            });
            RunConfig::from_toml(&text).unwrap_or_else(|e| {
                eprintln!("--config {path}: {e}");
                std::process::exit(2);
            })
        }
        None => RunConfig::default(),
    };
    cfg.apply_env();
    let seed = args.seed_or(figures::DEFAULT_SEED);
    match args.pos(0).unwrap_or("help") {
        "topo" => {
            let preset = Preset::parse(&args.str_or("preset", "h20x8")).unwrap_or(Preset::H20x8);
            print!("{}", preset.build().describe());
        }
        "microbench" => {
            let dir = match args.str_or("dir", "h2d").as_str() {
                "d2h" => Direction::D2H,
                _ => Direction::H2D,
            };
            let bytes = args.size_or("size", 1 << 30);
            let mcfg = mma_cfg(&args, &cfg.mma);
            let policy = mcfg.policy.name();
            let mut w = SimWorld::new(cfg.topology(), mcfg);
            let s = w.stream(GpuId(0));
            let t = w.memcpy_async(s, TransferDesc::new(dir, GpuId(0), NumaId(0), bytes));
            w.run_until_transfer(t);
            let rec = w.rec(t);
            println!(
                "{} {} via {}: {} ({} direct / {} relay)",
                dir.label(),
                fmt::bytes(bytes),
                policy,
                fmt::gbps(rec.bandwidth().unwrap_or(0.0)),
                fmt::bytes(rec.bytes_direct),
                fmt::bytes(rec.bytes_relay),
            );
        }
        "figure" => {
            let id = args.pos(1).unwrap_or("all");
            let fast = args.flag("fast");
            // Precedence: --jobs flag → MMA_JOBS (already folded into the
            // run config by apply_env) → [run] jobs → 1.
            figures::set_jobs(args.or("jobs", cfg.jobs).max(1));
            if id == "all" {
                for id in figures::all_ids() {
                    println!("\n===== figure {id} =====");
                    print!("{}", figures::run_by_name(id, fast, seed).unwrap());
                }
            } else {
                match figures::run_by_name(id, fast, seed) {
                    Some(s) => print!("{s}"),
                    None => {
                        eprintln!("unknown figure {id:?}; one of {:?}", figures::all_ids());
                        std::process::exit(2);
                    }
                }
            }
        }
        "serve" => {
            let model = model_by_name(&args.str_or("model", "qwen-7b-chat"));
            let ctx: u32 = args.or("ctx", 65_536);
            let docs: usize = args.or("docs", 4);
            let mcfg = mma_cfg(&args, &cfg.mma);
            let policy = mcfg.policy.name();
            let rate: f64 = args.or("arrival-rate", cfg.serving.arrival_rate_rps);
            let gpus: u32 = args.or("gpus", cfg.fleet.gpus);
            if gpus > 1 {
                // Fleet mode: N per-GPU instances under the event-driven
                // router, one SimWorld clock, shared host prefix tier.
                let fleet = fleet_cfg(&args, &cfg);
                let (router, peer_fetch) = (fleet.router, fleet.peer_fetch);
                let turns: u32 = args.or("turns", 3);
                let rate = if rate > 0.0 {
                    rate
                } else {
                    // Fleet mode is open-loop only; make the fallback loud
                    // rather than silently overriding a configured 0.
                    eprintln!("fleet mode is open-loop: defaulting to 2 req/s \
                               (set --arrival-rate R to change)");
                    2.0
                };
                // Same base as the single-GPU open-loop branch: the run
                // config's [serving] section is honored (tp, PD mode,
                // batch/seq knobs); only the pools and batch budget are
                // widened so admission, not capacity, governs concurrency.
                // NB: peer-NVLink fetches show up in aggregated mode
                // ([serving] pd_disaggregation = false) — PD mode offloads
                // prefill KV to host right away, leaving no GPU-resident
                // copy for siblings to pull.
                let serving = serving_overrides(
                    &args,
                    mma::config::ServingConfig {
                        arrival_rate_rps: rate,
                        max_concurrency: args.or("max-concurrency", cfg.serving.max_concurrency),
                        fetch_chunks: args.or("fetch-chunks", cfg.serving.fetch_chunks),
                        gpu_kv_blocks: 1 << 20,
                        host_kv_blocks: 1 << 22,
                        max_batch_tokens: 512 * 1024,
                        ..cfg.serving.clone()
                    },
                );
                let r = figures::fleet_scaling::fleet_run(
                    &model,
                    ctx,
                    mcfg,
                    serving,
                    fleet,
                    docs.max(1),
                    turns.max(1),
                    seed,
                );
                println!(
                    "{} ctx={}k gpus={gpus} router={} peer-fetch={peer_fetch} rate={rate}/s \
                     policy={policy}: mean TTFT {}, p99 {} \
                     (fetches: {} host, {} peer; per-instance {:?})",
                    model.name,
                    ctx / 1024,
                    router.name(),
                    fmt::secs(r.mean_ttft),
                    fmt::secs(r.p99_ttft),
                    r.host_fetches,
                    r.peer_fetches,
                    r.per_instance,
                );
            } else if rate > 0.0 {
                // Open-loop mode: Poisson arrivals of host-tier prefix
                // hits on the event-driven engine (fetches contend).
                // Base = the run config's serving section (tp, PD mode,
                // batch/seq knobs all honored); only the pools and batch
                // budget are widened so admission, not capacity, governs
                // the measured concurrency.
                let serving = serving_overrides(
                    &args,
                    mma::config::ServingConfig {
                        arrival_rate_rps: rate,
                        max_concurrency: args.or("max-concurrency", cfg.serving.max_concurrency),
                        fetch_chunks: args.or("fetch-chunks", cfg.serving.fetch_chunks),
                        gpu_kv_blocks: 1 << 20,
                        host_kv_blocks: 1 << 22,
                        max_batch_tokens: 512 * 1024,
                        ..cfg.serving.clone()
                    },
                );
                let (mean, p99) = figures::serve_concurrency::concurrency_run(
                    &model,
                    ctx,
                    mcfg,
                    serving,
                    docs.max(1),
                    seed,
                );
                println!(
                    "{} ctx={}k rate={rate}/s n={} policy={policy}: mean TTFT {}, p99 {}",
                    model.name,
                    ctx / 1024,
                    docs.max(1),
                    fmt::secs(mean),
                    fmt::secs(p99),
                );
            } else {
                let (ttft, frac) = figures::serving_figs::qa_ttft(&model, ctx, mcfg, docs, seed);
                println!(
                    "{} ctx={}k docs={docs} policy={policy}: mean TTFT {} (fetch share {:.0}%)",
                    model.name,
                    ctx / 1024,
                    fmt::secs(ttft),
                    frac * 100.0
                );
            }
        }
        "switch" => {
            let model = model_by_name(&args.str_or("model", "qwen3-32b"));
            let mcfg = mma_cfg(&args, &cfg.mma);
            let policy = mcfg.policy.name();
            let (s, w) = figures::serving_figs::sleep_wake(&model, mcfg);
            println!(
                "{} policy={policy}: sleep {} (transfer {:.0}%), wake {} (transfer {:.0}%)",
                model.name,
                fmt::secs(s.total().as_secs_f64()),
                s.transfer_fraction() * 100.0,
                fmt::secs(w.total().as_secs_f64()),
                w.transfer_fraction() * 100.0,
            );
        }
        "replay" => {
            let path = args
                .pos(1)
                .map(str::to_string)
                .or_else(|| cfg.workload.trace.clone());
            let Some(path) = path else {
                eprintln!(
                    "usage: mma replay <trace.jsonl> (or set [workload] trace / MMA_TRACE)"
                );
                std::process::exit(2);
            };
            let mcfg = mma_cfg(&args, &cfg.mma);
            let policy = mcfg.policy.name();
            let qos_on = mcfg.qos.enabled;
            let fleet = fleet_cfg(&args, &cfg);
            let gpus = fleet.gpus;
            let model = model_by_name(&args.str_or("model", "qwen-7b-chat"));
            let opts = ReplayOptions {
                sleep_all: args.flag("sleep-all"),
                follow_switches: args.flag("follow-switches"),
                max_requests: if args.flag("fast") {
                    64
                } else {
                    args.or::<usize>("max", 0)
                },
            };
            // Honor the run config's [serving] section (tp, block sizes,
            // fetch_chunks, PD mode ...); only the pools and batch
            // budget are widened so admission, not capacity, governs
            // concurrency. NB: as with serve, peer-NVLink fetches show
            // up in aggregated mode ([serving] pd_disaggregation =
            // false) — PD mode offloads prefill KV to host right away.
            let serving = serving_overrides(
                &args,
                mma::config::ServingConfig {
                    fetch_chunks: args.or("fetch-chunks", cfg.serving.fetch_chunks),
                    ..replay_serving_from(&cfg.serving)
                },
            );
            // Streaming ingestion: the trace is line-streamed through a
            // bounded reorder window (O(window) resident records); a
            // trace more disordered than the window — or a
            // --follow-switches run, which needs the whole schedule —
            // spills to the materialized path with identical output.
            let window = args.or("window", cfg.workload.reorder_window as usize);
            let report = replay_path(&path, &model, mcfg, serving, fleet, &opts, window)
                .unwrap_or_else(|e| {
                    eprintln!("invalid trace: {e}");
                    std::process::exit(1);
                });
            println!(
                "replay {path}: {} records, gpus={gpus} policy={policy} qos={}",
                report.requests,
                if qos_on { "on" } else { "off" },
            );
            print!("{}", report.render());
        }
        "trace" => {
            if args.pos(1) != Some("gen") {
                eprintln!(
                    "usage: mma trace gen [--out FILE] [--arrivals poisson|bursty|diurnal] \
                     [--rate R] [--requests N] [--tenants K] [--docs D] [--zipf S] \
                     [--ctx T] [--seed N] [--switch-models m1,m2 --phase S]"
                );
                std::process::exit(2);
            }
            let mut w = cfg.workload.clone();
            if let Some(v) = args.get("arrivals") {
                w.arrivals = v.to_string();
            }
            w.rate_rps = args.or("rate", w.rate_rps);
            w.burstiness = args.or("burstiness", w.burstiness);
            w.dwell_s = args.or("dwell", w.dwell_s);
            w.period_s = args.or("period", w.period_s);
            w.requests = args.or("requests", w.requests);
            w.tenants = args.or("tenants", w.tenants);
            w.docs_per_tenant = args.or("docs", w.docs_per_tenant);
            w.zipf_s = args.or("zipf", w.zipf_s);
            w.context_tokens = args.or("ctx", w.context_tokens);
            w.suffix_tokens = args.or("suffix", w.suffix_tokens);
            w.output_tokens = args.or("output-tokens", w.output_tokens);
            w.warm_start = args.flag("warm-start") || w.warm_start;
            if let Err(e) = w.validate() {
                eprintln!("invalid workload parameters: {e}");
                std::process::exit(2);
            }
            let mut rng = Rng::seed_from_u64(seed);
            let trace = match args.get("switch-models") {
                Some(_) => {
                    let names = args.list("switch-models");
                    if names.is_empty() {
                        eprintln!("--switch-models: need at least one model name");
                        std::process::exit(2);
                    }
                    model_switch_trace(
                        &mut rng,
                        &names,
                        w.rate_rps,
                        args.or("phase", 10.0),
                        w.context_tokens,
                        w.requests as usize,
                    )
                }
                None => TraceGen::from_config(&w).generate(&mut rng),
            };
            match args.get("out") {
                Some(path) => {
                    trace.save(path).unwrap_or_else(|e| {
                        eprintln!("{e}");
                        std::process::exit(1);
                    });
                    eprintln!("wrote {} records to {path}", trace.records.len());
                }
                None => print!("{}", trace.render()),
            }
        }
        "bench" => {
            if args.pos(1) != Some("hotpath") {
                eprintln!(
                    "usage: mma bench hotpath [--fast] [--json] [--out FILE] \
                     [--out-engine FILE] [--out-serving FILE] [--out-fabric FILE] \
                     [--out-batching FILE]"
                );
                std::process::exit(2);
            }
            let fast = args.flag("fast");
            let report = mma::perf::run_hotpath(fast);
            if !report.replay_deterministic {
                eprintln!("FATAL: incremental and reference replays diverged");
                std::process::exit(1);
            }
            if let Some(path) = args.get("out") {
                std::fs::write(path, report.to_json()).unwrap_or_else(|e| {
                    eprintln!("--out {path}: {e}");
                    std::process::exit(1);
                });
                eprintln!("wrote {path}");
            }
            // The BENCH_0007 engine leg: measured alongside the hotpath
            // harness so one CI invocation produces both documents.
            let engine = mma::perf::run_engine_bench(fast);
            if engine.engine.steady_state_allocs != 0 {
                eprintln!(
                    "FATAL: engine steady state allocated ({} sink growths)",
                    engine.engine.steady_state_allocs
                );
                std::process::exit(1);
            }
            if let Some(path) = args.get("out-engine") {
                std::fs::write(path, engine.to_json()).unwrap_or_else(|e| {
                    eprintln!("--out-engine {path}: {e}");
                    std::process::exit(1);
                });
                eprintln!("wrote {path}");
            }
            // The BENCH_0008 serving leg: LRU tier churn, streaming
            // histogram, and the streamed replay path vs its
            // materialized oracle.
            let serving =
                mma::perf::run_serving_bench_bins(fast, cfg.metrics.histogram_bins as usize);
            if !serving.serving.streaming_identical {
                eprintln!("FATAL: streamed and materialized replays diverged");
                std::process::exit(1);
            }
            if let Some(path) = args.get("out-serving") {
                std::fs::write(path, serving.to_json()).unwrap_or_else(|e| {
                    eprintln!("--out-serving {path}: {e}");
                    std::process::exit(1);
                });
                eprintln!("wrote {path}");
            }
            // The BENCH_0009 fabric leg: chunked churn through the
            // O(due) event loop, with the coalescing and zero-alloc
            // bars enforced here.
            let fabric = mma::perf::run_fabric_bench(fast);
            if !fabric.fabric.coalesced_identical {
                eprintln!("FATAL: coalesced and eager fabric runs diverged");
                std::process::exit(1);
            }
            if fabric.fabric.alloc_growth != 0 {
                eprintln!(
                    "FATAL: steady-state flow starts allocated ({} container growths)",
                    fabric.fabric.alloc_growth
                );
                std::process::exit(1);
            }
            if fabric.fabric.solves_per_event >= 1.0 {
                eprintln!(
                    "FATAL: solve coalescing collapsed no cascades ({:.3} solves/event)",
                    fabric.fabric.solves_per_event
                );
                std::process::exit(1);
            }
            if let Some(path) = args.get("out-fabric") {
                std::fs::write(path, fabric.to_json()).unwrap_or_else(|e| {
                    eprintln!("--out-fabric {path}: {e}");
                    std::process::exit(1);
                });
                eprintln!("wrote {path}");
            }
            // The BENCH_0010 batching leg: roofline-priced fused steps,
            // with the memory-wall and legacy-identity bars enforced
            // here.
            let batching = mma::perf::run_batching_bench(fast);
            if !batching.batching.decode_kv_monotone {
                eprintln!("FATAL: decode step time did not grow with aggregate KV bytes");
                std::process::exit(1);
            }
            if !batching.batching.legacy_identical {
                eprintln!(
                    "FATAL: batch-1 continuous batching diverged from the \
                     per-request scheduler"
                );
                std::process::exit(1);
            }
            if let Some(path) = args.get("out-batching") {
                std::fs::write(path, batching.to_json()).unwrap_or_else(|e| {
                    eprintln!("--out-batching {path}: {e}");
                    std::process::exit(1);
                });
                eprintln!("wrote {path}");
            }
            if args.flag("json") {
                print!("{}", report.to_json());
            } else {
                print!(
                    "{}{}{}{}{}",
                    report.render(),
                    engine.render(),
                    serving.render(),
                    fabric.render(),
                    batching.render()
                );
            }
        }
        "config-check" => {
            let path = args.pos(1).expect("usage: mma config-check <file.toml>");
            let text = std::fs::read_to_string(path).expect("read config");
            match RunConfig::from_toml(&text) {
                Ok(c) => println!(
                    "ok: preset={:?}, policy={}, chunk={}",
                    c.preset,
                    c.mma.policy.name(),
                    c.mma.chunk_bytes
                ),
                Err(e) => {
                    eprintln!("invalid config: {e}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            println!("mma — Multipath Memory Access (paper reproduction)");
            println!(
                "subcommands: topo | microbench | figure <id|all> | serve | switch | \
                 replay <trace> | trace gen | bench hotpath | config-check"
            );
            println!("figures: {:?}", figures::all_ids());
            println!(
                "policies (--policy): native | static-split | static:<gpu>:<w>[,...] | \
                 mma-greedy | congestion-feedback | numa-aware"
            );
            println!("qos (--qos on|off): weighted transfer classes (see `figure qos`)");
            println!(
                "workloads: `mma trace gen` writes JSONL traces (poisson | bursty | \
                 diurnal arrivals, multi-tenant Zipf mixes, --switch-models schedules); \
                 `mma replay <trace>` feeds one through the fleet deterministically"
            );
            println!("docs: rust/README.md, docs/PAPER_MAP.md, docs/CONFIG.md");
        }
    }
}
