//! `mma` CLI: the leader entrypoint.
//!
//! ```text
//! mma topo [--preset h20x8]               describe the simulated server
//! mma microbench [--dir h2d] [--size 1GB] [--relays 7] [--mode mma|native]
//! mma figure <id|all> [--fast]            regenerate a paper table/figure
//! mma serve [--model qwen-7b] [--ctx 65536] [--docs 4] [--mode mma|native]
//! mma switch [--model qwen3-32b] [--mode mma|native]
//! mma config-check <file.toml>            validate a config file
//! ```

use mma::config::RunConfig;
use mma::figures;
use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::models;
use mma::topology::{Direction, GpuId, NumaId, Preset};
use mma::util::cli::Args;
use mma::util::fmt;

fn mma_cfg(args: &Args) -> MmaConfig {
    let mut cfg = match args.str_or("mode", "mma").as_str() {
        "native" => MmaConfig::native(),
        _ => MmaConfig::default(),
    };
    if let Some(r) = args.get_as::<usize>("relays") {
        let topo = Preset::H20x8.build();
        cfg.relay_gpus = Some(
            topo.relay_order(GpuId(0), &[])
                .into_iter()
                .take(r)
                .collect(),
        );
    }
    cfg.chunk_bytes = args.size_or("chunk", cfg.chunk_bytes);
    cfg.outstanding_depth = args.or("depth", cfg.outstanding_depth);
    cfg
}

fn model_by_name(name: &str) -> models::ModelSpec {
    match name.to_ascii_lowercase().as_str() {
        "qwen3-0.6b" | "0.6b" => models::qwen3_0_6b(),
        "qwen3-4b" | "4b" => models::qwen3_4b(),
        "qwen-7b" | "qwen-7b-chat" | "7b" => models::qwen_7b_chat(),
        "qwen3-32b" | "32b" => models::qwen3_32b(),
        "tiny" => models::tiny_serve(),
        other => {
            eprintln!("unknown model {other:?}; using qwen-7b-chat");
            models::qwen_7b_chat()
        }
    }
}

fn main() {
    let args = Args::from_env();
    let mut cfg = RunConfig::default();
    cfg.apply_env();
    match args.pos(0).unwrap_or("help") {
        "topo" => {
            let preset = Preset::parse(&args.str_or("preset", "h20x8")).unwrap_or(Preset::H20x8);
            print!("{}", preset.build().describe());
        }
        "microbench" => {
            let dir = match args.str_or("dir", "h2d").as_str() {
                "d2h" => Direction::D2H,
                _ => Direction::H2D,
            };
            let bytes = args.size_or("size", 1 << 30);
            let mcfg = mma_cfg(&args);
            let mut w = SimWorld::new(cfg.topology(), mcfg);
            let s = w.stream(GpuId(0));
            let t = w.memcpy_async(s, TransferDesc::new(dir, GpuId(0), NumaId(0), bytes));
            w.run_until_transfer(t);
            let rec = w.rec(t);
            println!(
                "{} {} via {}: {} ({} direct / {} relay)",
                dir.label(),
                fmt::bytes(bytes),
                args.str_or("mode", "mma"),
                fmt::gbps(rec.bandwidth().unwrap_or(0.0)),
                fmt::bytes(rec.bytes_direct),
                fmt::bytes(rec.bytes_relay),
            );
        }
        "figure" => {
            let id = args.pos(1).unwrap_or("all");
            let fast = args.flag("fast");
            if id == "all" {
                for id in figures::all_ids() {
                    println!("\n===== figure {id} =====");
                    print!("{}", figures::run_by_name(id, fast).unwrap());
                }
            } else {
                match figures::run_by_name(id, fast) {
                    Some(s) => print!("{s}"),
                    None => {
                        eprintln!("unknown figure {id:?}; one of {:?}", figures::all_ids());
                        std::process::exit(2);
                    }
                }
            }
        }
        "serve" => {
            let model = model_by_name(&args.str_or("model", "qwen-7b-chat"));
            let ctx: u32 = args.or("ctx", 65_536);
            let docs: usize = args.or("docs", 4);
            let mcfg = mma_cfg(&args);
            let (ttft, frac) = figures::serving_figs::qa_ttft(&model, ctx, mcfg, docs);
            println!(
                "{} ctx={}k docs={docs} mode={}: mean TTFT {} (fetch share {:.0}%)",
                model.name,
                ctx / 1024,
                args.str_or("mode", "mma"),
                fmt::secs(ttft),
                frac * 100.0
            );
        }
        "switch" => {
            let model = model_by_name(&args.str_or("model", "qwen3-32b"));
            let mcfg = mma_cfg(&args);
            let (s, w) = figures::serving_figs::sleep_wake(&model, mcfg);
            println!(
                "{} mode={}: sleep {} (transfer {:.0}%), wake {} (transfer {:.0}%)",
                model.name,
                args.str_or("mode", "mma"),
                fmt::secs(s.total().as_secs_f64()),
                s.transfer_fraction() * 100.0,
                fmt::secs(w.total().as_secs_f64()),
                w.transfer_fraction() * 100.0,
            );
        }
        "config-check" => {
            let path = args.pos(1).expect("usage: mma config-check <file.toml>");
            let text = std::fs::read_to_string(path).expect("read config");
            match RunConfig::from_toml(&text) {
                Ok(c) => println!("ok: preset={:?}, chunk={}", c.preset, c.mma.chunk_bytes),
                Err(e) => {
                    eprintln!("invalid config: {e}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            println!("mma — Multipath Memory Access (paper reproduction)");
            println!("subcommands: topo | microbench | figure <id|all> | serve | switch | config-check");
            println!("figures: {:?}", figures::all_ids());
        }
    }
}
