//! # MMA — Multipath Memory Access
//!
//! Reproduction of *"Multipath Memory Access: Breaking Host-GPU Bandwidth
//! Bottlenecks in LLM Serving"* (Tang et al., 2025).
//!
//! MMA expands a single host↔GPU memory copy across the target GPU's direct
//! PCIe path plus relay paths through peer GPUs (peer PCIe link + NVLink
//! hop), within one multi-GPU server, without hardware/driver/application
//! changes.
//!
//! Because the paper's testbed (8×NVIDIA H20) is a hardware gate, this
//! crate ships a high-fidelity substrate:
//!
//! * [`sim`] — discrete-event simulation core (virtual nanosecond clock).
//! * [`topology`] — intra-server interconnect model (PCIe/NVLink/xGMI/DRAM).
//! * [`fabric`] — flow-level bandwidth simulator (weighted max-min fair
//!   sharing with per-flow QoS weights and rate caps; all weights equal
//!   degenerates to the classic unweighted allocation).
//! * [`gpusim`] — CUDA-semantics execution model (streams/events/kernels).
//!
//! and the paper's system on top:
//!
//! * [`mma`] — Transfer Task Interceptor, Sync Engine, Multipath Transfer
//!   Engine (Task Manager / Task Launcher); placement is delegated to a
//!   policy. Every transfer carries a QoS [`mma::TransferClass`]
//!   (latency-critical / interactive / bulk / background) honored by the
//!   fabric weights, the engine's class-aware issue order, and the
//!   serving layer's tagging (`[qos]` config section).
//! * [`policy`] — the pluggable transfer-policy layer: one
//!   [`policy::TransferPolicy`] trait, with the paper's greedy selector,
//!   the native and static-split baselines, and adaptive strategies
//!   (congestion feedback, NUMA-aware) as interchangeable implementations.
//! * [`serving`] — vLLM-like serving layer: a fleet of per-GPU serving
//!   instances (paged KV cache, GPU prefix tier, continuous batching, PD
//!   scheduling) under an event-driven router, over a fleet-shared host
//!   prefix tier with peer-NVLink fetches, plus the sleep/wake model
//!   registry.
//! * [`runtime`] — PJRT client: loads AOT-compiled JAX/Pallas artifacts and
//!   executes the real model on the serving path (stubbed without the
//!   `pjrt` feature).
//! * [`workload`] — trace-driven and generated request streams: the
//!   versioned JSONL trace format (`mma replay` / `mma trace gen`),
//!   Poisson / MMPP-bursty / diurnal arrival processes, multi-tenant
//!   mixes with Zipf document popularity, and model-switch schedules.
//! * [`figures`] — one runner per paper table/figure, plus the
//!   cross-policy `policy_sweep` and the repo's own serving sweeps
//!   (`serve_concurrency`, `fleet_scaling`, `qos_isolation`,
//!   `workload_replay`).
//!
//! The docs book under `docs/` maps paper sections to modules
//! (`docs/PAPER_MAP.md`) and documents every configuration surface
//! (`docs/CONFIG.md`).

// Every public item carries documentation; the CI lint job enforces it
// (clippy runs with -D warnings, which promotes this lint).
#![warn(missing_docs)]

pub mod testkit;
pub mod util;
pub mod config;
pub mod fabric;
pub mod figures;
pub mod gpusim;
pub mod memory;
pub mod metrics;
pub mod mma;
pub mod models;
pub mod perf;
pub mod policy;
pub mod roofline;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod topology;
pub mod workload;

/// Crate-wide error type (offline build: no `anyhow`).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
