//! H20 compute-time roofline model.
//!
//! The figure harnesses need *compute* time (prefill, decode) for
//! paper-scale models to put transfer time in context (Fig 2's "fetch
//! fraction of TTFT", Fig 12's end-to-end TTFT). The live end-to-end
//! example uses real PJRT execution of the tiny model; paper-scale models
//! use this roofline: time = max(flops/peak_flops, bytes/hbm_bw) / eff.
//!
//! H20 characteristics: ~148 TFLOPS dense FP16/BF16, ~4.0 TB/s HBM3.

use crate::models::ModelSpec;

/// GPU compute/memory capability for roofline estimates.
#[derive(Clone, Copy, Debug)]
pub struct GpuRoofline {
    /// Peak dense FP16 FLOPs/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Achievable fraction of peak in a tuned serving stack.
    pub efficiency: f64,
    /// Fixed per-step launch/framework overhead, seconds.
    pub step_overhead_s: f64,
}

/// NVIDIA H20 (the paper's testbed GPU).
pub fn h20() -> GpuRoofline {
    GpuRoofline {
        peak_flops: 148e12,
        hbm_bps: 4.0e12,
        efficiency: 0.55,
        step_overhead_s: 2.0e-3,
    }
}

impl GpuRoofline {
    /// Prefill time for `new_tokens` of a model with `context` total
    /// attended tokens, tensor-parallel over `tp` GPUs.
    ///
    /// Each TP rank holds 1/tp of the weights and does 1/tp of the
    /// FLOPs, so *both* legs are sharded before the roofline max — the
    /// per-GPU weight-streaming floor is `weight_bytes/tp`, not
    /// `max(compute, weights)/tp` applied after the envelope.
    pub fn prefill_secs(&self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        let tp = tp.max(1) as f64;
        let flops = m.flops_per_token(context) * new_tokens as f64;
        // Prefill is compute-bound: weights stream once per step.
        let compute = flops / (self.peak_flops * self.efficiency) / tp;
        let weights = m.weight_bytes() as f64 / self.hbm_bps / tp;
        compute.max(weights) + self.step_overhead_s
    }

    /// Per-output-token decode time (memory-bound: weights + KV stream).
    pub fn decode_secs_per_token(&self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        self.decode_step_secs(m, m.kv_bytes(context), 1, context, tp)
    }

    /// One decode iteration over a whole continuous batch: every rank
    /// streams its weight shard once plus its shard of the *aggregate*
    /// KV resident for the batch (`batch_kv_bytes = Σ KV(context_i)`),
    /// while the FLOPs leg scales with the batch's token count. This is
    /// the memory-wall regime: step time grows with batch × context ×
    /// KV bytes while weights amortize across the batch.
    pub fn decode_step_secs(
        &self,
        m: &ModelSpec,
        batch_kv_bytes: u64,
        batch: u32,
        max_context: u64,
        tp: u32,
    ) -> f64 {
        let tp = tp.max(1) as f64;
        let bytes = m.weight_bytes() as f64 + batch_kv_bytes as f64;
        let mem = bytes / (self.hbm_bps * self.efficiency) / tp;
        let flops =
            batch as f64 * m.flops_per_token(max_context) / (self.peak_flops * self.efficiency)
                / tp;
        mem.max(flops) + self.step_overhead_s
    }

    /// One fused continuous-batching step: a chunked-prefill leg
    /// (`prefill_tokens` attending `prefill_context`) sharing the
    /// iteration with `decode_batch` decode legs carrying
    /// `decode_kv_bytes` aggregate KV. Weights stream once for the
    /// whole step; the launch overhead is paid once, not per leg.
    #[allow(clippy::too_many_arguments)]
    pub fn step_secs(
        &self,
        m: &ModelSpec,
        prefill_tokens: u64,
        prefill_context: u64,
        decode_kv_bytes: u64,
        decode_batch: u32,
        max_decode_context: u64,
        tp: u32,
    ) -> f64 {
        let tp = tp.max(1) as f64;
        let flops = m.flops_per_token(prefill_context) * prefill_tokens as f64
            + decode_batch as f64 * m.flops_per_token(max_decode_context);
        let compute = flops / (self.peak_flops * self.efficiency) / tp;
        let bytes = m.weight_bytes() as f64 + decode_kv_bytes as f64;
        let mem = bytes / (self.hbm_bps * self.efficiency) / tp;
        compute.max(mem) + self.step_overhead_s
    }

    /// The prefill token count where the compute leg overtakes the
    /// weight-streaming leg at a fixed attended `context`: below this,
    /// `prefill_secs` is flat in `new_tokens` (weights-bound); above,
    /// it grows linearly (compute-bound).
    pub fn prefill_crossover_tokens(&self, m: &ModelSpec, context: u64) -> f64 {
        let weights_s = m.weight_bytes() as f64 / self.hbm_bps;
        weights_s * (self.peak_flops * self.efficiency) / m.flops_per_token(context)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{qwen3_0_6b, qwen3_32b, qwen_7b_chat};

    #[test]
    fn prefill_scales_with_tokens_and_model() {
        let g = h20();
        let small = g.prefill_secs(&qwen3_0_6b(), 16_384, 16_384, 1);
        let big = g.prefill_secs(&qwen3_32b(), 16_384, 16_384, 1);
        assert!(big > 10.0 * small, "32B prefill {big} vs 0.6B {small}");
        let longer = g.prefill_secs(&qwen3_0_6b(), 65_536, 65_536, 1);
        assert!(longer > 3.0 * small);
    }

    #[test]
    fn fig2_regime_fetch_can_dominate_ttft() {
        // Sanity for Fig 2: at 64k tokens on Qwen-7B-Chat, the KV fetch
        // over one PCIe link (~53.6 GB/s) should be comparable to or larger
        // than prefill-of-suffix compute, allowing fetch fractions ≥50%.
        let g = h20();
        let m = qwen_7b_chat();
        let fetch_s = m.kv_bytes(64 * 1024) as f64 / 53.6e9;
        // On a prefix hit only a small suffix is prefences — say 256 tokens.
        let prefill_s = g.prefill_secs(&m, 256, 64 * 1024, 1);
        assert!(
            fetch_s > prefill_s,
            "fetch {fetch_s:.3}s must dominate suffix prefill {prefill_s:.3}s"
        );
    }

    #[test]
    fn decode_is_memory_bound_for_7b() {
        let g = h20();
        let m = qwen_7b_chat();
        let t = g.decode_secs_per_token(&m, 8_192, 1);
        // ~15.4 GB weights / (4 TB/s * 0.55) ≈ 7 ms + overhead.
        assert!(t > 5e-3 && t < 30e-3, "decode tok time {t}");
    }

    #[test]
    fn tp_divides_compute() {
        let g = h20();
        let m = qwen3_32b();
        let t1 = g.prefill_secs(&m, 32_768, 32_768, 1);
        let t4 = g.prefill_secs(&m, 32_768, 32_768, 4);
        assert!(t4 < t1 / 2.0);
    }

    #[test]
    fn tp8_shards_the_weight_streaming_floor_too() {
        // Regression for the prefill tp bug: both legs must be divided
        // by tp *before* the roofline max. A weights-bound prefill (few
        // new tokens) gets its floor sharded 8×; the old
        // max-then-divide form left the unsharded weight-streaming
        // floor in place.
        let g = h20();
        let m = qwen3_32b();
        let x = g.prefill_crossover_tokens(&m, 8_192);
        let few = (x * 0.25).max(1.0) as u64; // safely weights-bound
        let t1 = g.prefill_secs(&m, few, 8_192, 1) - g.step_overhead_s;
        let t8 = g.prefill_secs(&m, few, 8_192, 8) - g.step_overhead_s;
        let want = m.weight_bytes() as f64 / g.hbm_bps / 8.0;
        assert!(
            (t8 - want).abs() < 1e-12,
            "tp=8 weights floor {t8} vs expected {want}"
        );
        assert!(
            (t1 / t8 - 8.0).abs() < 1e-9,
            "weights-bound prefill must shard 8x: {t1} vs {t8}"
        );
    }

    #[test]
    fn decode_time_is_monotone_in_context_and_batch_kv() {
        // Property (testkit): the decode memory wall only ever gets
        // taller — per-token decode time is non-decreasing in attended
        // context, and a fused decode step is non-decreasing in the
        // batch's aggregate KV bytes, for any plausible architecture.
        crate::testkit::check("decode-monotone", |rng| {
            let g = h20();
            let m = crate::models::sample_spec(rng);
            let tp = [1u32, 2, 4, 8][rng.range_usize(0, 4)];
            let c1 = rng.range_u64(1, 1 << 17);
            let c2 = c1 + rng.range_u64(0, 1 << 16);
            assert!(
                g.decode_secs_per_token(&m, c2, tp) >= g.decode_secs_per_token(&m, c1, tp),
                "{}: context {c1} -> {c2} sped decode up",
                m.name
            );
            let batch = rng.range_u64(1, 64) as u32;
            let kv1 = rng.range_u64(0, 1 << 40);
            let kv2 = kv1 + rng.range_u64(0, 1 << 38);
            assert!(
                g.decode_step_secs(&m, kv2, batch, c1, tp)
                    >= g.decode_step_secs(&m, kv1, batch, c1, tp),
                "{}: kv {kv1} -> {kv2} sped the step up",
                m.name
            );
        });
    }

    #[test]
    fn prefill_switches_regimes_at_the_predicted_crossover() {
        // Property (testkit): below `prefill_crossover_tokens` the step
        // sits exactly on the weight-streaming floor (flat in
        // new_tokens); above it, the compute leg has taken over and the
        // step costs strictly more than the floor.
        crate::testkit::check("prefill-crossover", |rng| {
            let g = h20();
            let m = crate::models::sample_spec(rng);
            let context = rng.range_u64(1024, 1 << 16);
            let x = g.prefill_crossover_tokens(&m, context);
            let floor = m.weight_bytes() as f64 / g.hbm_bps + g.step_overhead_s;
            let below = (x * 0.5).max(1.0) as u64;
            if (below as f64) < x {
                let t = g.prefill_secs(&m, below, context, 1);
                assert!(
                    (t - floor).abs() <= 1e-9 * floor,
                    "{}: weights-bound at {below} tokens must sit on the floor \
                     ({t} vs {floor}, crossover {x:.1})",
                    m.name
                );
            }
            let above = (x * 2.0).max(2.0).ceil() as u64;
            assert!(
                g.prefill_secs(&m, above, context, 1) > floor,
                "{}: compute-bound at {above} tokens must clear the floor \
                 (crossover {x:.1})",
                m.name
            );
        });
    }
}
