//! H20 compute-time roofline model.
//!
//! The figure harnesses need *compute* time (prefill, decode) for
//! paper-scale models to put transfer time in context (Fig 2's "fetch
//! fraction of TTFT", Fig 12's end-to-end TTFT). The live end-to-end
//! example uses real PJRT execution of the tiny model; paper-scale models
//! use this roofline: time = max(flops/peak_flops, bytes/hbm_bw) / eff.
//!
//! H20 characteristics: ~148 TFLOPS dense FP16/BF16, ~4.0 TB/s HBM3.

use crate::models::ModelSpec;

/// GPU compute/memory capability for roofline estimates.
#[derive(Clone, Copy, Debug)]
pub struct GpuRoofline {
    /// Peak dense FP16 FLOPs/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Achievable fraction of peak in a tuned serving stack.
    pub efficiency: f64,
    /// Fixed per-step launch/framework overhead, seconds.
    pub step_overhead_s: f64,
}

/// NVIDIA H20 (the paper's testbed GPU).
pub fn h20() -> GpuRoofline {
    GpuRoofline {
        peak_flops: 148e12,
        hbm_bps: 4.0e12,
        efficiency: 0.55,
        step_overhead_s: 2.0e-3,
    }
}

impl GpuRoofline {
    /// Prefill time for `new_tokens` of a model with `context` total
    /// attended tokens, tensor-parallel over `tp` GPUs.
    pub fn prefill_secs(&self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        let flops = m.flops_per_token(context) * new_tokens as f64;
        // Prefill is compute-bound: weights stream once per step.
        let compute = flops / (self.peak_flops * self.efficiency);
        let weights = m.weight_bytes() as f64 / self.hbm_bps;
        (compute.max(weights) / tp as f64) + self.step_overhead_s
    }

    /// Per-output-token decode time (memory-bound: weights + KV stream).
    pub fn decode_secs_per_token(&self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        let bytes = m.weight_bytes() as f64 + m.kv_bytes(context) as f64;
        let mem = bytes / (self.hbm_bps * self.efficiency);
        let flops = m.flops_per_token(context) / (self.peak_flops * self.efficiency);
        (mem.max(flops) / tp as f64) + self.step_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{qwen3_0_6b, qwen3_32b, qwen_7b_chat};

    #[test]
    fn prefill_scales_with_tokens_and_model() {
        let g = h20();
        let small = g.prefill_secs(&qwen3_0_6b(), 16_384, 16_384, 1);
        let big = g.prefill_secs(&qwen3_32b(), 16_384, 16_384, 1);
        assert!(big > 10.0 * small, "32B prefill {big} vs 0.6B {small}");
        let longer = g.prefill_secs(&qwen3_0_6b(), 65_536, 65_536, 1);
        assert!(longer > 3.0 * small);
    }

    #[test]
    fn fig2_regime_fetch_can_dominate_ttft() {
        // Sanity for Fig 2: at 64k tokens on Qwen-7B-Chat, the KV fetch
        // over one PCIe link (~53.6 GB/s) should be comparable to or larger
        // than prefill-of-suffix compute, allowing fetch fractions ≥50%.
        let g = h20();
        let m = qwen_7b_chat();
        let fetch_s = m.kv_bytes(64 * 1024) as f64 / 53.6e9;
        // On a prefix hit only a small suffix is prefences — say 256 tokens.
        let prefill_s = g.prefill_secs(&m, 256, 64 * 1024, 1);
        assert!(
            fetch_s > prefill_s,
            "fetch {fetch_s:.3}s must dominate suffix prefill {prefill_s:.3}s"
        );
    }

    #[test]
    fn decode_is_memory_bound_for_7b() {
        let g = h20();
        let m = qwen_7b_chat();
        let t = g.decode_secs_per_token(&m, 8_192, 1);
        // ~15.4 GB weights / (4 TB/s * 0.55) ≈ 7 ms + overhead.
        assert!(t > 5e-3 && t < 30e-3, "decode tok time {t}");
    }

    #[test]
    fn tp_divides_compute() {
        let g = h20();
        let m = qwen3_32b();
        let t1 = g.prefill_secs(&m, 32_768, 32_768, 1);
        let t4 = g.prefill_secs(&m, 32_768, 32_768, 4);
        assert!(t4 < t1 / 2.0);
    }
}
