//! Offline stand-in for the PJRT runtime (built without the `pjrt`
//! feature). Mirrors the real module's public surface so callers compile
//! unchanged; every operation reports that real execution is unavailable.

use crate::Result;
use std::collections::HashMap;
use std::path::Path;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(format!(
        "{what}: built without the `pjrt` feature (the `xla` crate is \
         unavailable offline); rebuild with `--features pjrt` in an \
         environment that provides it"
    )
    .into())
}

/// Opaque tensor placeholder matching `xla::Literal`'s role in signatures.
#[derive(Clone, Debug, Default)]
pub struct Literal;

/// A loaded, compiled computation (stub: name only).
pub struct LoadedModel {
    /// Artifact stem, e.g. "tiny_prefill".
    pub name: String,
}

/// PJRT client wrapper owning every compiled executable (stub).
pub struct PjrtRuntime {
    models: HashMap<String, LoadedModel>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjrtRuntime> {
        unavailable("pjrt cpu client")
    }

    /// Platform name ("stub" here; "Host" on the real CPU client).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Load + compile one HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, _name: &str, _path: &Path) -> Result<()> {
        unavailable("load_hlo_text")
    }

    /// Load every `*.hlo.txt` in a directory, keyed by file stem.
    pub fn load_dir(&mut self, _dir: &Path) -> Result<Vec<String>> {
        unavailable("load_dir")
    }

    /// Is a model loaded?
    pub fn has(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    /// Execute a loaded model.
    pub fn execute(&self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        unavailable("execute")
    }
}

/// Literal helpers mirroring the real module's `lit` namespace.
pub mod lit {
    use super::*;

    /// f32 tensor from data + dims (stub: always errors).
    pub fn f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
        unavailable("lit::f32")
    }

    /// i32 tensor from data + dims (stub: always errors).
    pub fn i32(_data: &[i32], _dims: &[i64]) -> Result<Literal> {
        unavailable("lit::i32")
    }

    /// Read back as Vec<f32> (stub: always errors).
    pub fn to_f32(_l: &Literal) -> Result<Vec<f32>> {
        unavailable("lit::to_f32")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtRuntime::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }
}
