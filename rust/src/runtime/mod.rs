//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO *text*,
//! see `python/compile/aot.py`) and executes them on the request path.
//!
//! Python never runs at serving time: `make artifacts` lowers the L2 model
//! (which calls the L1 Pallas kernel) once; this module compiles the HLO on
//! the PJRT CPU client and serves executions from Rust.
//!
//! The real client needs the `xla` crate, which is unavailable in the
//! offline build. The `pjrt` cargo feature gates it: without the feature
//! (the default) a stub with the identical public API compiles instead,
//! and every entry point returns a descriptive error. Enabling `pjrt`
//! requires adding `xla` to `[dependencies]`.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

use std::path::PathBuf;

/// Default artifacts directory (repo-root relative, overridable via
/// `MMA_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MMA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
