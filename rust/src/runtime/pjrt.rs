//! Real PJRT client (behind the `pjrt` feature; needs the `xla` crate).
//!
//! Interchange is HLO text, not serialized `HloModuleProto` — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use super::artifacts_dir;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Opaque tensor handle (re-export of the xla literal).
pub type Literal = xla::Literal;

fn err(msg: String) -> Error {
    msg.into()
}

/// A loaded, compiled computation.
pub struct LoadedModel {
    /// Artifact stem, e.g. "tiny_prefill".
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client wrapper owning every compiled executable.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| err(format!("pjrt cpu client: {e:?}")))?;
        Ok(PjrtRuntime {
            client,
            models: HashMap::new(),
        })
    }

    /// Platform name ("Host" for CPU).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let path_str = path
            .to_str()
            .ok_or_else(|| err("non-utf8 artifact path".to_string()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| err(format!("parse {path:?}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err(format!("compile {name}: {e:?}")))?;
        self.models.insert(
            name.to_string(),
            LoadedModel {
                name: name.to_string(),
                exe,
            },
        );
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory, keyed by file stem.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        let entries =
            std::fs::read_dir(dir).map_err(|e| err(format!("read {dir:?}: {e}")))?;
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.to_string_lossy().ends_with(".hlo.txt"))
            .collect();
        paths.sort();
        for p in paths {
            let stem = p
                .file_name()
                .unwrap()
                .to_string_lossy()
                .trim_end_matches(".hlo.txt")
                .to_string();
            self.load_hlo_text(&stem, &p)?;
            loaded.push(stem);
        }
        Ok(loaded)
    }

    /// Is a model loaded?
    pub fn has(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    /// Execute a loaded model. The jax side lowers with `return_tuple=True`,
    /// so the single output is a tuple we flatten into its leaves.
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let m = self
            .models
            .get(name)
            .ok_or_else(|| err(format!("model {name:?} not loaded")))?;
        let out = m
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| err(format!("execute {name}: {e:?}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| err(format!("fetch result of {name}: {e:?}")))?;
        lit.to_tuple()
            .map_err(|e| err(format!("untuple {name}: {e:?}")))
    }
}

/// Helpers to build/read literals without spelling xla types everywhere.
pub mod lit {
    use super::*;

    /// f32 tensor from data + dims.
    pub fn f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| err(format!("reshape f32 {dims:?}: {e:?}")))
    }

    /// i32 tensor from data + dims.
    pub fn i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| err(format!("reshape i32 {dims:?}: {e:?}")))
    }

    /// Read back as Vec<f32>.
    pub fn to_f32(l: &Literal) -> Result<Vec<f32>> {
        l.to_vec::<f32>()
            .map_err(|e| err(format!("to_vec f32: {e:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that need artifacts skip (with a note) when they are absent,
    /// so `cargo test` works before `make artifacts`.
    fn artifacts_ready() -> bool {
        let dir = artifacts_dir();
        let ok = dir.join("tiny_prefill.hlo.txt").exists();
        if !ok {
            eprintln!("skipping: run `make artifacts` to enable runtime tests");
        }
        ok
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().expect("pjrt cpu");
        let p = rt.platform().to_lowercase();
        assert!(p.contains("host") || p.contains("cpu"), "platform {p}");
    }

    #[test]
    fn load_and_execute_tiny_prefill() {
        if !artifacts_ready() {
            return;
        }
        let mut rt = PjrtRuntime::cpu().unwrap();
        let loaded = rt.load_dir(&artifacts_dir()).unwrap();
        assert!(loaded.iter().any(|n| n == "tiny_prefill"), "{loaded:?}");
        // Shapes must match python/compile/model.py::TINY and aot.py.
        let tokens: Vec<i32> = (0..32).map(|i| (i * 7) % 1024).collect();
        let out = rt
            .execute("tiny_prefill", &[lit::i32(&tokens, &[1, 32]).unwrap()])
            .unwrap();
        // Outputs: logits [1,32,vocab], k cache, v cache.
        assert_eq!(out.len(), 3, "prefill outputs");
        let logits = lit::to_f32(&out[0]).unwrap();
        assert_eq!(logits.len(), 32 * 1024);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_step_consumes_prefill_cache() {
        if !artifacts_ready() {
            return;
        }
        let mut rt = PjrtRuntime::cpu().unwrap();
        rt.load_dir(&artifacts_dir()).unwrap();
        let tokens: Vec<i32> = (0..32).map(|i| (i * 3) % 1024).collect();
        let pre = rt
            .execute("tiny_prefill", &[lit::i32(&tokens, &[1, 32]).unwrap()])
            .unwrap();
        let (_logits, k, v) = (&pre[0], &pre[1], &pre[2]);
        let out = rt
            .execute(
                "tiny_decode",
                &[
                    lit::i32(&[5], &[1]).unwrap(),
                    k.clone(),
                    v.clone(),
                    lit::i32(&[32], &[1]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3, "decode outputs: logits, k, v");
        let logits = lit::to_f32(&out[0]).unwrap();
        assert_eq!(logits.len(), 1024);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
