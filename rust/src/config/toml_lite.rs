//! Minimal TOML-subset parser: sections, `key = value` with string / int /
//! float / bool / homogeneous int-array values, `#` comments. Enough for
//! run configuration files; intentionally strict about everything else.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer (i64; sizes use plain integers).
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// `[1, 2, 3]`.
    IntArray(Vec<i64>),
}

/// Parse a document into `section → (key → value)`. Keys before any
/// section header land in section `""`.
pub fn parse(text: &str) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>, String> {
    let mut doc: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let value = parse_value(v.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let table = doc.entry(section.clone()).or_default();
        if table.insert(key.clone(), value).is_some() {
            return Err(format!("line {}: duplicate key {key:?}", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // No # inside strings in our subset's comments handling: scan outside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut xs = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            xs.push(
                part.parse::<i64>()
                    .map_err(|_| format!("bad array int {part:?}"))?,
            );
        }
        return Ok(TomlValue::IntArray(xs));
    }
    // Underscore separators allowed in ints (5_000_000).
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_types() {
        let d = parse(
            r#"
            top = 1
            [a]
            s = "hi"  # trailing comment
            i = 5_000_000
            f = 2.5
            b = true
            arr = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(d[""]["top"], TomlValue::Int(1));
        assert_eq!(d["a"]["s"], TomlValue::Str("hi".into()));
        assert_eq!(d["a"]["i"], TomlValue::Int(5_000_000));
        assert_eq!(d["a"]["f"], TomlValue::Float(2.5));
        assert_eq!(d["a"]["b"], TomlValue::Bool(true));
        assert_eq!(d["a"]["arr"], TomlValue::IntArray(vec![1, 2, 3]));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let d = parse("k = \"a#b\"").unwrap();
        assert_eq!(d[""]["k"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[x\n").unwrap_err().contains("line 1"));
        assert!(parse("k v").unwrap_err().contains("key = value"));
        assert!(parse("k = @").is_err());
        assert!(parse("k = 1\nk = 2").unwrap_err().contains("duplicate"));
        assert!(parse("k = [1, x]").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }
}
