//! Configuration system: a TOML-subset parser (`toml` crate is unavailable
//! offline) plus the typed run configuration assembled from file + env
//! overrides. The paper exposes all runtime parameters as environment
//! variables (§4: relay GPU list, chunk size, bandwidth threshold,
//! flow-control mode); we accept the same spellings.

mod toml_lite;

pub use toml_lite::{parse as parse_toml, TomlValue};

use crate::mma::MmaConfig;
use crate::topology::{GpuId, Preset, Topology};
use std::collections::BTreeMap;

/// Serving-layer knobs.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Tokens per KV block (vLLM-style paging).
    pub kv_block_tokens: u32,
    /// GPU KV capacity in blocks (per GPU).
    pub gpu_kv_blocks: u32,
    /// Host KV tier capacity in blocks.
    pub host_kv_blocks: u32,
    /// Max tokens scheduled per engine step (continuous batching budget).
    pub max_batch_tokens: u32,
    /// Max concurrent sequences in a batch.
    pub max_batch_seqs: u32,
    /// Prefill/decode disaggregation enabled.
    pub pd_disaggregation: bool,
    /// Tensor parallel degree of the serving group.
    pub tp: u32,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            kv_block_tokens: 16,
            gpu_kv_blocks: 8192,
            host_kv_blocks: 65536,
            max_batch_tokens: 8192,
            max_batch_seqs: 64,
            pd_disaggregation: true,
            tp: 1,
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which server preset to simulate.
    pub preset: Preset,
    /// MMA engine tunables.
    pub mma: MmaConfig,
    /// Serving knobs.
    pub serving: ServingConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: Preset::H20x8,
            mma: MmaConfig::default(),
            serving: ServingConfig::default(),
        }
    }
}

impl RunConfig {
    /// Build the topology for the configured preset.
    pub fn topology(&self) -> Topology {
        self.preset.build()
    }

    /// Parse from TOML-subset text. Unknown keys are rejected (typo guard).
    pub fn from_toml(text: &str) -> Result<RunConfig, String> {
        let doc = parse_toml(text)?;
        let mut cfg = RunConfig::default();
        for (section, table) in &doc {
            match section.as_str() {
                "" | "run" => apply_run(&mut cfg, table)?,
                "mma" => apply_mma(&mut cfg.mma, table)?,
                "serving" => apply_serving(&mut cfg.serving, table)?,
                other => return Err(format!("unknown section [{other}]")),
            }
        }
        Ok(cfg)
    }

    /// Apply the paper's environment-variable overrides
    /// (`MMA_CHUNK_SIZE`, `MMA_RELAY_GPUS`, `MMA_THRESHOLD`,
    /// `MMA_FLOW_CONTROL`, `MMA_DISABLE`).
    pub fn apply_env(&mut self) {
        let get = |k: &str| std::env::var(k).ok();
        if let Some(v) = get("MMA_CHUNK_SIZE") {
            if let Some(b) = crate::util::fmt::parse_bytes_or_int(&v) {
                self.mma.chunk_bytes = b;
            }
        }
        if let Some(v) = get("MMA_THRESHOLD") {
            if let Some(b) = crate::util::fmt::parse_bytes_or_int(&v) {
                self.mma.fallback_threshold = b;
            }
        }
        if let Some(v) = get("MMA_RELAY_GPUS") {
            let ids: Vec<GpuId> = v
                .split(',')
                .filter_map(|s| s.trim().parse::<u8>().ok())
                .map(GpuId)
                .collect();
            self.mma.relay_gpus = Some(ids);
        }
        if let Some(v) = get("MMA_FLOW_CONTROL") {
            self.mma.centralized_dispatch = v.eq_ignore_ascii_case("centralized");
        }
        if get("MMA_DISABLE").is_some() {
            self.mma.mode = crate::mma::Mode::Native;
        }
    }
}

fn bad<T>(key: &str, want: &str) -> Result<T, String> {
    Err(format!("key {key:?}: expected {want}"))
}

fn apply_run(cfg: &mut RunConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("preset", TomlValue::Str(s)) => {
                cfg.preset =
                    Preset::parse(s).ok_or_else(|| format!("unknown preset {s:?}"))?;
            }
            ("preset", _) => return bad(k, "string"),
            _ => return Err(format!("unknown key {k:?} in [run]")),
        }
    }
    Ok(())
}

fn apply_mma(m: &mut MmaConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("chunk_bytes", TomlValue::Int(i)) => m.chunk_bytes = *i as u64,
            ("outstanding_depth", TomlValue::Int(i)) => m.outstanding_depth = *i as usize,
            ("fallback_threshold", TomlValue::Int(i)) => m.fallback_threshold = *i as u64,
            ("direct_priority", TomlValue::Bool(b)) => m.direct_priority = *b,
            ("contention_backoff", TomlValue::Bool(b)) => m.contention_backoff = *b,
            ("numa_local_only", TomlValue::Bool(b)) => m.numa_local_only = *b,
            ("dual_pipeline", TomlValue::Bool(b)) => m.dual_pipeline = *b,
            ("centralized_dispatch", TomlValue::Bool(b)) => m.centralized_dispatch = *b,
            ("activation_ns", TomlValue::Int(i)) => m.activation_ns = *i as u64,
            ("contention_beta", TomlValue::Float(f)) => m.contention_beta = *f,
            ("contention_beta", TomlValue::Int(i)) => m.contention_beta = *i as f64,
            ("mode", TomlValue::Str(s)) => {
                m.mode = match s.as_str() {
                    "mma" => crate::mma::Mode::Mma,
                    "native" => crate::mma::Mode::Native,
                    other => return Err(format!("unknown mma mode {other:?}")),
                }
            }
            ("relay_gpus", TomlValue::IntArray(xs)) => {
                m.relay_gpus = Some(xs.iter().map(|&i| GpuId(i as u8)).collect());
            }
            _ => return Err(format!("unknown or mistyped key {k:?} in [mma]")),
        }
    }
    Ok(())
}

fn apply_serving(s: &mut ServingConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("kv_block_tokens", TomlValue::Int(i)) => s.kv_block_tokens = *i as u32,
            ("gpu_kv_blocks", TomlValue::Int(i)) => s.gpu_kv_blocks = *i as u32,
            ("host_kv_blocks", TomlValue::Int(i)) => s.host_kv_blocks = *i as u32,
            ("max_batch_tokens", TomlValue::Int(i)) => s.max_batch_tokens = *i as u32,
            ("max_batch_seqs", TomlValue::Int(i)) => s.max_batch_seqs = *i as u32,
            ("pd_disaggregation", TomlValue::Bool(b)) => s.pd_disaggregation = *b,
            ("tp", TomlValue::Int(i)) => s.tp = *i as u32,
            _ => return Err(format!("unknown or mistyped key {k:?} in [serving]")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let cfg = RunConfig::from_toml(
            r#"
            # paper testbed
            [run]
            preset = "h20x8"

            [mma]
            chunk_bytes = 5000000
            outstanding_depth = 2
            direct_priority = true
            relay_gpus = [1, 2, 3]
            contention_beta = 2.5

            [serving]
            kv_block_tokens = 16
            tp = 4
            pd_disaggregation = false
            "#,
        )
        .unwrap();
        assert_eq!(cfg.preset, Preset::H20x8);
        assert_eq!(cfg.mma.chunk_bytes, 5_000_000);
        assert_eq!(
            cfg.mma.relay_gpus,
            Some(vec![GpuId(1), GpuId(2), GpuId(3)])
        );
        assert_eq!(cfg.serving.tp, 4);
        assert!(!cfg.serving.pd_disaggregation);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(RunConfig::from_toml("[mma]\nchunk_size = 5").is_err());
        assert!(RunConfig::from_toml("[nope]\nx = 1").is_err());
    }

    #[test]
    fn env_overrides() {
        // Serialized via distinct var names to avoid test interference.
        std::env::set_var("MMA_CHUNK_SIZE", "2MB");
        std::env::set_var("MMA_RELAY_GPUS", "1,3,5");
        std::env::set_var("MMA_FLOW_CONTROL", "centralized");
        let mut cfg = RunConfig::default();
        cfg.apply_env();
        assert_eq!(cfg.mma.chunk_bytes, 2_000_000);
        assert_eq!(
            cfg.mma.relay_gpus,
            Some(vec![GpuId(1), GpuId(3), GpuId(5)])
        );
        assert!(cfg.mma.centralized_dispatch);
        std::env::remove_var("MMA_CHUNK_SIZE");
        std::env::remove_var("MMA_RELAY_GPUS");
        std::env::remove_var("MMA_FLOW_CONTROL");
    }

    #[test]
    fn default_roundtrip_topology() {
        let cfg = RunConfig::default();
        let t = cfg.topology();
        assert_eq!(t.gpu_count(), 8);
    }
}
