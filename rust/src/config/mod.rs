//! Configuration system: a TOML-subset parser (`toml` crate is unavailable
//! offline) plus the typed run configuration assembled from file + env
//! overrides. The paper exposes all runtime parameters as environment
//! variables (§4: relay GPU list, chunk size, bandwidth threshold,
//! flow-control mode); we accept the same spellings.

mod toml_lite;

pub use toml_lite::{parse as parse_toml, TomlValue};

use crate::mma::{MmaConfig, TransferClass};
use crate::policy::PolicySpec;
use crate::serving::router::RoutePolicy;
use crate::topology::{GpuId, Preset, Topology};
use std::collections::BTreeMap;

/// Which model the serving instances derive kernel durations from
/// (`[compute] source`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeSource {
    /// Per-request costs exactly as the seed scheduler priced them: the
    /// batch-aware [`crate::serving::Compute`] methods fall through to
    /// their per-request defaults, so output is byte-identical to
    /// pre-`[compute]` runs.
    Legacy,
    /// The H20 roofline ([`crate::roofline::GpuRoofline`]): prefill legs
    /// are compute-bound, decode steps stream `weights + Σ KV(context_i)`
    /// over HBM bandwidth — step time responds to batch composition.
    Roofline,
}

impl ComputeSource {
    /// Parse the `[compute] source` / `MMA_COMPUTE` spellings.
    pub fn parse(s: &str) -> Option<ComputeSource> {
        match s.to_ascii_lowercase().as_str() {
            "legacy" | "fixed" => Some(ComputeSource::Legacy),
            "roofline" | "h20" => Some(ComputeSource::Roofline),
            _ => None,
        }
    }

    /// The config-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            ComputeSource::Legacy => "legacy",
            ComputeSource::Roofline => "roofline",
        }
    }
}

/// Continuous-batching knobs (`[batching]` section). Off (the default)
/// the per-request seed scheduler runs untouched — byte-identical
/// output; on, the instance forms fused iteration-level steps (chunked
/// prefill interleaved with the whole decode batch, join/leave at step
/// boundaries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchingConfig {
    /// Master switch for iteration-level continuous batching.
    pub enabled: bool,
    /// Chunked-prefill chunk size, tokens per step; 0 schedules each
    /// prefill whole (no chunking).
    pub chunk_tokens: u32,
}

impl Default for BatchingConfig {
    fn default() -> Self {
        BatchingConfig {
            enabled: false,
            chunk_tokens: 0,
        }
    }
}

/// Serving-layer knobs.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Tokens per KV block (vLLM-style paging).
    pub kv_block_tokens: u32,
    /// GPU KV capacity in blocks (per GPU).
    pub gpu_kv_blocks: u32,
    /// Host KV tier capacity in blocks.
    pub host_kv_blocks: u32,
    /// Max tokens scheduled per engine step (continuous batching budget).
    pub max_batch_tokens: u32,
    /// Max concurrent sequences in a batch.
    pub max_batch_seqs: u32,
    /// Prefill/decode disaggregation enabled.
    pub pd_disaggregation: bool,
    /// Tensor parallel degree of the serving group.
    pub tp: u32,
    /// Open-loop offered load (requests/s) used by the concurrency
    /// runners (`mma serve --arrival-rate`, `figures::serve_concurrency`)
    /// to synthesize Poisson arrivals; 0 disables synthetic arrivals.
    pub arrival_rate_rps: f64,
    /// Admission cap on concurrently running sequences, on top of
    /// `max_batch_seqs`; 0 = no extra cap.
    pub max_concurrency: u32,
    /// Chunks per host-tier prefix-cache fetch. 1 = fetch fully before
    /// prefill (serialized); >1 pipelines the fetch with prefill compute
    /// (prefill starts once the first chunk lands).
    pub fetch_chunks: u32,
    /// Kernel-duration source (the `[compute]` TOML section).
    pub compute: ComputeSource,
    /// Continuous-batching knobs (the `[batching]` TOML section).
    pub batching: BatchingConfig,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            kv_block_tokens: 16,
            gpu_kv_blocks: 8192,
            host_kv_blocks: 65536,
            max_batch_tokens: 8192,
            max_batch_seqs: 64,
            pd_disaggregation: true,
            tp: 1,
            arrival_rate_rps: 0.0,
            max_concurrency: 0,
            fetch_chunks: 1,
            compute: ComputeSource::Legacy,
            batching: BatchingConfig::default(),
        }
    }
}

/// Fleet-layer knobs: how many per-GPU serving instances run under the
/// event-driven router, and how requests are placed on them.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Serving instances (one per GPU, on GPUs `0..gpus`).
    pub gpus: u32,
    /// Placement policy across instances.
    pub router: RoutePolicy,
    /// Fetch prefixes resident in a sibling GPU's HBM peer-to-peer over
    /// the NVLink fabric instead of from the host tier over PCIe (the
    /// transfer policy's `prefer_peer_fetch` surface decides per request).
    pub peer_fetch: bool,
    /// Route a prefix hit back to the instance already holding the prefix
    /// GPU-resident, overriding the placement policy.
    pub prefix_affinity: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            gpus: 1,
            router: RoutePolicy::RoundRobin,
            peer_fetch: true,
            prefix_affinity: false,
        }
    }
}

/// Workload-layer knobs: the default trace for `mma replay` and the
/// generator parameters `mma trace gen` starts from (every key has a CLI
/// flag override; see `docs/CONFIG.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Trace file replayed when `mma replay` gets no positional path
    /// (`MMA_TRACE` overrides).
    pub trace: Option<String>,
    /// Arrival shape for generation: `poisson` | `bursty` | `diurnal`
    /// (`MMA_WORKLOAD` overrides).
    pub arrivals: String,
    /// Mean offered rate, requests/second.
    pub rate_rps: f64,
    /// Burst intensity in `[0, 1)`: MMPP rate swing for `bursty`,
    /// sinusoidal amplitude for `diurnal`. Ignored by `poisson`.
    pub burstiness: f64,
    /// Mean MMPP state dwell, seconds (`bursty` only).
    pub dwell_s: f64,
    /// Diurnal cycle length, seconds (`diurnal` only).
    pub period_s: f64,
    /// Requests to generate.
    pub requests: u32,
    /// Tenants in the mix (1 = the legacy shared namespace).
    pub tenants: u32,
    /// Documents per tenant.
    pub docs_per_tenant: u32,
    /// Zipf exponent of document popularity (0 = uniform).
    pub zipf_s: f64,
    /// Document context length, tokens.
    pub context_tokens: u32,
    /// Fresh tokens appended per request.
    pub suffix_tokens: u32,
    /// Output tokens per request.
    pub output_tokens: u32,
    /// Documents were ingested by a previous session: even a document's
    /// first touch claims its context as cached prefix, and replay
    /// pre-seeds the host tier (the §5.2.1 warm-tier setup).
    pub warm_start: bool,
    /// Reorder window for streaming replay ingestion: `mma replay` holds
    /// at most this many trace records in memory while merging arrivals
    /// into time order (`--window` overrides). A trace more disordered
    /// than the window spills to the materialized path — same output,
    /// whole-trace memory.
    pub reorder_window: u32,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            trace: None,
            arrivals: "poisson".to_string(),
            rate_rps: 8.0,
            burstiness: 0.8,
            dwell_s: 2.0,
            period_s: 60.0,
            requests: 64,
            tenants: 2,
            docs_per_tenant: 6,
            zipf_s: 1.1,
            context_tokens: 16_384,
            suffix_tokens: 64,
            output_tokens: 16,
            warm_start: false,
            reorder_window: 1024,
        }
    }
}

impl WorkloadConfig {
    /// Reject parameter combinations the generators would panic on.
    pub fn validate(&self) -> Result<(), String> {
        if !matches!(self.arrivals.as_str(), "poisson" | "bursty" | "mmpp" | "diurnal") {
            return Err(format!(
                "unknown arrivals {:?} (poisson | bursty | diurnal)",
                self.arrivals
            ));
        }
        let rate_ok = self.rate_rps.is_finite() && self.rate_rps > 0.0;
        if !rate_ok {
            return Err(format!("rate_rps {} must be > 0", self.rate_rps));
        }
        if !(0.0..1.0).contains(&self.burstiness) {
            return Err(format!("burstiness {} must be in [0, 1)", self.burstiness));
        }
        if self.dwell_s <= 0.0 || self.period_s <= 0.0 {
            return Err("dwell_s and period_s must be > 0".to_string());
        }
        if self.requests == 0 || self.tenants == 0 || self.docs_per_tenant == 0 {
            return Err("requests, tenants, docs_per_tenant must be >= 1".to_string());
        }
        if self.zipf_s < 0.0 {
            return Err(format!("zipf_s {} must be >= 0", self.zipf_s));
        }
        if self.context_tokens == 0 || self.output_tokens == 0 {
            return Err("context_tokens and output_tokens must be >= 1".to_string());
        }
        if self.reorder_window == 0 {
            return Err("reorder_window must be >= 1".to_string());
        }
        Ok(())
    }
}

/// Metrics-layer knobs: the bounded-memory streaming histogram the perf
/// harness records latencies into (`docs/PERF.md`, BENCH_0008).
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsConfig {
    /// Log-spaced bins in the streaming latency histogram. More bins
    /// tighten the percentile relative-error bound (see
    /// [`crate::metrics::LogHistogram::rel_error_bound`]); the default
    /// 1024 keeps it under 1.4% across the [1 ns, 1000 s) span while the
    /// whole histogram stays in a few KiB.
    pub histogram_bins: u32,
}

impl Default for MetricsConfig {
    fn default() -> Self {
        MetricsConfig {
            histogram_bins: 1024,
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which server preset to simulate.
    pub preset: Preset,
    /// Worker threads for parallel sweep runners (`mma figure --jobs` /
    /// `MMA_JOBS` override; sweep output is byte-identical for any
    /// value). 1 = sequential.
    pub jobs: usize,
    /// MMA engine tunables.
    pub mma: MmaConfig,
    /// Serving knobs.
    pub serving: ServingConfig,
    /// Fleet knobs.
    pub fleet: FleetConfig,
    /// Workload knobs.
    pub workload: WorkloadConfig,
    /// Metrics knobs.
    pub metrics: MetricsConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: Preset::H20x8,
            jobs: 1,
            mma: MmaConfig::default(),
            serving: ServingConfig::default(),
            fleet: FleetConfig::default(),
            workload: WorkloadConfig::default(),
            metrics: MetricsConfig::default(),
        }
    }
}

impl RunConfig {
    /// Build the topology for the configured preset.
    pub fn topology(&self) -> Topology {
        self.preset.build()
    }

    /// Parse from TOML-subset text. Unknown keys are rejected (typo guard).
    pub fn from_toml(text: &str) -> Result<RunConfig, String> {
        let doc = parse_toml(text)?;
        let mut cfg = RunConfig::default();
        for (section, table) in &doc {
            match section.as_str() {
                "" | "run" => apply_run(&mut cfg, table)?,
                "mma" => apply_mma(&mut cfg.mma, table)?,
                "policy" => apply_policy(&mut cfg.mma, table)?,
                "qos" => apply_qos(&mut cfg.mma, table)?,
                "serving" => apply_serving(&mut cfg.serving, table)?,
                "compute" => apply_compute(&mut cfg.serving, table)?,
                "batching" => apply_batching(&mut cfg.serving.batching, table)?,
                "fleet" => apply_fleet(&mut cfg.fleet, table)?,
                "workload" => apply_workload(&mut cfg.workload, table)?,
                "metrics" => apply_metrics(&mut cfg.metrics, table)?,
                other => return Err(format!("unknown section [{other}]")),
            }
        }
        // Cross-validate after all sections landed ([run] may follow
        // [policy] in document order): a config that passes here must not
        // panic when the engines are built.
        let gpu_count = cfg.preset.build().gpu_count();
        cfg.mma
            .policy
            .validate(gpu_count)
            .map_err(|e| format!("[policy] {e}"))?;
        cfg.mma.qos.validate().map_err(|e| format!("[qos] {e}"))?;
        cfg.workload
            .validate()
            .map_err(|e| format!("[workload] {e}"))?;
        if cfg.metrics.histogram_bins == 0 {
            return Err("[metrics] histogram_bins must be >= 1".to_string());
        }
        if cfg.fleet.gpus as usize > gpu_count {
            return Err(format!(
                "[fleet] gpus = {} exceeds the preset's {gpu_count} GPUs",
                cfg.fleet.gpus
            ));
        }
        Ok(cfg)
    }

    /// Apply the paper's environment-variable overrides
    /// (`MMA_CHUNK_SIZE`, `MMA_RELAY_GPUS`, `MMA_THRESHOLD`,
    /// `MMA_FLOW_CONTROL`, `MMA_DISABLE`), plus `MMA_POLICY` naming a
    /// transfer policy (see [`PolicySpec::parse`]), `MMA_QOS`
    /// (`on`/`off`) toggling the QoS transfer classes, `MMA_TRACE`
    /// naming the default replay trace, `MMA_WORKLOAD` naming the
    /// generator arrival shape (`poisson`/`bursty`/`diurnal`),
    /// `MMA_COMPUTE` (`legacy`/`roofline`) selecting the kernel-duration
    /// source, and `MMA_BATCHING` (`on`/`off`) / `MMA_CHUNK_TOKENS`
    /// driving the continuous-batching section.
    pub fn apply_env(&mut self) {
        let get = |k: &str| std::env::var(k).ok();
        if let Some(v) = get("MMA_CHUNK_SIZE") {
            if let Some(b) = crate::util::fmt::parse_bytes_or_int(&v) {
                self.mma.chunk_bytes = b;
            }
        }
        if let Some(v) = get("MMA_THRESHOLD") {
            if let Some(b) = crate::util::fmt::parse_bytes_or_int(&v) {
                self.mma.fallback_threshold = b;
            }
        }
        if let Some(v) = get("MMA_RELAY_GPUS") {
            let ids: Vec<GpuId> = v
                .split(',')
                .filter_map(|s| s.trim().parse::<u8>().ok())
                .map(GpuId)
                .collect();
            self.mma.relay_gpus = Some(ids);
        }
        if let Some(v) = get("MMA_FLOW_CONTROL") {
            self.mma.centralized_dispatch = v.eq_ignore_ascii_case("centralized");
        }
        if let Some(v) = get("MMA_POLICY") {
            if let Some(spec) = PolicySpec::parse(&v) {
                self.mma.set_policy(spec);
            }
        }
        if let Some(v) = get("MMA_QOS") {
            // Same stance as MMA_POLICY: an unparseable value changes
            // nothing rather than silently disabling QoS.
            match v.to_ascii_lowercase().as_str() {
                "on" | "1" | "true" | "yes" => self.mma.qos.enabled = true,
                "off" | "0" | "false" | "no" => self.mma.qos.enabled = false,
                _ => {}
            }
        }
        if let Some(v) = get("MMA_TRACE") {
            if !v.is_empty() {
                self.workload.trace = Some(v);
            }
        }
        if let Some(v) = get("MMA_WORKLOAD") {
            // Same stance as MMA_POLICY: an unknown shape changes nothing.
            let v = v.to_ascii_lowercase();
            if matches!(v.as_str(), "poisson" | "bursty" | "mmpp" | "diurnal") {
                self.workload.arrivals = v;
            }
        }
        if let Some(v) = get("MMA_COMPUTE") {
            // Same stance as MMA_POLICY: an unknown source changes
            // nothing rather than silently reverting to legacy costs.
            if let Some(src) = ComputeSource::parse(&v) {
                self.serving.compute = src;
            }
        }
        if let Some(v) = get("MMA_BATCHING") {
            match v.to_ascii_lowercase().as_str() {
                "on" | "1" | "true" | "yes" => self.serving.batching.enabled = true,
                "off" | "0" | "false" | "no" => self.serving.batching.enabled = false,
                _ => {}
            }
        }
        if let Some(v) = get("MMA_CHUNK_TOKENS") {
            // Unparseable values change nothing (0 is valid: no chunking).
            if let Ok(n) = v.trim().parse::<u32>() {
                self.serving.batching.chunk_tokens = n;
            }
        }
        if let Some(v) = get("MMA_JOBS") {
            // Same stance as MMA_POLICY: an unparseable or zero value
            // changes nothing rather than silently going sequential.
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    self.jobs = n;
                }
            }
        }
        if get("MMA_DISABLE").is_some() {
            self.mma.policy = PolicySpec::Native;
        }
    }
}

fn bad<T>(key: &str, want: &str) -> Result<T, String> {
    Err(format!("key {key:?}: expected {want}"))
}

fn apply_run(cfg: &mut RunConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("preset", TomlValue::Str(s)) => {
                cfg.preset =
                    Preset::parse(s).ok_or_else(|| format!("unknown preset {s:?}"))?;
            }
            ("preset", _) => return bad(k, "string"),
            ("jobs", TomlValue::Int(i)) => {
                if *i < 1 {
                    return Err(format!("[run] jobs = {i} must be >= 1"));
                }
                cfg.jobs = *i as usize;
            }
            ("jobs", _) => return bad(k, "integer"),
            _ => return Err(format!("unknown key {k:?} in [run]")),
        }
    }
    Ok(())
}

fn apply_mma(m: &mut MmaConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("chunk_bytes", TomlValue::Int(i)) => m.chunk_bytes = *i as u64,
            ("outstanding_depth", TomlValue::Int(i)) => m.outstanding_depth = *i as usize,
            ("fallback_threshold", TomlValue::Int(i)) => m.fallback_threshold = *i as u64,
            ("direct_priority", TomlValue::Bool(b)) => m.direct_priority = *b,
            ("contention_backoff", TomlValue::Bool(b)) => m.contention_backoff = *b,
            ("numa_local_only", TomlValue::Bool(b)) => m.numa_local_only = *b,
            ("dual_pipeline", TomlValue::Bool(b)) => m.dual_pipeline = *b,
            ("centralized_dispatch", TomlValue::Bool(b)) => m.centralized_dispatch = *b,
            ("incremental_alloc", TomlValue::Bool(b)) => m.incremental_alloc = *b,
            ("coalesce_solves", TomlValue::Bool(b)) => m.coalesce_solves = *b,
            ("activation_ns", TomlValue::Int(i)) => m.activation_ns = *i as u64,
            ("contention_beta", TomlValue::Float(f)) => m.contention_beta = *f,
            ("contention_beta", TomlValue::Int(i)) => m.contention_beta = *i as f64,
            // Back-compat spelling; the [policy] section is the full form.
            ("mode", TomlValue::Str(s)) => {
                m.policy = match s.as_str() {
                    "mma" => PolicySpec::MmaGreedy,
                    "native" => PolicySpec::Native,
                    other => return Err(format!("unknown mma mode {other:?}")),
                }
            }
            ("relay_gpus", TomlValue::IntArray(xs)) => {
                m.relay_gpus = Some(xs.iter().map(|&i| GpuId(i as u8)).collect());
            }
            _ => return Err(format!("unknown or mistyped key {k:?} in [mma]")),
        }
    }
    Ok(())
}

/// `[policy]` section: selects and parameterizes the transfer policy.
///
/// ```text
/// [policy]
/// name = "congestion-feedback"   # native | static-split | mma-greedy |
///                                # congestion-feedback | numa-aware
/// ewma_alpha = 0.25              # congestion-feedback only
/// min_share = 0.35               # congestion-feedback only
/// remote_penalty = 0.25          # numa-aware only
/// min_remote_bytes = 32000000    # numa-aware only
/// split_gpus = [0, 1]            # static-split only (path GPUs;
/// split_weights = [1, 2]         #  parallel weights, ints)
/// ```
fn apply_policy(m: &mut MmaConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    let mut name: Option<String> = None;
    let mut split_gpus: Option<Vec<i64>> = None;
    let mut split_weights: Option<Vec<i64>> = None;
    let mut ewma_alpha: Option<f64> = None;
    let mut min_share: Option<f64> = None;
    let mut remote_penalty: Option<f64> = None;
    let mut min_remote_bytes: Option<u64> = None;
    let float = |v: &TomlValue| match v {
        TomlValue::Float(f) => Some(*f),
        TomlValue::Int(i) => Some(*i as f64),
        _ => None,
    };
    for (k, v) in table {
        match (k.as_str(), v) {
            ("name", TomlValue::Str(s)) => name = Some(s.clone()),
            ("name", _) => return bad(k, "string"),
            ("split_gpus", TomlValue::IntArray(xs)) => split_gpus = Some(xs.clone()),
            ("split_weights", TomlValue::IntArray(xs)) => split_weights = Some(xs.clone()),
            ("ewma_alpha", v) => ewma_alpha = Some(float(v).ok_or("ewma_alpha: number")?),
            ("min_share", v) => min_share = Some(float(v).ok_or("min_share: number")?),
            ("remote_penalty", v) => {
                remote_penalty = Some(float(v).ok_or("remote_penalty: number")?)
            }
            ("min_remote_bytes", TomlValue::Int(i)) => min_remote_bytes = Some(*i as u64),
            _ => return Err(format!("unknown or mistyped key {k:?} in [policy]")),
        }
    }
    let name = name.ok_or_else(|| "[policy] requires a name".to_string())?;
    let mut spec =
        PolicySpec::parse(&name).ok_or_else(|| format!("unknown policy {name:?}"))?;
    // Apply parameters, rejecting ones that don't fit the named policy
    // (same typo-guard stance as the rest of the config).
    match &mut spec {
        PolicySpec::Static(ratios) => {
            if ewma_alpha.is_some() || min_share.is_some() || remote_penalty.is_some()
                || min_remote_bytes.is_some()
            {
                return Err(format!("policy {name:?} takes only split_gpus/split_weights"));
            }
            match (split_gpus, split_weights) {
                (Some(g), Some(w)) => {
                    if g.is_empty() || g.len() != w.len() {
                        return Err(
                            "split_gpus and split_weights must be non-empty and equal-length"
                                .to_string(),
                        );
                    }
                    if let Some(bad) = g.iter().find(|&&x| !(0..=255).contains(&x)) {
                        return Err(format!("split_gpus entry {bad} is not a GPU id"));
                    }
                    *ratios = g
                        .iter()
                        .zip(&w)
                        .map(|(&g, &w)| (GpuId(g as u8), w as f64))
                        .collect();
                }
                (None, None) => {} // keep the parse default (1:1 over gpu0+gpu1)
                _ => {
                    return Err(
                        "split_gpus and split_weights must be given together".to_string()
                    )
                }
            }
        }
        PolicySpec::CongestionFeedback {
            ewma_alpha: a,
            min_share: s,
        } => {
            if split_gpus.is_some() || split_weights.is_some() || remote_penalty.is_some()
                || min_remote_bytes.is_some()
            {
                return Err(format!("policy {name:?} takes only ewma_alpha/min_share"));
            }
            if let Some(x) = ewma_alpha {
                *a = x;
            }
            if let Some(x) = min_share {
                *s = x;
            }
        }
        PolicySpec::NumaAware {
            remote_penalty: p,
            min_remote_bytes: b,
        } => {
            if split_gpus.is_some() || split_weights.is_some() || ewma_alpha.is_some()
                || min_share.is_some()
            {
                return Err(format!(
                    "policy {name:?} takes only remote_penalty/min_remote_bytes"
                ));
            }
            if let Some(x) = remote_penalty {
                *p = x;
            }
            if let Some(x) = min_remote_bytes {
                *b = x;
            }
        }
        PolicySpec::MmaGreedy | PolicySpec::Native => {
            if split_gpus.is_some() || split_weights.is_some() || ewma_alpha.is_some()
                || min_share.is_some() || remote_penalty.is_some()
                || min_remote_bytes.is_some()
            {
                return Err(format!("policy {name:?} takes no parameters"));
            }
        }
    }
    m.set_policy(spec);
    Ok(())
}

/// `[qos]` section: QoS transfer-class weights and the bulk throttle.
///
/// ```text
/// [qos]
/// enabled = true            # off = degenerate unweighted/FIFO behavior
/// latency_critical = 8.0    # per-class share weights (> 0)
/// interactive = 4.0
/// bulk = 1.0
/// background = 0.5
/// bulk_cap_gbps = 0.0       # per-flow rate cap on bulk-band DMA
///                           # (0 = uncapped)
/// ```
fn apply_qos(m: &mut MmaConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    let float = |v: &TomlValue| match v {
        TomlValue::Float(f) => Some(*f),
        TomlValue::Int(i) => Some(*i as f64),
        _ => None,
    };
    for (k, v) in table {
        match (k.as_str(), v) {
            ("enabled", TomlValue::Bool(b)) => m.qos.enabled = *b,
            ("enabled", _) => return bad(k, "bool"),
            ("latency_critical", v) => {
                let w = float(v).ok_or("latency_critical: number")?;
                m.qos.weights[TransferClass::LatencyCritical as usize] = w;
            }
            ("interactive", v) => {
                let w = float(v).ok_or("interactive: number")?;
                m.qos.weights[TransferClass::Interactive as usize] = w;
            }
            ("bulk", v) => {
                let w = float(v).ok_or("bulk: number")?;
                m.qos.weights[TransferClass::Bulk as usize] = w;
            }
            ("background", v) => {
                let w = float(v).ok_or("background: number")?;
                m.qos.weights[TransferClass::Background as usize] = w;
            }
            ("bulk_cap_gbps", v) => {
                let g = float(v).ok_or("bulk_cap_gbps: number")?;
                if g < 0.0 || !g.is_finite() {
                    return Err(format!("bulk_cap_gbps {g} must be >= 0"));
                }
                m.qos.bulk_cap_bps = if g == 0.0 { f64::INFINITY } else { g * 1e9 };
            }
            _ => return Err(format!("unknown or mistyped key {k:?} in [qos]")),
        }
    }
    Ok(())
}

/// `[fleet]` section: per-GPU serving instances under the event-driven
/// router.
///
/// ```text
/// [fleet]
/// gpus = 4                  # serving instances (one per GPU)
/// router = "least-loaded"   # round-robin | least-loaded
/// peer_fetch = true         # NVLink peer prefix fetches
/// prefix_affinity = false   # route prefix hits back to the holder
/// ```
fn apply_fleet(f: &mut FleetConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("gpus", TomlValue::Int(i)) => {
                if !(1..=255).contains(i) {
                    return Err(format!("[fleet] gpus = {i} out of range (1..=255)"));
                }
                f.gpus = *i as u32;
            }
            ("router", TomlValue::Str(s)) => {
                f.router = RoutePolicy::parse(s)
                    .ok_or_else(|| format!("unknown router {s:?} (round-robin | least-loaded)"))?;
            }
            ("router", _) => return bad(k, "string"),
            ("peer_fetch", TomlValue::Bool(b)) => f.peer_fetch = *b,
            ("prefix_affinity", TomlValue::Bool(b)) => f.prefix_affinity = *b,
            _ => return Err(format!("unknown or mistyped key {k:?} in [fleet]")),
        }
    }
    Ok(())
}

/// `[workload]` section: the default replay trace and the trace-generator
/// parameters (`mma trace gen` flags override per run).
///
/// ```text
/// [workload]
/// trace = "examples/sample_trace.jsonl"  # default `mma replay` input
/// arrivals = "bursty"       # poisson | bursty | diurnal
/// rate_rps = 8.0            # mean offered rate
/// burstiness = 0.8          # MMPP swing / diurnal amplitude, [0, 1)
/// dwell_s = 2.0             # MMPP mean state dwell (bursty)
/// period_s = 60.0           # diurnal cycle length
/// requests = 64
/// tenants = 2               # 1 = legacy shared prefix namespace
/// docs_per_tenant = 6
/// zipf_s = 1.1              # document popularity skew (0 = uniform)
/// context_tokens = 16384
/// suffix_tokens = 64
/// output_tokens = 16
/// warm_start = false        # first doc touches claim a warm host tier
/// reorder_window = 1024     # streaming-replay arrival-merge lookahead
/// ```
fn apply_workload(
    w: &mut WorkloadConfig,
    table: &BTreeMap<String, TomlValue>,
) -> Result<(), String> {
    let float = |v: &TomlValue| match v {
        TomlValue::Float(f) => Some(*f),
        TomlValue::Int(i) => Some(*i as f64),
        _ => None,
    };
    // Unlike a bare `as u32`, this refuses negatives and oversizes
    // instead of silently wrapping them into huge valid-looking values.
    let u32v = |k: &str, i: i64| -> Result<u32, String> {
        u32::try_from(i).map_err(|_| format!("key {k:?}: {i} out of range (0..=4294967295)"))
    };
    for (k, v) in table {
        match (k.as_str(), v) {
            ("trace", TomlValue::Str(s)) => w.trace = Some(s.clone()),
            ("trace", _) => return bad(k, "string"),
            ("arrivals", TomlValue::Str(s)) => w.arrivals = s.clone(),
            ("arrivals", _) => return bad(k, "string"),
            ("rate_rps", v) => w.rate_rps = float(v).ok_or("rate_rps: number")?,
            ("burstiness", v) => w.burstiness = float(v).ok_or("burstiness: number")?,
            ("dwell_s", v) => w.dwell_s = float(v).ok_or("dwell_s: number")?,
            ("period_s", v) => w.period_s = float(v).ok_or("period_s: number")?,
            ("requests", TomlValue::Int(i)) => w.requests = u32v(k, *i)?,
            ("tenants", TomlValue::Int(i)) => w.tenants = u32v(k, *i)?,
            ("docs_per_tenant", TomlValue::Int(i)) => w.docs_per_tenant = u32v(k, *i)?,
            ("zipf_s", v) => w.zipf_s = float(v).ok_or("zipf_s: number")?,
            ("context_tokens", TomlValue::Int(i)) => w.context_tokens = u32v(k, *i)?,
            ("suffix_tokens", TomlValue::Int(i)) => w.suffix_tokens = u32v(k, *i)?,
            ("output_tokens", TomlValue::Int(i)) => w.output_tokens = u32v(k, *i)?,
            ("warm_start", TomlValue::Bool(b)) => w.warm_start = *b,
            ("warm_start", _) => return bad(k, "bool"),
            ("reorder_window", TomlValue::Int(i)) => w.reorder_window = u32v(k, *i)?,
            _ => return Err(format!("unknown or mistyped key {k:?} in [workload]")),
        }
    }
    Ok(())
}

/// `[metrics]` section: bounded-memory metrics knobs.
///
/// ```text
/// [metrics]
/// histogram_bins = 1024     # log-spaced streaming-histogram bins
/// ```
fn apply_metrics(m: &mut MetricsConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("histogram_bins", TomlValue::Int(i)) => {
                m.histogram_bins = u32::try_from(*i)
                    .map_err(|_| format!("key {k:?}: {i} out of range (0..=4294967295)"))?;
            }
            _ => return Err(format!("unknown or mistyped key {k:?} in [metrics]")),
        }
    }
    Ok(())
}

/// `[compute]` section: the kernel-duration source.
///
/// ```text
/// [compute]
/// source = "roofline"       # legacy | roofline
/// ```
fn apply_compute(s: &mut ServingConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("source", TomlValue::Str(name)) => {
                s.compute = ComputeSource::parse(name)
                    .ok_or_else(|| format!("unknown compute source {name:?} (legacy | roofline)"))?;
            }
            ("source", _) => return bad(k, "string"),
            _ => return Err(format!("unknown or mistyped key {k:?} in [compute]")),
        }
    }
    Ok(())
}

/// `[batching]` section: iteration-level continuous batching.
///
/// ```text
/// [batching]
/// enabled = true            # off = the per-request seed scheduler
/// chunk_tokens = 512        # chunked-prefill step size (0 = whole prompt)
/// ```
fn apply_batching(b: &mut BatchingConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("enabled", TomlValue::Bool(x)) => b.enabled = *x,
            ("enabled", _) => return bad(k, "bool"),
            ("chunk_tokens", TomlValue::Int(i)) => {
                b.chunk_tokens = u32::try_from(*i)
                    .map_err(|_| format!("key {k:?}: {i} out of range (0..=4294967295)"))?;
            }
            ("chunk_tokens", _) => return bad(k, "integer"),
            _ => return Err(format!("unknown or mistyped key {k:?} in [batching]")),
        }
    }
    Ok(())
}

fn apply_serving(s: &mut ServingConfig, table: &BTreeMap<String, TomlValue>) -> Result<(), String> {
    for (k, v) in table {
        match (k.as_str(), v) {
            ("kv_block_tokens", TomlValue::Int(i)) => s.kv_block_tokens = *i as u32,
            ("gpu_kv_blocks", TomlValue::Int(i)) => s.gpu_kv_blocks = *i as u32,
            ("host_kv_blocks", TomlValue::Int(i)) => s.host_kv_blocks = *i as u32,
            ("max_batch_tokens", TomlValue::Int(i)) => s.max_batch_tokens = *i as u32,
            ("max_batch_seqs", TomlValue::Int(i)) => s.max_batch_seqs = *i as u32,
            ("pd_disaggregation", TomlValue::Bool(b)) => s.pd_disaggregation = *b,
            ("tp", TomlValue::Int(i)) => s.tp = *i as u32,
            ("arrival_rate_rps", TomlValue::Float(f)) => s.arrival_rate_rps = *f,
            ("arrival_rate_rps", TomlValue::Int(i)) => s.arrival_rate_rps = *i as f64,
            ("max_concurrency", TomlValue::Int(i)) => s.max_concurrency = *i as u32,
            ("fetch_chunks", TomlValue::Int(i)) => s.fetch_chunks = (*i as u32).max(1),
            _ => return Err(format!("unknown or mistyped key {k:?} in [serving]")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let cfg = RunConfig::from_toml(
            r#"
            # paper testbed
            [run]
            preset = "h20x8"

            [mma]
            chunk_bytes = 5000000
            outstanding_depth = 2
            direct_priority = true
            relay_gpus = [1, 2, 3]
            contention_beta = 2.5
            incremental_alloc = false
            coalesce_solves = false

            [serving]
            kv_block_tokens = 16
            tp = 4
            pd_disaggregation = false
            arrival_rate_rps = 2.5
            max_concurrency = 8
            fetch_chunks = 4
            "#,
        )
        .unwrap();
        assert_eq!(cfg.preset, Preset::H20x8);
        assert_eq!(cfg.mma.chunk_bytes, 5_000_000);
        assert_eq!(
            cfg.mma.relay_gpus,
            Some(vec![GpuId(1), GpuId(2), GpuId(3)])
        );
        assert!(!cfg.mma.incremental_alloc);
        assert!(!cfg.mma.coalesce_solves);
        assert_eq!(cfg.serving.tp, 4);
        assert!(!cfg.serving.pd_disaggregation);
        assert_eq!(cfg.serving.arrival_rate_rps, 2.5);
        assert_eq!(cfg.serving.max_concurrency, 8);
        assert_eq!(cfg.serving.fetch_chunks, 4);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(RunConfig::from_toml("[mma]\nchunk_size = 5").is_err());
        assert!(RunConfig::from_toml("[nope]\nx = 1").is_err());
    }

    #[test]
    fn fleet_section_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            r#"
            [fleet]
            gpus = 4
            router = "least-loaded"
            peer_fetch = false
            prefix_affinity = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.fleet.gpus, 4);
        assert_eq!(cfg.fleet.router, RoutePolicy::LeastLoaded);
        assert!(!cfg.fleet.peer_fetch);
        assert!(cfg.fleet.prefix_affinity);
        // Defaults: one instance, round-robin, peer fetches on.
        let d = RunConfig::default().fleet;
        assert_eq!(d.gpus, 1);
        assert_eq!(d.router, RoutePolicy::RoundRobin);
        assert!(d.peer_fetch);
        // Rejections: bad router, out-of-range sizes, unknown keys, and a
        // fleet larger than the preset.
        assert!(RunConfig::from_toml("[fleet]\nrouter = \"nope\"").is_err());
        assert!(RunConfig::from_toml("[fleet]\ngpus = 0").is_err());
        assert!(RunConfig::from_toml("[fleet]\ngpus = 9").is_err());
        assert!(RunConfig::from_toml("[fleet]\nnope = 1").is_err());
    }

    #[test]
    fn policy_section_selects_and_parameterizes() {
        let cfg = RunConfig::from_toml(
            r#"
            [policy]
            name = "congestion-feedback"
            ewma_alpha = 0.5
            min_share = 0.2
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.mma.policy,
            PolicySpec::CongestionFeedback {
                ewma_alpha: 0.5,
                min_share: 0.2
            }
        );

        let cfg = RunConfig::from_toml(
            r#"
            [policy]
            name = "static-split"
            split_gpus = [0, 1, 2]
            split_weights = [2, 1, 1]
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.mma.policy,
            PolicySpec::Static(vec![
                (GpuId(0), 2.0),
                (GpuId(1), 1.0),
                (GpuId(2), 1.0)
            ])
        );

        let cfg =
            RunConfig::from_toml("[policy]\nname = \"numa-aware\"\nmin_remote_bytes = 1000000")
                .unwrap();
        assert_eq!(
            cfg.mma.policy,
            PolicySpec::NumaAware {
                remote_penalty: crate::policy::DEFAULT_REMOTE_PENALTY,
                min_remote_bytes: 1_000_000
            }
        );
    }

    #[test]
    fn static_split_by_name_disables_adaptive_machinery() {
        // Choosing static-split through any named surface must establish
        // the same invariants as policy::static_split (Fig 10: no
        // adaptive machinery), not leave the greedy defaults on.
        let cfg = RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [0, 1]\nsplit_weights = [1, 1]",
        )
        .unwrap();
        assert!(!cfg.mma.contention_backoff);
        assert!(!cfg.mma.direct_priority);
        // Same invariant through the programmatic surface (which the
        // MMA_POLICY env path also funnels through).
        let mut direct = MmaConfig::default();
        direct.set_policy(PolicySpec::Static(vec![(GpuId(0), 1.0)]));
        assert!(!direct.contention_backoff);
        assert!(!direct.direct_priority);
    }

    #[test]
    fn config_validation_rejects_runtime_panics() {
        // Out-of-range parameters and nonexistent GPUs must fail at
        // config time, not when the engine is built.
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"congestion-feedback\"\newma_alpha = 3.0"
        )
        .is_err());
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"numa-aware\"\nremote_penalty = 2.0"
        )
        .is_err());
        // gpu 8 does not exist on the 8-GPU h20x8 preset.
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [0, 8]\nsplit_weights = [1, 1]"
        )
        .is_err());
        // Negative / oversized ids and non-positive weights.
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [-1]\nsplit_weights = [1]"
        )
        .is_err());
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [300]\nsplit_weights = [1]"
        )
        .is_err());
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [0]\nsplit_weights = [0]"
        )
        .is_err());
    }

    #[test]
    fn policy_section_rejects_mismatched_params() {
        // Parameters must match the named policy.
        assert!(RunConfig::from_toml("[policy]\nname = \"mma-greedy\"\newma_alpha = 0.5").is_err());
        assert!(RunConfig::from_toml("[policy]\nname = \"numa-aware\"\nmin_share = 0.5").is_err());
        // Unknown name / missing name / ragged split arrays.
        assert!(RunConfig::from_toml("[policy]\nname = \"nope\"").is_err());
        assert!(RunConfig::from_toml("[policy]\newma_alpha = 0.5").is_err());
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [0]\nsplit_weights = [1, 2]"
        )
        .is_err());
        assert!(RunConfig::from_toml(
            "[policy]\nname = \"static-split\"\nsplit_gpus = [0, 1]"
        )
        .is_err());
    }

    #[test]
    fn qos_section_parses_weights_and_cap() {
        let cfg = RunConfig::from_toml(
            r#"
            [qos]
            enabled = true
            latency_critical = 10
            interactive = 5.0
            bulk = 2
            background = 1
            bulk_cap_gbps = 20.0
            "#,
        )
        .unwrap();
        assert!(cfg.mma.qos.enabled);
        assert_eq!(cfg.mma.qos.weights, [10.0, 5.0, 2.0, 1.0]);
        assert_eq!(cfg.mma.qos.bulk_cap_bps, 20e9);
        // Defaults: disabled, standard weights, uncapped.
        let d = RunConfig::default().mma.qos;
        assert!(!d.enabled);
        assert_eq!(d.weights, crate::mma::DEFAULT_QOS_WEIGHTS);
        assert!(d.bulk_cap_bps.is_infinite());
        // bulk_cap_gbps = 0 means uncapped.
        let cfg = RunConfig::from_toml("[qos]\nenabled = true\nbulk_cap_gbps = 0").unwrap();
        assert!(cfg.mma.qos.bulk_cap_bps.is_infinite());
    }

    #[test]
    fn qos_section_rejects_bad_values() {
        assert!(RunConfig::from_toml("[qos]\nlatency_critical = 0").is_err());
        assert!(RunConfig::from_toml("[qos]\nbulk = -1.0").is_err());
        assert!(RunConfig::from_toml("[qos]\nbulk_cap_gbps = -5").is_err());
        assert!(RunConfig::from_toml("[qos]\nnope = 1").is_err());
        assert!(RunConfig::from_toml("[qos]\nenabled = 3").is_err());
    }

    #[test]
    fn qos_weight_helpers_degenerate_when_disabled() {
        use crate::mma::QosConfig;
        let off = QosConfig::off();
        let on = QosConfig::on();
        for c in TransferClass::ALL {
            assert_eq!(off.weight(c), 1.0, "disabled → unweighted");
            assert!(off.cap(c).is_infinite());
            assert!(on.weight(c) > 0.0);
        }
        assert!(on.weight(TransferClass::LatencyCritical) > on.weight(TransferClass::Bulk));
        // Caps apply to the bulk band only.
        let capped = QosConfig {
            bulk_cap_bps: 5e9,
            ..QosConfig::on()
        };
        assert_eq!(capped.cap(TransferClass::Bulk), 5e9);
        assert_eq!(capped.cap(TransferClass::Background), 5e9);
        assert!(capped.cap(TransferClass::LatencyCritical).is_infinite());
    }

    #[test]
    fn workload_section_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            r#"
            [workload]
            trace = "examples/sample_trace.jsonl"
            arrivals = "bursty"
            rate_rps = 12.5
            burstiness = 0.9
            dwell_s = 1.5
            requests = 32
            tenants = 3
            docs_per_tenant = 4
            zipf_s = 1.3
            context_tokens = 8192
            warm_start = true
            "#,
        )
        .unwrap();
        let w = &cfg.workload;
        assert!(w.warm_start);
        assert_eq!(w.trace.as_deref(), Some("examples/sample_trace.jsonl"));
        assert_eq!(w.arrivals, "bursty");
        assert_eq!(w.rate_rps, 12.5);
        assert_eq!(w.burstiness, 0.9);
        assert_eq!(w.dwell_s, 1.5);
        assert_eq!((w.requests, w.tenants, w.docs_per_tenant), (32, 3, 4));
        assert_eq!(w.zipf_s, 1.3);
        assert_eq!(w.context_tokens, 8192);
        // Untouched keys keep their defaults.
        let d = WorkloadConfig::default();
        assert_eq!(w.period_s, d.period_s);
        assert_eq!(w.output_tokens, d.output_tokens);
        assert!(d.validate().is_ok(), "defaults must validate");
        // Rejections: unknown shape, out-of-range numbers, unknown keys.
        assert!(RunConfig::from_toml("[workload]\narrivals = \"weibull\"").is_err());
        assert!(RunConfig::from_toml("[workload]\nrate_rps = 0").is_err());
        assert!(RunConfig::from_toml("[workload]\nburstiness = 1.5").is_err());
        assert!(RunConfig::from_toml("[workload]\nrequests = 0").is_err());
        assert!(RunConfig::from_toml("[workload]\nnope = 1").is_err());
        assert!(RunConfig::from_toml("[workload]\ntrace = 5").is_err());
        // Negative / oversized integers error instead of wrapping.
        assert!(RunConfig::from_toml("[workload]\nrequests = -1").is_err());
        assert!(RunConfig::from_toml("[workload]\ntenants = 5000000000").is_err());
    }

    #[test]
    fn reorder_window_and_metrics_sections_parse() {
        let cfg = RunConfig::from_toml(
            "[workload]\nreorder_window = 64\n\n[metrics]\nhistogram_bins = 256",
        )
        .unwrap();
        assert_eq!(cfg.workload.reorder_window, 64);
        assert_eq!(cfg.metrics.histogram_bins, 256);
        // Defaults match the documented values.
        let d = RunConfig::default();
        assert_eq!(d.workload.reorder_window, 1024);
        assert_eq!(d.metrics.histogram_bins, 1024);
        // Rejections: zero window/bins, wrapping integers, unknown keys.
        assert!(RunConfig::from_toml("[workload]\nreorder_window = 0").is_err());
        assert!(RunConfig::from_toml("[workload]\nreorder_window = -1").is_err());
        assert!(RunConfig::from_toml("[metrics]\nhistogram_bins = 0").is_err());
        assert!(RunConfig::from_toml("[metrics]\nhistogram_bins = -1").is_err());
        assert!(RunConfig::from_toml("[metrics]\nnope = 1").is_err());
    }

    #[test]
    fn workload_env_overrides() {
        std::env::set_var("MMA_TRACE", "/tmp/t.jsonl");
        std::env::set_var("MMA_WORKLOAD", "diurnal");
        let mut cfg = RunConfig::default();
        cfg.apply_env();
        assert_eq!(cfg.workload.trace.as_deref(), Some("/tmp/t.jsonl"));
        assert_eq!(cfg.workload.arrivals, "diurnal");
        // Unknown shape names change nothing (MMA_POLICY stance).
        std::env::set_var("MMA_WORKLOAD", "weibull");
        cfg.apply_env();
        assert_eq!(cfg.workload.arrivals, "diurnal");
        std::env::remove_var("MMA_TRACE");
        std::env::remove_var("MMA_WORKLOAD");
    }

    #[test]
    fn jobs_key_parses_and_validates() {
        let cfg = RunConfig::from_toml("[run]\njobs = 4").unwrap();
        assert_eq!(cfg.jobs, 4);
        assert_eq!(RunConfig::default().jobs, 1);
        assert!(RunConfig::from_toml("[run]\njobs = 0").is_err());
        assert!(RunConfig::from_toml("[run]\njobs = \"two\"").is_err());
        // MMA_JOBS overrides; junk values change nothing.
        std::env::set_var("MMA_JOBS", "8");
        let mut cfg = RunConfig::default();
        cfg.apply_env();
        assert_eq!(cfg.jobs, 8);
        std::env::set_var("MMA_JOBS", "zero");
        cfg.apply_env();
        assert_eq!(cfg.jobs, 8);
        std::env::set_var("MMA_JOBS", "0");
        cfg.apply_env();
        assert_eq!(cfg.jobs, 8);
        std::env::remove_var("MMA_JOBS");
    }

    #[test]
    fn mode_key_still_maps_to_policy() {
        let cfg = RunConfig::from_toml("[mma]\nmode = \"native\"").unwrap();
        assert_eq!(cfg.mma.policy, PolicySpec::Native);
        let cfg = RunConfig::from_toml("[mma]\nmode = \"mma\"").unwrap();
        assert_eq!(cfg.mma.policy, PolicySpec::MmaGreedy);
    }

    #[test]
    fn env_overrides() {
        // Serialized via distinct var names to avoid test interference.
        std::env::set_var("MMA_CHUNK_SIZE", "2MB");
        std::env::set_var("MMA_RELAY_GPUS", "1,3,5");
        std::env::set_var("MMA_FLOW_CONTROL", "centralized");
        std::env::set_var("MMA_POLICY", "numa-aware");
        let mut cfg = RunConfig::default();
        cfg.apply_env();
        assert_eq!(cfg.mma.chunk_bytes, 2_000_000);
        assert_eq!(
            cfg.mma.relay_gpus,
            Some(vec![GpuId(1), GpuId(3), GpuId(5)])
        );
        assert!(cfg.mma.centralized_dispatch);
        assert_eq!(cfg.mma.policy, PolicySpec::numa_aware());
        std::env::remove_var("MMA_CHUNK_SIZE");
        std::env::remove_var("MMA_RELAY_GPUS");
        std::env::remove_var("MMA_FLOW_CONTROL");
        std::env::remove_var("MMA_POLICY");
    }

    #[test]
    fn compute_and_batching_sections_parse() {
        let cfg = RunConfig::from_toml(
            r#"
            [compute]
            source = "roofline"

            [batching]
            enabled = true
            chunk_tokens = 512
            "#,
        )
        .unwrap();
        assert_eq!(cfg.serving.compute, ComputeSource::Roofline);
        assert!(cfg.serving.batching.enabled);
        assert_eq!(cfg.serving.batching.chunk_tokens, 512);
        // Defaults are the byte-identity pair: legacy costs, batching off.
        let d = RunConfig::default().serving;
        assert_eq!(d.compute, ComputeSource::Legacy);
        assert!(!d.batching.enabled);
        assert_eq!(d.batching.chunk_tokens, 0);
        // Spelling aliases.
        assert_eq!(ComputeSource::parse("h20"), Some(ComputeSource::Roofline));
        assert_eq!(ComputeSource::parse("fixed"), Some(ComputeSource::Legacy));
        assert_eq!(ComputeSource::Roofline.name(), "roofline");
        // Rejections: unknown source, mistyped keys, unknown keys,
        // negative chunk sizes.
        assert!(RunConfig::from_toml("[compute]\nsource = \"gpu\"").is_err());
        assert!(RunConfig::from_toml("[compute]\nsource = 1").is_err());
        assert!(RunConfig::from_toml("[compute]\nnope = 1").is_err());
        assert!(RunConfig::from_toml("[batching]\nenabled = 1").is_err());
        assert!(RunConfig::from_toml("[batching]\nchunk_tokens = -1").is_err());
        assert!(RunConfig::from_toml("[batching]\nnope = true").is_err());
    }

    #[test]
    fn compute_and_batching_env_overrides() {
        std::env::set_var("MMA_COMPUTE", "roofline");
        std::env::set_var("MMA_BATCHING", "on");
        std::env::set_var("MMA_CHUNK_TOKENS", "256");
        let mut cfg = RunConfig::default();
        cfg.apply_env();
        assert_eq!(cfg.serving.compute, ComputeSource::Roofline);
        assert!(cfg.serving.batching.enabled);
        assert_eq!(cfg.serving.batching.chunk_tokens, 256);
        // Junk values change nothing (MMA_POLICY stance).
        std::env::set_var("MMA_COMPUTE", "abacus");
        std::env::set_var("MMA_BATCHING", "maybe");
        std::env::set_var("MMA_CHUNK_TOKENS", "lots");
        cfg.apply_env();
        assert_eq!(cfg.serving.compute, ComputeSource::Roofline);
        assert!(cfg.serving.batching.enabled);
        assert_eq!(cfg.serving.batching.chunk_tokens, 256);
        std::env::remove_var("MMA_COMPUTE");
        std::env::remove_var("MMA_BATCHING");
        std::env::remove_var("MMA_CHUNK_TOKENS");
    }

    #[test]
    fn default_roundtrip_topology() {
        let cfg = RunConfig::default();
        let t = cfg.topology();
        assert_eq!(t.gpu_count(), 8);
    }
}
