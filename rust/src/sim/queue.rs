//! Deterministic event queue.
//!
//! Events at equal timestamps pop in insertion order (FIFO tie-break via a
//! monotone sequence number), which keeps every simulation run bit-exact —
//! a property the calibration tests and the figure harnesses rely on.

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    // BinaryHeap is a max-heap; invert so earliest (then lowest seq) pops first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of timestamped events with FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Time,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: Time::ZERO,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (the event fires "immediately", after already-queued
    /// events at `now`).
    pub fn schedule_at(&mut self, at: Time, ev: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Schedule `ev` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Time, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.at >= self.now, "time went backwards");
        self.now = entry.at;
        Some((entry.at, entry.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(30), "c");
        q.schedule_at(Time(10), "a");
        q.schedule_at(Time(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(Time(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_and_past_scheduling_clamps() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(100), 1);
        assert_eq!(q.pop(), Some((Time(100), 1)));
        assert_eq!(q.now(), Time(100));
        // Scheduling "in the past" fires at now, not before.
        q.schedule_at(Time(50), 2);
        assert_eq!(q.pop(), Some((Time(100), 2)));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(10), 1);
        q.pop();
        q.schedule_in(Time(5), 2);
        assert_eq!(q.pop(), Some((Time(15), 2)));
    }

    #[test]
    fn len_and_peek() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.schedule_at(Time(7), 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(Time(7)));
    }
}
