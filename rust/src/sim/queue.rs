//! Deterministic event queue.
//!
//! Events at equal timestamps pop in insertion order (FIFO tie-break via a
//! monotone sequence number), which keeps every simulation run bit-exact —
//! a property the calibration tests and the figure harnesses rely on.
//!
//! [`EventQueue`] is a hierarchical timer wheel (calendar queue): 11
//! levels of 64 slots, 6 bits of the nanosecond timestamp per level, with
//! one occupancy bitmap per level. Scheduling is O(1); popping is O(1)
//! bitmap scans plus a scan of one slot. An entry's level is the position
//! of the highest bit in which its deadline differs from the current
//! time, so levels are *time-ordered*: every level-ℓ entry is strictly
//! earlier than every level-(ℓ+1) entry, and within a level a lower slot
//! index is strictly earlier. The earliest entry therefore always sits in
//! the first occupied slot of the lowest non-empty level. When time
//! advances, entries whose deadline is now close re-file to a lower level
//! (the cascade); because an entry's slot index depends only on its
//! deadline, exactly the slot containing the new current time needs
//! draining at each level.
//!
//! The previous `BinaryHeap` implementation survives as
//! [`HeapEventQueue`] — the reference oracle for the wheel's
//! pop-order-equivalence property test and the baseline leg of the
//! hotpath benchmark (`mma bench hotpath`).

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    // BinaryHeap is a max-heap; invert so earliest (then lowest seq) pops first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Bits of the timestamp consumed per wheel level.
const LEVEL_BITS: u32 = 6;
/// Slots per level (`2^LEVEL_BITS`).
const SLOTS: usize = 1 << LEVEL_BITS;
/// Wheel levels; `11 * 6 = 66 >= 64` bits, so every u64 deadline fits.
const LEVELS: usize = 11;

/// Timestamped event queue with FIFO tie-breaking, implemented as a
/// hierarchical timer wheel.
pub struct EventQueue<E> {
    /// `LEVELS * SLOTS` buckets, indexed `level * SLOTS + slot`.
    slots: Vec<Vec<Entry<E>>>,
    /// Per-level slot-occupancy bitmap.
    occupied: [u64; LEVELS],
    len: usize,
    seq: u64,
    now: Time,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue {
            slots: std::iter::repeat_with(Vec::new).take(LEVELS * SLOTS).collect(),
            occupied: [0; LEVELS],
            len: 0,
            seq: 0,
            now: Time::ZERO,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (the event fires "immediately", after already-queued
    /// events at `now`).
    pub fn schedule_at(&mut self, at: Time, ev: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.file(Entry { at, seq, ev });
        self.len += 1;
    }

    /// Schedule `ev` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Time, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let (lvl, slot) = self.earliest_slot()?;
        let bucket = &mut self.slots[lvl * SLOTS + slot];
        let mut best = 0;
        for i in 1..bucket.len() {
            if (bucket[i].at, bucket[i].seq) < (bucket[best].at, bucket[best].seq) {
                best = i;
            }
        }
        let entry = bucket.swap_remove(best);
        if bucket.is_empty() {
            self.occupied[lvl] &= !(1u64 << slot);
        }
        self.len -= 1;
        debug_assert!(entry.at >= self.now, "time went backwards");
        if entry.at > self.now {
            self.now = entry.at;
            self.cascade();
        }
        Some((entry.at, entry.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        let (lvl, slot) = self.earliest_slot()?;
        self.slots[lvl * SLOTS + slot].iter().map(|e| e.at).min()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// File an entry into the wheel relative to the current time.
    /// Invariant: `entry.at >= self.now`.
    fn file(&mut self, entry: Entry<E>) {
        let x = entry.at.0 ^ self.now.0;
        let lvl = if x == 0 {
            0
        } else {
            (((63 - x.leading_zeros()) / LEVEL_BITS) as usize).min(LEVELS - 1)
        };
        let slot = ((entry.at.0 >> (LEVEL_BITS * lvl as u32)) & (SLOTS as u64 - 1)) as usize;
        self.slots[lvl * SLOTS + slot].push(entry);
        self.occupied[lvl] |= 1u64 << slot;
    }

    /// Lowest non-empty level + its first occupied slot — by the level
    /// ordering argument in the module docs, the bucket holding the
    /// earliest entry.
    fn earliest_slot(&self) -> Option<(usize, usize)> {
        self.occupied
            .iter()
            .position(|&b| b != 0)
            .map(|lvl| (lvl, self.occupied[lvl].trailing_zeros() as usize))
    }

    /// Re-file entries whose level dropped because `now` advanced. An
    /// entry of level ℓ needs demotion exactly when its deadline now
    /// agrees with `now` on all bits ≥ 6ℓ — i.e. it sits in the slot of
    /// level ℓ that contains `now`. Draining that one slot per level
    /// restores the filing invariant; demoted entries always land on a
    /// strictly lower level, never back in a drained slot.
    fn cascade(&mut self) {
        for lvl in (1..LEVELS).rev() {
            let slot = ((self.now.0 >> (LEVEL_BITS * lvl as u32)) & (SLOTS as u64 - 1)) as usize;
            if self.occupied[lvl] & (1u64 << slot) != 0 {
                let mut bucket = std::mem::take(&mut self.slots[lvl * SLOTS + slot]);
                self.occupied[lvl] &= !(1u64 << slot);
                for e in bucket.drain(..) {
                    self.file(e);
                }
                // Hand the (now empty) allocation back to the drained slot.
                self.slots[lvl * SLOTS + slot] = bucket;
            }
        }
    }
}

/// The original `BinaryHeap` event queue, kept verbatim as the reference
/// implementation: the wheel must pop the exact same `(time, event)`
/// sequence (see `property_wheel_matches_heap_pop_order`), and the
/// hotpath benchmark reports both so the wheel's speedup stays measured.
pub struct HeapEventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Time,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        HeapEventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: Time::ZERO,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (past times clamp to `now`).
    pub fn schedule_at(&mut self, at: Time, ev: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Schedule `ev` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Time, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.at >= self.now, "time went backwards");
        self.now = entry.at;
        Some((entry.at, entry.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(30), "c");
        q.schedule_at(Time(10), "a");
        q.schedule_at(Time(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(Time(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_and_past_scheduling_clamps() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(100), 1);
        assert_eq!(q.pop(), Some((Time(100), 1)));
        assert_eq!(q.now(), Time(100));
        // Scheduling "in the past" fires at now, not before.
        q.schedule_at(Time(50), 2);
        assert_eq!(q.pop(), Some((Time(100), 2)));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(Time(10), 1);
        q.pop();
        q.schedule_in(Time(5), 2);
        assert_eq!(q.pop(), Some((Time(15), 2)));
    }

    #[test]
    fn len_and_peek() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.schedule_at(Time(7), 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(Time(7)));
    }

    #[test]
    fn distant_deadlines_cross_many_levels() {
        // Deadlines spanning ns to ~19 minutes exercise levels 0..=6 and
        // the multi-level cascade on each pop.
        let mut q = EventQueue::new();
        let times = [
            1u64,
            63,
            64,
            4_095,
            4_096,
            1 << 18,
            (1 << 18) + 1,
            1 << 30,
            (1 << 40) - 1,
            1 << 40,
        ];
        for (i, &t) in times.iter().enumerate() {
            q.schedule_at(Time(t), i);
        }
        let popped: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t.0).collect();
        let mut want = times.to_vec();
        want.sort_unstable();
        assert_eq!(popped, want);
    }

    #[test]
    fn interleaved_insert_after_advance_stays_ordered() {
        // A fresh near-deadline insert after `now` has advanced must not
        // overtake an older, earlier entry parked on a higher level — the
        // failure mode the eager cascade exists to prevent.
        let mut q = EventQueue::new();
        q.schedule_at(Time(100), "early-far");
        q.schedule_at(Time(70), "first");
        assert_eq!(q.pop(), Some((Time(70), "first"))); // now = 70
        q.schedule_at(Time(101), "late-near");
        assert_eq!(q.pop(), Some((Time(100), "early-far")));
        assert_eq!(q.pop(), Some((Time(101), "late-near")));
    }

    /// The wheel must reproduce the heap's pop sequence exactly under
    /// random interleavings of scheduling (with duplicates and past
    /// clamps) and popping, across deadline spreads that hit many levels.
    #[test]
    fn property_wheel_matches_heap_pop_order() {
        testkit::check("timer-wheel-vs-heap", |rng| {
            let mut wheel = EventQueue::new();
            let mut heap = HeapEventQueue::new();
            let horizon = *rng.choose(&[1_000u64, 100_000, 1 << 24, 1 << 40]);
            let mut id = 0u32;
            let mut tie = Vec::new();
            for _ in 0..rng.range_usize(50, 300) {
                if wheel.is_empty() || rng.bool(0.6) {
                    // Absolute deadlines, sometimes in the past (both
                    // implementations clamp), sometimes exact duplicates.
                    let at = if !tie.is_empty() && rng.bool(0.3) {
                        *rng.choose(&tie)
                    } else {
                        let t = Time(rng.range_u64(0, horizon));
                        tie.push(t);
                        t
                    };
                    wheel.schedule_at(at, id);
                    heap.schedule_at(at, id);
                    id += 1;
                } else {
                    assert_eq!(wheel.peek_time(), heap.peek_time());
                    let a = wheel.pop();
                    let b = heap.pop();
                    assert_eq!(a, b, "pop diverged");
                    assert_eq!(wheel.now(), heap.now());
                }
                assert_eq!(wheel.len(), heap.len());
            }
            while let Some(b) = heap.pop() {
                assert_eq!(wheel.pop(), Some(b), "drain diverged");
            }
            assert!(wheel.is_empty());
        });
    }
}
