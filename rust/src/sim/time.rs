//! Virtual time: a monotone nanosecond counter.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in nanoseconds since simulation start.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

impl Time {
    /// Simulation epoch.
    pub const ZERO: Time = Time(0);
    /// Sentinel for "never" (events that must sort after everything real).
    pub const NEVER: Time = Time(u64::MAX);

    /// Construct from nanoseconds.
    pub fn from_ns(ns: u64) -> Time {
        Time(ns)
    }
    /// Construct from microseconds.
    pub fn from_us(us: u64) -> Time {
        Time(us * 1_000)
    }
    /// Construct from milliseconds.
    pub fn from_ms(ms: u64) -> Time {
        Time(ms * 1_000_000)
    }
    /// Construct from (possibly fractional) seconds.
    pub fn from_secs_f64(s: f64) -> Time {
        Time((s * 1e9).round().max(0.0) as u64)
    }

    /// Raw nanoseconds.
    pub fn ns(self) -> u64 {
        self.0
    }
    /// As fractional microseconds.
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    /// As fractional milliseconds.
    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    /// As fractional seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Saturating difference (self - earlier).
    pub fn since(self, earlier: Time) -> Time {
        Time(self.0.saturating_sub(earlier.0))
    }
}

impl Add for Time {
    type Output = Time;
    fn add(self, rhs: Time) -> Time {
        Time(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign for Time {
    fn add_assign(&mut self, rhs: Time) {
        self.0 = self.0.saturating_add(rhs.0);
    }
}

impl Sub for Time {
    type Output = Time;
    fn sub(self, rhs: Time) -> Time {
        Time(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Debug for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 == u64::MAX {
            write!(f, "t=never")
        } else {
            write!(f, "t={:.3}us", self.as_us_f64())
        }
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_round_trip() {
        assert_eq!(Time::from_us(3).ns(), 3_000);
        assert_eq!(Time::from_ms(2).ns(), 2_000_000);
        assert_eq!(Time::from_secs_f64(1.5).ns(), 1_500_000_000);
        assert!((Time::from_ns(2_500).as_us_f64() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn arithmetic_saturates() {
        assert_eq!(Time(5) - Time(9), Time::ZERO);
        assert_eq!(Time::NEVER + Time(1), Time::NEVER);
        assert_eq!(Time(7).since(Time(3)), Time(4));
    }

    #[test]
    fn ordering() {
        assert!(Time::ZERO < Time(1));
        assert!(Time(1) < Time::NEVER);
    }
}
