//! Discrete-event simulation core.
//!
//! Everything hardware-gated in the paper (PCIe/NVLink DMA, CUDA streams,
//! spin kernels) is reproduced against a virtual nanosecond clock. The core
//! is a deterministic event queue generic over the world's event type; the
//! composition of fabric + gpusim + MMA engine lives in [`crate::mma::driver`].

mod queue;
mod time;

pub use queue::{EventQueue, HeapEventQueue};
pub use time::Time;

/// Nanoseconds per microsecond.
pub const NS_PER_US: u64 = 1_000;
/// Nanoseconds per millisecond.
pub const NS_PER_MS: u64 = 1_000_000;
/// Nanoseconds per second.
pub const NS_PER_SEC: u64 = 1_000_000_000;
