//! Scoped-thread parallel map for independent sweep cells.
//!
//! Figure sweeps evaluate a grid of cells where each cell builds its own
//! `SimWorld` and shares nothing with its neighbours — embarrassingly
//! parallel work that previously ran sequentially. [`par_map`] fans the
//! cells out over `std::thread::scope` workers (zero dependencies, no
//! thread pool to manage) and writes every result into its input slot,
//! so the merged output is in canonical input order and **byte-identical
//! for any job count** — the determinism contract `--jobs` must keep.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` using up to `jobs` worker threads, preserving
/// input order in the result. `jobs <= 1` (or a single item) runs
/// sequentially on the caller's thread with no synchronization.
///
/// `f` receives `(index, item)` so cells can derive per-cell seeds from
/// their canonical position rather than from scheduling order. A panic
/// in any worker propagates to the caller once all workers have joined.
pub fn par_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if jobs <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("cell claimed twice");
                let r = f(i, item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell not computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order_for_any_job_count() {
        let items: Vec<u64> = (0..37).collect();
        let seq = par_map(1, items.clone(), |i, x| x * 100 + i as u64);
        for jobs in [2, 4, 16] {
            let par = par_map(jobs, items.clone(), |i, x| x * 100 + i as u64);
            assert_eq!(par, seq, "jobs={jobs}");
        }
    }

    #[test]
    fn more_jobs_than_items_is_fine() {
        let out = par_map(8, vec![1u32, 2], |_, x| x + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(4, empty, |_, x: u32| x).is_empty());
        assert_eq!(par_map(4, vec![9u32], |i, x| x + i as u32), vec![9]);
    }

    #[test]
    fn index_matches_canonical_position() {
        let items: Vec<&str> = vec!["a", "b", "c", "d", "e"];
        let out = par_map(3, items, |i, s| format!("{i}:{s}"));
        assert_eq!(out, vec!["0:a", "1:b", "2:c", "3:d", "4:e"]);
    }
}
