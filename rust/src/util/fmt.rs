//! Human-readable formatting for sizes, bandwidths, and durations.

/// Format a byte count with binary prefixes ("17.5 GiB").
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a bandwidth in GB/s (decimal, matching the paper's units).
pub fn gbps(bytes_per_sec: f64) -> String {
    format!("{:.1} GB/s", bytes_per_sec / 1e9)
}

/// Format a duration given in seconds adaptively (us / ms / s).
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Parse sizes like "512MB", "8GB", "64k", "1.5GiB" (case-insensitive,
/// decimal multipliers for B-suffixed units to match the paper's figures).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_lowercase();
    let split = t.find(|c: char| !(c.is_ascii_digit() || c == '.'))?;
    let (num, unit) = t.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult: f64 = match unit.trim() {
        "b" => 1.0,
        "k" | "kb" => 1e3,
        "m" | "mb" => 1e6,
        "g" | "gb" => 1e9,
        "t" | "tb" => 1e12,
        "kib" => 1024.0,
        "mib" => 1024.0 * 1024.0,
        "gib" => 1024.0 * 1024.0 * 1024.0,
        _ => return None,
    };
    Some((num * mult).round() as u64)
}

/// Parse a size that may also be a bare integer (bytes).
pub fn parse_bytes_or_int(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse_bytes(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_binary_prefixes() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn gbps_decimal() {
        assert_eq!(gbps(53.6e9), "53.6 GB/s");
    }

    #[test]
    fn secs_adaptive() {
        assert_eq!(secs(5e-6), "5.0 us");
        assert_eq!(secs(2.5e-3), "2.50 ms");
        assert_eq!(secs(2.5), "2.500 s");
    }

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_bytes("8GB"), Some(8_000_000_000));
        assert_eq!(parse_bytes("5mb"), Some(5_000_000));
        assert_eq!(parse_bytes("1.5GiB"), Some(1_610_612_736));
        assert_eq!(parse_bytes("100b"), Some(100));
        assert_eq!(parse_bytes_or_int("4096"), Some(4096));
        assert_eq!(parse_bytes("x"), None);
    }
}
