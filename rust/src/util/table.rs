//! Minimal ASCII table printer used by every figure/table harness so the
//! benches emit the same row structure the paper reports.

/// A simple left/right-aligned ASCII table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; missing cells render empty, extras are kept.
    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Table {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    /// Render to a string (first column left-aligned, rest right-aligned).
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(Vec::len)
            .chain([self.header.len()])
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                if i == 0 {
                    line.push_str(&format!("{cell:<w$}"));
                } else {
                    line.push_str(&format!("  {cell:>w$}"));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "bw"]);
        t.row(["native", "53.6"]);
        t.row(["mma", "245.1"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].contains("53.6"));
        assert!(lines[3].contains("245.1"));
        // right alignment: both numeric cells end at same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn ragged_rows_ok() {
        let mut t = Table::new(["a"]);
        t.row(["x", "y", "z"]);
        let s = t.render();
        assert!(s.contains('z'));
    }
}
