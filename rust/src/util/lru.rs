//! A slab-backed intrusive doubly-linked LRU list: `touch`, `push_front`,
//! `remove`, and `tail` (the LRU victim) are all O(1).
//!
//! The list stores no payload — callers keep their entries in a parallel
//! `Vec` indexed by the `u32` slot ids this list hands out, and a map from
//! their own keys to slots. Slots are recycled through a free list, so a
//! cache that churns at a steady population allocates nothing after
//! warm-up (the same slab discipline as `util::slab`, specialized to the
//! recency-order links the prefix tiers need).
//!
//! Recency order is the *only* order: the front is the most recently
//! used slot, the tail the least. Because every `insert`/`touch` moves
//! exactly one slot to the front, the tail is always the unique LRU
//! entry — the same total order the retired `min_by_key(last_use)` scan
//! produced with its strictly monotone use-clock (see
//! `serving::prefix_cache::oracle` for the retained reference).

/// Sentinel for "no slot".
const NIL: u32 = u32::MAX;

/// The intrusive list. All operations O(1); memory is O(high-water slots).
#[derive(Debug, Default, Clone)]
pub struct LruList {
    prev: Vec<u32>,
    next: Vec<u32>,
    head: u32,
    tail: u32,
    free: Vec<u32>,
    len: usize,
}

impl LruList {
    /// An empty list.
    pub fn new() -> LruList {
        LruList {
            prev: Vec::new(),
            next: Vec::new(),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            len: 0,
        }
    }

    /// Live slots.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no slot is live.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocate a slot and link it at the front (most recently used).
    /// Returns the slot id; ids are reused after [`Self::remove`], and a
    /// fresh id always equals the previous slot high-water mark (so a
    /// parallel payload `Vec` can `push` exactly when `id == vec.len()`).
    pub fn push_front(&mut self) -> u32 {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                let s = self.prev.len() as u32;
                assert!(s < NIL, "LruList slot ids exhausted");
                self.prev.push(NIL);
                self.next.push(NIL);
                s
            }
        };
        self.link_front(slot);
        self.len += 1;
        slot
    }

    /// Move a live slot to the front (most recently used).
    pub fn touch(&mut self, slot: u32) {
        if self.head == slot {
            return;
        }
        self.unlink(slot);
        self.link_front(slot);
    }

    /// Unlink a live slot and recycle its id.
    pub fn remove(&mut self, slot: u32) {
        self.unlink(slot);
        self.free.push(slot);
        self.len -= 1;
    }

    /// The least recently used slot (`None` when empty).
    pub fn tail(&self) -> Option<u32> {
        if self.tail == NIL {
            None
        } else {
            Some(self.tail)
        }
    }

    /// The most recently used slot (`None` when empty).
    pub fn front(&self) -> Option<u32> {
        if self.head == NIL {
            None
        } else {
            Some(self.head)
        }
    }

    /// Slots from most to least recently used (test/debug aid; O(len)).
    pub fn iter(&self) -> LruIter<'_> {
        LruIter {
            list: self,
            at: self.head,
        }
    }

    fn link_front(&mut self, slot: u32) {
        let s = slot as usize;
        self.prev[s] = NIL;
        self.next[s] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    fn unlink(&mut self, slot: u32) {
        let s = slot as usize;
        let (p, n) = (self.prev[s], self.next[s]);
        if p == NIL {
            self.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        self.prev[s] = NIL;
        self.next[s] = NIL;
    }
}

/// Iterator over slots, most recently used first.
pub struct LruIter<'a> {
    list: &'a LruList,
    at: u32,
}

impl Iterator for LruIter<'_> {
    type Item = u32;
    fn next(&mut self) -> Option<u32> {
        if self.at == NIL {
            return None;
        }
        let s = self.at;
        self.at = self.list.next[s as usize];
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn push_touch_evict_order() {
        let mut l = LruList::new();
        let a = l.push_front();
        let b = l.push_front();
        let c = l.push_front();
        assert_eq!(l.tail(), Some(a));
        l.touch(a); // order now a, c, b
        assert_eq!(l.tail(), Some(b));
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![a, c, b]);
        l.remove(b);
        assert_eq!(l.tail(), Some(c));
        l.remove(c);
        assert_eq!(l.tail(), Some(a));
        assert_eq!(l.front(), Some(a));
        l.remove(a);
        assert!(l.is_empty());
        assert_eq!(l.tail(), None);
    }

    #[test]
    fn slots_are_recycled_not_grown() {
        let mut l = LruList::new();
        let a = l.push_front();
        let b = l.push_front();
        l.remove(a);
        let c = l.push_front();
        assert_eq!(c, a, "freed slot reused");
        assert_eq!(l.len(), 2);
        let d = l.push_front();
        assert_eq!(d as usize, 2, "fresh ids extend the slab in order");
        let _ = b;
    }

    #[test]
    fn touching_the_front_is_a_noop() {
        let mut l = LruList::new();
        let a = l.push_front();
        let b = l.push_front();
        l.touch(b);
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![b, a]);
    }

    #[test]
    fn randomized_order_matches_vec_model() {
        // Model: a Vec kept in recency order (front = MRU). Every list op
        // must agree with the model after arbitrary interleavings.
        let mut l = LruList::new();
        let mut model: Vec<u32> = Vec::new();
        let mut rng = Rng::seed_from_u64(0x10b);
        for _ in 0..4000 {
            match rng.range_u64(0, 3) {
                0 => {
                    let s = l.push_front();
                    model.insert(0, s);
                }
                1 if !model.is_empty() => {
                    let i = rng.range_u64(0, model.len() as u64) as usize;
                    let s = model.remove(i);
                    l.touch(s);
                    model.insert(0, s);
                }
                2 if !model.is_empty() => {
                    let i = rng.range_u64(0, model.len() as u64) as usize;
                    let s = model.remove(i);
                    l.remove(s);
                }
                _ => {}
            }
            assert_eq!(l.len(), model.len());
            assert_eq!(l.tail(), model.last().copied());
        }
        assert_eq!(l.iter().collect::<Vec<_>>(), model);
    }
}
