//! Generational slab: dense storage with stable, compact `u32` keys.
//!
//! The MMA engine tracks every in-flight chunk and active transfer by a
//! key that also rides inside the 24-bit `b` field of a fabric flow tag
//! (`mma` driver tag packing). A hash map works but costs a hash + probe
//! per event and re-allocates as it grows; a generational slab gives
//! O(1) array indexing, reuses slots without reallocating at steady
//! state, and detects stale keys.
//!
//! A key packs a slot index in its low 16 bits and a generation counter
//! in the next 8 bits, so every key fits in 24 bits. Removing an entry
//! bumps the slot's generation; a stale key held by an outside observer
//! (e.g. a completion notice for an already-retired chunk) then misses
//! instead of aliasing the slot's new occupant.

/// Maximum live entries (slot index is 16 bits).
pub const MAX_SLOTS: usize = 1 << 16;

struct Entry<T> {
    gen: u8,
    val: Option<T>,
}

/// A generational slab. Keys are handed out by [`Slab::insert`] and stay
/// valid until [`Slab::remove`] retires them.
pub struct Slab<T> {
    entries: Vec<Entry<T>>,
    free: Vec<u16>,
    len: usize,
}

impl<T> Slab<T> {
    /// Empty slab.
    pub fn new() -> Slab<T> {
        Slab {
            entries: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    fn split(key: u32) -> (usize, u8) {
        ((key & 0xFFFF) as usize, ((key >> 16) & 0xFF) as u8)
    }

    /// Insert a value, returning its key (always < 2^24).
    ///
    /// Panics if the slab already holds [`MAX_SLOTS`] live entries.
    pub fn insert(&mut self, val: T) -> u32 {
        let slot = match self.free.pop() {
            Some(s) => {
                let e = &mut self.entries[s as usize];
                debug_assert!(e.val.is_none());
                e.val = Some(val);
                s
            }
            None => {
                assert!(self.entries.len() < MAX_SLOTS, "slab full");
                self.entries.push(Entry { gen: 0, val: Some(val) });
                (self.entries.len() - 1) as u16
            }
        };
        self.len += 1;
        ((self.entries[slot as usize].gen as u32) << 16) | slot as u32
    }

    /// Look up a live entry; `None` for stale or never-issued keys.
    pub fn get(&self, key: u32) -> Option<&T> {
        let (slot, gen) = Self::split(key);
        match self.entries.get(slot) {
            Some(e) if e.gen == gen => e.val.as_ref(),
            _ => None,
        }
    }

    /// Mutable lookup; `None` for stale or never-issued keys.
    pub fn get_mut(&mut self, key: u32) -> Option<&mut T> {
        let (slot, gen) = Self::split(key);
        match self.entries.get_mut(slot) {
            Some(e) if e.gen == gen => e.val.as_mut(),
            _ => None,
        }
    }

    /// Remove and return a live entry, bumping the slot's generation so
    /// the key (and any copies of it) go stale. `None` if the key is
    /// already stale.
    pub fn remove(&mut self, key: u32) -> Option<T> {
        let (slot, gen) = Self::split(key);
        let e = self.entries.get_mut(slot)?;
        if e.gen != gen || e.val.is_none() {
            return None;
        }
        let val = e.val.take();
        e.gen = e.gen.wrapping_add(1);
        self.free.push(slot as u16);
        self.len -= 1;
        val
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entries are live.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<&str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        *s.get_mut(a).unwrap() = "a2";
        assert_eq!(s.remove(a), Some("a2"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(a), None);
    }

    #[test]
    fn stale_key_misses_after_slot_reuse() {
        let mut s: Slab<u32> = Slab::new();
        let k1 = s.insert(1);
        assert_eq!(s.remove(k1), Some(1));
        let k2 = s.insert(2);
        // Same slot, new generation: distinct key, stale one misses.
        assert_eq!(k1 & 0xFFFF, k2 & 0xFFFF);
        assert_ne!(k1, k2);
        assert_eq!(s.get(k1), None);
        assert_eq!(s.remove(k1), None);
        assert_eq!(s.get(k2), Some(&2));
    }

    #[test]
    fn keys_fit_in_24_bits_and_slots_are_reused() {
        let mut s: Slab<usize> = Slab::new();
        let keys: Vec<u32> = (0..32).map(|i| s.insert(i)).collect();
        for (i, k) in keys.iter().enumerate() {
            assert!(*k < (1 << 24));
            assert_eq!(s.get(*k), Some(&i));
        }
        for k in &keys {
            s.remove(*k).unwrap();
        }
        assert!(s.is_empty());
        // Re-inserting reuses retired slots instead of growing.
        let before = s.entries.len();
        for i in 0..32 {
            s.insert(i);
        }
        assert_eq!(s.entries.len(), before);
    }

    #[test]
    fn double_remove_is_safe() {
        let mut s: Slab<u8> = Slab::new();
        let k = s.insert(7);
        assert_eq!(s.remove(k), Some(7));
        assert_eq!(s.remove(k), None);
        assert!(s.is_empty());
    }
}
