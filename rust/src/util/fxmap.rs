//! Deterministic, fast hashing for hot integer-keyed maps.
//!
//! `std::collections::HashMap`'s default `RandomState` re-seeds SipHash
//! per process — robust against adversarial keys, but (a) slow for the
//! simulator's u32/u64 keys (request ids, prefix hashes, packed flow
//! tags) and (b) a source of run-to-run iteration-order variance that
//! deterministic code has to keep defending against. [`FxHasher`] is the
//! multiply-rotate hash used by rustc (firefox "Fx" hash): one rotate,
//! one xor and one multiply per 8-byte chunk, with a fixed seed — the
//! same inputs hash identically in every process. Only use it for
//! trusted, internally-generated keys; it is not DoS-resistant.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed by [`FxHasher`] — drop-in for internally-generated keys.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

/// rustc's FxHash: multiply-rotate over 8-byte chunks, fixed seed.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

/// Knuth-style odd multiplier (golden-ratio derived), as used by rustc.
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf) | ((rem.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_to_hash(n as u64);
    }
    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add_to_hash(n as u64);
    }
    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }
    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_one<T: Hash>(x: &T) -> u64 {
        let mut h = FxHasher::default();
        x.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_across_builders() {
        // Unlike RandomState, two independent maps hash identically.
        let b1: BuildHasherDefault<FxHasher> = Default::default();
        let b2: BuildHasherDefault<FxHasher> = Default::default();
        for k in [0u64, 1, 42, u64::MAX, 0xdead_beef] {
            let mut h1 = b1.build_hasher();
            let mut h2 = b2.build_hasher();
            k.hash(&mut h1);
            k.hash(&mut h2);
            assert_eq!(h1.finish(), h2.finish());
        }
    }

    #[test]
    fn distinguishes_nearby_keys() {
        let a = hash_one(&1u64);
        let b = hash_one(&2u64);
        let c = hash_one(&3u64);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
        // Strings hash by content, with length folded into the tail chunk.
        assert_ne!(hash_one(&"abc"), hash_one(&"abd"));
        assert_ne!(hash_one(&"ab"), hash_one(&"ab\0"));
    }

    #[test]
    fn map_and_set_round_trip() {
        let mut m: FxHashMap<u64, &str> = FxHashMap::default();
        m.insert(7, "seven");
        m.insert(11, "eleven");
        assert_eq!(m.get(&7), Some(&"seven"));
        assert_eq!(m.len(), 2);
        let mut s: FxHashSet<u32> = FxHashSet::default();
        assert!(s.insert(5));
        assert!(!s.insert(5));
        assert!(s.contains(&5));
    }
}
