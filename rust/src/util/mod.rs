//! Small self-contained substrates that would normally come from crates.io
//! (`rand`, `clap`, `criterion`, `prettytable`) but are unavailable in this
//! offline build. Each is implemented from scratch and unit-tested.

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod fxmap;
pub mod lru;
pub mod par;
pub mod rng;
pub mod slab;
pub mod small;
pub mod table;

pub use small::SmallPath;
