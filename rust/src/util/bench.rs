//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warms up, then runs timed batches until a target wall budget is spent,
//! reporting mean / p50 / p99 per-iteration times. Used by
//! `rust/benches/hotpath.rs` and `mma bench hotpath` for the perf
//! trajectory (`BENCH_*.json`).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark. Holds its per-batch samples presorted, so any
/// number of [`Self::percentile`] queries costs one sort total (paid at
/// construction), not one sort per call.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Total iterations measured.
    pub iters: u64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// Median ns per iteration (over batches).
    pub p50_ns: f64,
    /// 99th percentile ns per iteration (over batches).
    pub p99_ns: f64,
    /// Per-batch ns/iter samples, sorted ascending.
    samples: Vec<f64>,
}

impl BenchResult {
    /// Build from raw per-batch samples (ns/iter); sorts them once.
    pub fn from_samples(name: &str, iters: u64, mut samples: Vec<f64>) -> BenchResult {
        assert!(!samples.is_empty(), "benchmark produced no samples");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: 0.0,
            p99_ns: 0.0,
            samples,
        };
        r.p50_ns = r.percentile(0.50);
        r.p99_ns = r.percentile(0.99);
        r
    }

    /// Percentile (0.0..=1.0) of the per-batch ns/iter distribution —
    /// an index into the presorted samples, O(1) per query.
    pub fn percentile(&self, p: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&p));
        self.samples[((self.samples.len() - 1) as f64 * p) as usize]
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12.0} ns/iter  (p50 {:>10.0}, p99 {:>10.0}, n={})",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.iters
        )
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_millis(200), Duration::from_secs(1))
    }
}

impl Bencher {
    /// Create with explicit warmup and measurement budgets.
    pub fn new(warmup: Duration, budget: Duration) -> Bencher {
        Bencher {
            warmup,
            budget,
            results: Vec::new(),
        }
    }

    /// Run one benchmark. `f` is invoked repeatedly; keep per-call state
    /// outside the closure.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + batch-size estimation.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        // Aim for ~200 batches over the budget.
        let batch = ((self.budget.as_secs_f64() / 200.0 / per_iter).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new(); // ns per iter, per batch
        let mut iters = 0u64;
        let m0 = Instant::now();
        while m0.elapsed() < self.budget {
            let b0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = b0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(dt);
            iters += batch;
        }
        let res = BenchResult::from_samples(name, iters, samples);
        println!("{}", res.summary());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bencher::new(Duration::from_millis(10), Duration::from_millis(50));
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.iters > 1000);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns * 1.0001);
    }

    #[test]
    fn percentiles_index_presorted_samples() {
        let r = BenchResult::from_samples("t", 5, vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 5.0);
        assert_eq!(r.p50_ns, r.percentile(0.5));
        assert_eq!(r.p50_ns, 3.0);
        assert_eq!(r.p99_ns, r.percentile(0.99));
        assert!((r.mean_ns - 3.0).abs() < 1e-12);
    }
}
