//! Tiny command-line parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch is done by the caller on the first
//! positional.

use std::collections::BTreeMap;

/// Parsed arguments: options map + positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next token is another option or absent
                    let is_flag = iter
                        .peek()
                        .map(|n| n.starts_with("--"))
                        .unwrap_or(true);
                    if is_flag {
                        out.opts.insert(stripped.to_string(), "true".to_string());
                    } else {
                        out.opts.insert(stripped.to_string(), iter.next().unwrap());
                    }
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(String::as_str)
    }

    /// All positionals.
    pub fn positionals(&self) -> &[String] {
        &self.pos
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option; panics with a clear message on parse failure.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).map(|v| {
            v.parse().unwrap_or_else(|_| {
                panic!("--{key}: cannot parse {v:?} as {}", std::any::type_name::<T>())
            })
        })
    }

    /// Typed option with default.
    pub fn or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get_as(key).unwrap_or(default)
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list option (`--switch-models a,b`): entries
    /// trimmed, empties dropped; an absent key yields an empty vec (use
    /// [`Self::get`] to distinguish absent from present-but-empty).
    pub fn list(&self, key: &str) -> Vec<String> {
        match self.get(key) {
            None => Vec::new(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        }
    }

    /// Byte-size option accepting "512MB" style suffixes.
    pub fn size_or(&self, key: &str, default: u64) -> u64 {
        match self.get(key) {
            None => default,
            Some(v) => super::fmt::parse_bytes_or_int(v)
                .unwrap_or_else(|| panic!("--{key}: bad size {v:?}")),
        }
    }

    /// The shared `--seed` flag: every stochastic runner (workload
    /// generation, figure harnesses) derives its RNG from this one value
    /// so runs are reproducible. Accepts decimal or `0x`-prefixed hex.
    pub fn seed_or(&self, default: u64) -> u64 {
        match self.get("seed") {
            None => default,
            Some(v) => {
                let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
                    Some(hex) => u64::from_str_radix(hex, 16).ok(),
                    None => v.parse().ok(),
                };
                parsed.unwrap_or_else(|| panic!("--seed: bad value {v:?}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        // NB: a bare `--flag` greedily takes the next token as its value
        // unless that token is another option or absent — boolean flags in
        // front of positionals must use `--flag=true`.
        let a = parse("serve run --gpus 8 --chunk=5MB --verbose");
        assert_eq!(a.pos(0), Some("serve"));
        assert_eq!(a.pos(1), Some("run"));
        assert_eq!(a.or::<u32>("gpus", 1), 8);
        assert_eq!(a.size_or("chunk", 0), 5_000_000);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.or::<f64>("ratio", 1.5), 1.5);
        assert_eq!(a.str_or("mode", "mma"), "mma");
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("--fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn float_option_parses() {
        // The serve concurrency flags ride on the generic typed getter.
        let a = parse("serve --arrival-rate 2.5 --max-concurrency 8");
        assert_eq!(a.or::<f64>("arrival-rate", 0.0), 2.5);
        assert_eq!(a.or::<f64>("missing", 1.5), 1.5);
        assert_eq!(a.or::<u32>("max-concurrency", 0), 8);
    }

    #[test]
    fn list_splits_and_trims() {
        let a = parse("trace gen --switch-models qwen-7b-chat,qwen3-32b");
        assert_eq!(a.list("switch-models"), vec!["qwen-7b-chat", "qwen3-32b"]);
        assert!(a.list("missing").is_empty());
        let b = parse("--models a,,b");
        assert_eq!(b.list("models"), vec!["a", "b"]);
    }

    #[test]
    fn seed_accepts_decimal_and_hex() {
        assert_eq!(parse("x").seed_or(42), 42);
        assert_eq!(parse("--seed 7 x").seed_or(42), 7);
        assert_eq!(parse("--seed 0xF16 x").seed_or(42), 0xF16);
    }
}
