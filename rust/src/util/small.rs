//! [`SmallPath`]: an inline small-vector for fabric link paths.
//!
//! Every chunk the MMA engine dispatches carries the `LinkId` path it
//! crosses. Real paths are short — the longest preset path (cross-socket
//! relay D2H) is 7 links — so storing them in a `Vec` costs one heap
//! allocation per dispatched chunk for data that fits in two machine
//! words. `SmallPath` keeps up to [`INLINE_LINKS`] links inline and only
//! spills to a heap `Vec` beyond that, making path construction
//! allocation-free on the engine's steady-state path.

use crate::topology::LinkId;
use std::fmt;
use std::ops::Deref;

/// Links stored inline before spilling to the heap.
pub const INLINE_LINKS: usize = 8;

#[derive(Clone)]
enum Repr {
    Inline { len: u8, buf: [LinkId; INLINE_LINKS] },
    Heap(Vec<LinkId>),
}

/// A path of fabric links with inline storage for up to
/// [`INLINE_LINKS`] entries. Dereferences to `&[LinkId]`, so it drops
/// into any API that takes a link slice.
#[derive(Clone)]
pub struct SmallPath(Repr);

impl SmallPath {
    /// Empty path (inline representation).
    pub fn new() -> SmallPath {
        SmallPath(Repr::Inline {
            len: 0,
            buf: [LinkId(0); INLINE_LINKS],
        })
    }

    /// Copy a slice into a path, spilling only if it exceeds the inline
    /// capacity.
    pub fn from_slice(links: &[LinkId]) -> SmallPath {
        let mut p = SmallPath::new();
        for &l in links {
            p.push(l);
        }
        p
    }

    /// Append a link, spilling to the heap past the inline capacity.
    pub fn push(&mut self, l: LinkId) {
        match &mut self.0 {
            Repr::Inline { len, buf } => {
                let n = *len as usize;
                if n < INLINE_LINKS {
                    buf[n] = l;
                    *len += 1;
                } else {
                    let mut v = Vec::with_capacity(INLINE_LINKS * 2);
                    v.extend_from_slice(&buf[..]);
                    v.push(l);
                    self.0 = Repr::Heap(v);
                }
            }
            Repr::Heap(v) => v.push(l),
        }
    }

    /// The links as a slice.
    pub fn as_slice(&self) -> &[LinkId] {
        match &self.0 {
            Repr::Inline { len, buf } => &buf[..*len as usize],
            Repr::Heap(v) => v,
        }
    }

    /// Number of links.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// True when the path has no links.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the path has spilled to heap storage (diagnostics/tests).
    pub fn spilled(&self) -> bool {
        matches!(self.0, Repr::Heap(_))
    }
}

impl Default for SmallPath {
    fn default() -> Self {
        SmallPath::new()
    }
}

impl Deref for SmallPath {
    type Target = [LinkId];
    fn deref(&self) -> &[LinkId] {
        self.as_slice()
    }
}

impl From<Vec<LinkId>> for SmallPath {
    fn from(v: Vec<LinkId>) -> SmallPath {
        if v.len() <= INLINE_LINKS {
            SmallPath::from_slice(&v)
        } else {
            SmallPath(Repr::Heap(v))
        }
    }
}

impl FromIterator<LinkId> for SmallPath {
    fn from_iter<I: IntoIterator<Item = LinkId>>(iter: I) -> SmallPath {
        let mut p = SmallPath::new();
        for l in iter {
            p.push(l);
        }
        p
    }
}

impl<'a> IntoIterator for &'a SmallPath {
    type Item = &'a LinkId;
    type IntoIter = std::slice::Iter<'a, LinkId>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl PartialEq for SmallPath {
    fn eq(&self, other: &SmallPath) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl Eq for SmallPath {}

impl fmt::Debug for SmallPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.as_slice().fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(ns: &[u16]) -> Vec<LinkId> {
        ns.iter().map(|&n| LinkId(n)).collect()
    }

    #[test]
    fn inline_push_preserves_order_without_spilling() {
        let mut p = SmallPath::new();
        assert!(p.is_empty());
        for n in 0..INLINE_LINKS as u16 {
            p.push(LinkId(n));
        }
        assert_eq!(p.len(), INLINE_LINKS);
        assert!(!p.spilled());
        assert_eq!(p.as_slice(), &ids(&[0, 1, 2, 3, 4, 5, 6, 7])[..]);
    }

    #[test]
    fn push_past_inline_capacity_spills_and_keeps_contents() {
        let mut p = SmallPath::new();
        for n in 0..12u16 {
            p.push(LinkId(n));
        }
        assert!(p.spilled());
        assert_eq!(p.len(), 12);
        let want: Vec<LinkId> = (0..12u16).map(LinkId).collect();
        assert_eq!(p.as_slice(), &want[..]);
    }

    #[test]
    fn from_vec_stays_inline_when_short() {
        let short = SmallPath::from(ids(&[3, 1, 4]));
        assert!(!short.spilled());
        assert_eq!(short.as_slice(), &ids(&[3, 1, 4])[..]);
        let long = SmallPath::from(ids(&[0, 1, 2, 3, 4, 5, 6, 7, 8]));
        assert!(long.spilled());
        assert_eq!(long.len(), 9);
    }

    #[test]
    fn eq_compares_contents_across_representations() {
        let inline = SmallPath::from_slice(&ids(&[1, 2, 3]));
        let mut heap = SmallPath::from(ids(&[0, 1, 2, 3, 4, 5, 6, 7, 9]));
        assert_ne!(inline, heap);
        heap = SmallPath(Repr::Heap(ids(&[1, 2, 3])));
        assert_eq!(inline, heap);
    }

    #[test]
    fn deref_gives_slice_methods() {
        let p: SmallPath = ids(&[5, 6]).into();
        assert_eq!(p.iter().map(|l| l.0).sum::<u16>(), 11);
        assert_eq!(p[1], LinkId(6));
        let collected: SmallPath = p.iter().copied().collect();
        assert_eq!(collected, p);
    }

    #[test]
    fn clone_is_independent() {
        let mut a = SmallPath::from_slice(&ids(&[1]));
        let b = a.clone();
        a.push(LinkId(2));
        assert_eq!(b.len(), 1);
        assert_eq!(a.len(), 2);
    }
}
