//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded via splitmix64 — the same construction the `rand`
//! crate's small RNGs use. All simulation randomness flows through this so
//! runs are reproducible from a single `u64` seed.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        let span = hi - lo;
        // Rejection-free multiply-shift (Lemire); bias is negligible for
        // simulation purposes but we debias with one retry loop anyway.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)` as usize.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with the given mean (for Poisson arrivals).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (for skewed
    /// prefix-cache reuse patterns). Uses inverse-CDF over precomputed
    /// weights; O(n) setup per call is fine for workload generation.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let mut u = self.f64() * total;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
            seen_lo |= x == 10;
            seen_hi |= x == 19;
        }
        assert!(seen_lo && seen_hi, "range endpoints never drawn");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::seed_from_u64(3);
        let mean = 5.0;
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let x = r.exp(mean);
            assert!(x >= 0.0);
            sum += x;
        }
        let m = sum / n as f64;
        assert!((m - mean).abs() / mean < 0.05, "exp mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "shuffle was identity");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::seed_from_u64(5);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            let k = r.zipf(10, 1.2);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[4], "zipf not skewed toward rank 0");
        assert!(counts[0] > counts[9] * 3);
    }
}
