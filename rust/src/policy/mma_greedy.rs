//! The paper's Path Selector (§3.4.2) as a [`TransferPolicy`]: pull-based
//! selection with outstanding-queue backpressure as the implicit
//! congestion signal.
//!
//! One *outstanding queue* exists per PCIe link (per direction), statically
//! bound to its GPU. The selector never pushes work to a path; a path
//! *pulls* a micro-task only when its outstanding queue has capacity. A
//! congested path retires slowly, stays full, and stops pulling — no
//! explicit link-state feedback needed.

use super::{PolicyView, Pulled, TransferPolicy};
use crate::mma::task_manager::TaskManager;
use crate::mma::MmaConfig;
use crate::topology::GpuId;

/// The greedy pull policy, honoring:
///
/// 1. **Direct-path-first** (if `direct_priority`): own-destination
///    micro-tasks before any relay work, minimizing NVLink spend.
/// 2. **Longest-remaining-destination stealing**: relay work comes from
///    the destination with the most pending bytes.
/// 3. **Relay eligibility**: this GPU must be in the relay set, and
///    NUMA restrictions respected.
#[derive(Debug, Clone)]
pub struct MmaGreedy {
    /// Prefer micro-tasks destined to the queue's own GPU (§3.4.2).
    pub direct_priority: bool,
    /// Relay candidates; `None` = every peer GPU.
    pub relay_gpus: Option<Vec<GpuId>>,
    /// Restrict relays to the target's NUMA node (§6).
    pub numa_local_only: bool,
}

impl MmaGreedy {
    /// Build from the engine's shared knobs.
    pub fn from_cfg(cfg: &MmaConfig) -> MmaGreedy {
        MmaGreedy {
            direct_priority: cfg.direct_priority,
            relay_gpus: cfg.relay_gpus.clone(),
            numa_local_only: cfg.numa_local_only,
        }
    }
}

impl TransferPolicy for MmaGreedy {
    fn name(&self) -> &'static str {
        "mma-greedy"
    }

    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, view: &PolicyView) -> Option<Pulled> {
        let topo = view.topo;
        let numa_local_only = self.numa_local_only;
        let relay_ok = super::in_relay_set(&self.relay_gpus, gpu);
        let cp = view.class_pull;
        super::greedy_pull(tm, gpu, self.direct_priority, relay_ok, cp, |dest, remaining| {
            if !numa_local_only || topo.numa_of(dest) == topo.numa_of(gpu) {
                Some(remaining as f64)
            } else {
                None
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::mma::task_manager::{Chunk, PullClassPolicy};
    use crate::mma::TransferClass;
    use crate::sim::Time;
    use crate::topology::{h20x8, Direction, Topology};

    fn view(topo: &Topology) -> PolicyView<'_> {
        PolicyView {
            topo,
            dir: Direction::H2D,
            queues: &[],
            now: Time::ZERO,
            class_pull: PullClassPolicy::default(),
            class_pending: [0; crate::mma::NUM_CLASSES],
        }
    }

    fn split(t: u32, dest: GpuId, bytes: u64) -> Vec<Chunk> {
        TaskManager::split(TransferId(t), dest, bytes, 5_000_000, TransferClass::Interactive)
    }

    fn mgr_with(dest: GpuId, bytes: u64) -> TaskManager {
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, dest, bytes));
        tm
    }

    #[test]
    fn direct_priority_wins_over_steal() {
        let topo = h20x8();
        let mut p = MmaGreedy::from_cfg(&MmaConfig::default());
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 10_000_000));
        tm.push_pending(&split(2, GpuId(1), 50_000_000));
        // GPU 0 has own work → direct, even though dest 1 has more bytes.
        let got = p.pull(&mut tm, GpuId(0), &view(&topo)).unwrap();
        assert_eq!(
            got,
            Pulled::Direct(Chunk {
                transfer: TransferId(1),
                index: 0,
                bytes: 5_000_000,
                dest: GpuId(0),
                class: TransferClass::Interactive,
            })
        );
    }

    #[test]
    fn without_direct_priority_steal_comes_first() {
        let topo = h20x8();
        let mut p = MmaGreedy {
            direct_priority: false,
            ..MmaGreedy::from_cfg(&MmaConfig::default())
        };
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 10_000_000));
        tm.push_pending(&split(2, GpuId(1), 50_000_000));
        let got = p.pull(&mut tm, GpuId(0), &view(&topo)).unwrap();
        assert!(got.is_relay(), "{got:?}");
        assert_eq!(got.chunk().dest, GpuId(1));
    }

    #[test]
    fn relay_set_restriction() {
        let topo = h20x8();
        let mut p = MmaGreedy::from_cfg(&MmaConfig::with_relays(vec![GpuId(2)]));
        let mut tm = mgr_with(GpuId(0), 50_000_000);
        // GPU 1 is not in the relay set: no pull.
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).is_none());
        // GPU 2 is: relay pull.
        let got = p.pull(&mut tm, GpuId(2), &view(&topo)).unwrap();
        assert!(got.is_relay());
    }

    #[test]
    fn numa_local_only_blocks_cross_socket_relay() {
        let topo = h20x8();
        let mut p = MmaGreedy {
            numa_local_only: true,
            ..MmaGreedy::from_cfg(&MmaConfig::default())
        };
        let mut tm = mgr_with(GpuId(0), 50_000_000); // dest on numa0
        // GPU 5 lives on numa1 → not eligible.
        assert!(p.pull(&mut tm, GpuId(5), &view(&topo)).is_none());
        // GPU 1 (numa0) is eligible.
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).is_some());
    }
}
