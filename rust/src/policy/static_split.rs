//! Static splitting baseline (Fig 10) as a [`TransferPolicy`]: fixed byte
//! ratios across a fixed path set, chosen in advance. The strawman MMA's
//! pull-based scheduling is measured against — it cannot react when a
//! path's effective bandwidth changes mid-transfer.

use super::{PolicyView, Pulled, TransferPolicy};
use crate::mma::task_manager::{Chunk, TaskManager};
use crate::topology::GpuId;

/// Pre-assigns each transfer's micro-tasks to paths by smooth weighted
/// round-robin; paths then drain only their own assignment (no stealing).
#[derive(Debug, Clone)]
pub struct StaticSplit {
    /// `(path_gpu, weight)`; the destination's own entry is the direct
    /// path, others are relays.
    pub ratios: Vec<(GpuId, f64)>,
}

impl StaticSplit {
    /// New splitter over the given ratios. Panics on an empty set.
    pub fn new(ratios: Vec<(GpuId, f64)>) -> StaticSplit {
        assert!(!ratios.is_empty(), "static split needs at least one path");
        StaticSplit { ratios }
    }
}

impl TransferPolicy for StaticSplit {
    fn name(&self) -> &'static str {
        "static-split"
    }

    /// Smooth weighted round-robin over the configured paths, interleaving
    /// assignments so every path starts pulling immediately.
    fn admit(&mut self, chunks: &[Chunk], tm: &mut TaskManager, _view: &PolicyView) {
        let total_w: f64 = self.ratios.iter().map(|(_, w)| *w).sum();
        let mut current: Vec<f64> = vec![0.0; self.ratios.len()];
        for c in chunks {
            let mut best = 0;
            for i in 0..self.ratios.len() {
                current[i] += self.ratios[i].1;
                if current[i] > current[best] {
                    best = i;
                }
            }
            current[best] -= total_w;
            tm.push_assigned(self.ratios[best].0, *c);
        }
    }

    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, _view: &PolicyView) -> Option<Pulled> {
        let c = tm.pop_assigned(gpu)?;
        if c.dest == gpu {
            Some(Pulled::Direct(c))
        } else {
            Some(Pulled::Relay(c))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::sim::Time;
    use crate::topology::{h20x8, Direction};

    #[test]
    fn wrr_assignment_matches_ratios_and_drains_per_path() {
        let topo = h20x8();
        let view = PolicyView {
            topo: &topo,
            dir: Direction::H2D,
            queues: &[],
            now: Time::ZERO,
            class_pull: Default::default(),
            class_pending: [0; crate::mma::NUM_CLASSES],
        };
        let mut p = StaticSplit::new(vec![(GpuId(0), 1.0), (GpuId(1), 2.0)]);
        let mut tm = TaskManager::new(8);
        // 30 MB → 6 chunks; 1:2 split → 2 on gpu0 (direct), 4 on gpu1.
        let chunks = TaskManager::split(
            TransferId(0),
            GpuId(0),
            30_000_000,
            5_000_000,
            crate::mma::TransferClass::Interactive,
        );
        p.admit(&chunks, &mut tm, &view);
        let mut direct = 0;
        let mut relay = 0;
        while let Some(got) = p.pull(&mut tm, GpuId(0), &view) {
            assert!(!got.is_relay());
            direct += 1;
        }
        while let Some(got) = p.pull(&mut tm, GpuId(1), &view) {
            assert!(got.is_relay());
            relay += 1;
        }
        assert_eq!((direct, relay), (2, 4));
        assert!(tm.is_empty());
        // No stealing: an unconfigured path never receives work.
        p.admit(&chunks, &mut tm, &view);
        assert!(p.pull(&mut tm, GpuId(2), &view).is_none());
    }
}
