//! Native single-path baseline as a [`TransferPolicy`].
//!
//! The real baseline is *no interception at all*: the interceptor (which
//! consults [`super::PolicySpec::engine_eligible`]) routes every copy to a
//! single whole-transfer DMA on the direct PCIe path, so engine machinery
//! never runs. This impl covers the remaining case — a transfer that does
//! enter the engine under the native policy — by pulling only
//! own-destination micro-tasks: chunked, but strictly single-path.

use super::{PolicyView, Pulled, TransferPolicy};
use crate::mma::task_manager::TaskManager;
use crate::topology::GpuId;

/// Direct-path-only pulls; never relays.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeDirect;

impl TransferPolicy for NativeDirect {
    fn name(&self) -> &'static str {
        "native"
    }

    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, view: &PolicyView) -> Option<Pulled> {
        tm.pop_direct(gpu, view.class_pull).map(Pulled::Direct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::sim::Time;
    use crate::topology::{h20x8, Direction};

    #[test]
    fn pulls_only_own_destination() {
        let topo = h20x8();
        let view = PolicyView {
            topo: &topo,
            dir: Direction::H2D,
            queues: &[],
            now: Time::ZERO,
            class_pull: Default::default(),
            class_pending: [0; crate::mma::NUM_CLASSES],
        };
        let mut p = NativeDirect;
        let mut tm = TaskManager::new(8);
        tm.push_pending(&TaskManager::split(
            TransferId(1),
            GpuId(0),
            50_000_000,
            5_000_000,
            crate::mma::TransferClass::Interactive,
        ));
        // A would-be relay path gets nothing...
        assert!(p.pull(&mut tm, GpuId(1), &view).is_none());
        // ...while the destination drains its own queue.
        let got = p.pull(&mut tm, GpuId(0), &view).unwrap();
        assert!(!got.is_relay());
        assert_eq!(got.chunk().dest, GpuId(0));
    }
}
