//! NUMA-aware policy: greedy selection with *soft* cross-socket penalties.
//!
//! The paper's `numa_local_only` knob is a hard gate — cross-socket relays
//! are either allowed or forbidden. This policy prices the hop instead:
//! when a relay chooses whom to help, a destination on the other socket
//! has its backlog discounted by `remote_penalty` (so local work wins
//! ties by a wide margin) and is skipped entirely while its backlog sits
//! below `min_remote_bytes` — small transfers stay NUMA-local for
//! predictable latency (§6), while bulk transfers still recruit the whole
//! server. Inexpressible in the old architecture, whose eligibility
//! filter was a boolean with no notion of backlog size.

use super::{PolicyView, Pulled, TransferPolicy};
use crate::mma::task_manager::TaskManager;
use crate::mma::MmaConfig;
use crate::topology::GpuId;

/// Greedy pulls with discounted cross-socket stealing.
#[derive(Debug, Clone)]
pub struct NumaAware {
    /// Prefer own-destination micro-tasks first.
    pub direct_priority: bool,
    /// Relay candidates; `None` = every peer GPU.
    pub relay_gpus: Option<Vec<GpuId>>,
    /// Hard NUMA gate inherited from the shared config: when set,
    /// cross-socket steals are forbidden outright (the soft penalty only
    /// prices hops this knob still allows).
    pub numa_local_only: bool,
    /// Multiplier applied to a cross-socket destination's backlog when
    /// ranking steal candidates (0 = never, 1 = no penalty).
    pub remote_penalty: f64,
    /// Minimum cross-socket backlog worth a relay hop at all.
    pub min_remote_bytes: u64,
}

impl NumaAware {
    /// Build from the engine's shared knobs plus the penalty parameters.
    pub fn new(cfg: &MmaConfig, remote_penalty: f64, min_remote_bytes: u64) -> NumaAware {
        assert!(
            (0.0..=1.0).contains(&remote_penalty),
            "remote_penalty must be in [0, 1]"
        );
        NumaAware {
            direct_priority: cfg.direct_priority,
            relay_gpus: cfg.relay_gpus.clone(),
            numa_local_only: cfg.numa_local_only,
            remote_penalty,
            min_remote_bytes,
        }
    }
}

impl TransferPolicy for NumaAware {
    fn name(&self) -> &'static str {
        "numa-aware"
    }

    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, view: &PolicyView) -> Option<Pulled> {
        let topo = view.topo;
        let my_numa = topo.numa_of(gpu);
        let penalty = self.remote_penalty;
        let min_remote = self.min_remote_bytes;
        let numa_local_only = self.numa_local_only;
        let relay_ok = super::in_relay_set(&self.relay_gpus, gpu);
        let cp = view.class_pull;
        super::greedy_pull(tm, gpu, self.direct_priority, relay_ok, cp, |dest, remaining| {
            if topo.numa_of(dest) == my_numa {
                Some(remaining as f64)
            } else if !numa_local_only && penalty > 0.0 && remaining >= min_remote {
                Some(remaining as f64 * penalty)
            } else {
                None
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::sim::Time;
    use crate::topology::{h20x8, Direction, Topology};

    fn view(topo: &Topology) -> PolicyView<'_> {
        PolicyView {
            topo,
            dir: Direction::H2D,
            queues: &[],
            now: Time::ZERO,
            class_pull: Default::default(),
            class_pending: [0; crate::mma::NUM_CLASSES],
        }
    }

    fn split(t: u32, dest: GpuId, bytes: u64) -> Vec<crate::mma::task_manager::Chunk> {
        TaskManager::split(
            TransferId(t),
            dest,
            bytes,
            5_000_000,
            crate::mma::TransferClass::Interactive,
        )
    }

    fn policy() -> NumaAware {
        NumaAware::new(&MmaConfig::default(), 0.25, 32_000_000)
    }

    #[test]
    fn small_remote_backlogs_are_refused() {
        let topo = h20x8();
        let mut p = policy();
        let mut tm = TaskManager::new(8);
        // 10 MB destined to gpu0 (numa0): below the 32 MB remote bar.
        tm.push_pending(&split(1, GpuId(0), 10_000_000));
        // gpu5 (numa1) refuses the cross-socket hop...
        assert!(p.pull(&mut tm, GpuId(5), &view(&topo)).is_none());
        // ...but gpu1 (numa0) relays it.
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).unwrap().is_relay());
    }

    #[test]
    fn bulk_remote_backlogs_recruit_the_other_socket() {
        let topo = h20x8();
        let mut p = policy();
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 200_000_000));
        let got = p.pull(&mut tm, GpuId(5), &view(&topo)).unwrap();
        assert!(got.is_relay());
        assert_eq!(got.chunk().dest, GpuId(0));
    }

    #[test]
    fn local_backlog_wins_despite_larger_remote_one() {
        let topo = h20x8();
        let mut p = policy();
        let mut tm = TaskManager::new(8);
        // gpu6 (numa1): 100 MB local backlog on gpu4 vs 300 MB remote on
        // gpu0. Discounted remote score 75 MB < 100 MB local → helps local.
        tm.push_pending(&split(1, GpuId(0), 300_000_000));
        tm.push_pending(&split(2, GpuId(4), 100_000_000));
        let got = p.pull(&mut tm, GpuId(6), &view(&topo)).unwrap();
        assert_eq!(got.chunk().dest, GpuId(4));
        // At 4x the local backlog, the remote destination wins even after
        // the 0.25x discount.
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 500_000_000));
        tm.push_pending(&split(2, GpuId(4), 100_000_000));
        let got = p.pull(&mut tm, GpuId(6), &view(&topo)).unwrap();
        assert_eq!(got.chunk().dest, GpuId(0));
    }

    #[test]
    fn numa_local_only_is_a_hard_gate() {
        let topo = h20x8();
        let cfg = MmaConfig {
            numa_local_only: true,
            ..Default::default()
        };
        let mut p = NumaAware::new(&cfg, 0.25, 32_000_000);
        let mut tm = TaskManager::new(8);
        // 500 MB remote backlog, far above the soft threshold — still
        // refused because the shared hard gate is set.
        tm.push_pending(&split(1, GpuId(0), 500_000_000));
        assert!(p.pull(&mut tm, GpuId(5), &view(&topo)).is_none());
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).is_some());
    }

    #[test]
    fn zero_penalty_degenerates_to_hard_numa_local() {
        let topo = h20x8();
        let mut p = NumaAware::new(&MmaConfig::default(), 0.0, 0);
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 500_000_000));
        assert!(p.pull(&mut tm, GpuId(5), &view(&topo)).is_none());
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).is_some());
    }
}
