//! Pluggable transfer-policy layer: *which path carries which micro-task*.
//!
//! The Multipath Transfer Engine ([`crate::mma::engine`]) owns the
//! *mechanism* — outstanding queues, DMA lanes, two-stage relay launch,
//! retirement. This module owns the *policy*: given the topology, the
//! per-link outstanding queues, and observed flow completions, decide
//! which micro-task each path pulls next. The paper's pull-based greedy
//! selector (§3.4.2), the native single-path baseline, and the Fig-10
//! static splitter are three implementations of one [`TransferPolicy`]
//! trait, alongside two adaptive strategies the old hardwired dispatch
//! could not express (congestion feedback, NUMA-aware penalties).
//!
//! Policies are *declared* by a [`PolicySpec`] (cloneable, parseable from
//! `--policy` / the `[policy]` config section) and *instantiated* per
//! engine instance via [`PolicySpec::build`], so the H2D and D2H engines
//! each carry their own policy state.
//!
//! To add a new policy:
//!
//! 1. implement [`TransferPolicy`] in a new submodule (decide placement in
//!    `pull`, optionally pre-assign in `admit` and learn in
//!    `on_completion`);
//! 2. add a [`PolicySpec`] variant, its [`PolicySpec::parse`] spelling and
//!    [`PolicySpec::build`] arm;
//! 3. it is now selectable end-to-end: CLI (`--policy`), TOML
//!    (`[policy] name = "..."`), the serving engine, and
//!    `figures::policy_sweep` (add it to the sweep's policy list).

pub mod congestion;
pub mod mma_greedy;
pub mod native;
pub mod numa_aware;
pub mod static_split;

pub use congestion::CongestionFeedback;
pub use mma_greedy::MmaGreedy;
pub use native::NativeDirect;
pub use numa_aware::NumaAware;
pub use static_split::StaticSplit;

use crate::mma::task_manager::{Chunk, PullClassPolicy, TaskManager};
use crate::mma::transfer_task::{TransferClass, NUM_CLASSES};
use crate::mma::MmaConfig;
use crate::sim::Time;
use crate::topology::{Direction, GpuId, LinkKind, Topology};

/// Default EWMA smoothing factor of [`CongestionFeedback`].
pub const DEFAULT_EWMA_ALPHA: f64 = 0.25;
/// Default minimum delivered-bandwidth share (vs the best path) below
/// which [`CongestionFeedback`] stops handing a path relay work.
pub const DEFAULT_MIN_SHARE: f64 = 0.35;
/// Default discount [`NumaAware`] applies to cross-socket relay backlog.
pub const DEFAULT_REMOTE_PENALTY: f64 = 0.25;
/// Default backlog below which [`NumaAware`] refuses cross-socket relays.
pub const DEFAULT_MIN_REMOTE_BYTES: u64 = 32_000_000;

/// Declarative description of a transfer policy. Lives in
/// [`MmaConfig`]; built into a live [`TransferPolicy`] per engine.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    /// The paper's pull-based greedy selector (§3.4.2): direct-path
    /// priority + longest-remaining-destination relay stealing.
    MmaGreedy,
    /// Native CUDA semantics: single direct path. The interceptor routes
    /// every copy around the engine (no chunking); if a transfer does run
    /// through the engine under this policy, only direct micro-tasks are
    /// pulled.
    Native,
    /// Fixed byte ratios per path (Fig 10). Entries are
    /// `(path_gpu, weight)`; the destination's own entry is the direct
    /// path, others are relays.
    Static(Vec<(GpuId, f64)>),
    /// Greedy selection re-weighted by observed per-path delivered
    /// bandwidth: a path whose completion-rate EWMA falls below
    /// `min_share` of the best path stops pulling relay work until its
    /// EWMA recovers.
    CongestionFeedback {
        /// EWMA smoothing factor in `(0, 1]` (higher = more reactive).
        ewma_alpha: f64,
        /// Relay-eligibility threshold as a fraction of the best path's
        /// EWMA bandwidth.
        min_share: f64,
    },
    /// Greedy selection that penalizes cross-socket relay hops: remote
    /// destinations' backlogs are discounted by `remote_penalty` when
    /// choosing whom to help, and ignored entirely below
    /// `min_remote_bytes` (small transfers stay NUMA-local, §6).
    NumaAware {
        /// Multiplier applied to a cross-socket destination's backlog.
        remote_penalty: f64,
        /// Minimum cross-socket backlog worth a relay hop.
        min_remote_bytes: u64,
    },
}

impl PolicySpec {
    /// Canonical name (the spelling `parse` accepts and tables print).
    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::MmaGreedy => "mma-greedy",
            PolicySpec::Native => "native",
            PolicySpec::Static(_) => "static-split",
            PolicySpec::CongestionFeedback { .. } => "congestion-feedback",
            PolicySpec::NumaAware { .. } => "numa-aware",
        }
    }

    /// Congestion-feedback spec with default parameters.
    pub fn congestion_feedback() -> PolicySpec {
        PolicySpec::CongestionFeedback {
            ewma_alpha: DEFAULT_EWMA_ALPHA,
            min_share: DEFAULT_MIN_SHARE,
        }
    }

    /// NUMA-aware spec with default parameters.
    pub fn numa_aware() -> PolicySpec {
        PolicySpec::NumaAware {
            remote_penalty: DEFAULT_REMOTE_PENALTY,
            min_remote_bytes: DEFAULT_MIN_REMOTE_BYTES,
        }
    }

    /// Parse a policy name as used by `--policy` and `[policy] name`.
    ///
    /// Accepted: `mma-greedy` (aliases `mma`, `greedy`), `native`,
    /// `congestion-feedback` (alias `congestion`), `numa-aware` (alias
    /// `numa`), `static-split` (alias `static`; defaults to a 1:1 split
    /// over gpu0's direct path + gpu1 as in Fig 10), and the explicit
    /// form `static:<gpu>:<weight>,<gpu>:<weight>,...`.
    pub fn parse(s: &str) -> Option<PolicySpec> {
        let s = s.trim();
        match s {
            "mma" | "greedy" | "mma-greedy" => return Some(PolicySpec::MmaGreedy),
            "native" => return Some(PolicySpec::Native),
            "congestion" | "congestion-feedback" => {
                return Some(PolicySpec::congestion_feedback())
            }
            "numa" | "numa-aware" => return Some(PolicySpec::numa_aware()),
            "static" | "static-split" => {
                return Some(PolicySpec::Static(vec![
                    (GpuId(0), 1.0),
                    (GpuId(1), 1.0),
                ]))
            }
            _ => {}
        }
        // static:<gpu>:<weight>,<gpu>:<weight>,...
        let rest = s.strip_prefix("static:")?;
        let mut ratios = Vec::new();
        for pair in rest.split(',') {
            let (g, w) = pair.split_once(':')?;
            let g: u8 = g.trim().parse().ok()?;
            let w: f64 = w.trim().parse().ok()?;
            if !(w.is_finite() && w > 0.0) {
                return None;
            }
            ratios.push((GpuId(g), w));
        }
        if ratios.is_empty() {
            return None;
        }
        Some(PolicySpec::Static(ratios))
    }

    /// Does this policy want large copies routed through the engine?
    /// `false` only for [`PolicySpec::Native`], whose whole point is the
    /// un-intercepted single-flow DMA.
    pub fn engine_eligible(&self) -> bool {
        !matches!(self, PolicySpec::Native)
    }

    /// Validate the spec against a concrete server size. Config loading
    /// calls this so a bad `[policy]` section fails at `config-check`
    /// time rather than panicking when the engine is built.
    pub fn validate(&self, gpu_count: usize) -> Result<(), String> {
        match self {
            PolicySpec::Static(ratios) => {
                if ratios.is_empty() {
                    return Err("static split needs at least one path".to_string());
                }
                for (g, w) in ratios {
                    if g.0 as usize >= gpu_count {
                        return Err(format!(
                            "static split path gpu{} out of range (server has {gpu_count} GPUs)",
                            g.0
                        ));
                    }
                    if !(w.is_finite() && *w > 0.0) {
                        return Err(format!("static split weight {w} must be positive"));
                    }
                }
            }
            PolicySpec::CongestionFeedback {
                ewma_alpha,
                min_share,
            } => {
                if !(*ewma_alpha > 0.0 && *ewma_alpha <= 1.0) {
                    return Err(format!("ewma_alpha {ewma_alpha} must be in (0, 1]"));
                }
                if !(0.0..=1.0).contains(min_share) {
                    return Err(format!("min_share {min_share} must be in [0, 1]"));
                }
            }
            PolicySpec::NumaAware { remote_penalty, .. } => {
                if !(0.0..=1.0).contains(remote_penalty) {
                    return Err(format!("remote_penalty {remote_penalty} must be in [0, 1]"));
                }
            }
            PolicySpec::MmaGreedy | PolicySpec::Native => {}
        }
        Ok(())
    }

    /// Instantiate the live policy for one engine instance. Shared knobs
    /// (`relay_gpus`, `direct_priority`, `numa_local_only`) come from the
    /// surrounding [`MmaConfig`].
    pub fn build(&self, cfg: &MmaConfig) -> Box<dyn TransferPolicy> {
        match self {
            PolicySpec::MmaGreedy => Box::new(MmaGreedy::from_cfg(cfg)),
            PolicySpec::Native => Box::new(NativeDirect),
            PolicySpec::Static(ratios) => Box::new(StaticSplit::new(ratios.clone())),
            PolicySpec::CongestionFeedback {
                ewma_alpha,
                min_share,
            } => Box::new(CongestionFeedback::new(cfg, *ewma_alpha, *min_share)),
            PolicySpec::NumaAware {
                remote_penalty,
                min_remote_bytes,
            } => Box::new(NumaAware::new(cfg, *remote_penalty, *min_remote_bytes)),
        }
    }
}

/// Read-only view the engine exposes to a policy at decision points.
pub struct PolicyView<'a> {
    /// Server topology (link capacities, NUMA placement, relay ordering).
    pub topo: &'a Topology,
    /// Direction this engine instance serves.
    pub dir: Direction,
    /// Per-PCIe-link outstanding queues (occupancy + contention marks).
    pub queues: &'a [OutstandingQueue],
    /// Current virtual time.
    pub now: Time,
    /// How this pull round may treat QoS classes (class-priority pops,
    /// bulk depth throttle, bulk-steal guard). All-false when QoS is off —
    /// the legacy FIFO behavior.
    pub class_pull: PullClassPolicy,
    /// Pending pull-mode chunks per [`TransferClass`] id — the class mix a
    /// policy can inspect (e.g. to spare PCIe for critical traffic).
    pub class_pending: [u64; NUM_CLASSES],
}

/// A transfer policy: decides chunk→path placement for one engine
/// instance. The engine calls `admit` once per activated transfer,
/// `pull` whenever a path's outstanding queue has capacity, and
/// `on_completion` as micro-tasks retire (the feedback channel).
pub trait TransferPolicy {
    /// Canonical policy name (matches [`PolicySpec::name`]).
    fn name(&self) -> &'static str;

    /// A transfer's micro-tasks entered the engine. The default places
    /// them in the shared destination-tagged queue for pull-based
    /// policies; pre-assigning policies (static split) override this.
    fn admit(&mut self, chunks: &[Chunk], tm: &mut TaskManager, view: &PolicyView) {
        let _ = view;
        tm.push_pending(chunks);
    }

    /// Decide the next micro-task for `gpu`'s outstanding queue, or
    /// `None` to leave the path idle this round.
    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, view: &PolicyView) -> Option<Pulled>;

    /// A micro-task dispatched on `path_gpu`'s queue retired.
    /// `observed_s` is dispatch→retire wall time, `expected_s` the
    /// uncontended expectation — together the congestion signal.
    fn on_completion(
        &mut self,
        path_gpu: GpuId,
        bytes: u64,
        relay: bool,
        observed_s: f64,
        expected_s: f64,
    ) {
        let _ = (path_gpu, bytes, relay, observed_s, expected_s);
    }

    /// Serving-layer fetch-path decision surface: a prefix needed on `dst`
    /// is resident both in the fleet's shared host tier and in sibling
    /// `src`'s HBM. Returning `true` routes the fetch peer-to-peer over
    /// the NVLink fabric; `false` keeps it on the host→GPU path this
    /// policy would otherwise place (multipath or native). The default
    /// compares the NVLink pair bandwidth against the destination's PCIe
    /// lane — except for bulk-band classes, which prefer NVLink whenever a
    /// peer path exists at all, sparing PCIe for latency-critical fetches.
    /// Policies with a better model of their own host-path throughput can
    /// override.
    fn prefer_peer_fetch(
        &self,
        topo: &Topology,
        src: GpuId,
        dst: GpuId,
        bytes: u64,
        class: TransferClass,
    ) -> bool {
        let _ = bytes;
        let nv = topo
            .capacity(topo.link(LinkKind::NvOut(src)))
            .min(topo.capacity(topo.link(LinkKind::NvIn(dst))));
        if class.is_bulk_band() {
            return nv > 0.0;
        }
        nv > topo.pcie_capacity(dst, Direction::H2D)
    }
}

/// Is `gpu` in an optional relay set? `None` = every peer GPU relays.
pub fn in_relay_set(set: &Option<Vec<GpuId>>, gpu: GpuId) -> bool {
    match set {
        None => true,
        Some(set) => set.contains(&gpu),
    }
}

/// The shared greedy pull skeleton (§3.4.2 ordering) that the
/// greedy-family policies parameterize instead of duplicating:
///
/// 1. own-destination work first when `direct_priority`;
/// 2. a relay steal ranked by `score` (see
///    [`TaskManager::pop_steal_scored`]) when `relay_ok`;
/// 3. own-destination work *after* stealing otherwise (the Table 2
///    ablation ordering).
///
/// `cp` (usually `view.class_pull`) carries the round's QoS class policy:
/// class-priority pops, the bulk depth throttle, and the bulk-steal guard.
pub fn greedy_pull(
    tm: &mut TaskManager,
    gpu: GpuId,
    direct_priority: bool,
    relay_ok: bool,
    cp: PullClassPolicy,
    score: impl FnMut(GpuId, u64) -> Option<f64>,
) -> Option<Pulled> {
    if direct_priority {
        if let Some(c) = tm.pop_direct(gpu, cp) {
            return Some(Pulled::Direct(c));
        }
    }
    if relay_ok {
        if let Some(c) = tm.pop_steal_scored(gpu, cp, score) {
            return Some(Pulled::Relay(c));
        }
    }
    if !direct_priority {
        if let Some(c) = tm.pop_direct(gpu, cp) {
            return Some(Pulled::Direct(c));
        }
    }
    None
}

/// Per-GPU pull decision outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pulled {
    /// A direct micro-task (dest == this GPU).
    Direct(Chunk),
    /// A relay micro-task (this GPU forwards to `chunk.dest`).
    Relay(Chunk),
}

impl Pulled {
    /// The underlying chunk.
    pub fn chunk(&self) -> Chunk {
        match self {
            Pulled::Direct(c) | Pulled::Relay(c) => *c,
        }
    }
    /// Is this a relay pull?
    pub fn is_relay(&self) -> bool {
        matches!(self, Pulled::Relay(_))
    }
}

/// State of one outstanding queue (one per GPU per direction, §3.4.2).
/// Owned by the engine; policies observe it through [`PolicyView`].
#[derive(Debug, Clone)]
pub struct OutstandingQueue {
    /// The GPU whose PCIe link this queue is bound to.
    pub gpu: GpuId,
    /// In-flight micro-task keys.
    pub slots: Vec<u64>,
    /// Depth limit.
    pub depth: usize,
    /// In-flight critical-band (`LatencyCritical`/`Interactive`) chunks.
    pub critical_inflight: u32,
    /// In-flight bulk-band (`Bulk`/`Background`) chunks.
    pub bulk_inflight: u32,
    /// Contention detected on this path (backoff mode, §3.4.2).
    pub contended: bool,
    /// CPU "transfer thread" is busy dispatching until this time.
    pub busy_until: Time,
}

impl OutstandingQueue {
    /// New queue with the configured depth.
    pub fn new(gpu: GpuId, depth: usize) -> OutstandingQueue {
        OutstandingQueue {
            gpu,
            slots: Vec::with_capacity(depth),
            depth,
            critical_inflight: 0,
            bulk_inflight: 0,
            contended: false,
            busy_until: Time::ZERO,
        }
    }

    /// Effective capacity: a contended queue backs off to depth 1, yielding
    /// bandwidth to latency-sensitive co-running traffic.
    pub fn effective_depth(&self, backoff_enabled: bool) -> usize {
        if backoff_enabled && self.contended {
            1
        } else {
            self.depth
        }
    }

    /// Can this queue pull more work?
    pub fn has_capacity(&self, backoff_enabled: bool) -> bool {
        self.slots.len() < self.effective_depth(backoff_enabled)
    }

    /// Occupy a slot with a chunk key of the given class.
    pub fn occupy(&mut self, key: u64, class: TransferClass) {
        debug_assert!(self.slots.len() < self.depth);
        self.slots.push(key);
        if class.is_bulk_band() {
            self.bulk_inflight += 1;
        } else {
            self.critical_inflight += 1;
        }
    }

    /// Retire a chunk key; returns true if it was present.
    pub fn retire(&mut self, key: u64, class: TransferClass) -> bool {
        if let Some(p) = self.slots.iter().position(|&k| k == key) {
            self.slots.swap_remove(p);
            if class.is_bulk_band() {
                self.bulk_inflight -= 1;
            } else {
                self.critical_inflight -= 1;
            }
            true
        } else {
            false
        }
    }
}

// ----- baseline configuration constructors ------------------------------
//
// These used to live in a separate `baseline` module with its own
// dispatch path; they are now thin constructors over the policy layer so
// every baseline runs through the identical engine code.

/// Native single-path configuration (plain `cudaMemcpyAsync` semantics).
pub fn native() -> MmaConfig {
    MmaConfig::native()
}

/// Static split across the direct path and `relays`, with the given
/// weights. `weights[0]` belongs to the direct path; `weights[1..]` map to
/// `relays` in order. Panics on length mismatch.
pub fn static_split(target: GpuId, relays: &[GpuId], weights: &[f64]) -> MmaConfig {
    assert_eq!(
        weights.len(),
        relays.len() + 1,
        "need one weight for the direct path plus one per relay"
    );
    let mut ratios = vec![(target, weights[0])];
    for (r, w) in relays.iter().zip(&weights[1..]) {
        assert_ne!(*r, target, "relay cannot be the target");
        ratios.push((*r, *w));
    }
    MmaConfig {
        policy: PolicySpec::Static(ratios),
        // Static splitting has no adaptive machinery.
        contention_backoff: false,
        direct_priority: false,
        ..Default::default()
    }
}

/// Convenience: equal 1:1 split over direct + one relay (Fig 10's "1:1").
pub fn split_1_1(target: GpuId, relay: GpuId) -> MmaConfig {
    static_split(target, &[relay], &[1.0, 1.0])
}

/// 1:2 split (Fig 10's tuned-for-congestion setting: one third on the
/// congested direct path, two thirds on the relay).
pub fn split_1_2(target: GpuId, relay: GpuId) -> MmaConfig {
    static_split(target, &[relay], &[1.0, 2.0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrips_names() {
        for name in [
            "mma-greedy",
            "native",
            "static-split",
            "congestion-feedback",
            "numa-aware",
        ] {
            let spec = PolicySpec::parse(name).unwrap();
            assert_eq!(spec.name(), name);
        }
        assert_eq!(PolicySpec::parse("mma"), Some(PolicySpec::MmaGreedy));
        assert_eq!(PolicySpec::parse("nope"), None);
    }

    #[test]
    fn spec_parse_explicit_static_ratios() {
        let spec = PolicySpec::parse("static:0:1,1:2.5").unwrap();
        assert_eq!(
            spec,
            PolicySpec::Static(vec![(GpuId(0), 1.0), (GpuId(1), 2.5)])
        );
        assert_eq!(PolicySpec::parse("static:"), None);
        assert_eq!(PolicySpec::parse("static:0"), None);
        assert_eq!(PolicySpec::parse("static:0:-1"), None);
    }

    #[test]
    fn only_native_bypasses_the_engine() {
        assert!(!PolicySpec::Native.engine_eligible());
        assert!(PolicySpec::MmaGreedy.engine_eligible());
        assert!(PolicySpec::congestion_feedback().engine_eligible());
    }

    #[test]
    fn validate_catches_bad_parameters() {
        assert!(PolicySpec::MmaGreedy.validate(8).is_ok());
        assert!(PolicySpec::congestion_feedback().validate(8).is_ok());
        // Static split: GPU ids must exist, weights must be positive.
        assert!(PolicySpec::Static(vec![(GpuId(0), 1.0)]).validate(8).is_ok());
        assert!(PolicySpec::Static(vec![(GpuId(8), 1.0)]).validate(8).is_err());
        assert!(PolicySpec::Static(vec![(GpuId(0), 0.0)]).validate(8).is_err());
        assert!(PolicySpec::Static(vec![]).validate(8).is_err());
        // Parameter ranges.
        assert!(PolicySpec::CongestionFeedback {
            ewma_alpha: 3.0,
            min_share: 0.5
        }
        .validate(8)
        .is_err());
        assert!(PolicySpec::CongestionFeedback {
            ewma_alpha: 0.5,
            min_share: 1.5
        }
        .validate(8)
        .is_err());
        assert!(PolicySpec::NumaAware {
            remote_penalty: 2.0,
            min_remote_bytes: 0
        }
        .validate(8)
        .is_err());
    }

    #[test]
    fn greedy_pull_skeleton_ordering() {
        use crate::gpusim::TransferId;
        let cp = PullClassPolicy::default();
        let cls = TransferClass::Interactive;
        let mut tm = TaskManager::new(4);
        tm.push_pending(&TaskManager::split(TransferId(1), GpuId(0), 10_000_000, 5_000_000, cls));
        tm.push_pending(&TaskManager::split(TransferId(2), GpuId(1), 50_000_000, 5_000_000, cls));
        // direct_priority: own work wins.
        let p = greedy_pull(&mut tm, GpuId(0), true, true, cp, |_, r| Some(r as f64)).unwrap();
        assert!(!p.is_relay());
        // without priority: steal first.
        let p = greedy_pull(&mut tm, GpuId(0), false, true, cp, |_, r| Some(r as f64)).unwrap();
        assert!(p.is_relay());
        // relay_ok=false: falls back to own work even without priority.
        let p = greedy_pull(&mut tm, GpuId(0), false, false, cp, |_, r| Some(r as f64)).unwrap();
        assert!(!p.is_relay());
    }

    #[test]
    fn greedy_pull_honors_critical_only_rounds() {
        use crate::gpusim::TransferId;
        let mut tm = TaskManager::new(4);
        tm.push_pending(&TaskManager::split(
            TransferId(1),
            GpuId(0),
            10_000_000,
            5_000_000,
            TransferClass::Bulk,
        ));
        let throttled = PullClassPolicy {
            by_class: true,
            critical_only: true,
            no_bulk_steal: false,
        };
        // A bulk-throttled round leaves bulk-band work queued...
        assert!(greedy_pull(&mut tm, GpuId(0), true, true, throttled, |_, r| {
            Some(r as f64)
        })
        .is_none());
        // ...while critical work still flows.
        tm.push_pending(&TaskManager::split(
            TransferId(2),
            GpuId(0),
            5_000_000,
            5_000_000,
            TransferClass::LatencyCritical,
        ));
        let p = greedy_pull(&mut tm, GpuId(0), true, true, throttled, |_, r| {
            Some(r as f64)
        })
        .unwrap();
        assert_eq!(p.chunk().class, TransferClass::LatencyCritical);
    }

    #[test]
    fn every_policy_prefers_nvlink_peer_fetch_on_h20() {
        // Default decision surface: NVLink (368 GB/s) > PCIe lane (53.6).
        let topo = crate::topology::h20x8();
        let cfg = MmaConfig::default();
        for spec in [
            PolicySpec::MmaGreedy,
            PolicySpec::Native,
            PolicySpec::Static(vec![(GpuId(0), 1.0)]),
            PolicySpec::congestion_feedback(),
            PolicySpec::numa_aware(),
        ] {
            let p = spec.build(&cfg);
            for class in TransferClass::ALL {
                assert!(
                    p.prefer_peer_fetch(&topo, GpuId(0), GpuId(1), 1 << 30, class),
                    "{} must prefer the NVLink peer path on h20x8 for {}",
                    p.name(),
                    class.name()
                );
            }
        }
    }

    #[test]
    fn bulk_band_prefers_any_peer_path_to_spare_pcie() {
        // On a topology where the peer path is *slower* than the PCIe
        // lane, latency-critical fetches keep PCIe, but bulk traffic still
        // routes over NVLink to leave the lane to critical fetches.
        let mut topo = crate::topology::h20x8();
        let nv_out = topo.link(LinkKind::NvOut(GpuId(0)));
        let nv_in = topo.link(LinkKind::NvIn(GpuId(1)));
        topo.links[nv_out.0 as usize].capacity_bps = 10e9; // << 53.6 GB/s PCIe
        topo.links[nv_in.0 as usize].capacity_bps = 10e9;
        let p = PolicySpec::MmaGreedy.build(&MmaConfig::default());
        assert!(!p.prefer_peer_fetch(
            &topo,
            GpuId(0),
            GpuId(1),
            1 << 30,
            TransferClass::LatencyCritical
        ));
        assert!(p.prefer_peer_fetch(&topo, GpuId(0), GpuId(1), 1 << 30, TransferClass::Bulk));
    }

    #[test]
    fn build_produces_matching_names() {
        let cfg = MmaConfig::default();
        for spec in [
            PolicySpec::MmaGreedy,
            PolicySpec::Native,
            PolicySpec::Static(vec![(GpuId(0), 1.0)]),
            PolicySpec::congestion_feedback(),
            PolicySpec::numa_aware(),
        ] {
            assert_eq!(spec.build(&cfg).name(), spec.name());
        }
    }

    #[test]
    fn outstanding_queue_capacity_and_backoff() {
        let mut q = OutstandingQueue::new(GpuId(0), 2);
        assert!(q.has_capacity(true));
        q.occupy(1, TransferClass::LatencyCritical);
        q.occupy(2, TransferClass::Bulk);
        assert!(!q.has_capacity(true));
        assert_eq!((q.critical_inflight, q.bulk_inflight), (1, 1));
        assert!(q.retire(1, TransferClass::LatencyCritical));
        assert!(!q.retire(1, TransferClass::LatencyCritical));
        assert_eq!((q.critical_inflight, q.bulk_inflight), (0, 1));
        assert!(q.has_capacity(true));
        // Contended queues back off to depth 1.
        q.contended = true;
        assert_eq!(q.effective_depth(true), 1);
        assert!(!q.has_capacity(true), "1 slot used, backoff depth 1");
        assert!(q.has_capacity(false), "backoff disabled → full depth");
    }

    #[test]
    fn static_split_builds_ratios() {
        let cfg = static_split(GpuId(0), &[GpuId(1), GpuId(2)], &[1.0, 2.0, 3.0]);
        let PolicySpec::Static(r) = &cfg.policy else {
            panic!()
        };
        assert_eq!(r.len(), 3);
        assert_eq!(r[0], (GpuId(0), 1.0));
        assert_eq!(r[2], (GpuId(2), 3.0));
        assert!(!cfg.contention_backoff);
    }

    #[test]
    #[should_panic(expected = "one weight")]
    fn weight_mismatch_panics() {
        static_split(GpuId(0), &[GpuId(1)], &[1.0]);
    }

    #[test]
    #[should_panic(expected = "relay cannot be the target")]
    fn relay_equals_target_panics() {
        static_split(GpuId(0), &[GpuId(0)], &[1.0, 1.0]);
    }
}
