//! Congestion-feedback policy: greedy selection re-weighted by *observed*
//! per-path delivered bandwidth.
//!
//! The paper's selector infers congestion per micro-task (observed vs
//! expected service time) and backs the queue off binarily. This policy
//! instead integrates the completion stream into a per-path EWMA of
//! delivered bandwidth and compares paths against each other: a path whose
//! EWMA falls below `min_share` of the current best path stops volunteering
//! for relay work (its own destination's traffic still flows) until its
//! EWMA recovers. The old architecture could not express this — the
//! hardwired selector had no completion feedback channel and no cross-path
//! state.

use super::{PolicyView, Pulled, TransferPolicy};
use crate::mma::task_manager::TaskManager;
use crate::mma::MmaConfig;
use crate::topology::GpuId;

/// Greedy pulls gated by relative per-path EWMA delivered bandwidth.
#[derive(Debug, Clone)]
pub struct CongestionFeedback {
    /// Prefer own-destination micro-tasks first.
    pub direct_priority: bool,
    /// Relay candidates; `None` = every peer GPU.
    pub relay_gpus: Option<Vec<GpuId>>,
    /// Restrict relays to the target's NUMA node.
    pub numa_local_only: bool,
    /// EWMA smoothing factor in `(0, 1]`.
    pub ewma_alpha: f64,
    /// Relay-eligibility threshold vs the best path's EWMA.
    pub min_share: f64,
    /// Per-path EWMA of delivered bandwidth (B/s); `None` = no samples yet.
    ewma_bps: Vec<Option<f64>>,
}

impl CongestionFeedback {
    /// Build from the engine's shared knobs plus the feedback parameters.
    pub fn new(cfg: &MmaConfig, ewma_alpha: f64, min_share: f64) -> CongestionFeedback {
        assert!(
            ewma_alpha > 0.0 && ewma_alpha <= 1.0,
            "ewma_alpha must be in (0, 1]"
        );
        assert!(
            (0.0..=1.0).contains(&min_share),
            "min_share must be in [0, 1]"
        );
        CongestionFeedback {
            direct_priority: cfg.direct_priority,
            relay_gpus: cfg.relay_gpus.clone(),
            numa_local_only: cfg.numa_local_only,
            ewma_alpha,
            min_share,
            ewma_bps: Vec::new(),
        }
    }

    /// Current EWMA for a path, if it has completions.
    pub fn ewma_bps(&self, gpu: GpuId) -> Option<f64> {
        self.ewma_bps.get(gpu.0 as usize).copied().flatten()
    }

    /// Is `gpu`'s delivered bandwidth healthy enough to take relay work?
    /// Optimistic before the first sample (cold paths must get a chance to
    /// prove themselves).
    pub fn share_ok(&self, gpu: GpuId) -> bool {
        let Some(mine) = self.ewma_bps(gpu) else {
            return true;
        };
        let best = self
            .ewma_bps
            .iter()
            .filter_map(|x| *x)
            .fold(0.0f64, f64::max);
        best <= 0.0 || mine >= self.min_share * best
    }
}

impl TransferPolicy for CongestionFeedback {
    fn name(&self) -> &'static str {
        "congestion-feedback"
    }

    fn pull(&mut self, tm: &mut TaskManager, gpu: GpuId, view: &PolicyView) -> Option<Pulled> {
        let topo = view.topo;
        let numa_local_only = self.numa_local_only;
        // Greedy, with the EWMA gate layered onto relay eligibility.
        let relay_ok = super::in_relay_set(&self.relay_gpus, gpu) && self.share_ok(gpu);
        let cp = view.class_pull;
        super::greedy_pull(tm, gpu, self.direct_priority, relay_ok, cp, |dest, remaining| {
            if !numa_local_only || topo.numa_of(dest) == topo.numa_of(gpu) {
                Some(remaining as f64)
            } else {
                None
            }
        })
    }

    fn on_completion(
        &mut self,
        path_gpu: GpuId,
        bytes: u64,
        _relay: bool,
        observed_s: f64,
        _expected_s: f64,
    ) {
        let i = path_gpu.0 as usize;
        if self.ewma_bps.len() <= i {
            self.ewma_bps.resize(i + 1, None);
        }
        let inst = bytes as f64 / observed_s.max(1e-12);
        self.ewma_bps[i] = Some(match self.ewma_bps[i] {
            None => inst,
            Some(prev) => self.ewma_alpha * inst + (1.0 - self.ewma_alpha) * prev,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::sim::Time;
    use crate::topology::{h20x8, Direction, Topology};

    fn view(topo: &Topology) -> PolicyView<'_> {
        PolicyView {
            topo,
            dir: Direction::H2D,
            queues: &[],
            now: Time::ZERO,
            class_pull: Default::default(),
            class_pending: [0; crate::mma::NUM_CLASSES],
        }
    }

    fn split(t: u32, dest: GpuId, bytes: u64) -> Vec<crate::mma::task_manager::Chunk> {
        TaskManager::split(
            TransferId(t),
            dest,
            bytes,
            5_000_000,
            crate::mma::TransferClass::Interactive,
        )
    }

    fn policy() -> CongestionFeedback {
        CongestionFeedback::new(&MmaConfig::default(), 0.5, 0.4)
    }

    #[test]
    fn ewma_tracks_completions() {
        let mut p = policy();
        assert_eq!(p.ewma_bps(GpuId(2)), None);
        // 5 MB in 100 us → 50 GB/s.
        p.on_completion(GpuId(2), 5_000_000, true, 100e-6, 80e-6);
        let first = p.ewma_bps(GpuId(2)).unwrap();
        assert!((first - 50e9).abs() < 1e6, "{first}");
        // A slow completion (5 GB/s) pulls the EWMA halfway down (α=0.5).
        p.on_completion(GpuId(2), 5_000_000, true, 1e-3, 80e-6);
        let second = p.ewma_bps(GpuId(2)).unwrap();
        assert!((second - 27.5e9).abs() < 1e6, "{second}");
    }

    #[test]
    fn slow_path_loses_relay_eligibility_and_recovers() {
        let topo = h20x8();
        let mut p = policy();
        // Healthy peer at 50 GB/s; gpu1 crawling at 2 GB/s (< 40% of best).
        p.on_completion(GpuId(2), 5_000_000, true, 100e-6, 80e-6);
        p.on_completion(GpuId(1), 5_000_000, true, 2.5e-3, 80e-6);
        assert!(p.share_ok(GpuId(2)));
        assert!(!p.share_ok(GpuId(1)));

        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 50_000_000));
        // The degraded path declines relay work; the healthy one takes it.
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).is_none());
        assert!(p.pull(&mut tm, GpuId(2), &view(&topo)).unwrap().is_relay());

        // Fast completions restore the EWMA and eligibility (α=0.5 →
        // two 50 GB/s samples lift 2 GB/s back above the 20 GB/s bar).
        p.on_completion(GpuId(1), 5_000_000, true, 100e-6, 80e-6);
        p.on_completion(GpuId(1), 5_000_000, true, 100e-6, 80e-6);
        assert!(p.share_ok(GpuId(1)));
        assert!(p.pull(&mut tm, GpuId(1), &view(&topo)).unwrap().is_relay());
    }

    #[test]
    fn direct_work_flows_even_on_a_degraded_path() {
        let topo = h20x8();
        let mut p = policy();
        p.on_completion(GpuId(2), 5_000_000, true, 100e-6, 80e-6);
        p.on_completion(GpuId(0), 5_000_000, false, 2.5e-3, 80e-6);
        assert!(!p.share_ok(GpuId(0)));
        let mut tm = TaskManager::new(8);
        tm.push_pending(&split(1, GpuId(0), 10_000_000));
        // gpu0's own destination traffic is never gated.
        assert!(!p.pull(&mut tm, GpuId(0), &view(&topo)).unwrap().is_relay());
    }

    #[test]
    fn cold_paths_are_optimistic() {
        let p = policy();
        assert!(p.share_ok(GpuId(7)), "no samples yet → eligible");
    }
}
