//! Streaming trace ingestion: parse JSONL records line-by-line off any
//! [`BufRead`] and merge near-sorted arrivals through a bounded
//! lookahead window, so replaying a trace holds O(window) records in
//! memory instead of materializing — and sorting — the whole file the
//! way [`Trace::load`] + [`Trace::requests`] do.
//!
//! # The bounded-lookahead merge
//!
//! Generated traces are near-sorted by construction (diurnal/MMPP
//! generators emit in arrival order; multi-tenant interleaving displaces
//! records by at most a burst). [`ArrivalMerger`] exploits that: it
//! holds a min-heap of at most `window + 1` records keyed by
//! `(arrival, file_index)` — **exactly** the `(r.arrival, r.id.0)` key
//! the fleet's materialized path sorts by, with arrivals compared as
//! quantized [`Time`] values, not raw `f64` seconds — and emits the
//! minimum whenever the heap exceeds the window. If no record is
//! displaced by more than `window` positions, the emitted sequence is
//! globally sorted and replay is byte-identical to the materialized
//! path.
//!
//! # The spill path
//!
//! A bounded merger cannot repair disorder it has already emitted past,
//! so disorder is detected *up front*: [`scan`] makes a cheap first pass
//! (file-order, O(window) + O(distinct prefixes) memory) that simulates
//! the merge on sort keys alone and reports whether the window suffices.
//! When it does not — or when a consumer needs random access, like
//! `--follow-switches`' model-boundary scan — callers fall back to the
//! documented spill path: materialize via [`Trace::load`] and take the
//! O(trace) memory cost. Same bytes out either way; only peak memory
//! differs.

use std::collections::BinaryHeap;
use std::io::BufRead;

use super::trace::{
    header_version, parse_object, record_from_fields, Trace, TraceRecord, TRACE_VERSION,
};
use crate::sim::Time;
use crate::util::fxmap::FxHashMap;

/// Streaming JSONL trace parser over any [`BufRead`]. Yields records in
/// file order, reusing one line buffer; errors carry the same 1-based
/// line numbers and messages as [`Trace::parse`].
pub struct TraceReader<R: BufRead> {
    inner: R,
    line: String,
    lineno: usize,
    saw_header: bool,
    done: bool,
}

impl<R: BufRead> TraceReader<R> {
    /// Stream records from `inner` (header validated on first line).
    pub fn new(inner: R) -> TraceReader<R> {
        TraceReader {
            inner,
            line: String::new(),
            lineno: 0,
            saw_header: false,
            done: false,
        }
    }

    /// High-water capacity of the reused line buffer, bytes.
    pub fn line_buffer_bytes(&self) -> u64 {
        self.line.capacity() as u64
    }

    fn fail(&mut self, e: String) -> Option<Result<TraceRecord, String>> {
        self.done = true;
        Some(Err(format!("line {}: {e}", self.lineno)))
    }
}

impl<R: BufRead> Iterator for TraceReader<R> {
    type Item = Result<TraceRecord, String>;

    fn next(&mut self) -> Option<Result<TraceRecord, String>> {
        if self.done {
            return None;
        }
        loop {
            self.line.clear();
            match self.inner.read_line(&mut self.line) {
                Ok(0) => {
                    self.done = true;
                    if !self.saw_header {
                        return Some(Err(format!(
                            "missing trace header (expected {{\"mma_trace\": {TRACE_VERSION}}})"
                        )));
                    }
                    return None;
                }
                Ok(_) => {}
                Err(e) => {
                    self.done = true;
                    return Some(Err(format!("read: {e}")));
                }
            }
            self.lineno += 1;
            let line = self.line.trim();
            if line.is_empty() {
                continue;
            }
            let fields = match parse_object(line) {
                Ok(f) => f,
                Err(e) => return self.fail(e),
            };
            if !self.saw_header {
                let version = match header_version(&fields) {
                    Ok(v) => v,
                    Err(e) => return self.fail(e),
                };
                if version != TRACE_VERSION as u64 {
                    return self.fail(format!(
                        "unsupported trace version {version} \
                         (this build reads {TRACE_VERSION})"
                    ));
                }
                self.saw_header = true;
                continue;
            }
            return match record_from_fields(fields) {
                Ok(r) => Some(Ok(r)),
                Err(e) => self.fail(e),
            };
        }
    }
}

/// Open a trace file for streaming (buffered; errors match
/// [`Trace::load`]'s `read {path:?}: ...` form).
pub fn open_trace(
    path: &str,
) -> Result<TraceReader<std::io::BufReader<std::fs::File>>, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("read {path:?}: {e}"))?;
    Ok(TraceReader::new(std::io::BufReader::new(f)))
}

/// A record waiting in the merge window, ordered by the fleet's sort key.
struct Pending {
    key: (Time, u64), // (arrival, file index) — the materialized sort key
    rec: TraceRecord,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Pending) -> bool {
        self.key == other.key
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Pending) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Pending) -> std::cmp::Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the minimum key.
        other.key.cmp(&self.key)
    }
}

/// Bounded-lookahead arrival merge: push records in file order, receive
/// them in `(arrival, file_index)` order as long as no record is
/// displaced by more than `window` positions (guaranteed when a prior
/// [`scan`] reported `sorted_within_window`). Holds at most
/// `window + 1` records; tracks its own peak footprint.
pub struct ArrivalMerger {
    window: usize,
    heap: BinaryHeap<Pending>,
    held_bytes: u64,
    peak_entries: usize,
    peak_bytes: u64,
}

fn record_bytes(r: &TraceRecord) -> u64 {
    (std::mem::size_of::<Pending>() + r.model.capacity()) as u64
}

impl ArrivalMerger {
    /// Merger holding at most `window + 1` records (window 0 = pass-through).
    pub fn new(window: usize) -> ArrivalMerger {
        ArrivalMerger {
            window,
            heap: BinaryHeap::with_capacity(window + 2),
            held_bytes: 0,
            peak_entries: 0,
            peak_bytes: 0,
        }
    }

    /// Offer the next file-order record (`seq` = 0-based file index, the
    /// replay request id). Returns an emitted record once the window is
    /// full.
    pub fn push(&mut self, seq: u64, rec: TraceRecord) -> Option<(u64, TraceRecord)> {
        self.held_bytes += record_bytes(&rec);
        self.heap.push(Pending {
            key: (Time::from_secs_f64(rec.arrival_s), seq),
            rec,
        });
        self.peak_entries = self.peak_entries.max(self.heap.len());
        self.peak_bytes = self.peak_bytes.max(self.held_bytes);
        if self.heap.len() > self.window {
            return self.pop();
        }
        None
    }

    /// Drain one record after input is exhausted (sorted order).
    pub fn pop(&mut self) -> Option<(u64, TraceRecord)> {
        let p = self.heap.pop()?;
        self.held_bytes -= record_bytes(&p.rec);
        Some((p.key.1, p.rec))
    }

    /// Most records ever held at once (≤ `window + 1`).
    pub fn peak_entries(&self) -> usize {
        self.peak_entries
    }

    /// Peak bytes of held records (struct + model-string storage).
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }
}

/// What one cheap file-order pass learns about a trace — everything
/// replay needs *before* streaming requests into the fleet.
#[derive(Debug, Clone, Default)]
pub struct TraceScan {
    /// Records the replay will consume (after any `max_requests` cap).
    pub requests: usize,
    /// Last arrival among consumed records, seconds.
    pub duration_s: f64,
    /// Pre-seeded host-tier prefixes, identical to
    /// [`Trace::warm_prefixes`] on the consumed records.
    pub warm: Vec<(u32, u64, u32)>,
    /// True when a `window`-bounded merge emits the consumed records in
    /// globally sorted order — i.e. the streaming path is exact. False
    /// means the caller must take the materialize-and-sort spill path.
    pub sorted_within_window: bool,
}

/// First pass over a trace: count (capped at `max_requests`), duration,
/// warm prefixes, and whether the reorder `window` suffices. Memory is
/// O(window) for the merge simulation plus O(distinct prefix keys) for
/// the warm-prefix map — never O(trace).
pub fn scan<R: BufRead>(
    reader: TraceReader<R>,
    max_requests: Option<usize>,
    window: usize,
) -> Result<TraceScan, String> {
    let cap = max_requests.unwrap_or(usize::MAX);
    let mut out = TraceScan {
        sorted_within_window: true,
        ..TraceScan::default()
    };
    // (tenant, key) → first appearance by the stable-sort order
    // (arrival, file index), carrying its cached-token claim.
    let mut first: FxHashMap<(u32, u64), (f64, u64, u32)> = FxHashMap::default();
    // The merge simulated on sort keys alone.
    let mut keys: BinaryHeap<std::cmp::Reverse<(Time, u64)>> = BinaryHeap::new();
    let mut last_emitted: Option<(Time, u64)> = None;
    let mut check = |k: (Time, u64), last: &mut Option<(Time, u64)>, ok: &mut bool| {
        if last.is_some_and(|l| k < l) {
            *ok = false;
        }
        *last = Some(k);
    };
    for (seq, rec) in reader.enumerate() {
        if seq >= cap {
            break;
        }
        let rec = rec?;
        out.requests += 1;
        out.duration_s = out.duration_s.max(rec.arrival_s);
        if rec.prefix_key != 0 {
            let at = (rec.arrival_s, seq as u64, rec.cached_prefix_tokens);
            first
                .entry((rec.tenant, rec.prefix_key))
                .and_modify(|cur| {
                    if at.0.total_cmp(&cur.0).then(at.1.cmp(&cur.1)).is_lt() {
                        *cur = at;
                    }
                })
                .or_insert(at);
        }
        keys.push(std::cmp::Reverse((
            Time::from_secs_f64(rec.arrival_s),
            seq as u64,
        )));
        if keys.len() > window {
            let std::cmp::Reverse(k) = keys.pop().unwrap();
            check(k, &mut last_emitted, &mut out.sorted_within_window);
        }
    }
    while let Some(std::cmp::Reverse(k)) = keys.pop() {
        check(k, &mut last_emitted, &mut out.sorted_within_window);
    }
    // Warm prefixes in the materialized order: stable sort by arrival
    // (ties by file position), first appearance wins, cold firsts drop.
    let mut warm: Vec<(f64, u64, u32, u64, u32)> = first
        .into_iter()
        .filter(|(_, (_, _, cached))| *cached > 0)
        .map(|((tenant, key), (t, seq, cached))| (t, seq, tenant, key, cached))
        .collect();
    warm.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    out.warm = warm.into_iter().map(|(_, _, t, k, c)| (t, k, c)).collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn reader(text: &str) -> TraceReader<Cursor<&[u8]>> {
        TraceReader::new(Cursor::new(text.as_bytes()))
    }

    fn rec(t: f64, key: u64, cached: u32) -> TraceRecord {
        TraceRecord {
            arrival_s: t,
            prompt_tokens: 1024,
            output_tokens: 8,
            prefix_key: key,
            cached_prefix_tokens: cached,
            tenant: 0,
            model: String::new(),
            class: None,
        }
    }

    #[test]
    fn streaming_parse_matches_materialized() {
        let t = Trace {
            records: vec![rec(0.5, 7, 0), rec(0.25, 9, 512), rec(1.0, 0, 0)],
        };
        let text = t.render();
        let streamed: Result<Vec<_>, _> = reader(&text).collect();
        assert_eq!(streamed.unwrap(), t.records);
    }

    #[test]
    fn streaming_errors_match_trace_parse() {
        // Same messages, same line numbers, for every failure mode.
        for text in [
            "",                                                  // no header
            "{\"mma_trace\": 2}\n",                              // bad version
            "{\"t\": 0.0, \"prompt\": 8, \"output\": 1}\n",      // record first
            "{\"mma_trace\": 1}\nnot json\n",                    // malformed line
            "{\"mma_trace\": 1}\n{\"t\": 0.0, \"prompt\": 8}\n", // missing field
        ] {
            let want = Trace::parse(text).unwrap_err();
            let got = reader(text)
                .collect::<Result<Vec<_>, _>>()
                .expect_err(text);
            assert_eq!(got, want, "for {text:?}");
        }
    }

    #[test]
    fn merger_sorts_within_window() {
        // Displacements of 1-2 positions; window 2 suffices.
        let arrivals = [0.1, 0.0, 0.3, 0.2, 0.5, 0.4];
        let mut m = ArrivalMerger::new(2);
        let mut out = Vec::new();
        for (seq, &t) in arrivals.iter().enumerate() {
            if let Some((s, r)) = m.push(seq as u64, rec(t, 0, 0)) {
                out.push((s, r.arrival_s));
            }
        }
        while let Some((s, r)) = m.pop() {
            out.push((s, r.arrival_s));
        }
        let sorted: Vec<f64> = out.iter().map(|(_, t)| *t).collect();
        assert_eq!(sorted, vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5]);
        // Sequence numbers come along for request ids.
        assert_eq!(out[0].0, 1);
        assert!(m.peak_entries() <= 3, "window+1 bound: {}", m.peak_entries());
        assert!(m.peak_bytes() > 0);
    }

    #[test]
    fn merger_ties_resolve_by_file_order() {
        // Equal arrivals must emit in file order — the fleet's sort key.
        let mut m = ArrivalMerger::new(4);
        let mut out = Vec::new();
        for seq in 0..4u64 {
            if let Some((s, _)) = m.push(seq, rec(1.0, 0, 0)) {
                out.push(s);
            }
        }
        while let Some((s, _)) = m.pop() {
            out.push(s);
        }
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn scan_reports_counts_warm_and_window() {
        let t = Trace {
            records: vec![
                rec(0.5, 7, 512), // warm (first appearance, cached)
                rec(0.25, 9, 0),  // cold first appearance of 9
                rec(1.0, 9, 256), // later claim of 9: NOT warm
                rec(2.0, 0, 0),
            ],
        };
        let s = scan(reader(&t.render()), None, 2).unwrap();
        assert_eq!(s.requests, 4);
        assert_eq!(s.duration_s, 2.0);
        assert_eq!(s.warm, t.warm_prefixes());
        assert_eq!(s.warm, vec![(0, 7, 512)]);
        assert!(s.sorted_within_window, "displacement 1 fits window 2");
        // Window 0 cannot fix any disorder.
        let s0 = scan(reader(&t.render()), None, 0).unwrap();
        assert!(!s0.sorted_within_window);
        // The cap truncates exactly like `Trace::truncated`.
        let s1 = scan(reader(&t.render()), Some(2), 2).unwrap();
        assert_eq!(s1.requests, 2);
        assert_eq!(s1.duration_s, 0.5);
        assert_eq!(s1.warm, t.truncated(2).warm_prefixes());
    }

    #[test]
    fn scan_detects_window_violation() {
        // One record displaced 3 positions; window 2 is insufficient,
        // window 3 is enough.
        let t = Trace {
            records: vec![rec(1.0, 0, 0), rec(2.0, 0, 0), rec(3.0, 0, 0), rec(0.5, 0, 0)],
        };
        assert!(!scan(reader(&t.render()), None, 2).unwrap().sorted_within_window);
        assert!(scan(reader(&t.render()), None, 3).unwrap().sorted_within_window);
    }

    #[test]
    fn open_trace_error_mentions_path() {
        let e = open_trace("/nonexistent/trace.jsonl").unwrap_err();
        assert!(e.contains("/nonexistent/trace.jsonl"), "{e}");
    }
}
