//! String interning for hot-path workload keys.
//!
//! Replaying a million-request trace compares model / tenant names on
//! every record; hashing and equality-checking `String`s in that loop is
//! pure overhead. A [`SymbolTable`] maps each distinct string to a dense
//! [`Sym`] (u32) once, after which comparisons and map keys are integer
//! ops. Symbols are handed out in first-insertion order, so interning the
//! same stream of names always yields the same ids — determinism is
//! preserved across runs and across optimized/reference simulations.

use crate::util::fxmap::FxHashMap;

/// Interned string handle: dense index into the owning [`SymbolTable`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct Sym(pub u32);

/// Insertion-ordered string interner.
#[derive(Default, Clone, Debug)]
pub struct SymbolTable {
    by_name: FxHashMap<String, Sym>,
    names: Vec<String>,
}

impl SymbolTable {
    /// Empty table.
    pub fn new() -> SymbolTable {
        SymbolTable::default()
    }

    /// Intern `name`, returning its symbol (allocating one on first sight).
    pub fn intern(&mut self, name: &str) -> Sym {
        if let Some(&s) = self.by_name.get(name) {
            return s;
        }
        let s = Sym(self.names.len() as u32);
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), s);
        s
    }

    /// Look up an already-interned name without allocating a symbol.
    pub fn get(&self, name: &str) -> Option<Sym> {
        self.by_name.get(name).copied()
    }

    /// The string behind a symbol. Panics on a foreign symbol.
    pub fn resolve(&self, s: Sym) -> &str {
        &self.names[s.0 as usize]
    }

    /// Number of distinct interned strings.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if nothing has been interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent_and_dense() {
        let mut t = SymbolTable::new();
        let a = t.intern("llama-70b");
        let b = t.intern("qwen-32b");
        assert_eq!(a, Sym(0));
        assert_eq!(b, Sym(1));
        assert_eq!(t.intern("llama-70b"), a);
        assert_eq!(t.len(), 2);
        assert_eq!(t.resolve(a), "llama-70b");
        assert_eq!(t.resolve(b), "qwen-32b");
        assert_eq!(t.get("qwen-32b"), Some(b));
        assert_eq!(t.get("absent"), None);
    }

    #[test]
    fn symbols_follow_first_insertion_order() {
        // Same name stream ⇒ same ids, regardless of how often names repeat.
        let stream = ["b", "a", "b", "c", "a"];
        let mut t1 = SymbolTable::new();
        let mut t2 = SymbolTable::new();
        let s1: Vec<Sym> = stream.iter().map(|n| t1.intern(n)).collect();
        let s2: Vec<Sym> = stream.iter().map(|n| t2.intern(n)).collect();
        assert_eq!(s1, s2);
        assert_eq!(s1, vec![Sym(0), Sym(1), Sym(0), Sym(2), Sym(1)]);
    }
}
