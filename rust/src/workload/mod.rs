//! The workload subsystem: trace-driven and generated request streams.
//!
//! * [`trace`] — the versioned JSONL trace format (`mma replay` input,
//!   `mma trace gen` output): per-request arrival, prompt/output tokens,
//!   prefix key + cached-prefix length, tenant/model id, optional QoS
//!   class.
//! * [`gen`] — trace generators: Poisson / MMPP-bursty / diurnal
//!   arrivals, multi-tenant mixes with Zipf document popularity, and
//!   model-switch schedules.
//! * [`stream`] — line-streaming trace ingestion with a bounded-lookahead
//!   arrival merge, so replay holds O(window) records instead of the
//!   whole trace.
//! * [`intern`] — u32 symbol table for model/tenant names, so replay hot
//!   loops compare integers instead of hashing strings.
//! * this module — the original in-process helpers: multi-turn QA
//!   sessions over long documents (the LongBench v2-style setup of
//!   §5.2.1) and raw Poisson arrival times, used by the Fig 2/12
//!   harnesses.

pub mod gen;
pub mod intern;
pub mod stream;
pub mod trace;

pub use gen::{model_switch_trace, ArrivalProcess, TenantSpec, TraceGen};
pub use intern::{Sym, SymbolTable};
pub use stream::{open_trace, ArrivalMerger, TraceReader, TraceScan};
pub use trace::{Trace, TraceRecord, TRACE_VERSION};

use crate::serving::{Request, RequestId};
use crate::sim::Time;
use crate::util::rng::Rng;

/// A long-document multi-turn QA session: turn 1 misses the prefix cache,
/// later turns hit it (the paper discards turn 1 and averages the rest).
#[derive(Clone, Debug)]
pub struct QaSession {
    /// Prefix-cache key of the document.
    pub key: u64,
    /// Document context length in tokens.
    pub context_tokens: u32,
    /// Tokens appended per turn (the new question).
    pub turn_suffix_tokens: u32,
    /// Number of turns.
    pub turns: u32,
}

impl QaSession {
    /// Generate the per-turn requests with `gap` between turns.
    pub fn requests(&self, first_id: u64, start: Time, gap: Time) -> Vec<Request> {
        (0..self.turns)
            .map(|t| {
                let cached = if t == 0 { 0 } else { self.context_tokens };
                Request {
                    id: RequestId(first_id + t as u64),
                    arrival: start + Time::from_ns(gap.ns() * t as u64),
                    prompt_tokens: self.context_tokens + (t + 1) * self.turn_suffix_tokens,
                    cached_prefix_tokens: cached,
                    prefix_key: self.key,
                    output_tokens: 32,
                    tenant: 0,
                    class: None,
                }
            })
            .collect()
    }
}

/// Build a batch of QA sessions over documents of roughly `context` tokens
/// (±5%, mimicking "documents whose context lengths are around 16K/32K/64K").
pub fn longdoc_sessions(
    rng: &mut Rng,
    n_docs: usize,
    context: u32,
    turns: u32,
) -> Vec<QaSession> {
    (0..n_docs)
        .map(|_| {
            let jitter = rng.range_f64(0.95, 1.05);
            QaSession {
                key: rng.next_u64() | 1, // nonzero
                context_tokens: ((context as f64 * jitter) as u32).max(1),
                turn_suffix_tokens: 64,
                turns,
            }
        })
        .collect()
}

/// Poisson arrival times with mean rate `rps`, `n` arrivals from `start`.
pub fn poisson_arrivals(rng: &mut Rng, start: Time, rps: f64, n: usize) -> Vec<Time> {
    let mut t = start.as_secs_f64();
    (0..n)
        .map(|_| {
            t += rng.exp(1.0 / rps);
            Time::from_secs_f64(t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_turn_misses_later_turns_hit() {
        let s = QaSession {
            key: 7,
            context_tokens: 1000,
            turn_suffix_tokens: 64,
            turns: 3,
        };
        let reqs = s.requests(10, Time::ZERO, Time::from_ms(100));
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].cached_prefix_tokens, 0, "turn 1 cold");
        assert_eq!(reqs[1].cached_prefix_tokens, 1000);
        assert_eq!(reqs[2].cached_prefix_tokens, 1000);
        assert!(reqs[1].prompt_tokens > reqs[0].prompt_tokens);
        assert_eq!(reqs[2].arrival, Time::from_ms(200));
    }

    #[test]
    fn sessions_are_near_target_length() {
        let mut rng = Rng::seed_from_u64(1);
        let ss = longdoc_sessions(&mut rng, 20, 32_000, 4);
        assert_eq!(ss.len(), 20);
        for s in &ss {
            assert!((30_000..=34_000).contains(&s.context_tokens));
            assert_ne!(s.key, 0);
        }
    }

    #[test]
    fn poisson_mean_rate_roughly_holds() {
        let mut rng = Rng::seed_from_u64(2);
        let arr = poisson_arrivals(&mut rng, Time::ZERO, 100.0, 2000);
        assert_eq!(arr.len(), 2000);
        let span = arr.last().unwrap().as_secs_f64();
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() / 100.0 < 0.1, "rate {rate}");
        for w in arr.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
