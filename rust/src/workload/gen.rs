//! Trace generators: arrival processes (Poisson, 2-state MMPP bursts,
//! diurnal rate curves), multi-tenant mixes with Zipf-skewed document
//! popularity, and model-switch schedules — everything `mma trace gen`
//! materializes into the JSONL [`super::Trace`] format.
//!
//! The generators answer the traffic-model critique of the end-to-end
//! claims: Poisson-only arrivals hide queueing tails that burst-modulated
//! processes expose at the *same mean rate*, and uniform single-tenant
//! document pools overstate prefix-hit locality. All randomness flows
//! through one [`Rng`] seed, so `mma trace gen --seed N` is byte-stable.

use super::trace::{Trace, TraceRecord};
use crate::config::WorkloadConfig;
use crate::mma::TransferClass;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// An arrival time process. All variants are parameterized so their
/// *mean* rate is explicit — the burstiness comparisons in
/// `figures::workload_replay` hold it fixed across shapes.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate (the classic baseline).
    Poisson {
        /// Mean rate, requests/second.
        rate_rps: f64,
    },
    /// 2-state Markov-modulated Poisson process: the rate alternates
    /// between a low and a high state with exponentially distributed
    /// dwell times — bursts at the same long-run mean rate as a Poisson
    /// process with `(rate_lo + rate_hi) / 2`.
    Mmpp {
        /// Rate in the quiet state, requests/second.
        rate_lo_rps: f64,
        /// Rate in the burst state, requests/second.
        rate_hi_rps: f64,
        /// Mean dwell time in each state, seconds.
        mean_dwell_s: f64,
    },
    /// Sinusoidal rate curve (diurnal load), sampled by thinning:
    /// `λ(t) = mean · (1 + amplitude · sin(2πt / period))`.
    Diurnal {
        /// Mean rate, requests/second.
        mean_rps: f64,
        /// Relative swing in `[0, 1)`.
        amplitude: f64,
        /// Cycle length, seconds.
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// A bursty MMPP holding the same mean rate as `Poisson { rate_rps }`:
    /// the rate splits into `rate · (1 ± burstiness)` with equal mean
    /// dwell in both states. `burstiness = 0` degenerates to Poisson-like
    /// behavior; values near 1 concentrate almost all arrivals in bursts.
    pub fn bursty(rate_rps: f64, burstiness: f64, mean_dwell_s: f64) -> ArrivalProcess {
        ArrivalProcess::Mmpp {
            rate_lo_rps: rate_rps * (1.0 - burstiness),
            rate_hi_rps: rate_rps * (1.0 + burstiness),
            mean_dwell_s,
        }
    }

    /// Long-run mean rate, requests/second.
    pub fn mean_rate_rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            // Equal mean dwell in both states → the time-average rate is
            // the plain average of the two state rates.
            ArrivalProcess::Mmpp {
                rate_lo_rps,
                rate_hi_rps,
                ..
            } => 0.5 * (rate_lo_rps + rate_hi_rps),
            ArrivalProcess::Diurnal { mean_rps, .. } => mean_rps,
        }
    }

    /// Sample `n` arrival times (seconds from 0, non-decreasing).
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_rps } => {
                assert!(rate_rps > 0.0, "poisson rate must be > 0");
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exp(1.0 / rate_rps);
                    out.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                rate_lo_rps,
                rate_hi_rps,
                mean_dwell_s,
            } => {
                assert!(rate_hi_rps > 0.0, "mmpp burst rate must be > 0");
                assert!(rate_lo_rps >= 0.0, "mmpp quiet rate must be >= 0");
                assert!(mean_dwell_s > 0.0, "mmpp dwell must be > 0");
                let mut t = 0.0;
                let mut hi = false;
                let mut state_end = rng.exp(mean_dwell_s);
                while out.len() < n {
                    let rate = if hi { rate_hi_rps } else { rate_lo_rps };
                    // Exponential gaps are memoryless, so discarding the
                    // partial gap at a state boundary keeps the process
                    // exact (no bias at switches).
                    let next = if rate > 0.0 {
                        t + rng.exp(1.0 / rate)
                    } else {
                        f64::INFINITY
                    };
                    if next < state_end {
                        t = next;
                        out.push(t);
                    } else {
                        t = state_end;
                        state_end = t + rng.exp(mean_dwell_s);
                        hi = !hi;
                    }
                }
            }
            ArrivalProcess::Diurnal {
                mean_rps,
                amplitude,
                period_s,
            } => {
                assert!(mean_rps > 0.0, "diurnal mean rate must be > 0");
                assert!((0.0..1.0).contains(&amplitude), "amplitude in [0, 1)");
                assert!(period_s > 0.0, "period must be > 0");
                // Lewis–Shedler thinning against the peak rate.
                let peak = mean_rps * (1.0 + amplitude);
                let mut t = 0.0;
                while out.len() < n {
                    t += rng.exp(1.0 / peak);
                    let lam = mean_rps
                        * (1.0
                            + amplitude
                                * (2.0 * std::f64::consts::PI * t / period_s).sin());
                    if rng.f64() < lam / peak {
                        out.push(t);
                    }
                }
            }
        }
        out
    }
}

/// One tenant's slice of a multi-tenant mix.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant id (nonzero keeps tenants' prefix keys namespaced apart;
    /// a single tenant 0 reproduces the legacy shared namespace).
    pub tenant: u32,
    /// Share of total traffic (relative weight, > 0).
    pub share: f64,
    /// Distinct documents in the tenant's pool.
    pub n_docs: usize,
    /// Zipf exponent of document popularity (0 = uniform; higher skews
    /// reuse onto the head documents — prefix-sharing locality).
    pub zipf_s: f64,
    /// Document context length, tokens.
    pub context_tokens: u32,
    /// Fresh tokens appended per request (the new question).
    pub suffix_tokens: u32,
    /// Output tokens per request.
    pub output_tokens: u32,
    /// Model id the tenant's requests target (empty = run default).
    pub model: String,
    /// QoS class of the tenant's KV fetches (`None` = latency-critical).
    pub class: Option<TransferClass>,
    /// Documents were ingested by a previous session: even the first
    /// touch of a document claims its context as cached prefix, so
    /// replay pre-seeds the host tier (the §5.2.1 setup, where turn 1 is
    /// discarded). `false` = cold-start, first touch prefills from
    /// scratch.
    pub warm_start: bool,
}

impl TenantSpec {
    /// An interactive chat tenant over `n_docs` documents of `context`
    /// tokens (the defaults most sweeps use).
    pub fn interactive(tenant: u32, n_docs: usize, context_tokens: u32) -> TenantSpec {
        TenantSpec {
            tenant,
            share: 1.0,
            n_docs,
            zipf_s: 1.1,
            context_tokens,
            suffix_tokens: 64,
            output_tokens: 16,
            model: String::new(),
            class: None,
            warm_start: false,
        }
    }
}

/// A full trace generator: an arrival process fanned out over a tenant
/// mix. The first request touching a document is cold (`cached = 0`);
/// repeats claim the document context as cached prefix — the multi-turn
/// QA shape of §5.2.1, generalized.
#[derive(Clone, Debug)]
pub struct TraceGen {
    /// Arrival time process.
    pub arrivals: ArrivalProcess,
    /// Tenant mix (at least one).
    pub tenants: Vec<TenantSpec>,
    /// Requests to emit.
    pub requests: usize,
}

impl TraceGen {
    /// Build a generator from the `[workload]` config section.
    pub fn from_config(cfg: &WorkloadConfig) -> TraceGen {
        let arrivals = match cfg.arrivals.as_str() {
            "bursty" | "mmpp" => {
                ArrivalProcess::bursty(cfg.rate_rps, cfg.burstiness, cfg.dwell_s)
            }
            "diurnal" => ArrivalProcess::Diurnal {
                mean_rps: cfg.rate_rps,
                amplitude: cfg.burstiness,
                period_s: cfg.period_s,
            },
            _ => ArrivalProcess::Poisson {
                rate_rps: cfg.rate_rps,
            },
        };
        // Tenant 0 keeps a single-tenant config in the legacy shared
        // namespace; multi-tenant mixes get ids 1..=N so their keys
        // never collide.
        let tenants = (0..cfg.tenants.max(1))
            .map(|i| TenantSpec {
                tenant: if cfg.tenants <= 1 { 0 } else { i + 1 },
                share: 1.0,
                n_docs: cfg.docs_per_tenant.max(1) as usize,
                zipf_s: cfg.zipf_s,
                context_tokens: cfg.context_tokens,
                suffix_tokens: cfg.suffix_tokens,
                output_tokens: cfg.output_tokens,
                model: String::new(),
                class: None,
                warm_start: cfg.warm_start,
            })
            .collect();
        TraceGen {
            arrivals,
            tenants,
            requests: cfg.requests as usize,
        }
    }

    /// Generate the trace. Deterministic in `rng`'s seed.
    pub fn generate(&self, rng: &mut Rng) -> Trace {
        assert!(!self.tenants.is_empty(), "a trace needs at least one tenant");
        for t in &self.tenants {
            assert!(t.share > 0.0, "tenant {} share must be > 0", t.tenant);
            assert!(t.n_docs > 0, "tenant {} needs documents", t.tenant);
        }
        // Per-tenant document key pools, drawn up front so the key space
        // is independent of the arrival ordering.
        let doc_keys: Vec<Vec<u64>> = self
            .tenants
            .iter()
            .map(|t| (0..t.n_docs).map(|_| rng.next_u64() | 1).collect())
            .collect();
        let times = self.arrivals.sample(rng, self.requests);
        let total_share: f64 = self.tenants.iter().map(|t| t.share).sum();
        let mut seen: HashMap<(u32, u64), u32> = HashMap::new();
        let mut records = Vec::with_capacity(times.len());
        for t in times {
            // Pick the tenant by share, then the document by Zipf rank.
            let mut pick = rng.f64() * total_share;
            let mut ti = 0;
            for (i, spec) in self.tenants.iter().enumerate() {
                pick -= spec.share;
                if pick <= 0.0 {
                    ti = i;
                    break;
                }
            }
            let spec = &self.tenants[ti];
            let rank = rng.zipf(spec.n_docs, spec.zipf_s);
            let key = doc_keys[ti][rank];
            let visits = seen.entry((spec.tenant, key)).or_insert(0);
            *visits += 1;
            let turn = *visits;
            let cold = turn == 1 && !spec.warm_start;
            records.push(TraceRecord {
                arrival_s: t,
                prompt_tokens: spec.context_tokens + turn * spec.suffix_tokens,
                output_tokens: spec.output_tokens,
                prefix_key: key,
                cached_prefix_tokens: if cold { 0 } else { spec.context_tokens },
                tenant: spec.tenant,
                model: spec.model.clone(),
                class: spec.class,
            });
        }
        Trace { records }
    }
}

/// A model-switch schedule: Poisson traffic whose target model rotates
/// through `models` every `phase_s` seconds (one tenant per model, so
/// each phase reuses its own documents). Replayed with
/// `--follow-switches`, the model boundaries drive
/// [`crate::serving::ModelRegistry`] sleep/wake co-running with the
/// serving traffic — the paper's sleep-mode switching scenario under
/// realistic load.
pub fn model_switch_trace(
    rng: &mut Rng,
    models: &[String],
    rate_rps: f64,
    phase_s: f64,
    context_tokens: u32,
    requests: usize,
) -> Trace {
    assert!(!models.is_empty(), "need at least one model");
    assert!(phase_s > 0.0, "phase must be > 0");
    let times = ArrivalProcess::Poisson { rate_rps }.sample(rng, requests);
    let keys: Vec<u64> = models.iter().map(|_| rng.next_u64() | 1).collect();
    let mut seen = vec![false; models.len()];
    let records = times
        .into_iter()
        .map(|t| {
            let phase = (t / phase_s) as usize % models.len();
            let cold = !seen[phase];
            seen[phase] = true;
            TraceRecord {
                arrival_s: t,
                prompt_tokens: context_tokens + 64,
                output_tokens: 8,
                prefix_key: keys[phase],
                cached_prefix_tokens: if cold { 0 } else { context_tokens },
                tenant: phase as u32 + 1,
                model: models[phase].clone(),
                class: None,
            }
        })
        .collect();
    Trace { records }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_mean_rate_but_mmpp_is_burstier() {
        // The generator sanity gate: at the same long-run mean rate, the
        // MMPP trace's inter-arrival CV must clearly exceed Poisson's
        // (which sits near 1 by construction).
        let n = 4000;
        let mut rng = Rng::seed_from_u64(11);
        let poisson = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let bursty = ArrivalProcess::bursty(20.0, 0.9, 2.0);
        assert!((poisson.mean_rate_rps() - bursty.mean_rate_rps()).abs() < 1e-12);
        let tp = Trace {
            records: poisson
                .sample(&mut rng, n)
                .into_iter()
                .map(|t| TraceRecord {
                    arrival_s: t,
                    prompt_tokens: 100,
                    output_tokens: 1,
                    prefix_key: 0,
                    cached_prefix_tokens: 0,
                    tenant: 0,
                    model: String::new(),
                    class: None,
                })
                .collect(),
        };
        let mut rng = Rng::seed_from_u64(11);
        let tb = Trace {
            records: bursty
                .sample(&mut rng, n)
                .into_iter()
                .map(|t| TraceRecord {
                    arrival_s: t,
                    prompt_tokens: 100,
                    output_tokens: 1,
                    prefix_key: 0,
                    cached_prefix_tokens: 0,
                    tenant: 0,
                    model: String::new(),
                    class: None,
                })
                .collect(),
        };
        let cv_p = tp.interarrival_cv();
        let cv_b = tb.interarrival_cv();
        assert!((0.9..1.1).contains(&cv_p), "poisson CV {cv_p}");
        assert!(cv_b > 1.5, "mmpp CV {cv_b} not bursty");
        // Mean rates realized within 15% of the target.
        assert!((tp.mean_rate_rps() - 20.0).abs() / 20.0 < 0.15);
        assert!((tb.mean_rate_rps() - 20.0).abs() / 20.0 < 0.15);
    }

    #[test]
    fn arrivals_are_sorted_and_positive() {
        let mut rng = Rng::seed_from_u64(3);
        for p in [
            ArrivalProcess::Poisson { rate_rps: 5.0 },
            ArrivalProcess::bursty(5.0, 0.8, 1.0),
            ArrivalProcess::Diurnal {
                mean_rps: 5.0,
                amplitude: 0.6,
                period_s: 30.0,
            },
        ] {
            let xs = p.sample(&mut rng, 500);
            assert_eq!(xs.len(), 500);
            assert!(xs[0] > 0.0);
            assert!(xs.windows(2).all(|w| w[1] >= w[0]), "{p:?} unsorted");
        }
    }

    #[test]
    fn diurnal_peaks_beat_troughs() {
        // Arrivals in the rising half-cycle outnumber the falling one.
        let mut rng = Rng::seed_from_u64(7);
        let period = 40.0;
        let p = ArrivalProcess::Diurnal {
            mean_rps: 10.0,
            amplitude: 0.8,
            period_s: period,
        };
        let xs = p.sample(&mut rng, 3000);
        let (mut peak, mut trough) = (0usize, 0usize);
        for t in xs {
            if (t % period) < period / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "diurnal skew missing: {peak} vs {trough}"
        );
    }

    #[test]
    fn tenant_mix_respects_shares_and_first_touch_is_cold() {
        let mut a = TenantSpec::interactive(1, 4, 8192);
        a.share = 3.0;
        let mut b = TenantSpec::interactive(2, 4, 8192);
        b.share = 1.0;
        b.class = Some(TransferClass::Bulk);
        let g = TraceGen {
            arrivals: ArrivalProcess::Poisson { rate_rps: 50.0 },
            tenants: vec![a, b],
            requests: 2000,
        };
        let mut rng = Rng::seed_from_u64(5);
        let t = g.generate(&mut rng);
        assert_eq!(t.records.len(), 2000);
        let n1 = t.records.iter().filter(|r| r.tenant == 1).count();
        let n2 = t.records.iter().filter(|r| r.tenant == 2).count();
        let frac = n1 as f64 / (n1 + n2) as f64;
        assert!((0.70..0.80).contains(&frac), "3:1 share split, got {frac}");
        // Tenant classes propagate.
        assert!(t
            .records
            .iter()
            .filter(|r| r.tenant == 2)
            .all(|r| r.class == Some(TransferClass::Bulk)));
        // First touch of every (tenant, key) is cold; repeats are warm.
        let mut seen = std::collections::HashSet::new();
        for r in &t.records {
            if seen.insert((r.tenant, r.prefix_key)) {
                assert_eq!(r.cached_prefix_tokens, 0, "first touch must be cold");
            } else {
                assert_eq!(r.cached_prefix_tokens, 8192);
            }
        }
        // Zipf skew: the most popular doc clearly beats the median one.
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for r in t.records.iter().filter(|r| r.tenant == 1) {
            *counts.entry(r.prefix_key).or_insert(0) += 1;
        }
        let mut cs: Vec<usize> = counts.values().copied().collect();
        cs.sort_unstable();
        assert!(cs[cs.len() - 1] > 2 * cs[0], "zipf skew missing: {cs:?}");
    }

    #[test]
    fn warm_start_claims_cached_prefixes_from_the_first_touch() {
        let mut spec = TenantSpec::interactive(1, 3, 8192);
        spec.warm_start = true;
        let g = TraceGen {
            arrivals: ArrivalProcess::Poisson { rate_rps: 20.0 },
            tenants: vec![spec],
            requests: 30,
        };
        let t = g.generate(&mut Rng::seed_from_u64(8));
        assert!(t.records.iter().all(|r| r.cached_prefix_tokens == 8192));
        // Every visited document shows up in the replay pre-seed list.
        let warm = t.warm_prefixes();
        let distinct: std::collections::HashSet<u64> =
            t.records.iter().map(|r| r.prefix_key).collect();
        assert_eq!(warm.len(), distinct.len());
        assert!(warm.iter().all(|&(tenant, _, tok)| tenant == 1 && tok == 8192));
    }

    #[test]
    fn generation_is_seed_deterministic_and_round_trips() {
        let g = TraceGen {
            arrivals: ArrivalProcess::bursty(10.0, 0.8, 2.0),
            tenants: vec![
                TenantSpec::interactive(1, 3, 4096),
                TenantSpec::interactive(2, 3, 4096),
            ],
            requests: 64,
        };
        let a = g.generate(&mut Rng::seed_from_u64(42));
        let b = g.generate(&mut Rng::seed_from_u64(42));
        assert_eq!(a, b, "same seed → identical trace");
        let c = g.generate(&mut Rng::seed_from_u64(43));
        assert_ne!(a, c, "different seed → different trace");
        // Generated traces round-trip through the JSONL format.
        let back = Trace::parse(&a.render()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn model_switch_phases_rotate_models() {
        let mut rng = Rng::seed_from_u64(9);
        let models = vec!["qwen-7b-chat".to_string(), "qwen3-32b".to_string()];
        let t = model_switch_trace(&mut rng, &models, 4.0, 5.0, 8192, 80);
        assert_eq!(t.models(), models, "both models appear, in phase order");
        for r in &t.records {
            let phase = (r.arrival_s / 5.0) as usize % 2;
            assert_eq!(r.model, models[phase], "model follows the schedule");
            assert_eq!(r.tenant, phase as u32 + 1);
        }
        // Each phase's documents repeat within the phase → warm turns.
        assert!(t.records.iter().any(|r| r.cached_prefix_tokens > 0));
    }
}
