//! Versioned JSONL workload traces: the on-disk request format every
//! generator writes and `mma replay` feeds through the serving fleet.
//!
//! A trace is newline-delimited JSON in the spirit of
//! [`crate::config::toml_lite`]: a zero-dependency, intentionally strict
//! parser/writer for exactly the subset we need (flat objects, unsigned
//! integers, floats, strings). The first line is a version header,
//! `{"mma_trace": 1}`; every following line is one request:
//!
//! ```text
//! {"mma_trace": 1}
//! {"t": 0.0, "prompt": 16448, "output": 32, "key": 7, "cached": 0}
//! {"t": 0.41, "prompt": 16448, "output": 32, "key": 7, "cached": 16384, "tenant": 2, "model": "qwen-7b-chat", "class": "latency-critical"}
//! ```
//!
//! `t` is the arrival time in seconds from trace start; `key`/`cached`
//! carry the prefix-cache key and the cached-prefix length the request
//! claims; `tenant`, `model`, and `class` are optional (defaults: tenant
//! 0, the run's model, latency-critical fetches). Keys are scoped per
//! tenant at replay time through [`Request::cache_key`], so two tenants
//! reusing the same document key never share KV.
//!
//! Integer keys are parsed as exact `u64`s (never through `f64`, which
//! would corrupt keys above 2^53). Rendering is canonical — stable key
//! order, shortest-roundtrip floats, defaults omitted — so
//! `parse(render(t)) == t` and `mma trace gen` output is byte-stable.

use crate::mma::TransferClass;
use crate::serving::{Request, RequestId};
use crate::sim::Time;

/// The trace format version this build reads and writes.
pub const TRACE_VERSION: u32 = 1;

/// One request record in a workload trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Full prompt length in tokens.
    pub prompt_tokens: u32,
    /// Output tokens to generate.
    pub output_tokens: u32,
    /// Prefix-cache key (0 = no cached prefix), scoped to `tenant`.
    pub prefix_key: u64,
    /// Cached-prefix length the request claims, in tokens.
    pub cached_prefix_tokens: u32,
    /// Tenant id (0 = the default namespace).
    pub tenant: u32,
    /// Model id the request targets (empty = the run's default model).
    /// Boundaries where consecutive records change model form the
    /// sleep/wake switch schedule replay drives through the registry.
    pub model: String,
    /// QoS class of the request's KV fetch (`None` = latency-critical).
    pub class: Option<TransferClass>,
}

impl TraceRecord {
    /// Convert to a serving [`Request`] with the given id.
    pub fn to_request(&self, id: u64) -> Request {
        Request {
            id: RequestId(id),
            arrival: Time::from_secs_f64(self.arrival_s),
            prompt_tokens: self.prompt_tokens,
            cached_prefix_tokens: self.cached_prefix_tokens,
            prefix_key: self.prefix_key,
            output_tokens: self.output_tokens,
            tenant: self.tenant,
            class: self.class,
        }
    }

    fn render(&self, out: &mut String) {
        out.push_str("{\"t\": ");
        out.push_str(&format_f64(self.arrival_s));
        out.push_str(&format!(
            ", \"prompt\": {}, \"output\": {}, \"key\": {}",
            self.prompt_tokens, self.output_tokens, self.prefix_key
        ));
        if self.cached_prefix_tokens != 0 {
            out.push_str(&format!(", \"cached\": {}", self.cached_prefix_tokens));
        }
        if self.tenant != 0 {
            out.push_str(&format!(", \"tenant\": {}", self.tenant));
        }
        if !self.model.is_empty() {
            out.push_str(", \"model\": ");
            render_str(&self.model, out);
        }
        if let Some(c) = self.class {
            out.push_str(&format!(", \"class\": \"{}\"", c.name()));
        }
        out.push('}');
    }
}

/// A parsed workload trace: the version header plus its records.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// The records, in file order (not necessarily sorted by arrival —
    /// the fleet sorts on ingestion).
    pub records: Vec<TraceRecord>,
}

impl Trace {
    /// Parse JSONL text. Errors carry 1-based line numbers; a missing or
    /// mismatched version header is rejected before any record parses.
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut records = Vec::new();
        let mut saw_header = false;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let fields = parse_object(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            if !saw_header {
                let version = header_version(&fields)
                    .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                if version != TRACE_VERSION as u64 {
                    return Err(format!(
                        "line {}: unsupported trace version {version} \
                         (this build reads {TRACE_VERSION})",
                        lineno + 1
                    ));
                }
                saw_header = true;
                continue;
            }
            records.push(
                record_from_fields(fields).map_err(|e| format!("line {}: {e}", lineno + 1))?,
            );
        }
        if !saw_header {
            return Err(format!(
                "missing trace header (expected {{\"mma_trace\": {TRACE_VERSION}}})"
            ));
        }
        Ok(Trace { records })
    }

    /// Render to canonical JSONL (header + one record per line).
    pub fn render(&self) -> String {
        let mut out = format!("{{\"mma_trace\": {TRACE_VERSION}}}\n");
        for r in &self.records {
            r.render(&mut out);
            out.push('\n');
        }
        out
    }

    /// Read and parse a trace file.
    pub fn load(path: &str) -> Result<Trace, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
        Trace::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    /// Write the canonical rendering to a file.
    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.render()).map_err(|e| format!("write {path:?}: {e}"))
    }

    /// Convert every record to a serving [`Request`] (ids = record index).
    pub fn requests(&self) -> Vec<Request> {
        self.records
            .iter()
            .enumerate()
            .map(|(i, r)| r.to_request(i as u64))
            .collect()
    }

    /// A copy truncated to the first `n` records (`mma replay --fast`).
    pub fn truncated(&self, n: usize) -> Trace {
        Trace {
            records: self.records.iter().take(n).cloned().collect(),
        }
    }

    /// `(tenant, key, tokens)` for every prefix whose *first* appearance
    /// already claims a cached prefix — state a previous session left in
    /// the host tier, which replay must seed before running.
    pub fn warm_prefixes(&self) -> Vec<(u32, u64, u32)> {
        warm_prefixes_of(&self.records)
    }

    /// Distinct model ids in arrival order of first appearance (empty
    /// string = the default model).
    pub fn models(&self) -> Vec<String> {
        models_of(&self.records)
    }

    /// Trace duration: the last arrival, seconds.
    pub fn duration_s(&self) -> f64 {
        duration_of(&self.records)
    }

    /// Mean offered rate over the trace span, requests/second.
    pub fn mean_rate_rps(&self) -> f64 {
        let d = self.duration_s();
        if d <= 0.0 {
            0.0
        } else {
            self.records.len() as f64 / d
        }
    }

    /// Coefficient of variation of the inter-arrival gaps (1 ≈ Poisson,
    /// higher = burstier). The burstiness yardstick the generator tests
    /// and the replay figure report.
    pub fn interarrival_cv(&self) -> f64 {
        let mut times: Vec<f64> = self.records.iter().map(|r| r.arrival_s).collect();
        times.sort_by(f64::total_cmp);
        if times.len() < 3 {
            return 0.0;
        }
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        var.sqrt() / mean
    }
}

/// Slice form of [`Trace::warm_prefixes`]: replay works on
/// `&trace.records[..n]` directly (no per-record clone for `--max`).
/// Order: stable sort by arrival — ties resolve by file position — then
/// first appearance per `(tenant, key)`.
pub fn warm_prefixes_of(records: &[TraceRecord]) -> Vec<(u32, u64, u32)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    let mut by_time: Vec<&TraceRecord> = records.iter().collect();
    by_time.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    for r in by_time {
        // `insert` must run for every first appearance (cold ones
        // too), so it sits in the chain ahead of the cached check.
        if r.prefix_key != 0
            && seen.insert((r.tenant, r.prefix_key))
            && r.cached_prefix_tokens > 0
        {
            out.push((r.tenant, r.prefix_key, r.cached_prefix_tokens));
        }
    }
    out
}

/// Slice form of [`Trace::duration_s`]: the last arrival, seconds.
pub fn duration_of(records: &[TraceRecord]) -> f64 {
    records.iter().map(|r| r.arrival_s).fold(0.0, f64::max)
}

/// Slice form of [`Trace::models`].
pub fn models_of(records: &[TraceRecord]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for r in records {
        if !out.contains(&r.model) {
            out.push(r.model.clone());
        }
    }
    out
}

/// Shortest-roundtrip float rendering (Rust's `{:?}` guarantees the
/// printed form parses back to the identical bits).
fn format_f64(x: f64) -> String {
    format!("{x:?}")
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out.push('"');
}

/// One parsed JSON scalar. Integers stay exact (`u64`), never routed
/// through `f64`.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum JsonValue {
    /// Unsigned integer (exact).
    UInt(u64),
    /// Float.
    Float(f64),
    /// String.
    Str(String),
}

impl JsonValue {
    fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::UInt(u) => Some(*u as f64),
            JsonValue::Float(f) => Some(*f),
            JsonValue::Str(_) => None,
        }
    }
    fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::UInt(u) => Some(*u),
            _ => None,
        }
    }
    fn as_u32(&self) -> Option<u32> {
        self.as_u64().and_then(|u| u32::try_from(u).ok())
    }
}

/// Parse one flat JSON object (`{"k": v, ...}`). Strict about everything
/// the format does not need: no nesting, no arrays, no null, no duplicate
/// keys, no negative numbers. Shared with the line-streaming reader in
/// [`crate::workload::stream`].
pub(crate) fn parse_object(line: &str) -> Result<Vec<(String, JsonValue)>, String> {
    let b = line.as_bytes();
    let mut i = 0usize;
    let skip_ws = |i: &mut usize| {
        while *i < b.len() && (b[*i] as char).is_ascii_whitespace() {
            *i += 1;
        }
    };
    skip_ws(&mut i);
    if i >= b.len() || b[i] != b'{' {
        return Err("expected a JSON object".to_string());
    }
    i += 1;
    let mut fields: Vec<(String, JsonValue)> = Vec::new();
    skip_ws(&mut i);
    if i < b.len() && b[i] == b'}' {
        i += 1;
    } else {
        loop {
            skip_ws(&mut i);
            let key = parse_string(line, &mut i)?;
            skip_ws(&mut i);
            if i >= b.len() || b[i] != b':' {
                return Err(format!("key {key:?}: expected ':'"));
            }
            i += 1;
            skip_ws(&mut i);
            let value = parse_value(line, &mut i)?;
            if fields.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate key {key:?}"));
            }
            fields.push((key, value));
            skip_ws(&mut i);
            match b.get(i) {
                Some(b',') => i += 1,
                Some(b'}') => {
                    i += 1;
                    break;
                }
                _ => return Err("expected ',' or '}'".to_string()),
            }
        }
    }
    skip_ws(&mut i);
    if i != b.len() {
        return Err("trailing garbage after object".to_string());
    }
    Ok(fields)
}

fn parse_string(line: &str, i: &mut usize) -> Result<String, String> {
    let b = line.as_bytes();
    if *i >= b.len() || b[*i] != b'"' {
        return Err("expected a string".to_string());
    }
    *i += 1;
    let mut out = String::new();
    let chars: Vec<char> = line[*i..].chars().collect();
    let mut ci = 0usize;
    while ci < chars.len() {
        match chars[ci] {
            '"' => {
                // Advance the byte cursor past the consumed chars + quote.
                let consumed: usize = chars[..ci].iter().map(|c| c.len_utf8()).sum();
                *i += consumed + 1;
                return Ok(out);
            }
            '\\' => {
                ci += 1;
                match chars.get(ci) {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    other => return Err(format!("unsupported escape {other:?}")),
                }
            }
            c => out.push(c),
        }
        ci += 1;
    }
    Err("unterminated string".to_string())
}

fn parse_value(line: &str, i: &mut usize) -> Result<JsonValue, String> {
    let b = line.as_bytes();
    if *i < b.len() && b[*i] == b'"' {
        return Ok(JsonValue::Str(parse_string(line, i)?));
    }
    let start = *i;
    while *i < b.len() && matches!(b[*i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *i += 1;
    }
    let tok = &line[start..*i];
    if tok.is_empty() {
        return Err("expected a value".to_string());
    }
    if tok.starts_with('-') {
        return Err(format!("negative value {tok:?} not allowed"));
    }
    if tok.contains(['.', 'e', 'E']) {
        let f: f64 = tok
            .parse()
            .map_err(|_| format!("cannot parse number {tok:?}"))?;
        if !f.is_finite() {
            return Err(format!("non-finite number {tok:?}"));
        }
        Ok(JsonValue::Float(f))
    } else {
        let u: u64 = tok
            .parse()
            .map_err(|_| format!("cannot parse integer {tok:?}"))?;
        Ok(JsonValue::UInt(u))
    }
}

pub(crate) fn header_version(fields: &[(String, JsonValue)]) -> Result<u64, String> {
    if fields.len() != 1 || fields[0].0 != "mma_trace" {
        return Err(format!(
            "first line must be the header {{\"mma_trace\": {TRACE_VERSION}}}"
        ));
    }
    fields[0]
        .1
        .as_u64()
        .ok_or_else(|| "header version must be an integer".to_string())
}

pub(crate) fn record_from_fields(
    fields: Vec<(String, JsonValue)>,
) -> Result<TraceRecord, String> {
    let mut r = TraceRecord {
        arrival_s: f64::NAN,
        prompt_tokens: 0,
        output_tokens: 0,
        prefix_key: 0,
        cached_prefix_tokens: 0,
        tenant: 0,
        model: String::new(),
        class: None,
    };
    let mut saw = [false; 3]; // t, prompt, output — the required fields
    for (k, v) in fields {
        match k.as_str() {
            "t" => {
                r.arrival_s = v.as_f64().ok_or("\"t\": expected a number")?;
                saw[0] = true;
            }
            "prompt" => {
                r.prompt_tokens = v.as_u32().ok_or("\"prompt\": expected a u32")?;
                saw[1] = true;
            }
            "output" => {
                r.output_tokens = v.as_u32().ok_or("\"output\": expected a u32")?;
                saw[2] = true;
            }
            "key" => r.prefix_key = v.as_u64().ok_or("\"key\": expected a u64")?,
            "cached" => {
                r.cached_prefix_tokens = v.as_u32().ok_or("\"cached\": expected a u32")?
            }
            "tenant" => r.tenant = v.as_u32().ok_or("\"tenant\": expected a u32")?,
            "model" => match v {
                JsonValue::Str(s) => r.model = s,
                _ => return Err("\"model\": expected a string".to_string()),
            },
            "class" => match v {
                JsonValue::Str(s) => {
                    r.class = Some(TransferClass::parse(&s).ok_or_else(|| {
                        format!(
                            "\"class\": unknown class {s:?} (latency-critical | \
                             interactive | bulk | background)"
                        )
                    })?)
                }
                _ => return Err("\"class\": expected a string".to_string()),
            },
            other => return Err(format!("unknown key {other:?}")),
        }
    }
    for (seen, name) in saw.iter().zip(["t", "prompt", "output"]) {
        if !seen {
            return Err(format!("missing required field {name:?}"));
        }
    }
    if !r.arrival_s.is_finite() || r.arrival_s < 0.0 {
        return Err(format!("\"t\": {} out of range", r.arrival_s));
    }
    if r.prompt_tokens == 0 {
        return Err("\"prompt\": must be >= 1".to_string());
    }
    if r.output_tokens == 0 {
        return Err("\"output\": must be >= 1".to_string());
    }
    if r.cached_prefix_tokens > r.prompt_tokens {
        return Err(format!(
            "\"cached\": {} exceeds prompt {}",
            r.cached_prefix_tokens, r.prompt_tokens
        ));
    }
    if r.cached_prefix_tokens > 0 && r.prefix_key == 0 {
        return Err("\"cached\" > 0 requires a nonzero \"key\"".to_string());
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: f64, key: u64, cached: u32) -> TraceRecord {
        TraceRecord {
            arrival_s: t,
            prompt_tokens: 16_448,
            output_tokens: 32,
            prefix_key: key,
            cached_prefix_tokens: cached,
            tenant: 0,
            model: String::new(),
            class: None,
        }
    }

    #[test]
    fn round_trip_is_exact() {
        let t = Trace {
            records: vec![
                rec(0.0, 7, 0),
                TraceRecord {
                    arrival_s: 0.125,
                    tenant: 2,
                    model: "qwen-7b-chat".to_string(),
                    class: Some(TransferClass::Bulk),
                    cached_prefix_tokens: 16_384,
                    ..rec(0.0, u64::MAX, 0)
                },
                rec(3.25e-3, 0, 0),
            ],
        };
        let text = t.render();
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back, t, "write → parse must be identity:\n{text}");
        // Canonical rendering is a fixpoint.
        assert_eq!(back.render(), text);
        // u64 keys survive exactly (no f64 round-trip).
        assert_eq!(back.records[1].prefix_key, u64::MAX);
    }

    #[test]
    fn header_is_required_and_versioned() {
        let good = "{\"mma_trace\": 1}\n";
        assert!(Trace::parse(good).unwrap().records.is_empty());
        let e = Trace::parse("").unwrap_err();
        assert!(e.contains("missing trace header"), "{e}");
        let e = Trace::parse("{\"mma_trace\": 2}\n").unwrap_err();
        assert!(e.contains("unsupported trace version 2"), "{e}");
        // A record line first = not a header.
        let e =
            Trace::parse("{\"t\": 0.0, \"prompt\": 10, \"output\": 1}\n").unwrap_err();
        assert!(e.contains("header"), "{e}");
    }

    #[test]
    fn malformed_lines_are_rejected_with_line_numbers() {
        let head = "{\"mma_trace\": 1}\n";
        for (bad, needle) in [
            ("{\"t\": 0.0, \"prompt\": 10}", "missing required field \"output\""),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1, \"nope\": 2}", "unknown key"),
            ("{\"t\": -1.0, \"prompt\": 10, \"output\": 1}", "negative"),
            ("{\"t\": 0.0, \"prompt\": 0, \"output\": 1}", "\"prompt\""),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1, \"cached\": 11, \"key\": 3}", "exceeds prompt"),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1, \"cached\": 5}", "nonzero \"key\""),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1, \"class\": \"x\"}", "unknown class"),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1", "expected ',' or '}'"),
            ("{\"t\": 0.0, \"t\": 1.0, \"prompt\": 10, \"output\": 1}", "duplicate"),
            ("not json", "object"),
            ("{\"t\": 0.0, \"prompt\": 10, \"output\": 1} extra", "trailing garbage"),
        ] {
            let e = Trace::parse(&format!("{head}{bad}\n")).unwrap_err();
            assert!(e.contains("line 2"), "{bad}: {e}");
            assert!(e.contains(needle), "{bad}: expected {needle:?}, got {e}");
        }
    }

    #[test]
    fn optional_fields_default_and_strings_escape() {
        let t = Trace::parse(
            "{\"mma_trace\": 1}\n{\"t\": 1, \"prompt\": 8, \"output\": 2, \
             \"model\": \"a\\\"b\\\\c\"}\n",
        )
        .unwrap();
        let r = &t.records[0];
        assert_eq!(r.tenant, 0);
        assert_eq!(r.prefix_key, 0);
        assert_eq!(r.cached_prefix_tokens, 0);
        assert_eq!(r.class, None);
        assert_eq!(r.model, "a\"b\\c");
        assert_eq!(r.arrival_s, 1.0, "integer t accepted as seconds");
        // And the escaped model round-trips.
        let back = Trace::parse(&t.render()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn requests_and_stats_derive_from_records() {
        let t = Trace {
            records: vec![rec(0.0, 9, 0), rec(1.0, 9, 16_384), rec(4.0, 9, 16_384)],
        };
        let reqs = t.requests();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[1].id, RequestId(1));
        assert_eq!(reqs[1].arrival, Time::from_secs_f64(1.0));
        assert_eq!(reqs[1].cached_prefix_tokens, 16_384);
        assert_eq!(t.duration_s(), 4.0);
        assert!((t.mean_rate_rps() - 0.75).abs() < 1e-12);
        // First appearance of key 9 is cold → nothing to pre-seed.
        assert!(t.warm_prefixes().is_empty());
        let warm = Trace {
            records: vec![rec(0.0, 9, 16_384)],
        };
        assert_eq!(warm.warm_prefixes(), vec![(0, 9, 16_384)]);
    }

    #[test]
    fn truncated_caps_record_count() {
        let t = Trace {
            records: (0..10).map(|i| rec(i as f64, 0, 0)).collect(),
        };
        assert_eq!(t.truncated(3).records.len(), 3);
        assert_eq!(t.truncated(99).records.len(), 10);
    }
}
