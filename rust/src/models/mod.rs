//! Model zoo: architecture tables for the models the paper evaluates
//! (Qwen3-0.6B / Qwen3-4B / Qwen-7B-Chat / Qwen3-32B) plus the tiny
//! transformer served live by the end-to-end example.
//!
//! The figures that involve models (Fig 2/3/12/13) are driven entirely by
//! two derived quantities: **KV-cache bytes per token** (what a prefix-hit
//! fetch moves) and **weight bytes** (what sleep/wake moves). Both follow
//! exactly from the architecture table, so paper-scale transfer volumes are
//! reproduced without the actual checkpoints.

use crate::topology::NumaId;
use crate::util::rng::Rng;

/// Numeric format of stored tensors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// bfloat16 / float16.
    F16,
    /// float32.
    F32,
    /// 8-bit (fp8/int8) — used by KV-quantizing deployments.
    I8,
}

impl Dtype {
    /// Bytes per element.
    pub fn bytes(self) -> u64 {
        match self {
            Dtype::F16 => 2,
            Dtype::F32 => 4,
            Dtype::I8 => 1,
        }
    }
}

/// Decoder-only transformer architecture description.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Display name.
    pub name: &'static str,
    /// Total parameter count.
    pub params: u64,
    /// Transformer layers.
    pub layers: u32,
    /// Hidden size.
    pub hidden: u32,
    /// Attention (query) heads.
    pub heads: u32,
    /// KV heads (GQA; == heads for MHA).
    pub kv_heads: u32,
    /// Per-head dimension.
    pub head_dim: u32,
    /// FFN intermediate size.
    pub intermediate: u32,
    /// Vocabulary size.
    pub vocab: u32,
    /// Weight storage dtype.
    pub weight_dtype: Dtype,
    /// KV-cache storage dtype.
    pub kv_dtype: Dtype,
}

impl ModelSpec {
    /// KV-cache bytes per token: K and V, all layers, all KV heads.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers as u64
            * self.kv_heads as u64
            * self.head_dim as u64
            * self.kv_dtype.bytes()
    }

    /// KV-cache bytes for a full context of `tokens`.
    pub fn kv_bytes(&self, tokens: u64) -> u64 {
        self.kv_bytes_per_token() * tokens
    }

    /// Total weight bytes (what sleep/wake moves).
    pub fn weight_bytes(&self) -> u64 {
        self.params * self.weight_dtype.bytes()
    }

    /// Per-tensor weight sizes, in load order: embedding, then per layer
    /// {q, k, v, o, gate, up, down} projections, then the LM head.
    ///
    /// Sleep/wake moves weights tensor-by-tensor (vLLM iterates the state
    /// dict), so per-transfer sizes — not the total — determine how much
    /// multipath helps: small tensors fall under MMA's fallback threshold
    /// and go native, large ones fan out. This is what produces the
    /// 1.12–2.48× switching range of Fig 13.
    pub fn tensor_sizes(&self) -> Vec<u64> {
        let d = self.weight_dtype.bytes();
        let h = self.hidden as u64;
        let qd = self.heads as u64 * self.head_dim as u64;
        let kvd = self.kv_heads as u64 * self.head_dim as u64;
        let i = self.intermediate as u64;
        let mut v = vec![self.vocab as u64 * h * d]; // tok embedding
        for _ in 0..self.layers {
            v.push(h * qd * d); // q_proj
            v.push(h * kvd * d); // k_proj
            v.push(h * kvd * d); // v_proj
            v.push(qd * h * d); // o_proj
            v.push(h * i * d); // gate_proj
            v.push(h * i * d); // up_proj
            v.push(i * h * d); // down_proj
        }
        v.push(self.vocab as u64 * h * d); // lm head
        v
    }

    /// Sum of [`Self::tensor_sizes`] — the bytes sleep/wake actually moves.
    pub fn tensor_bytes(&self) -> u64 {
        self.tensor_sizes().iter().sum()
    }

    /// Forward FLOPs per token (the standard 2·params approximation plus
    /// the attention term over `context` tokens).
    pub fn flops_per_token(&self, context: u64) -> f64 {
        let dense = 2.0 * self.params as f64;
        let attn = 2.0
            * self.layers as f64
            * self.heads as f64
            * self.head_dim as f64
            * context as f64
            * 2.0; // QK^T and PV
        dense + attn
    }
}

/// Qwen3-0.6B (28 layers, GQA 16/8, head 128).
pub fn qwen3_0_6b() -> ModelSpec {
    ModelSpec {
        name: "Qwen3-0.6B",
        params: 600_000_000,
        layers: 28,
        hidden: 1024,
        heads: 16,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 3072,
        vocab: 151_936,
        weight_dtype: Dtype::F16,
        kv_dtype: Dtype::F16,
    }
}

/// Qwen3-4B (36 layers, GQA 32/8, head 128).
pub fn qwen3_4b() -> ModelSpec {
    ModelSpec {
        name: "Qwen3-4B",
        params: 4_000_000_000,
        layers: 36,
        hidden: 2560,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 9728,
        vocab: 151_936,
        weight_dtype: Dtype::F16,
        kv_dtype: Dtype::F16,
    }
}

/// Qwen-7B-Chat (32 layers, MHA 32 heads, head 128). The paper reports a
/// 17.5 GB KV cache at 64 k tokens (§5.2.1), which corresponds to an
/// 8-bit KV store at this architecture — we model it accordingly.
pub fn qwen_7b_chat() -> ModelSpec {
    ModelSpec {
        name: "Qwen-7B-Chat",
        params: 7_720_000_000,
        layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 32,
        head_dim: 128,
        intermediate: 11008,
        vocab: 151_936,
        weight_dtype: Dtype::F16,
        kv_dtype: Dtype::I8,
    }
}

/// Qwen3-32B (64 layers, GQA 64/8, head 128).
pub fn qwen3_32b() -> ModelSpec {
    ModelSpec {
        name: "Qwen3-32B",
        params: 32_800_000_000,
        layers: 64,
        hidden: 5120,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        intermediate: 25600,
        vocab: 151_936,
        weight_dtype: Dtype::F16,
        kv_dtype: Dtype::F16,
    }
}

/// Look up a model preset by its CLI / trace spelling (case-insensitive;
/// accepts both the full name and the size shorthand). `None` for
/// unknown names — the CLI and trace replay decide the fallback.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name.to_ascii_lowercase().as_str() {
        "qwen3-0.6b" | "0.6b" => Some(qwen3_0_6b()),
        "qwen3-4b" | "4b" => Some(qwen3_4b()),
        "qwen-7b" | "qwen-7b-chat" | "7b" => Some(qwen_7b_chat()),
        "qwen3-32b" | "32b" => Some(qwen3_32b()),
        "tiny" | "tiny-serve" => Some(tiny_serve()),
        _ => None,
    }
}

/// The tiny transformer served live by `examples/kv_offload_serving.rs`
/// through the real JAX→Pallas→HLO→PJRT pipeline. Must match
/// `python/compile/model.py::TINY`.
pub fn tiny_serve() -> ModelSpec {
    ModelSpec {
        name: "tiny-serve",
        params: 3_700_000,
        layers: 4,
        hidden: 256,
        heads: 4,
        kv_heads: 4,
        head_dim: 64,
        intermediate: 1024,
        vocab: 1024,
        weight_dtype: Dtype::F32,
        kv_dtype: Dtype::F32,
    }
}

/// The evaluation set of §5.2, in size order.
pub fn paper_models() -> Vec<ModelSpec> {
    vec![qwen3_0_6b(), qwen3_4b(), qwen_7b_chat(), qwen3_32b()]
}

/// A randomized but architecturally plausible decoder spec for property
/// tests ([`crate::testkit::check`]): GQA ratios, head dims, and KV
/// dtypes drawn from the ranges real deployments use, with `params`
/// derived from the projection shapes so every derived quantity
/// (`kv_bytes_per_token`, `weight_bytes`, `flops_per_token`) stays
/// mutually consistent.
pub fn sample_spec(rng: &mut Rng) -> ModelSpec {
    let layers = rng.range_u64(4, 96) as u32;
    let head_dim = [64u32, 128][rng.range_usize(0, 2)];
    let heads = [8u32, 16, 32, 64][rng.range_usize(0, 4)];
    let kv_heads = [heads, heads / 2, heads / 4, heads.min(8)][rng.range_usize(0, 4)].max(1);
    let hidden = heads * head_dim;
    let intermediate = hidden * rng.range_u64(2, 5) as u32;
    let vocab = 32_000u32;
    let (h, i) = (hidden as u64, intermediate as u64);
    let qd = heads as u64 * head_dim as u64;
    let kvd = kv_heads as u64 * head_dim as u64;
    let per_layer = 2 * h * qd + 2 * h * kvd + 3 * h * i;
    let params = 2 * vocab as u64 * h + layers as u64 * per_layer;
    ModelSpec {
        name: "sampled",
        params,
        layers,
        hidden,
        heads,
        kv_heads,
        head_dim,
        intermediate,
        vocab,
        weight_dtype: Dtype::F16,
        kv_dtype: [Dtype::F16, Dtype::I8][rng.range_usize(0, 2)],
    }
}

/// Where the serving stack pins its host staging buffers (the paper's
/// testbed pins near the first socket).
pub fn default_host_numa() -> NumaId {
    NumaId(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_per_token_formulas() {
        // Qwen3-0.6B: 2*28*8*128*2 = 114,688 B/token.
        assert_eq!(qwen3_0_6b().kv_bytes_per_token(), 114_688);
        // Qwen3-32B: 2*64*8*128*2 = 262,144.
        assert_eq!(qwen3_32b().kv_bytes_per_token(), 262_144);
    }

    #[test]
    fn qwen7b_64k_kv_matches_paper_17_5_gb() {
        // §5.2.1: "Qwen-7B-Chat, 64K context, 17.5 GB KV cache".
        let m = qwen_7b_chat();
        let bytes = m.kv_bytes(64 * 1024);
        let gb = bytes as f64 / 1e9;
        assert!((gb - 17.2).abs() < 1.0, "64k KV = {gb:.1} GB, want ~17.5");
    }

    #[test]
    fn weight_bytes_scale_with_params() {
        assert_eq!(qwen3_0_6b().weight_bytes(), 1_200_000_000);
        let b32 = qwen3_32b().weight_bytes() as f64 / 1e9;
        assert!((b32 - 65.6).abs() < 0.1);
    }

    #[test]
    fn flops_grow_with_context() {
        let m = qwen3_4b();
        assert!(m.flops_per_token(64_000) > m.flops_per_token(1_000));
        assert!(m.flops_per_token(0) >= 2.0 * m.params as f64);
    }

    #[test]
    fn tensor_sizes_sum_near_param_count() {
        for m in paper_models() {
            let sum = m.tensor_bytes() as f64;
            let total = m.weight_bytes() as f64;
            let ratio = sum / total;
            assert!(
                (0.8..1.3).contains(&ratio),
                "{}: tensor bytes {sum:.3e} vs weights {total:.3e}",
                m.name
            );
        }
    }

    #[test]
    fn small_models_have_mostly_small_tensors() {
        // The Fig 13 mechanism: at 0.6B most tensors sit below the 11.3 MB
        // fallback threshold; at 32B most bytes are in large tensors.
        let small = qwen3_0_6b();
        let below: u64 = small
            .tensor_sizes()
            .iter()
            .filter(|&&b| b < 11_300_000)
            .sum();
        assert!(below as f64 / small.tensor_bytes() as f64 > 0.4);
        let big = qwen3_32b();
        let above: u64 = big
            .tensor_sizes()
            .iter()
            .filter(|&&b| b >= 11_300_000)
            .sum();
        assert!(above as f64 / big.tensor_bytes() as f64 > 0.9);
    }

    #[test]
    fn sampled_specs_stay_internally_consistent() {
        crate::testkit::check("sample-spec", |rng| {
            let m = sample_spec(rng);
            assert!(m.kv_heads >= 1 && m.kv_heads <= m.heads);
            assert_eq!(m.heads % m.kv_heads, 0, "GQA groups divide evenly");
            assert!(m.kv_bytes_per_token() > 0);
            assert!(m.flops_per_token(0) >= 2.0 * m.params as f64);
            // `params` is derived from the projection shapes, so the
            // tensor-by-tensor walk recovers exactly the weight bytes.
            assert_eq!(m.tensor_bytes(), m.weight_bytes());
        });
    }

    #[test]
    fn paper_models_ordered_by_size() {
        let ms = paper_models();
        assert_eq!(ms.len(), 4);
        for w in ms.windows(2) {
            assert!(w[0].params < w[1].params);
        }
    }
}
