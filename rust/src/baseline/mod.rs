//! Baselines the paper compares against.
//!
//! * **Native**: plain `cudaMemcpyAsync` statically bound to the target
//!   GPU's PCIe link (the paper's main baseline, §5.1).
//! * **Static splitting** (Fig 10): a fixed byte ratio across a fixed path
//!   set, chosen in advance — the strawman MMA's pull-based scheduling is
//!   measured against.
//!
//! Both are expressed as [`MmaConfig`] modes so every harness runs the
//! identical submission path and measurement code.

use crate::mma::{Mode, MmaConfig};
use crate::topology::GpuId;

/// Native single-path configuration.
pub fn native() -> MmaConfig {
    MmaConfig::native()
}

/// Static split across the direct path and `relays`, with the given
/// weights. `weights[0]` belongs to the direct path; `weights[1..]` map to
/// `relays` in order. Panics on length mismatch.
pub fn static_split(target: GpuId, relays: &[GpuId], weights: &[f64]) -> MmaConfig {
    assert_eq!(
        weights.len(),
        relays.len() + 1,
        "need one weight for the direct path plus one per relay"
    );
    let mut ratios = vec![(target, weights[0])];
    for (r, w) in relays.iter().zip(&weights[1..]) {
        assert_ne!(*r, target, "relay cannot be the target");
        ratios.push((*r, *w));
    }
    MmaConfig {
        mode: Mode::Static(ratios),
        // Static splitting has no adaptive machinery.
        contention_backoff: false,
        direct_priority: false,
        ..Default::default()
    }
}

/// Convenience: equal 1:1 split over direct + one relay (Fig 10's "1:1").
pub fn split_1_1(target: GpuId, relay: GpuId) -> MmaConfig {
    static_split(target, &[relay], &[1.0, 1.0])
}

/// 1:2 split (Fig 10's tuned-for-congestion setting: one third on the
/// congested direct path, two thirds on the relay).
pub fn split_1_2(target: GpuId, relay: GpuId) -> MmaConfig {
    static_split(target, &[relay], &[1.0, 2.0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_split_builds_ratios() {
        let cfg = static_split(GpuId(0), &[GpuId(1), GpuId(2)], &[1.0, 2.0, 3.0]);
        let Mode::Static(r) = &cfg.mode else { panic!() };
        assert_eq!(r.len(), 3);
        assert_eq!(r[0], (GpuId(0), 1.0));
        assert_eq!(r[2], (GpuId(2), 3.0));
        assert!(!cfg.contention_backoff);
    }

    #[test]
    #[should_panic(expected = "one weight")]
    fn weight_mismatch_panics() {
        static_split(GpuId(0), &[GpuId(1)], &[1.0]);
    }

    #[test]
    #[should_panic(expected = "relay cannot be the target")]
    fn relay_equals_target_panics() {
        static_split(GpuId(0), &[GpuId(0)], &[1.0, 1.0]);
    }
}
