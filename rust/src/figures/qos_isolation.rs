//! QoS isolation: TTFT under a co-running bulk model wake, with the QoS
//! transfer classes off vs on — this repo's own figure for the
//! whole-stack class refactor.
//!
//! The scenario generalizes the Fig 9(c) / fleet wake co-run: a serving
//! instance on gpu0 answers a stream of host-tier prefix hits (each fetch
//! `LatencyCritical`) while a 32B model parked host-side wakes onto gpu4
//! (`Bulk`, the registry default). Under the multipath engine the wake's
//! relay traffic crosses every PCIe lane and the shared DRAM port, so
//! with QoS off it tramples the fetches. With QoS on, the fetches hold
//! their weighted share of every shared link, issue first in the engine's
//! class-aware queues, and bulk backs off to one outstanding slot —
//! TTFT under the wake approaches the no-wake baseline while the wake
//! itself only degrades modestly.

use crate::config::ServingConfig;
use crate::mma::{MmaConfig, SimWorld};
use crate::models::{qwen3_32b, qwen_7b_chat};
use crate::serving::{FixedCompute, ModelRegistry, Request, RequestId, ServingEngine};
use crate::sim::Time;
use crate::topology::{h20x8, GpuId, NumaId};
use crate::util::rng::Rng;
use crate::util::table::Table;

/// One co-run's aggregate result.
#[derive(Clone, Copy, Debug)]
pub struct QosRun {
    /// Mean TTFT over all requests, seconds.
    pub mean_ttft: f64,
    /// Worst TTFT, seconds.
    pub worst_ttft: f64,
    /// Wake transfer time, seconds (0 when no wake co-runs).
    pub wake_s: f64,
}

/// Serving knobs for the co-run: pools and batch budget wide enough that
/// admission, not capacity, governs concurrency (same stance as the other
/// serving sweeps).
fn serving_cfg() -> ServingConfig {
    ServingConfig {
        gpu_kv_blocks: 1 << 20,
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 512 * 1024,
        pd_disaggregation: false,
        ..Default::default()
    }
}

/// Run `n` host-tier prefix hits of `ctx` tokens against a gpu0 serving
/// instance, optionally co-running a 32B wake onto gpu4, with QoS on or
/// off. `seed` jitters the arrival spacing so the sweep is not a single
/// phase-locked alignment.
pub fn qos_corun(ctx: u32, with_wake: bool, qos_on: bool, n: usize, seed: u64) -> QosRun {
    let mut mcfg = MmaConfig::default();
    mcfg.qos.enabled = qos_on;
    let world = SimWorld::new(h20x8(), mcfg);
    let mut e = ServingEngine::new(
        serving_cfg(),
        qwen_7b_chat(),
        world,
        Box::new(FixedCompute {
            prefill_s: 0.02,
            decode_s: 0.001,
        }),
        GpuId(0),
        NumaId(0),
    );
    let mut rng = Rng::seed_from_u64(seed);
    // Distinct documents so every request pays a host fetch (no GPU-tier
    // hits hiding the bandwidth story).
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() | 1).collect();
    for &k in &keys {
        e.seed_host_prefix(k, ctx);
    }
    // Park the 32B model host-side; its wake starts just before the first
    // request arrives — the PR 2/3 wake-co-run scenario.
    let mut reg = ModelRegistry::new(NumaId(1));
    let m = reg.register(qwen3_32b(), vec![GpuId(4)]);
    reg.sleep(e.world_mut(), m);
    let t0 = e.now();
    let wake = if with_wake {
        Some(reg.start_wake(e.world_mut(), m))
    } else {
        None
    };
    let reqs: Vec<Request> = keys
        .iter()
        .enumerate()
        .map(|(i, &k)| Request {
            id: RequestId(i as u64 + 1),
            arrival: t0 + Time::from_ms(5 + 60 * i as u64 + rng.range_u64(0, 10)),
            prompt_tokens: ctx + 64,
            cached_prefix_tokens: ctx,
            prefix_key: k,
            output_tokens: 2,
            tenant: 0,
            class: None,
        })
        .collect();
    let out = e.run(reqs);
    let wake_s = match wake {
        Some(w) => w.wait(e.world_mut()).transfer.as_secs_f64(),
        None => 0.0,
    };
    let ttfts: Vec<f64> = out.iter().map(|o| o.ttft_s()).collect();
    QosRun {
        mean_ttft: ttfts.iter().sum::<f64>() / ttfts.len() as f64,
        worst_ttft: ttfts.iter().fold(0.0f64, |a, &b| a.max(b)),
        wake_s,
    }
}

/// The figure: no-wake baseline vs wake co-run with QoS off and on.
pub fn qos_isolation(fast: bool, seed: u64) -> Table {
    let ctx = if fast { 16_384 } else { 32_768 };
    let n = if fast { 4 } else { 6 };
    let base = qos_corun(ctx, false, false, n, seed);
    let off = qos_corun(ctx, true, false, n, seed);
    let on = qos_corun(ctx, true, true, n, seed);
    let mut t = Table::new([
        "scenario",
        "mean TTFT (s)",
        "worst TTFT (s)",
        "wake transfer (s)",
    ]);
    let row = |t: &mut Table, name: &str, r: &QosRun, wake: bool| {
        t.row([
            name.to_string(),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.worst_ttft),
            if wake {
                format!("{:.3}", r.wake_s)
            } else {
                "-".to_string()
            },
        ]);
    };
    row(&mut t, "no wake (baseline)", &base, false);
    row(&mut t, "wake co-run, qos off", &off, true);
    row(&mut t, "wake co-run, qos on", &on, true);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = crate::figures::DEFAULT_SEED;

    #[test]
    fn qos_protects_ttft_under_corunning_wake() {
        // The acceptance gate: with QoS on, TTFT under a co-running wake
        // is strictly better than with QoS off, while the wake itself
        // degrades only modestly (it still gets residual bandwidth).
        let base = qos_corun(16_384, false, false, 4, SEED);
        let off = qos_corun(16_384, true, false, 4, SEED);
        let on = qos_corun(16_384, true, true, 4, SEED);
        assert!(
            off.mean_ttft > base.mean_ttft,
            "scenario sanity: the wake must hurt without QoS \
             (base {} vs off {})",
            base.mean_ttft,
            off.mean_ttft
        );
        assert!(
            on.mean_ttft < off.mean_ttft,
            "QoS on must strictly beat QoS off: {} vs {}",
            on.mean_ttft,
            off.mean_ttft
        );
        assert!(off.wake_s > 0.0 && on.wake_s > 0.0, "wake lands either way");
        assert!(
            on.wake_s < 5.0 * off.wake_s,
            "wake completion must degrade only modestly: {} vs {}",
            on.wake_s,
            off.wake_s
        );
    }

    #[test]
    fn qos_corun_is_seed_reproducible() {
        let a = qos_corun(16_384, true, true, 3, SEED);
        let b = qos_corun(16_384, true, true, 3, SEED);
        assert_eq!(a.mean_ttft, b.mean_ttft);
        assert_eq!(a.wake_s, b.wake_s);
    }

    #[test]
    fn figure_renders_three_scenarios() {
        let s = qos_isolation(true, SEED).render();
        for needle in ["no wake", "qos off", "qos on"] {
            assert!(s.contains(needle), "missing {needle:?}:\n{s}");
        }
    }
}
