//! Trace-driven workload replay: feed any [`Trace`] through the serving
//! fleet deterministically and report TTFT, prefix-hit, and fabric-
//! utilization metrics — same trace + same configuration ⇒ byte-identical
//! output.
//!
//! The figure compares arrival shapes at *equal mean rate*: Poisson
//! arrivals (the classic assumption every §5.2-style sweep makes) vs an
//! MMPP burst process, across transfer policies and QoS on/off. Bursts
//! expose queueing tails Poisson hides — the reason the workload layer
//! grew a trace format in the first place.
//!
//! Model-switch traces (`workload::model_switch_trace`) additionally
//! drive [`ModelRegistry`] sleep/wake from the trace's model boundaries:
//! the outgoing model's D2H sleep and the incoming model's H2D wake are
//! issued *mid-replay* on sidecar GPUs, so switch weight traffic contends
//! with live serving fetches on the shared fabric (the paper's sleep-mode
//! switching scenario under realistic load).

use std::io::BufRead;

use crate::config::{FleetConfig, ServingConfig};
use crate::metrics::Summary;
use crate::mma::{MmaConfig, SimWorld};
use crate::models::{self, qwen_7b_chat, ModelSpec};
use crate::serving::{
    compute_from, Compute, ModelRegistry, ModelState, RequestOutcome, RoutePolicy, ServingFleet,
};
use crate::sim::Time;
use crate::topology::{h20x8, Direction, GpuId, NumaId};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::stream::{scan, ArrivalMerger, TraceReader};
use crate::workload::trace::{duration_of, models_of, warm_prefixes_of, TraceRecord};
use crate::workload::{open_trace, ArrivalProcess, Sym, SymbolTable, TenantSpec, Trace, TraceGen};

/// Namespace for replay's model-switch timer tokens ("SWIT" tag), kept
/// out of the fleet's arrival-token namespace.
const SWITCH_TOKEN_BASE: u64 = 0x5357_4954 << 32;

/// Replay options beyond the fleet/serving/MMA configuration.
#[derive(Clone, Debug, Default)]
pub struct ReplayOptions {
    /// Start every instance asleep, so the trace's first arrivals drive
    /// on-demand, non-blocking wakes (cold-start under load).
    pub sleep_all: bool,
    /// Follow the trace's model boundaries: at each switch, sleep the
    /// outgoing model and wake the incoming one on sidecar GPUs,
    /// co-running with the serving traffic.
    pub follow_switches: bool,
    /// Replay only the first N records (0 = all; `mma replay --fast`).
    pub max_requests: usize,
}

/// How a replay ingested its trace: the streaming path's memory story.
/// Like [`ReplayReport::fabric_stats`], deliberately NOT part of
/// [`ReplayReport::render`] — the streamed and materialized paths hold
/// different amounts of memory (that is the point) while rendering
/// byte-identical metrics. `mma bench` reports these.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestStats {
    /// True when requests streamed through the bounded-window arrival
    /// merge (O(window) ingestion memory); false when the trace was
    /// materialized up front (O(trace)).
    pub streamed: bool,
    /// True when streaming was requested but the pre-scan found disorder
    /// beyond the reorder window, forcing the documented materialize-and-
    /// sort spill path.
    pub spilled: bool,
    /// The reorder window the streaming path ran (or would run) with.
    pub reorder_window: usize,
    /// Most records the merge window ever held (≤ `reorder_window + 1`).
    pub peak_window: usize,
    /// Peak bytes of ingestion state: merge-window records plus the
    /// streaming reader's line buffer. Zero on the materialized path.
    pub peak_tracked_bytes: u64,
}

/// Aggregate result of one replay run. All fields derive from the
/// deterministic simulation, so [`Self::render`] is byte-stable.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// Requests replayed.
    pub requests: usize,
    /// Trace span (last arrival), seconds.
    pub trace_span_s: f64,
    /// Makespan (last request fully finished), seconds.
    pub makespan_s: f64,
    /// Mean TTFT, seconds.
    pub mean_ttft: f64,
    /// Median TTFT, seconds.
    pub p50_ttft: f64,
    /// p99 TTFT, seconds.
    pub p99_ttft: f64,
    /// Admitted prefills that reused a cached prefix.
    pub prefix_hits: u64,
    /// Admitted prefills that ran cold.
    pub prefix_misses: u64,
    /// Host-tier fetches across the fleet.
    pub host_fetches: u64,
    /// Peer-NVLink fetches across the fleet.
    pub peer_fetches: u64,
    /// Bytes moved by host-tier fetches.
    pub host_fetch_bytes: u64,
    /// Mean host-PCIe utilization of the serving lanes over the makespan
    /// (host fetch bytes / (makespan × per-lane H2D capacity × lanes)).
    pub pcie_utilization: f64,
    /// Requests routed to each instance.
    pub per_instance: Vec<u32>,
    /// Per-tenant `(tenant, requests, mean TTFT s)`, ascending tenant.
    pub per_tenant: Vec<(u32, usize, f64)>,
    /// On-demand instance wakes (the `sleep_all` path).
    pub wakes: usize,
    /// Model switches performed (the `follow_switches` path).
    pub switches: usize,
    /// Total switch weight-transfer time, seconds.
    pub switch_transfer_s: f64,
    /// Fabric allocator work counters for the run. Deliberately NOT part
    /// of [`Self::render`]: the incremental and reference allocators do
    /// different amounts of work (that is the point) while rendering
    /// byte-identical metrics. `mma bench hotpath` reports these.
    pub fabric_stats: crate::fabric::FabricStats,
    /// Trace-ingestion stats (streamed vs materialized, peak bytes).
    /// Also excluded from [`Self::render`]; `mma bench` reports these.
    pub ingest: IngestStats,
}

impl ReplayReport {
    /// Prefix-hit rate over admitted prefills.
    pub fn hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// The deterministic metrics block `mma replay` prints. Same trace +
    /// same seed/config ⇒ byte-identical text (the acceptance gate).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests          {}\ntrace span        {:.6} s\nmakespan          {:.6} s\n",
            self.requests, self.trace_span_s, self.makespan_s
        ));
        s.push_str(&format!(
            "ttft mean/p50/p99 {:.6} / {:.6} / {:.6} s\n",
            self.mean_ttft, self.p50_ttft, self.p99_ttft
        ));
        s.push_str(&format!(
            "prefix hits       {} / {} ({:.1}%)\n",
            self.prefix_hits,
            self.prefix_hits + self.prefix_misses,
            100.0 * self.hit_rate()
        ));
        s.push_str(&format!(
            "fetches           {} host ({} B), {} peer\n",
            self.host_fetches, self.host_fetch_bytes, self.peer_fetches
        ));
        s.push_str(&format!(
            "pcie utilization  {:.1}%\nper-instance      {:?}\n",
            100.0 * self.pcie_utilization,
            self.per_instance
        ));
        for (t, n, ttft) in &self.per_tenant {
            s.push_str(&format!(
                "tenant {t:<3} {n:>5} requests, mean ttft {ttft:.6} s\n"
            ));
        }
        if self.wakes > 0 {
            s.push_str(&format!("on-demand wakes   {}\n", self.wakes));
        }
        if self.switches > 0 {
            s.push_str(&format!(
                "model switches    {} (transfer {:.6} s total)\n",
                self.switches, self.switch_transfer_s
            ));
        }
        s
    }
}

/// Widen an existing `[serving]` configuration for a replay run: pools
/// and batch budget grow so admission, not capacity, governs the
/// measured concurrency; every other knob (tp, block sizes, PD mode,
/// fetch chunking ...) is honored as configured.
pub fn replay_serving_from(base: &ServingConfig) -> ServingConfig {
    ServingConfig {
        gpu_kv_blocks: 1 << 20, // clamped to HBM by the instance
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 512 * 1024,
        ..base.clone()
    }
}

/// Default replay serving config: [`replay_serving_from`] the defaults,
/// in aggregated (non-PD) mode so promoted prefixes stay GPU-resident
/// and peer-fetchable (same stance as the other serving sweeps).
pub fn replay_serving() -> ServingConfig {
    ServingConfig {
        pd_disaggregation: false,
        ..replay_serving_from(&ServingConfig::default())
    }
}

fn build_fleet(
    model: &ModelSpec,
    mma: MmaConfig,
    serving: ServingConfig,
    fleet_cfg: FleetConfig,
) -> ServingFleet {
    let world = SimWorld::new(h20x8(), mma);
    // `[compute] source` picks the cost model: "legacy" is the seed
    // per-request view (byte-identical to pre-batching replays),
    // "roofline" the batch-aware fused-step H20 roofline.
    let computes: Vec<Box<dyn Compute>> = (0..fleet_cfg.gpus)
        .map(|_| compute_from(serving.compute))
        .collect();
    ServingFleet::new(
        fleet_cfg,
        serving,
        model.clone(),
        world,
        computes,
        NumaId(0),
    )
}

/// Replay `trace` through a serving fleet. Deterministic: the trace
/// fixes arrivals, the simulation fixes everything else. Works on a
/// borrowed record slice — `--max` truncation never clones a record.
pub fn replay(
    trace: &Trace,
    model: &ModelSpec,
    mma: MmaConfig,
    serving: ServingConfig,
    fleet_cfg: FleetConfig,
    opts: &ReplayOptions,
) -> ReplayReport {
    let records: &[TraceRecord] = if opts.max_requests > 0 {
        &trace.records[..opts.max_requests.min(trace.records.len())]
    } else {
        &trace.records
    };
    let mut f = build_fleet(model, mma, serving, fleet_cfg);
    // Warm state the trace claims a previous session left in the host
    // tier: seed it before the first arrival, tenant-namespaced.
    for (tenant, key, tokens) in warm_prefixes_of(records) {
        f.seed_tenant_prefix(tenant, key, tokens);
    }
    if opts.sleep_all {
        for i in 0..f.instance_count() {
            f.sleep_instance(i);
        }
    }

    // Model-switch schedule: every boundary where consecutive arrivals
    // change model becomes a world timer; the hook sleeps the outgoing
    // model and wakes the incoming one on sidecar GPUs (top of the GPU
    // range, away from the serving instances when the fleet leaves room).
    let mut reg = ModelRegistry::new(NumaId(0));
    let mut boundaries: Vec<(usize, usize)> = Vec::new(); // (from, to) model idx
    let mut boundary_times: Vec<f64> = Vec::new();
    let mut phases = Vec::new();
    if opts.follow_switches {
        let names = models_of(records);
        if names.len() > 1 {
            let gpu_count = f.world.topo.gpu_count();
            // Intern every model name once (symbol k == registry index k);
            // the per-record boundary scan below then compares u32 symbols
            // instead of string-comparing and position-searching per pair.
            let mut syms = SymbolTable::new();
            for (k, name) in names.iter().enumerate() {
                let s = syms.intern(name);
                debug_assert_eq!(s.0 as usize, k);
                let spec = models::by_name(name).unwrap_or_else(|| model.clone());
                let gpu = GpuId((gpu_count - 1 - (k % gpu_count)) as u8);
                reg.register(spec, vec![gpu]);
            }
            let mut sorted: Vec<&TraceRecord> = records.iter().collect();
            sorted.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            let rec_syms: Vec<Sym> = sorted.iter().map(|r| syms.intern(&r.model)).collect();
            // Everything but the first phase's model starts host-side.
            if let Some(&first) = rec_syms.first() {
                for k in 0..names.len() {
                    if k != first.0 as usize {
                        reg.sleep(&mut f.world, k);
                    }
                }
            }
            for (i, w) in rec_syms.windows(2).enumerate() {
                if w[1] != w[0] {
                    boundaries.push((w[0].0 as usize, w[1].0 as usize));
                    boundary_times.push(sorted[i + 1].arrival_s);
                }
            }
        }
    }

    // Setup (initial sleeps) ran on the shared clock, so trace time 0 is
    // *now*: offset every arrival and switch timer by it, keeping the
    // trace's relative schedule exact.
    let t0 = f.now();
    for (i, &bt) in boundary_times.iter().enumerate() {
        let token = SWITCH_TOKEN_BASE | i as u64;
        f.world.schedule_timer(t0 + Time::from_secs_f64(bt), token);
    }
    let reqs: Vec<_> = records
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut q = r.to_request(i as u64);
            q.arrival = t0 + q.arrival;
            q
        })
        .collect();
    let mut switches = 0usize;
    let out = f.run_with(reqs, |world, token| {
        if (token & SWITCH_TOKEN_BASE) != SWITCH_TOKEN_BASE {
            return;
        }
        let idx = (token ^ SWITCH_TOKEN_BASE) as usize;
        let Some(&(from, to)) = boundaries.get(idx) else {
            return;
        };
        // The registry flips residency at issue time, so the guards hold
        // even while an earlier phase's transfers are still in flight
        // (the flights just contend — that is the point).
        if reg.instance(from).state == ModelState::Active {
            phases.push(reg.start_sleep(world, from));
        }
        if reg.instance(to).state == ModelState::Asleep {
            phases.push(reg.start_wake(world, to));
            switches += 1;
        }
    });

    // Drain any switch phases still in flight so their cost is complete.
    let mut switch_transfer_s = 0.0;
    for p in &phases {
        switch_transfer_s += p.wait(&mut f.world).transfer.as_secs_f64();
    }

    let tenants: Vec<u32> = records.iter().map(|r| r.tenant).collect();
    finish_report(
        &f,
        t0,
        &out,
        &tenants,
        duration_of(records),
        switches,
        switch_transfer_s,
        IngestStats::default(),
    )
}

/// Aggregate a finished run into a [`ReplayReport`]. Shared by the
/// materialized and streamed paths: `outcomes` and `tenants` are in
/// *record* order (request id order), so both paths sum TTFTs in the
/// same sequence and render byte-identically.
#[allow(clippy::too_many_arguments)]
fn finish_report(
    f: &ServingFleet,
    t0: Time,
    outcomes: &[RequestOutcome],
    tenants: &[u32],
    trace_span_s: f64,
    switches: usize,
    switch_transfer_s: f64,
    ingest: IngestStats,
) -> ReplayReport {
    let mut ttft = Summary::new();
    let mut makespan = 0.0f64;
    let mut tenant_sums: Vec<(u32, usize, f64)> = Vec::new();
    for (o, &tenant) in outcomes.iter().zip(tenants) {
        ttft.record(o.ttft_s());
        if let Some(fin) = o.finished_at {
            // Relative to trace start (t0), like every other metric.
            makespan = makespan.max(fin.since(t0).as_secs_f64());
        }
        match tenant_sums.iter_mut().find(|(t, _, _)| *t == tenant) {
            Some((_, n, sum)) => {
                *n += 1;
                *sum += o.ttft_s();
            }
            None => tenant_sums.push((tenant, 1, o.ttft_s())),
        }
    }
    tenant_sums.sort_by_key(|(t, _, _)| *t);
    let per_tenant = tenant_sums
        .into_iter()
        .map(|(t, n, sum)| (t, n, sum / n.max(1) as f64))
        .collect();

    let (prefix_hits, prefix_misses) = f.prefix_hit_counts();
    let (host_fetches, peer_fetches) = f.fetch_counts();
    let (host_fetch_bytes, _peer_bytes) = f.fetch_bytes();
    let lane_bps = f.world.topo.pcie_capacity(GpuId(0), Direction::H2D);
    let lanes = f.instance_count() as f64;
    let pcie_utilization = if makespan > 0.0 {
        host_fetch_bytes as f64 / (makespan * lane_bps * lanes)
    } else {
        0.0
    };
    ReplayReport {
        requests: outcomes.len(),
        trace_span_s,
        makespan_s: makespan,
        mean_ttft: ttft.mean(),
        p50_ttft: ttft.p50(),
        p99_ttft: ttft.p99(),
        prefix_hits,
        prefix_misses,
        host_fetches,
        peer_fetches,
        host_fetch_bytes,
        pcie_utilization,
        per_instance: f.per_instance_counts(),
        per_tenant,
        wakes: f.wake_costs.len(),
        switches,
        switch_transfer_s,
        fabric_stats: f.world.fabric.stats(),
        ingest,
    }
}

/// Streaming replay: two passes over a re-openable trace source, holding
/// O(reorder window) records instead of the whole trace.
///
/// Pass 1 ([`scan`]) learns the request count, span, warm prefixes, and
/// whether `reorder_window` suffices. Pass 2 streams records through an
/// [`ArrivalMerger`] straight into [`ServingFleet::run_streamed`].
/// When the window is exceeded — or `--follow-switches` needs the whole
/// trace for its boundary scan — this falls back to the documented spill
/// path: [`Trace`]-materialize and run the exact [`replay`]. Either way
/// the rendered report is byte-identical to the materialized path; only
/// `ingest` (and peak memory) differ.
pub fn replay_streamed<R, F>(
    mut open: F,
    model: &ModelSpec,
    mma: MmaConfig,
    serving: ServingConfig,
    fleet_cfg: FleetConfig,
    opts: &ReplayOptions,
    reorder_window: usize,
) -> Result<ReplayReport, String>
where
    R: BufRead,
    F: FnMut() -> Result<TraceReader<R>, String>,
{
    let max = (opts.max_requests > 0).then_some(opts.max_requests);
    let materialize = |open: &mut F| -> Result<Trace, String> {
        let records: Result<Vec<TraceRecord>, String> = open()?.collect();
        Ok(Trace { records: records? })
    };
    if opts.follow_switches {
        // The model-boundary scan needs every record, time-sorted: spill
        // by design (not a window failure).
        let trace = materialize(&mut open)?;
        let mut report = replay(&trace, model, mma, serving, fleet_cfg, opts);
        report.ingest.reorder_window = reorder_window;
        return Ok(report);
    }
    let info = scan(open()?, max, reorder_window)?;
    if !info.sorted_within_window {
        let trace = materialize(&mut open)?;
        let mut report = replay(&trace, model, mma, serving, fleet_cfg, opts);
        report.ingest.spilled = true;
        report.ingest.reorder_window = reorder_window;
        return Ok(report);
    }

    let mut f = build_fleet(model, mma, serving, fleet_cfg);
    for &(tenant, key, tokens) in &info.warm {
        f.seed_tenant_prefix(tenant, key, tokens);
    }
    if opts.sleep_all {
        for i in 0..f.instance_count() {
            f.sleep_instance(i);
        }
    }
    let t0 = f.now();

    let n = info.requests;
    let cap = max.unwrap_or(usize::MAX);
    let mut rdr = open()?;
    let mut merger = ArrivalMerger::new(reorder_window);
    let mut tenants = vec![0u32; n];
    let mut seq = 0usize;
    let mut input_done = false;
    let make_req = |s: u64, r: TraceRecord| {
        let mut q = r.to_request(s);
        q.arrival = t0 + q.arrival;
        q
    };
    let requests = std::iter::from_fn(|| loop {
        if input_done {
            let (s, rec) = merger.pop()?;
            return Some(make_req(s, rec));
        }
        if seq >= cap {
            input_done = true;
            continue;
        }
        match rdr.next() {
            None => input_done = true,
            // Pass 1 validated every consumed line; a failure here means
            // the source changed between the passes.
            Some(Err(e)) => panic!("trace changed between replay passes: {e}"),
            Some(Ok(rec)) => {
                tenants[seq] = rec.tenant;
                let emitted = merger.push(seq as u64, rec);
                seq += 1;
                if let Some((s, rec)) = emitted {
                    return Some(make_req(s, rec));
                }
            }
        }
    });
    let out = f.run_streamed(requests, |_, _| {});

    // run_streamed returns arrival order; the report aggregates in
    // record (id) order, exactly like the materialized path.
    let mut by_id: Vec<Option<RequestOutcome>> = vec![None; n];
    for o in out {
        by_id[o.id.0 as usize] = Some(o);
    }
    let ordered: Vec<RequestOutcome> = by_id
        .into_iter()
        .map(|o| o.expect("every streamed request has an outcome"))
        .collect();
    let ingest = IngestStats {
        streamed: true,
        spilled: false,
        reorder_window,
        peak_window: merger.peak_entries(),
        peak_tracked_bytes: merger.peak_bytes() + rdr.line_buffer_bytes(),
    };
    Ok(finish_report(
        &f,
        t0,
        &ordered,
        &tenants,
        info.duration_s,
        0,
        0.0,
        ingest,
    ))
}

/// [`replay_streamed`] over a trace file path (`mma replay`'s default
/// ingestion). Opens the file twice: once to scan, once to stream.
pub fn replay_path(
    path: &str,
    model: &ModelSpec,
    mma: MmaConfig,
    serving: ServingConfig,
    fleet_cfg: FleetConfig,
    opts: &ReplayOptions,
    reorder_window: usize,
) -> Result<ReplayReport, String> {
    replay_streamed(
        || open_trace(path),
        model,
        mma,
        serving,
        fleet_cfg,
        opts,
        reorder_window,
    )
    .map_err(|e| {
        // `open_trace` labels IO errors with the path already; record
        // parse errors carry only a line number, so label them here —
        // the CLI error text must match `Trace::load` byte for byte.
        if e.starts_with("read ") {
            e
        } else {
            format!("{path}: {e}")
        }
    })
}

/// The figure's two-tenant mix: tenant 1 is an interactive chat tenant
/// (latency-critical fetches), tenant 2 a batch tenant tagged `bulk` —
/// the class dimension QoS acts on. Warm-start (documents ingested by a
/// previous session) puts every fetch on the host tier, the
/// bandwidth-bound regime the paper studies.
fn figure_tenants(context: u32, docs: usize) -> Vec<TenantSpec> {
    let mut chat = TenantSpec::interactive(1, docs, context);
    chat.share = 2.0;
    chat.warm_start = true;
    let mut batch = TenantSpec::interactive(2, docs, context);
    batch.share = 1.0;
    batch.class = Some(crate::mma::TransferClass::Bulk);
    batch.warm_start = true;
    vec![chat, batch]
}

/// One figure cell: generate the trace for `arrivals` and replay it.
fn figure_cell(
    arrivals: ArrivalProcess,
    context: u32,
    docs: usize,
    requests: usize,
    gpus: u32,
    mma: MmaConfig,
    seed: u64,
) -> ReplayReport {
    let gen = TraceGen {
        arrivals,
        tenants: figure_tenants(context, docs),
        requests,
    };
    let trace = gen.generate(&mut Rng::seed_from_u64(seed));
    let fleet = FleetConfig {
        gpus,
        router: RoutePolicy::RoundRobin,
        peer_fetch: true,
        prefix_affinity: false,
    };
    replay(
        &trace,
        &qwen_7b_chat(),
        mma,
        replay_serving(),
        fleet,
        &ReplayOptions::default(),
    )
}

/// The sweep: TTFT mean/p99 + prefix-hit + PCIe-utilization per arrival
/// shape × policy × QoS, at *equal mean offered rate* across shapes.
pub fn workload_replay(fast: bool, seed: u64) -> Table {
    let context = if fast { 8_192 } else { 16_384 };
    let docs = if fast { 4 } else { 8 };
    let requests = if fast { 32 } else { 96 };
    let gpus = if fast { 2 } else { 4 };
    let rate = if fast { 24.0 } else { 16.0 };
    let shapes: [(&str, ArrivalProcess); 2] = [
        ("poisson", ArrivalProcess::Poisson { rate_rps: rate }),
        ("bursty", ArrivalProcess::bursty(rate, 0.9, 2.0)),
    ];
    let mut t = Table::new([
        "arrivals",
        "policy",
        "qos",
        "mean TTFT (s)",
        "p99 TTFT (s)",
        "hit rate",
        "pcie util",
        "host/peer fetches",
    ]);
    for (shape_name, shape) in shapes {
        for (policy_name, mma, qos) in [
            ("native", MmaConfig::native(), false),
            ("mma-greedy", MmaConfig::default(), false),
            ("mma-greedy", MmaConfig::default(), true),
        ] {
            let mut mma = mma;
            mma.qos.enabled = qos;
            let r = figure_cell(
                shape.clone(),
                context,
                docs,
                requests,
                gpus,
                mma,
                seed,
            );
            t.row([
                shape_name.to_string(),
                policy_name.to_string(),
                if qos { "on" } else { "off" }.to_string(),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.0}%", 100.0 * r.hit_rate()),
                format!("{:.0}%", 100.0 * r.pcie_utilization),
                format!("{}/{}", r.host_fetches, r.peer_fetches),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::model_switch_trace;

    const SEED: u64 = crate::figures::DEFAULT_SEED;

    fn small_cell(shape: ArrivalProcess) -> ReplayReport {
        figure_cell(shape, 8_192, 4, 40, 2, MmaConfig::native(), SEED)
    }

    #[test]
    fn bursty_arrivals_raise_the_tail_at_equal_mean_rate() {
        // The acceptance gate: same mean offered rate, same service
        // capacity — the MMPP trace's queueing tail must clearly exceed
        // the Poisson one.
        let poisson = small_cell(ArrivalProcess::Poisson { rate_rps: 20.0 });
        let bursty = small_cell(ArrivalProcess::bursty(20.0, 0.9, 2.0));
        assert_eq!(poisson.requests, 40);
        assert!(
            bursty.p99_ttft > 1.2 * poisson.p99_ttft,
            "bursts must expose a queueing tail: bursty p99 {} vs poisson p99 {}",
            bursty.p99_ttft,
            poisson.p99_ttft
        );
        // Both shapes reuse prefixes (the Zipf mix) and move host bytes.
        assert!(poisson.hit_rate() > 0.2, "hit rate {}", poisson.hit_rate());
        assert!(poisson.host_fetch_bytes > 0);
        assert!(poisson.pcie_utilization > 0.0 && poisson.pcie_utilization <= 1.0);
    }

    #[test]
    fn replay_is_deterministic_to_the_byte() {
        let a = small_cell(ArrivalProcess::bursty(20.0, 0.9, 2.0));
        let b = small_cell(ArrivalProcess::bursty(20.0, 0.9, 2.0));
        assert_eq!(a.render(), b.render(), "same trace+seed ⇒ identical metrics");
    }

    #[test]
    fn incremental_alloc_matches_reference_to_the_byte() {
        // The tentpole's hard constraint: the optimized (incremental,
        // component-scoped) fabric allocator and the reference full
        // re-solve must produce byte-identical replay output — through
        // the full stack (fleet, engines, QoS, prefix fetches).
        let shape = ArrivalProcess::bursty(20.0, 0.9, 2.0);
        let mut reference = MmaConfig::default();
        reference.incremental_alloc = false;
        let opt = figure_cell(shape.clone(), 8_192, 4, 40, 2, MmaConfig::default(), SEED);
        let refr = figure_cell(shape, 8_192, 4, 40, 2, reference, SEED);
        assert_eq!(
            opt.render(),
            refr.render(),
            "incremental allocator changed simulation output"
        );
    }

    #[test]
    fn coalesce_solves_matches_eager_to_the_byte() {
        // The solve-coalescing analogue of the incremental-allocator
        // constraint: deferring same-timestamp fabric recomputes to one
        // batch solve must produce byte-identical replay output through
        // the full stack (fleet, engines, QoS, prefix fetches).
        let shape = ArrivalProcess::bursty(20.0, 0.9, 2.0);
        let mut eager = MmaConfig::default();
        eager.coalesce_solves = false;
        let coal = figure_cell(shape.clone(), 8_192, 4, 40, 2, MmaConfig::default(), SEED);
        let eag = figure_cell(shape, 8_192, 4, 40, 2, eager, SEED);
        assert_eq!(
            coal.render(),
            eag.render(),
            "solve coalescing changed simulation output"
        );
    }

    #[test]
    fn continuous_batching_batch1_matches_per_request_oracle() {
        // The oracle gate (ISSUE 10): continuous batching with batch
        // size 1 + chunking off forms one-leg fused steps whose
        // durations, streams, and admission order are exactly the
        // per-request scheduler's — so under legacy costs the rendered
        // replay must be byte-identical. The seed scheduler survives as
        // the oracle, same pattern as the incremental-allocator and
        // solve-coalescing gates above.
        use crate::config::{BatchingConfig, ComputeSource};
        let gen = TraceGen {
            arrivals: ArrivalProcess::bursty(20.0, 0.9, 2.0),
            tenants: figure_tenants(8_192, 4),
            requests: 40,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        let per_request = ServingConfig {
            max_batch_seqs: 1,
            max_concurrency: 1,
            compute: ComputeSource::Legacy,
            ..replay_serving()
        };
        let batched = ServingConfig {
            batching: BatchingConfig {
                enabled: true,
                chunk_tokens: 0,
            },
            ..per_request.clone()
        };
        let opts = ReplayOptions::default();
        let base = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            per_request,
            replay_fleet(2),
            &opts,
        );
        let cb = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            batched,
            replay_fleet(2),
            &opts,
        );
        assert_eq!(
            cb.render(),
            base.render(),
            "batch-1/chunk-off continuous batching diverged from the per-request oracle"
        );
    }

    #[test]
    fn roofline_costs_change_replay_but_stay_deterministic() {
        // Flipping `[compute] source` to the batch-aware roofline must
        // actually change the simulation (otherwise the wiring is dead)
        // while staying byte-deterministic run-to-run.
        use crate::config::{BatchingConfig, ComputeSource};
        let gen = TraceGen {
            arrivals: ArrivalProcess::bursty(20.0, 0.9, 2.0),
            tenants: figure_tenants(8_192, 4),
            requests: 40,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        let roofline = ServingConfig {
            compute: ComputeSource::Roofline,
            batching: BatchingConfig {
                enabled: true,
                chunk_tokens: 2048,
            },
            ..replay_serving()
        };
        let opts = ReplayOptions::default();
        let run = |cfg: ServingConfig| {
            replay(
                &trace,
                &qwen_7b_chat(),
                MmaConfig::native(),
                cfg,
                replay_fleet(2),
                &opts,
            )
        };
        let a = run(roofline.clone());
        let b = run(roofline);
        assert_eq!(a.render(), b.render(), "roofline replay must be deterministic");
        let legacy = run(replay_serving());
        assert_ne!(
            a.render(),
            legacy.render(),
            "batch-aware roofline costs must change the replay"
        );
    }

    #[test]
    fn sleep_all_records_on_demand_wakes() {
        let gen = TraceGen {
            arrivals: ArrivalProcess::Poisson { rate_rps: 10.0 },
            tenants: vec![TenantSpec::interactive(0, 2, 4_096)],
            requests: 8,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        let fleet = FleetConfig {
            gpus: 2,
            router: RoutePolicy::RoundRobin,
            peer_fetch: true,
            prefix_affinity: false,
        };
        let opts = ReplayOptions {
            sleep_all: true,
            ..Default::default()
        };
        let r = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            fleet,
            &opts,
        );
        assert!(r.wakes >= 1, "cold-start replay must wake instances");
        assert_eq!(r.requests, 8);
        assert!(r.render().contains("on-demand wakes"));
    }

    #[test]
    fn model_switch_trace_drives_registry_phases() {
        let models = vec!["qwen-7b-chat".to_string(), "qwen3-4b".to_string()];
        let trace = model_switch_trace(
            &mut Rng::seed_from_u64(SEED),
            &models,
            6.0,
            2.0,
            4_096,
            36,
        );
        let fleet = FleetConfig {
            gpus: 2,
            router: RoutePolicy::RoundRobin,
            peer_fetch: true,
            prefix_affinity: false,
        };
        let opts = ReplayOptions {
            follow_switches: true,
            ..Default::default()
        };
        let r = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            fleet,
            &opts,
        );
        assert!(r.switches >= 1, "model boundaries must trigger switches");
        assert!(
            r.switch_transfer_s > 0.0,
            "switch weight movement must cost transfer time"
        );
        assert!(r.render().contains("model switches"));
        // Deterministic too.
        let r2 = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            fleet,
            &opts,
        );
        assert_eq!(r.render(), r2.render());
    }

    fn stream_from(text: &str) -> impl FnMut() -> Result<TraceReader<std::io::Cursor<Vec<u8>>>, String> + '_ {
        move || Ok(TraceReader::new(std::io::Cursor::new(text.as_bytes().to_vec())))
    }

    fn replay_fleet(gpus: u32) -> FleetConfig {
        crate::testkit::fleet_config(gpus, true)
    }

    #[test]
    fn streamed_replay_is_byte_identical_to_materialized() {
        // The tentpole gate: the O(window) streaming path and the
        // O(trace) materialized path must render the same bytes — across
        // arrival shapes, warm prefixes, multi-tenant mixes, and `--max`.
        for (requests, max_requests) in [(40usize, 0usize), (40, 13)] {
            let gen = TraceGen {
                arrivals: ArrivalProcess::bursty(20.0, 0.9, 2.0),
                tenants: figure_tenants(8_192, 4),
                requests,
            };
            let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
            let text = trace.render();
            let opts = ReplayOptions {
                max_requests,
                ..Default::default()
            };
            let base = replay(
                &trace,
                &qwen_7b_chat(),
                MmaConfig::native(),
                replay_serving(),
                replay_fleet(2),
                &opts,
            );
            let streamed = replay_streamed(
                stream_from(&text),
                &qwen_7b_chat(),
                MmaConfig::native(),
                replay_serving(),
                replay_fleet(2),
                &opts,
                1024,
            )
            .unwrap();
            assert_eq!(
                streamed.render(),
                base.render(),
                "streamed vs materialized (max={max_requests})"
            );
            assert!(streamed.ingest.streamed);
            assert!(!streamed.ingest.spilled);
            assert!(streamed.ingest.peak_window <= 1025);
            assert!(streamed.ingest.peak_tracked_bytes > 0);
        }
    }

    #[test]
    fn streamed_replay_spills_when_window_too_small() {
        // Generator traces are emitted in arrival order per tenant but
        // interleaved across tenants; window 0 forces the spill path,
        // which must still render identically.
        let gen = TraceGen {
            arrivals: ArrivalProcess::bursty(20.0, 0.9, 2.0),
            tenants: figure_tenants(8_192, 4),
            requests: 24,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        // Force disorder the window cannot hold by prepending a late
        // record at the end of the file.
        let mut shuffled = trace.clone();
        let first = shuffled.records.remove(0);
        shuffled.records.push(first);
        let text = shuffled.render();
        let opts = ReplayOptions::default();
        let base = replay(
            &shuffled,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
        );
        let streamed = replay_streamed(
            stream_from(&text),
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
            1,
        )
        .unwrap();
        assert_eq!(streamed.render(), base.render(), "spill path must match");
        assert!(!streamed.ingest.streamed);
        assert!(streamed.ingest.spilled);
    }

    #[test]
    fn streamed_replay_supports_sleep_all() {
        let gen = TraceGen {
            arrivals: ArrivalProcess::Poisson { rate_rps: 10.0 },
            tenants: vec![TenantSpec::interactive(0, 2, 4_096)],
            requests: 8,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        let text = trace.render();
        let opts = ReplayOptions {
            sleep_all: true,
            ..Default::default()
        };
        let base = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
        );
        let streamed = replay_streamed(
            stream_from(&text),
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
            256,
        )
        .unwrap();
        assert_eq!(streamed.render(), base.render());
        assert!(streamed.render().contains("on-demand wakes"));
    }

    #[test]
    fn follow_switches_takes_the_materialized_path() {
        let models = vec!["qwen-7b-chat".to_string(), "qwen3-4b".to_string()];
        let trace = model_switch_trace(
            &mut Rng::seed_from_u64(SEED),
            &models,
            6.0,
            2.0,
            4_096,
            36,
        );
        let text = trace.render();
        let opts = ReplayOptions {
            follow_switches: true,
            ..Default::default()
        };
        let base = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
        );
        let streamed = replay_streamed(
            stream_from(&text),
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            replay_fleet(2),
            &opts,
            256,
        )
        .unwrap();
        assert_eq!(streamed.render(), base.render());
        assert!(!streamed.ingest.streamed, "switch replay materializes");
        assert!(streamed.switches >= 1);
    }

    #[test]
    fn max_requests_truncates() {
        let gen = TraceGen {
            arrivals: ArrivalProcess::Poisson { rate_rps: 10.0 },
            tenants: vec![TenantSpec::interactive(0, 2, 4_096)],
            requests: 20,
        };
        let trace = gen.generate(&mut Rng::seed_from_u64(SEED));
        let fleet = FleetConfig {
            gpus: 1,
            router: RoutePolicy::RoundRobin,
            peer_fetch: false,
            prefix_affinity: false,
        };
        let opts = ReplayOptions {
            max_requests: 5,
            ..Default::default()
        };
        let r = replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            fleet,
            &opts,
        );
        assert_eq!(r.requests, 5);
    }

    #[test]
    fn figure_renders_both_shapes() {
        let s = workload_replay(true, SEED).render();
        for needle in ["poisson", "bursty", "native", "mma-greedy"] {
            assert!(s.contains(needle), "missing {needle}:\n{s}");
        }
    }
}
