//! Microbenchmark figures: Fig 7/8/14/15/16 and Table 2 (§5.1.1, §5.3, §6).

use crate::mma::{MmaConfig, SimWorld, TransferClass, TransferDesc};

use crate::topology::{h20x8, Direction, GpuId, NumaId};
use crate::util::table::Table;

/// Measure the host-visible bandwidth (B/s) of one async copy.
pub fn measure_bw(dir: Direction, bytes: u64, cfg: MmaConfig) -> f64 {
    let mut w = SimWorld::new(h20x8(), cfg);
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, TransferDesc::new(dir, GpuId(0), NumaId(0), bytes));
    w.run_until_transfer(t);
    w.rec(t).bandwidth().unwrap_or(0.0)
}

/// MMA config restricted to the first `n` relays (NUMA-local first).
pub fn mma_with_relays(n: usize) -> MmaConfig {
    let topo = h20x8();
    let relays: Vec<GpuId> = topo
        .relay_order(GpuId(0), &[])
        .into_iter()
        .take(n)
        .collect();
    MmaConfig::with_relays(relays)
}

/// Fig 7: H2D/D2H bandwidth vs transfer size, MMA vs native.
pub fn fig7_bw_vs_size(fast: bool) -> Table {
    let sizes: &[u64] = if fast {
        &[1 << 20, 10 << 20, 100 << 20, 1 << 30, 4 << 30]
    } else {
        &[
            1 << 10,
            16 << 10,
            256 << 10,
            1 << 20,
            5 << 20,
            10 << 20,
            20 << 20,
            50 << 20,
            100 << 20,
            256 << 20,
            512 << 20,
            1 << 30,
            2 << 30,
            4u64 << 30,
            8u64 << 30,
        ]
    };
    let mut t = Table::new([
        "size",
        "H2D native",
        "H2D MMA",
        "H2D x",
        "D2H native",
        "D2H MMA",
        "D2H x",
    ]);
    for &b in sizes {
        let mut cells = vec![crate::util::fmt::bytes(b)];
        for dir in [Direction::H2D, Direction::D2H] {
            let native = measure_bw(dir, b, MmaConfig::native());
            let mma = measure_bw(dir, b, MmaConfig::default());
            cells.push(format!("{:.1}", native / 1e9));
            cells.push(format!("{:.1}", mma / 1e9));
            cells.push(format!("{:.2}x", mma / native));
        }
        t.row(cells);
    }
    t
}

/// Fig 8: bandwidth vs number of relay paths (saturation at ~6 relays).
pub fn fig8_bw_vs_paths(fast: bool) -> Table {
    let bytes: u64 = if fast { 1 << 30 } else { 4 << 30 };
    let mut t = Table::new(["relays", "H2D GB/s", "D2H GB/s", "H2D speedup"]);
    let base = measure_bw(Direction::H2D, bytes, MmaConfig::native());
    for n in 0..=7usize {
        let h = measure_bw(Direction::H2D, bytes, mma_with_relays(n));
        let d = measure_bw(Direction::D2H, bytes, mma_with_relays(n));
        t.row([
            n.to_string(),
            format!("{:.1}", h / 1e9),
            format!("{:.1}", d / 1e9),
            format!("{:.2}x", h / base),
        ]);
    }
    t
}

/// Fig 14: bandwidth vs relay availability under TP configurations (§6).
/// TP=k occupies k GPUs with the serving group; the remaining 8-k act as
/// relays. Measured at a moderate transfer size (256 MB — a KV-fetch-scale
/// object under serving conditions), so values sit below Fig 8's 8 GB
/// asymptote, as in the paper.
pub fn fig14_tp_sweep() -> Table {
    let bytes: u64 = 256 << 20;
    let base = measure_bw(Direction::H2D, bytes, MmaConfig::native());
    let mut t = Table::new(["TP", "relays", "H2D GB/s", "speedup"]);
    for tp in [1u32, 2, 4, 8] {
        let relays = 8 - tp as usize; // GPUs outside the serving group
        // The target is gpu0 (inside the group); peers in the group are
        // busy serving and excluded from the relay set.
        let topo = h20x8();
        let busy: Vec<GpuId> = (1..tp as u8).map(GpuId).collect();
        let relay_set: Vec<GpuId> = topo
            .relay_order(GpuId(0), &busy)
            .into_iter()
            .take(relays)
            .collect();
        let bw = measure_bw(Direction::H2D, bytes, MmaConfig::with_relays(relay_set));
        t.row([
            format!("TP={tp}"),
            relays.to_string(),
            format!("{:.1}", bw / 1e9),
            format!("{:.2}x", bw / base),
        ]);
    }
    t
}

/// Fig 15: sensitivity to chunk size and outstanding-queue depth (512 MB).
pub fn fig15_sensitivity(fast: bool) -> Table {
    let bytes: u64 = 512 << 20;
    let chunks: &[u64] = if fast {
        &[1_000_000, 2_810_000, 5_370_000, 16_000_000]
    } else {
        &[
            500_000, 1_000_000, 2_000_000, 2_810_000, 4_000_000, 5_370_000, 8_000_000,
            16_000_000, 32_000_000, 64_000_000,
        ]
    };
    let depths: &[usize] = &[1, 2, 4, 8];
    let mut t = Table::new(["chunk", "depth", "H2D GB/s", "D2H GB/s"]);
    for &c in chunks {
        for &d in depths {
            let cfg = MmaConfig {
                chunk_bytes: c,
                outstanding_depth: d,
                ..Default::default()
            };
            let h = measure_bw(Direction::H2D, bytes, cfg.clone());
            let dd = measure_bw(Direction::D2H, bytes, cfg);
            t.row([
                crate::util::fmt::bytes(c),
                d.to_string(),
                format!("{:.1}", h / 1e9),
                format!("{:.1}", dd / 1e9),
            ]);
        }
    }
    t
}

/// Fig 16: the MMA-vs-native break-even transfer size (5 MB chunks,
/// fallback disabled so the engine runs at every size).
pub fn fig16_fallback() -> Table {
    let sizes: Vec<u64> = (1..=30).map(|m| m * 1_000_000).collect();
    let mut t = Table::new(["size", "dir", "native ms", "MMA ms", "winner"]);
    let mut crossover = [None::<u64>; 2];
    for (di, dir) in [Direction::H2D, Direction::D2H].into_iter().enumerate() {
        for &b in &sizes {
            let timed = |cfg: MmaConfig| {
                let mut w = SimWorld::new(h20x8(), cfg);
                let s = w.stream(GpuId(0));
                let id = w.memcpy_async(s, TransferDesc::new(dir, GpuId(0), NumaId(0), b));
                w.run_until_idle();
                w.rec(id)
                    .released
                    .unwrap_or_else(|| w.rec(id).completed.unwrap())
                    .as_ms_f64()
            };
            let native = timed(MmaConfig::native());
            let cfg = MmaConfig {
                chunk_bytes: 5_000_000,
                ..MmaConfig::default().no_fallback()
            };
            let mma = timed(cfg);
            let winner = if mma < native { "MMA" } else { "native" };
            if mma < native && crossover[di].is_none() {
                crossover[di] = Some(b);
            }
            t.row([
                crate::util::fmt::bytes(b),
                dir.label().to_string(),
                format!("{native:.3}"),
                format!("{mma:.3}"),
                winner.to_string(),
            ]);
        }
    }
    t.row([
        "break-even".to_string(),
        "H2D".to_string(),
        crossover[0]
            .map(|b| crate::util::fmt::bytes(b))
            .unwrap_or_else(|| "none".into()),
        "D2H".to_string(),
        crossover[1]
            .map(|b| crate::util::fmt::bytes(b))
            .unwrap_or_else(|| "none".into()),
    ]);
    t
}

/// Table 2: influence of direct priority on GPU P2P bandwidth.
/// Eight concurrent 1 GB H2D transfers (one per GPU) run under MMA while a
/// P2P probe between two GPUs measures the NVLink fabric.
pub fn table2_direct_priority() -> Table {
    let probe_bw = |with_transfers: Option<bool>| -> f64 {
        let cfg = match with_transfers {
            Some(direct_priority) => MmaConfig {
                direct_priority,
                ..Default::default()
            },
            None => MmaConfig::native(),
        };
        let mut w = SimWorld::new(h20x8(), cfg);
        // The probe: repeated 256 MB P2P copies gpu6 → gpu7.
        let p2p_path = w.topo.p2p(GpuId(6), GpuId(7));
        let probe = w.start_bg_loop(p2p_path, 256 << 20, 24, TransferClass::Background);
        if with_transfers.is_some() {
            for g in 0..8u8 {
                let s = w.stream(GpuId(g));
                let numa = w.topo.numa_of(GpuId(g));
                w.memcpy_async(
                    s,
                    TransferDesc::new(Direction::H2D, GpuId(g), numa, 1 << 30),
                );
            }
        }
        w.run_until_idle();
        let iters = w.bg_iters(probe);
        assert!(iters.len() >= 2);
        // Steady-state: average inter-iteration bandwidth.
        let span = iters.last().unwrap().since(iters[0]).as_secs_f64();
        (iters.len() - 1) as f64 * (256u64 << 20) as f64 / span
    };

    let alone = probe_bw(None);
    let with_dp = probe_bw(Some(true));
    let without_dp = probe_bw(Some(false));
    let mut t = Table::new(["Method", "GPU P2P Bandwidth (GB/s)"]);
    t.row(["P2P_alone", &format!("{:.2}", alone / 1e9)]);
    t.row(["MMA", &format!("{:.2}", with_dp / 1e9)]);
    t.row([
        "MMA without direct priority",
        &format!("{:.2}", without_dp / 1e9),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_saturates_around_six_relays() {
        let b = 4 << 30;
        let bw6 = measure_bw(Direction::H2D, b, mma_with_relays(6));
        let bw7 = measure_bw(Direction::H2D, b, mma_with_relays(7));
        let bw4 = measure_bw(Direction::H2D, b, mma_with_relays(4));
        assert!(bw7 / bw6 < 1.03, "no saturation: {bw6} → {bw7}");
        assert!(bw6 > bw4 * 1.05, "still growing before saturation");
        // Peak in the paper's band (245 GB/s ± 10%).
        assert!((220e9..270e9).contains(&bw7), "peak {bw7}");
    }

    #[test]
    fn fig8_monotone_in_relays() {
        let b = 2 << 30;
        let mut last = 0.0;
        for n in 0..=7 {
            let bw = measure_bw(Direction::H2D, b, mma_with_relays(n));
            assert!(
                bw >= last * 0.97,
                "bandwidth regressed at {n} relays: {last} → {bw}"
            );
            last = bw;
        }
    }

    #[test]
    fn numa_local_four_paths_near_180() {
        // §6: restricting relay to same-NUMA GPUs ≈ 180 GB/s.
        let cfg = MmaConfig {
            numa_local_only: true,
            ..mma_with_relays(3)
        };
        let bw = measure_bw(Direction::H2D, 4 << 30, cfg);
        assert!((160e9..210e9).contains(&bw), "local-4 bw {bw}");
    }

    #[test]
    fn fig16_breakeven_in_paper_band() {
        // Paper: 11.3 MB H2D / 13 MB D2H. Accept 6–20 MB.
        let t = fig16_fallback().render();
        let line = t.lines().last().unwrap().to_string();
        assert!(line.contains("break-even"), "{t}");
        // Extract the H2D break-even cell roughly.
        assert!(
            !line.contains("none"),
            "no break-even found:\n{t}"
        );
    }

    #[test]
    fn table2_direct_priority_protects_p2p() {
        let t = table2_direct_priority();
        let s = t.render();
        let vals: Vec<f64> = s
            .lines()
            .skip(2)
            .filter_map(|l| l.split_whitespace().last()?.parse().ok())
            .collect();
        assert_eq!(vals.len(), 3, "{s}");
        let (alone, with_dp, without_dp) = (vals[0], vals[1], vals[2]);
        assert!(
            (with_dp - alone).abs() / alone < 0.05,
            "direct priority must leave P2P intact: alone {alone}, mma {with_dp}"
        );
        assert!(
            without_dp < with_dp - 5.0,
            "disabling direct priority must cost P2P bandwidth: {with_dp} vs {without_dp}"
        );
    }

    #[test]
    fn fig14_tp8_falls_back_gracefully() {
        let bytes = 256 << 20;
        let base = measure_bw(Direction::H2D, bytes, MmaConfig::native());
        let tp8 = measure_bw(Direction::H2D, bytes, MmaConfig::with_relays(vec![]));
        let ratio = tp8 / base;
        assert!((0.85..1.02).contains(&ratio), "TP=8 ratio {ratio}");
        let tp1 = measure_bw(Direction::H2D, bytes, mma_with_relays(7));
        assert!(tp1 / base > 2.5, "TP=1 speedup {}", tp1 / base);
    }
}
