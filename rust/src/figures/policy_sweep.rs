//! Policy sweep: every transfer policy on the Fig-8 workload
//! (bandwidth vs number of relay paths, one large H2D copy to gpu0).
//!
//! This is the cross-policy comparison the old architecture could not
//! produce: native, static-split, the paper's greedy selector, and the
//! two adaptive policies all run through the identical engine/measurement
//! path, differing only in their [`crate::policy::TransferPolicy`].

use crate::figures::micro::measure_bw;
use crate::mma::MmaConfig;
use crate::policy::{self, PolicySpec};
use crate::topology::{h20x8, Direction, GpuId};
use crate::util::par::par_map;
use crate::util::table::Table;

/// Policies compared, in table-column order.
pub const POLICIES: [&str; 5] = [
    "native",
    "static-split",
    "mma-greedy",
    "congestion-feedback",
    "numa-aware",
];

/// The first `n` relays for gpu0 (NUMA-local first, as Fig 8 sweeps them).
fn relays_for(n: usize) -> Vec<GpuId> {
    h20x8().relay_order(GpuId(0), &[]).into_iter().take(n).collect()
}

/// Engine configuration for one `(policy, relay-count)` sweep cell.
/// Static-split spreads equal weights over the direct path + relays.
pub fn cfg_for(policy: &str, n_relays: usize) -> MmaConfig {
    let relays = relays_for(n_relays);
    match policy {
        "native" => MmaConfig::native(),
        "static-split" => {
            let weights = vec![1.0; relays.len() + 1];
            policy::static_split(GpuId(0), &relays, &weights)
        }
        "mma-greedy" => MmaConfig::with_relays(relays),
        "congestion-feedback" => MmaConfig {
            policy: PolicySpec::congestion_feedback(),
            ..MmaConfig::with_relays(relays)
        },
        "numa-aware" => MmaConfig {
            policy: PolicySpec::numa_aware(),
            ..MmaConfig::with_relays(relays)
        },
        other => panic!("unknown sweep policy {other:?}"),
    }
}

/// The sweep table: H2D GB/s per policy at 0..=7 relay paths. Cells run
/// on [`crate::figures::jobs`] worker threads (each cell owns its
/// `SimWorld`, so cells are independent) and merge in canonical row-major
/// order — the table is byte-identical for any worker count.
pub fn policy_sweep(fast: bool) -> Table {
    policy_sweep_jobs(fast, crate::figures::jobs())
}

/// [`policy_sweep`] with an explicit worker count — the seam the
/// jobs-byte-identity test drives without touching the process-global
/// jobs setting.
pub fn policy_sweep_jobs(fast: bool, jobs: usize) -> Table {
    let bytes: u64 = if fast { 1 << 30 } else { 4 << 30 };
    let mut header = vec!["relays".to_string()];
    header.extend(POLICIES.iter().map(|p| format!("{p} GB/s")));
    let mut t = Table::new(header);
    let cells: Vec<(usize, &str)> = (0..=7usize)
        .flat_map(|n| POLICIES.iter().map(move |&p| (n, p)))
        .collect();
    let bws = par_map(jobs, cells, |_, (n, p)| {
        measure_bw(Direction::H2D, bytes, cfg_for(p, n))
    });
    for (n, row_bws) in bws.chunks(POLICIES.len()).enumerate() {
        let mut row = vec![n.to_string()];
        row.extend(row_bws.iter().map(|bw| format!("{:.1}", bw / 1e9)));
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::micro::mma_with_relays;

    #[test]
    fn sweep_reports_all_five_policies() {
        let t = policy_sweep(true).render();
        for p in POLICIES {
            assert!(t.contains(p), "missing column {p}:\n{t}");
        }
        assert_eq!(t.lines().count(), 2 + 8, "8 relay rows:\n{t}");
    }

    #[test]
    fn greedy_cell_matches_fig8_measurement_exactly() {
        // The sweep must reproduce Fig 8's mma numbers: same policy, same
        // engine path, same workload → within 1% (they are in fact the
        // identical configuration).
        let bytes = 2u64 << 30;
        for n in [0usize, 3, 7] {
            let fig8 = measure_bw(Direction::H2D, bytes, mma_with_relays(n));
            let sweep = measure_bw(Direction::H2D, bytes, cfg_for("mma-greedy", n));
            assert!(
                (sweep - fig8).abs() <= 0.01 * fig8,
                "{n} relays: sweep {sweep} vs fig8 {fig8}"
            );
        }
    }

    #[test]
    fn adaptive_policies_track_greedy_on_clean_fabric() {
        // Uncontended Fig-8 workload: congestion feedback has no reason to
        // demote paths, and numa-aware's 1 GB backlog is far above its
        // remote threshold — both should land near greedy.
        let bytes = 1u64 << 30;
        let greedy = measure_bw(Direction::H2D, bytes, cfg_for("mma-greedy", 7));
        for p in ["congestion-feedback", "numa-aware"] {
            let bw = measure_bw(Direction::H2D, bytes, cfg_for(p, 7));
            assert!(
                bw > 0.9 * greedy,
                "{p} fell behind greedy: {bw} vs {greedy}"
            );
        }
    }

    #[test]
    fn sweep_output_identical_across_job_counts() {
        // The acceptance bar for the parallel executor: merged output is
        // byte-for-byte the sequential output, for any worker count.
        let seq = policy_sweep_jobs(true, 1).render();
        let par = policy_sweep_jobs(true, 4).render();
        assert_eq!(seq, par, "parallel sweep must be byte-identical");
    }

    #[test]
    fn native_cell_is_single_path() {
        let bw = measure_bw(Direction::H2D, 1 << 30, cfg_for("native", 7));
        assert!((45e9..60e9).contains(&bw), "native bw {bw}");
    }
}
