//! The continuous-batching sweep (ISSUE 10): TTFT / TPOT versus batch
//! size × prefill chunk size × context, with step durations priced by
//! the batch-aware H20 roofline ([`crate::roofline::GpuRoofline`]).
//!
//! The point of the figure is the memory-wall signature the paper's §5
//! TTFT/TPOT claims rest on ("Mind the Memory Gap", "AI and Memory
//! Wall"): each decode iteration streams `weights + Σ KV(context_i)`
//! over HBM, so decode step time grows with the batch's aggregate KV
//! bytes, while prefill — compute-bound above the roofline crossover —
//! stays roughly flat per token no matter how the batch is composed.
//! Every cell is a deterministic simulation (no RNG: arrivals are all at
//! t=0), so the table is byte-stable.

use crate::config::{BatchingConfig, ComputeSource, ServingConfig};
use crate::mma::{MmaConfig, SimWorld};
use crate::models::qwen_7b_chat;
use crate::serving::{compute_from, Request, RequestId, ServingFleet, StepRecord};
use crate::sim::Time;
use crate::topology::{h20x8, NumaId};
use crate::util::table::Table;

/// One sweep cell: `batch` identical cold requests of `context` prompt
/// tokens served to completion under continuous batching.
#[derive(Clone, Debug)]
pub struct BatchingCell {
    /// Mean time to first token, seconds.
    pub mean_ttft: f64,
    /// Mean time per output token after the first, seconds.
    pub mean_tpot: f64,
    /// Every fused step the instance ran, in launch order.
    pub steps: Vec<StepRecord>,
}

impl BatchingCell {
    /// Pure-decode steps at the full `batch` width, in launch order —
    /// the steps the memory-wall signature is read off.
    pub fn full_decode_steps(&self, batch: u32) -> Vec<StepRecord> {
        self.steps
            .iter()
            .filter(|s| s.prefill_tokens == 0 && s.decode_batch == batch)
            .copied()
            .collect()
    }

    /// Decode step time strictly increases with aggregate KV bytes over
    /// the full-batch decode steps (the memory-wall signature).
    pub fn decode_kv_monotone(&self, batch: u32) -> bool {
        let steps = self.full_decode_steps(batch);
        steps.len() >= 2
            && steps
                .windows(2)
                .all(|w| w[1].decode_kv_bytes > w[0].decode_kv_bytes && w[1].secs > w[0].secs)
    }

    /// Mean seconds per prefill token over the steps that carried
    /// prefill work (compute-bound ⇒ roughly flat across batch sizes).
    pub fn prefill_secs_per_token(&self) -> f64 {
        let (mut secs, mut tokens) = (0.0, 0u64);
        for s in &self.steps {
            if s.prefill_tokens > 0 {
                secs += s.secs;
                tokens += s.prefill_tokens as u64;
            }
        }
        if tokens == 0 {
            0.0
        } else {
            secs / tokens as f64
        }
    }

    /// Largest aggregate decode KV footprint any step carried, bytes.
    pub fn peak_kv_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.decode_kv_bytes).max().unwrap_or(0)
    }
}

/// Run one cell: `batch` cold requests (no prefix reuse — this figure
/// isolates compute, not transfer) of `context` prompt tokens and
/// `output_tokens` generated tokens each, under the roofline compute
/// source and chunked prefill of `chunk_tokens` (0 = unchunked).
pub fn batching_cell(batch: u32, chunk_tokens: u32, context: u32, output_tokens: u32) -> BatchingCell {
    let serving = ServingConfig {
        compute: ComputeSource::Roofline,
        batching: BatchingConfig {
            enabled: true,
            chunk_tokens,
        },
        // Wide pools/budget so batching policy, not capacity, shapes the
        // steps (same stance as `replay_serving`).
        gpu_kv_blocks: 1 << 20,
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 512 * 1024,
        max_batch_seqs: batch,
        max_concurrency: batch,
        pd_disaggregation: false,
        ..ServingConfig::default()
    };
    let fleet_cfg = crate::testkit::fleet_config(1, false);
    let world = SimWorld::new(h20x8(), MmaConfig::native());
    let mut fleet = ServingFleet::new(
        fleet_cfg,
        serving.clone(),
        qwen_7b_chat(),
        world,
        vec![compute_from(serving.compute)],
        NumaId(0),
    );
    let reqs: Vec<Request> = (0..batch as u64)
        .map(|i| Request {
            id: RequestId(i),
            arrival: Time::ZERO,
            prompt_tokens: context,
            cached_prefix_tokens: 0,
            prefix_key: 0,
            output_tokens,
            tenant: 0,
            class: None,
        })
        .collect();
    let out = fleet.run(reqs);
    let n = out.len().max(1) as f64;
    let mean_ttft = out.iter().map(|o| o.ttft_s()).sum::<f64>() / n;
    let mean_tpot = out
        .iter()
        .filter_map(|o| {
            let fin = o.finished_at?;
            let toks = output_tokens.saturating_sub(1);
            (toks > 0).then(|| fin.since(o.first_token_at).as_secs_f64() / toks as f64)
        })
        .sum::<f64>()
        / n;
    BatchingCell {
        mean_ttft,
        mean_tpot,
        steps: fleet.instance(0).steps().to_vec(),
    }
}

/// The sweep: TTFT / TPOT / step shape per batch × chunk × context.
pub fn batching(fast: bool) -> Table {
    let contexts: &[u32] = if fast {
        &[4_096, 16_384]
    } else {
        &[4_096, 16_384, 65_536]
    };
    let batches: &[u32] = if fast { &[1, 8] } else { &[1, 8, 32] };
    let chunks: &[u32] = if fast { &[0, 2_048] } else { &[0, 2_048, 8_192] };
    let output_tokens = if fast { 16 } else { 32 };
    let mut t = Table::new([
        "batch",
        "chunk",
        "context",
        "mean TTFT (s)",
        "mean TPOT (ms)",
        "prefill (us/tok)",
        "steps",
        "peak KV (GB)",
    ]);
    for &context in contexts {
        for &batch in batches {
            for &chunk in chunks {
                let cell = batching_cell(batch, chunk, context, output_tokens);
                t.row([
                    format!("{batch}"),
                    format!("{chunk}"),
                    format!("{context}"),
                    format!("{:.3}", cell.mean_ttft),
                    format!("{:.3}", 1e3 * cell.mean_tpot),
                    format!("{:.2}", 1e6 * cell.prefill_secs_per_token()),
                    format!("{}", cell.steps.len()),
                    format!("{:.2}", cell.peak_kv_bytes() as f64 / 1e9),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_step_time_grows_with_aggregate_kv_bytes() {
        // The acceptance gate: with roofline costs on, decode step time
        // strictly increases with the batch's aggregate KV bytes.
        let cell = batching_cell(8, 0, 16_384, 16);
        assert!(
            cell.decode_kv_monotone(8),
            "memory-wall signature missing: {:?}",
            cell.full_decode_steps(8)
        );
    }

    #[test]
    fn tpot_grows_with_batch_while_prefill_stays_flat() {
        // Bigger batches stream more aggregate KV per decode iteration ⇒
        // TPOT rises; prefill is compute-bound, so its per-token cost
        // stays roughly flat across batch sizes.
        let small = batching_cell(1, 0, 16_384, 16);
        let big = batching_cell(16, 0, 16_384, 16);
        assert!(
            big.mean_tpot > 1.2 * small.mean_tpot,
            "TPOT must feel the memory wall: batch 16 {} vs batch 1 {}",
            big.mean_tpot,
            small.mean_tpot
        );
        let (a, b) = (small.prefill_secs_per_token(), big.prefill_secs_per_token());
        assert!(
            b < 1.5 * a && a < 1.5 * b,
            "prefill must stay roughly flat: {a} vs {b}"
        );
    }

    #[test]
    fn chunked_prefill_splits_steps_without_changing_work() {
        let whole = batching_cell(4, 0, 16_384, 4);
        let chunked = batching_cell(4, 2_048, 16_384, 4);
        let tokens = |c: &BatchingCell| -> u64 {
            c.steps.iter().map(|s| s.prefill_tokens as u64).sum()
        };
        assert_eq!(tokens(&whole), tokens(&chunked), "same prefill tokens");
        assert!(
            chunked.steps.len() > whole.steps.len(),
            "chunking must split prefill across more steps: {} vs {}",
            chunked.steps.len(),
            whole.steps.len()
        );
        // Per-step prefill legs respect the chunk size.
        assert!(chunked
            .steps
            .iter()
            .all(|s| s.prefill_tokens <= 4 * 2_048));
    }

    #[test]
    fn figure_is_deterministic_and_renders() {
        let a = batching(true).render();
        let b = batching(true).render();
        assert_eq!(a, b);
        for needle in ["batch", "TPOT", "peak KV"] {
            assert!(a.contains(needle), "missing {needle}:\n{a}");
        }
    }
}
