//! End-to-end serving figures: Fig 2 (fetch share of TTFT), Fig 3
//! (transfer share of sleep/wake), Fig 12 (TTFT baseline vs MMA), Fig 13
//! (switch latency baseline vs MMA). §2.1 and §5.2.

use crate::config::ServingConfig;
use crate::metrics::Summary;
use crate::mma::{MmaConfig, SimWorld};
use crate::models::{paper_models, ModelSpec};
use crate::roofline::h20;
use crate::serving::{ModelRegistry, ServingEngine};
use crate::sim::Time;
use crate::topology::{h20x8, GpuId, NumaId};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::longdoc_sessions;

/// Context lengths of §5.2.1.
pub const CONTEXTS: [u32; 3] = [16_384, 32_768, 65_536];

/// Run the §5.2.1 multi-turn QA workload: returns (mean TTFT seconds,
/// mean fetch fraction) over prefix-hit turns (turn 1 discarded).
/// `seed` drives the session generator (`--seed` end to end).
pub fn qa_ttft(
    model: &ModelSpec,
    context: u32,
    mma: MmaConfig,
    n_docs: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::seed_from_u64(seed);
    let sessions = longdoc_sessions(&mut rng, n_docs, context, 3);
    let cfg = ServingConfig {
        // Big enough pools that capacity effects don't interfere; the
        // prefix starts in the HOST tier (the §5.2.1 offloaded state).
        gpu_kv_blocks: 1 << 20,
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 128 * 1024,
        ..Default::default()
    };
    let world = SimWorld::new(h20x8(), mma);
    let mut eng = ServingEngine::new(
        cfg,
        model.clone(),
        world,
        Box::new(h20()),
        GpuId(0),
        NumaId(0),
    );
    let mut requests = Vec::new();
    let mut id = 0u64;
    for (i, s) in sessions.iter().enumerate() {
        // Seed the document KV in host memory, as after a previous session.
        eng.seed_host_prefix(s.key, s.context_tokens);
        // Wide spacing: each turn runs on an otherwise idle engine, as in
        // the paper's per-request TTFT measurement.
        let mut reqs = s.requests(
            id,
            Time::from_secs_f64(2000.0 * i as f64),
            Time::from_secs_f64(200.0),
        );
        id += reqs.len() as u64;
        // Drop turn 1 later: mark by remembering ids.
        requests.append(&mut reqs);
    }
    let outcomes = eng.run(requests.clone());
    let mut ttft = Summary::new();
    let mut frac = Summary::new();
    for (req, out) in requests.iter().zip(&outcomes) {
        if req.cached_prefix_tokens == 0 {
            continue; // discard the cold first turn, as the paper does
        }
        // GPU-tier hits (fetch 0) happen when a later turn reuses blocks
        // still resident; the paper's offloaded setting is the host hit.
        ttft.record(out.ttft_s());
        frac.record(out.ttft.fetch_fraction());
    }
    (ttft.mean(), frac.mean())
}

/// Fig 2: proportion of prefix-cache fetching time in TTFT (baseline).
pub fn fig2_ttft_share(fast: bool, seed: u64) -> Table {
    let n_docs = if fast { 2 } else { 5 };
    let mut t = Table::new(["model", "context", "TTFT (s)", "fetch share"]);
    for m in paper_models() {
        for ctx in CONTEXTS {
            let (ttft, frac) = qa_ttft(&m, ctx, MmaConfig::native(), n_docs, seed);
            t.row([
                m.name.to_string(),
                format!("{}k", ctx / 1024),
                format!("{ttft:.3}"),
                format!("{:.0}%", frac * 100.0),
            ]);
        }
    }
    t
}

/// Fig 12: TTFT baseline vs MMA across models × context lengths.
pub fn fig12_ttft(fast: bool, seed: u64) -> Table {
    let n_docs = if fast { 2 } else { 5 };
    let mut t = Table::new(["model", "context", "baseline TTFT (s)", "MMA TTFT (s)", "speedup"]);
    for m in paper_models() {
        for ctx in CONTEXTS {
            let (base, _) = qa_ttft(&m, ctx, MmaConfig::native(), n_docs, seed);
            let (mma, _) = qa_ttft(&m, ctx, MmaConfig::default(), n_docs, seed);
            t.row([
                m.name.to_string(),
                format!("{}k", ctx / 1024),
                format!("{base:.3}"),
                format!("{mma:.3}"),
                format!("{:.2}x", base / mma),
            ]);
        }
    }
    t
}

/// One sleep/wake measurement. Returns (sleep, wake) phase results.
pub fn sleep_wake(
    model: &ModelSpec,
    mma: MmaConfig,
) -> (
    crate::serving::model_registry::PhaseResult,
    crate::serving::model_registry::PhaseResult,
) {
    let mut world = SimWorld::new(h20x8(), mma);
    let mut reg = ModelRegistry::new(NumaId(0));
    let idx = reg.register(model.clone(), vec![GpuId(0)]);
    let s = reg.sleep(&mut world, idx);
    let w = reg.wake(&mut world, idx);
    (s, w)
}

/// Fig 3: proportion of H2D/D2H transfer time in swap-in/out latency.
pub fn fig3_swap_share() -> Table {
    let mut t = Table::new([
        "model",
        "sleep total (s)",
        "sleep transfer share",
        "wake total (s)",
        "wake transfer share",
    ]);
    for m in paper_models() {
        let (s, w) = sleep_wake(&m, MmaConfig::native());
        t.row([
            m.name.to_string(),
            format!("{:.3}", s.total().as_secs_f64()),
            format!("{:.0}%", s.transfer_fraction() * 100.0),
            format!("{:.3}", w.total().as_secs_f64()),
            format!("{:.0}%", w.transfer_fraction() * 100.0),
        ]);
    }
    t
}

/// Fig 13: fall-asleep and wake-up latency, baseline vs MMA.
pub fn fig13_switching() -> Table {
    let mut t = Table::new([
        "model",
        "sleep base (s)",
        "sleep MMA (s)",
        "sleep x",
        "wake base (s)",
        "wake MMA (s)",
        "wake x",
    ]);
    for m in paper_models() {
        let (sb, wb) = sleep_wake(&m, MmaConfig::native());
        let (sm, wm) = sleep_wake(&m, MmaConfig::default());
        let sx = sb.total().as_secs_f64() / sm.total().as_secs_f64();
        let wx = wb.total().as_secs_f64() / wm.total().as_secs_f64();
        t.row([
            m.name.to_string(),
            format!("{:.3}", sb.total().as_secs_f64()),
            format!("{:.3}", sm.total().as_secs_f64()),
            format!("{sx:.2}x"),
            format!("{:.3}", wb.total().as_secs_f64()),
            format!("{:.3}", wm.total().as_secs_f64()),
            format!("{wx:.2}x"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{qwen3_32b, qwen_7b_chat};

    const SEED: u64 = crate::figures::DEFAULT_SEED;

    #[test]
    fn fig2_fetch_share_grows_with_context_and_hits_70pct() {
        let m = qwen_7b_chat();
        let (_, f16) = qa_ttft(&m, 16_384, MmaConfig::native(), 2, SEED);
        let (_, f64k) = qa_ttft(&m, 65_536, MmaConfig::native(), 2, SEED);
        assert!(f64k > f16, "share must grow with context: {f16} → {f64k}");
        // Paper: up to 70% at 64k on Qwen-7B-Chat.
        assert!((0.5..0.9).contains(&f64k), "64k fetch share {f64k}");
    }

    #[test]
    fn fig12_speedup_band() {
        let m = qwen_7b_chat();
        let (base, _) = qa_ttft(&m, 65_536, MmaConfig::native(), 2, SEED);
        let (mma, _) = qa_ttft(&m, 65_536, MmaConfig::default(), 2, SEED);
        let x = base / mma;
        // Paper: 1.14–2.38x, largest at 64k (2.38x).
        assert!((1.5..3.2).contains(&x), "64k TTFT speedup {x}");
        let (b16, _) = qa_ttft(&m, 16_384, MmaConfig::native(), 2, SEED);
        let (m16, _) = qa_ttft(&m, 16_384, MmaConfig::default(), 2, SEED);
        assert!(b16 / m16 < x, "longer prefixes must benefit more");
    }

    #[test]
    fn qa_ttft_reproducible_and_seed_sensitive() {
        // Same seed → identical results; the seed genuinely reaches the
        // workload generator (different seed → different sessions).
        let m = qwen_7b_chat();
        let a = qa_ttft(&m, 16_384, MmaConfig::native(), 2, 7);
        let b = qa_ttft(&m, 16_384, MmaConfig::native(), 2, 7);
        assert_eq!(a, b, "same seed must reproduce bit-exactly");
        let c = qa_ttft(&m, 16_384, MmaConfig::native(), 2, 8);
        assert_ne!(a, c, "different seed must change the workload");
    }

    #[test]
    fn fig13_32b_switching_band() {
        let m = qwen3_32b();
        let (sb, wb) = sleep_wake(&m, MmaConfig::native());
        let (sm, wm) = sleep_wake(&m, MmaConfig::default());
        let sx = sb.total().as_secs_f64() / sm.total().as_secs_f64();
        let wx = wb.total().as_secs_f64() / wm.total().as_secs_f64();
        // Paper: 2.32–2.48x for Qwen3-32B.
        assert!((1.9..3.5).contains(&sx), "sleep speedup {sx}");
        assert!((1.9..3.5).contains(&wx), "wake speedup {wx}");
        // Baseline wake ~2.5s headline ("switching a 32B model takes ~2.5s").
        let switch_base = sb.total().as_secs_f64() + wb.total().as_secs_f64();
        assert!((1.8..3.2).contains(&switch_base), "32B switch {switch_base}");
    }
}
