//! Robustness / load-balancing figures: Fig 9 (coexistence), Fig 10
//! (adaptivity vs static splits), Fig 11 (CPU overhead). §5.1.2, §5.3.
//! Scenario (c) of the coexistence figure generalizes Fig 9 end-to-end:
//! the event-driven serving engine's KV fetch and a model-registry wake
//! co-run on the same fabric under one clock.

use crate::config::ServingConfig;
use crate::mma::{MmaConfig, SimWorld, TransferClass, TransferDesc};
use crate::models::{qwen3_32b, qwen_7b_chat};
use crate::policy;
use crate::roofline::h20;
use crate::serving::{ModelRegistry, Request, RequestId, ServingEngine};
use crate::sim::Time;
use crate::topology::{h20x8, Direction, GpuId, NumaId};
use crate::util::table::Table;

/// Fig 9: bandwidth over time when (a) an MMA flow shares the fabric with
/// a native CUDA stream pinning one direct link, (b) two concurrent MMA
/// flows share the relay capacity, and (c) a serving KV fetch co-runs
/// with a model-registry wake through the event-driven serving engine.
pub fn fig9_coexistence() -> Table {
    let mut t = Table::new(["t (ms)", "scenario", "MMA-A GB/s", "other GB/s"]);

    // (a) MMA + native background on gpu2's PCIe link. The background
    // loop is Bulk-class third-party traffic; QoS is off here, so the
    // class is a sampling label only.
    {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        w.enable_sampling(Time::from_ms(10), Time::from_ms(120));
        let bg_path = w.topo.h2d_direct(NumaId(0), GpuId(2));
        w.start_bg_loop(bg_path, 128 << 20, 45, TransferClass::Bulk);
        let s = w.stream(GpuId(0));
        w.memcpy_async(
            s,
            TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 8 << 30)
                .with_class(TransferClass::Interactive),
        );
        w.run_until_idle();
        for smp in w.samples.iter() {
            t.row([
                format!("{:.0}", smp.at.as_ms_f64()),
                "a:mma+native".to_string(),
                format!("{:.1}", smp.rates[TransferClass::Interactive as usize].abs() / 1e9),
                format!("{:.1}", smp.rates[TransferClass::Bulk as usize].abs() / 1e9),
            ]);
        }
    }

    // (b) two concurrent MMA flows (separate processes/queues), sampled
    // on distinct class channels (Interactive vs Bulk; equal weights with
    // QoS off, so the split stays the unweighted fair one).
    {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let p1 = w.add_process(MmaConfig::default());
        w.enable_sampling(Time::from_ms(10), Time::from_ms(120));
        let s0 = w.stream(GpuId(0));
        let s4 = w.stream(GpuId(4));
        w.memcpy_async_on(
            0,
            s0,
            TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 6 << 30)
                .with_class(TransferClass::Interactive),
        );
        w.memcpy_async_on(
            p1,
            s4,
            TransferDesc::new(Direction::H2D, GpuId(4), NumaId(1), 6 << 30)
                .with_class(TransferClass::Bulk),
        );
        w.run_until_idle();
        for smp in w.samples.iter() {
            t.row([
                format!("{:.0}", smp.at.as_ms_f64()),
                "b:mma+mma".to_string(),
                format!("{:.1}", smp.rates[TransferClass::Interactive as usize].abs() / 1e9),
                format!("{:.1}", smp.rates[TransferClass::Bulk as usize].abs() / 1e9),
            ]);
        }
    }

    // (c) end-to-end: a serving KV fetch (LatencyCritical) and a 32B
    // model wake (Bulk, the registry default) co-run on the one event
    // loop — the generalization the unified serving layer enables.
    {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let mut reg = ModelRegistry::new(NumaId(1));
        let m = reg.register(qwen3_32b(), vec![GpuId(4)]);
        reg.sleep(&mut w, m); // park the weights host-side first
        let t0 = w.now();
        w.enable_sampling(Time::from_ms(10), t0 + Time::from_ms(400));
        let mut eng = ServingEngine::new(
            ServingConfig {
                gpu_kv_blocks: 1 << 20,
                host_kv_blocks: 1 << 22,
                max_batch_tokens: 128 * 1024,
                ..Default::default()
            },
            qwen_7b_chat(),
            w,
            Box::new(h20()),
            GpuId(0),
            NumaId(0),
        );
        eng.seed_host_prefix(11, 65_536);
        let wake = reg.start_wake(eng.world_mut(), m);
        eng.run(vec![Request {
            id: RequestId(1),
            arrival: t0,
            prompt_tokens: 65_536 + 128,
            cached_prefix_tokens: 65_536,
            prefix_key: 11,
            output_tokens: 4,
            tenant: 0,
            class: None,
        }]);
        wake.wait(eng.world_mut());
        eng.world_mut().run_until_idle(); // flush the remaining sampling window
        for smp in eng.world().samples.iter() {
            t.row([
                format!("{:.0}", smp.at.since(t0).as_ms_f64()),
                "c:serve+wake".to_string(),
                format!(
                    "{:.1}",
                    smp.rates[TransferClass::LatencyCritical as usize].abs() / 1e9
                ),
                format!("{:.1}", smp.rates[TransferClass::Bulk as usize].abs() / 1e9),
            ]);
        }
    }
    t
}

/// One Fig 10 cell: completion time of a 512 MB H2D to gpu0 over two paths
/// (direct + relay gpu1) under a given splitter, ± background traffic on
/// the direct link.
fn fig10_cell(cfg: MmaConfig, background: bool) -> f64 {
    let mut w = SimWorld::new(h20x8(), cfg);
    if background {
        // Third-party native traffic pinning gpu0's direct PCIe link for
        // the whole experiment window.
        let bg = w.topo.h2d_direct(NumaId(0), GpuId(0));
        w.start_bg_loop(bg, 256 << 20, 40, TransferClass::Bulk);
    }
    let s = w.stream(GpuId(0));
    let id = w.memcpy_async(
        s,
        TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 512 << 20),
    );
    let done = w.run_until_transfer(id);
    done.since(w.rec(id).submitted).as_ms_f64()
}

/// Fig 10: MMA's pull-based scheduling vs static splits, ± background.
pub fn fig10_static_split() -> Table {
    let two_path = MmaConfig::with_relays(vec![GpuId(1)]);
    let rows: Vec<(&str, MmaConfig)> = vec![
        ("native", MmaConfig::native()),
        ("static 1:1", policy::split_1_1(GpuId(0), GpuId(1))),
        ("static 1:2", policy::split_1_2(GpuId(0), GpuId(1))),
        ("MMA (pull)", two_path),
    ];
    let mut t = Table::new(["method", "no-bg (ms)", "with-bg (ms)"]);
    for (name, cfg) in rows {
        let a = fig10_cell(cfg.clone(), false);
        let b = fig10_cell(cfg, true);
        t.row([name.to_string(), format!("{a:.1}"), format!("{b:.1}")]);
    }
    t
}

/// Fig 11: additional CPU cores consumed by MMA vs number of relay GPUs,
/// under bidirectional traffic (both engines active, as in the paper's
/// default flow-control mode accounting).
pub fn fig11_cpu_overhead() -> Table {
    let mut t = Table::new(["active GPUs", "equivalent cores"]);
    for gpus in 1..=8usize {
        let relays = gpus - 1;
        let topo = h20x8();
        let relay_set: Vec<GpuId> = topo
            .relay_order(GpuId(0), &[])
            .into_iter()
            .take(relays)
            .collect();
        let cfg = MmaConfig::with_relays(relay_set);
        let mut w = SimWorld::new(topo, cfg);
        let s = w.stream(GpuId(0));
        w.memcpy_async(
            s,
            TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 4 << 30),
        );
        let sd = w.stream(GpuId(0));
        w.memcpy_async(
            sd,
            TransferDesc::new(Direction::D2H, GpuId(0), NumaId(0), 4 << 30),
        );
        let end = w.run_until_idle();
        let cores = w.engine(0, Direction::H2D).stats.equivalent_cores(end)
            + w.engine(0, Direction::D2H).stats.equivalent_cores(end);
        t.row([gpus.to_string(), format!("{cores:.2}")]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9a_mma_keeps_most_bandwidth_under_native_contention() {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        w.enable_sampling(Time::from_ms(1), Time::from_ms(200));
        let bg_path = w.topo.h2d_direct(NumaId(0), GpuId(2));
        w.start_bg_loop(bg_path, 512 << 20, 10, TransferClass::Bulk);
        let s = w.stream(GpuId(0));
        w.memcpy_async(
            s,
            TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 8 << 30)
                .with_class(TransferClass::Interactive),
        );
        w.run_until_idle();
        // During contention, MMA still gets far above single-link rate and
        // the native stream still makes progress.
        let mma_ch = TransferClass::Interactive as usize;
        let bg_ch = TransferClass::Bulk as usize;
        let peak_mma = w.samples.iter().map(|s| s.rates[mma_ch]).fold(0.0, f64::max);
        let peak_bg = w.samples.iter().map(|s| s.rates[bg_ch]).fold(0.0, f64::max);
        assert!(peak_mma > 150e9, "mma peak {peak_mma}");
        assert!(peak_bg > 20e9, "bg starved: {peak_bg}");
    }

    #[test]
    fn fig9b_two_mma_flows_both_beat_native() {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let p1 = w.add_process(MmaConfig::default());
        let s0 = w.stream(GpuId(0));
        let s4 = w.stream(GpuId(4));
        let a = w.memcpy_async_on(
            0,
            s0,
            TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 4 << 30),
        );
        let b = w.memcpy_async_on(
            p1,
            s4,
            TransferDesc::new(Direction::H2D, GpuId(4), NumaId(1), 4 << 30),
        );
        w.run_until_idle();
        let bwa = w.rec(a).bandwidth().unwrap();
        let bwb = w.rec(b).bandwidth().unwrap();
        assert!(bwa > 90e9 && bwb > 90e9, "{bwa} {bwb}");
    }

    #[test]
    fn fig10_mma_tracks_best_static_split() {
        let t = fig10_static_split();
        let s = t.render();
        let mut rows: std::collections::HashMap<String, (f64, f64)> = Default::default();
        for line in s.lines().skip(2) {
            let cells: Vec<&str> = line.split_whitespace().collect();
            let n = cells.len();
            if n >= 3 {
                let name = cells[..n - 2].join(" ");
                rows.insert(
                    name,
                    (cells[n - 2].parse().unwrap(), cells[n - 1].parse().unwrap()),
                );
            }
        }
        let mma = rows["MMA (pull)"];
        let s11 = rows["static 1:1"];
        let s12 = rows["static 1:2"];
        let native = rows["native"];
        // No background: 1:1 is the good static split; MMA must match it
        // (within 15%) and beat the mis-tuned 1:2.
        assert!(mma.0 <= s11.0 * 1.15, "no-bg: mma {} vs 1:1 {}", mma.0, s11.0);
        assert!(s12.0 > s11.0 * 1.1, "1:2 should lag without bg");
        // With background: 1:2 becomes the good split; MMA must track it.
        assert!(mma.1 <= s12.1 * 1.15, "bg: mma {} vs 1:2 {}", mma.1, s12.1);
        assert!(s11.1 > s12.1 * 1.05, "1:1 should lag with bg");
        // And MMA always beats native.
        assert!(mma.0 < native.0 && mma.1 < native.1);
    }

    #[test]
    fn fig11_linear_and_capped() {
        let t = fig11_cpu_overhead();
        let s = t.render();
        let cores: Vec<f64> = s
            .lines()
            .skip(2)
            .filter_map(|l| l.split_whitespace().last()?.parse().ok())
            .collect();
        assert_eq!(cores.len(), 8);
        // Monotone growth, roughly linear, ≤ ~10 cores at 8 GPUs (paper: 8.2).
        for w in cores.windows(2) {
            assert!(w[1] >= w[0] * 0.9, "{cores:?}");
        }
        assert!(cores[7] > cores[0] * 3.0, "{cores:?}");
        assert!((5.0..11.0).contains(&cores[7]), "8-GPU cores {}", cores[7]);
    }
}
