//! One runner per paper table/figure. Each returns the same rows/series
//! the paper reports; `rust/benches/*` and the `mma figure <id>` CLI both
//! print them. See DESIGN.md §5 for the experiment index.

pub mod batching;
pub mod fleet_scaling;
pub mod micro;
pub mod policy_sweep;
pub mod qos_isolation;
pub mod robust;
pub mod serve_concurrency;
pub mod serving_figs;
pub mod workload_replay;

pub use batching::batching;
pub use fleet_scaling::fleet_scaling;
pub use micro::{
    fig14_tp_sweep, fig15_sensitivity, fig16_fallback, fig7_bw_vs_size, fig8_bw_vs_paths,
    table2_direct_priority,
};
pub use policy_sweep::policy_sweep;
pub use qos_isolation::qos_isolation;
pub use robust::{fig10_static_split, fig11_cpu_overhead, fig9_coexistence};
pub use serve_concurrency::serve_concurrency;
pub use serving_figs::{fig12_ttft, fig13_switching, fig2_ttft_share, fig3_swap_share};
pub use workload_replay::workload_replay;

use crate::topology::h20x8;
use crate::util::table::Table;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default RNG seed of the stochastic runners (overridable via `--seed`).
/// Historically hardwired inside `serving_figs`; kept at the same value so
/// default outputs are unchanged.
pub const DEFAULT_SEED: u64 = 0xF16;

/// Worker threads for the parallel sweep runners (`--jobs` / `MMA_JOBS` /
/// `[run] jobs`). Sweeps fan independent cells over
/// [`crate::util::par::par_map`] and merge results in canonical cell
/// order, so output is byte-identical for any value.
static JOBS: AtomicUsize = AtomicUsize::new(1);

/// Set the sweep worker-thread count (clamped to at least 1).
pub fn set_jobs(n: usize) {
    JOBS.store(n.max(1), Ordering::Relaxed);
}

/// Current sweep worker-thread count (see [`set_jobs`]; default 1).
pub fn jobs() -> usize {
    JOBS.load(Ordering::Relaxed)
}

/// Table 1: effective interconnect bandwidths of the simulated testbed.
pub fn table1_interconnects() -> Table {
    let topo = h20x8();
    let mut t = Table::new(["link (effective, simulated)", "GB/s"]);
    for l in &topo.links {
        t.row([format!("{:?}", l.kind), format!("{:.1}", l.capacity_bps / 1e9)]);
    }
    t
}

/// Run a figure by id ("2", "7", "table2", "policy", ...) with default
/// parameters; returns the printable report. `seed` drives the stochastic
/// runners (Fig 2/12 workload generation). Used by the CLI.
pub fn run_by_name(id: &str, fast: bool, seed: u64) -> Option<String> {
    let s = match id {
        "table1" | "1" => table1_interconnects().render(),
        "2" | "fig2" => fig2_ttft_share(fast, seed).render(),
        "3" | "fig3" => fig3_swap_share().render(),
        "7" | "fig7" => fig7_bw_vs_size(fast).render(),
        "8" | "fig8" => fig8_bw_vs_paths(fast).render(),
        "9" | "fig9" => fig9_coexistence().render(),
        "10" | "fig10" => fig10_static_split().render(),
        "11" | "fig11" => fig11_cpu_overhead().render(),
        "12" | "fig12" => fig12_ttft(fast, seed).render(),
        "13" | "fig13" => fig13_switching().render(),
        "14" | "fig14" => fig14_tp_sweep().render(),
        "15" | "fig15" => fig15_sensitivity(fast).render(),
        "16" | "fig16" => fig16_fallback().render(),
        "table2" => table2_direct_priority().render(),
        "policy" | "policy_sweep" => policy_sweep(fast).render(),
        "concurrency" | "serve_concurrency" => serve_concurrency(fast, seed).render(),
        "fleet" | "fleet_scaling" => fleet_scaling(fast, seed).render(),
        "qos" | "qos_isolation" => qos_isolation(fast, seed).render(),
        "replay" | "workload_replay" => workload_replay(fast, seed).render(),
        "batching" => batching(fast).render(),
        _ => return None,
    };
    Some(s)
}

/// All figure ids, in paper order (the policy sweep, the serving
/// concurrency sweep, the fleet-scaling sweep, the QoS-isolation co-run,
/// the workload-replay sweep, and the continuous-batching sweep are this
/// repo's own).
pub fn all_ids() -> &'static [&'static str] {
    &[
        "table1", "2", "3", "7", "8", "9", "10", "11", "12", "13", "14", "15", "16", "table2",
        "policy", "concurrency", "fleet", "qos", "replay", "batching",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_every_link_class() {
        let s = table1_interconnects().render();
        for needle in ["PcieH2D", "NvOut", "DramRd", "Xgmi", "SwitchH2D"] {
            assert!(s.contains(needle), "missing {needle}:\n{s}");
        }
    }

    #[test]
    fn run_by_name_dispatches() {
        assert!(run_by_name("table1", true, DEFAULT_SEED).is_some());
        assert!(run_by_name("nope", true, DEFAULT_SEED).is_none());
    }
}
