//! Fleet scaling: TTFT vs fleet size, with peer-NVLink prefix fetches on
//! vs off — this repo's own sweep on the multi-GPU serving fleet.
//!
//! Poisson arrivals of host-tier prefix hits over a pool of shared
//! documents are round-robined across N per-GPU instances on one
//! `SimWorld` clock. With peer fetching off, every instance that missed a
//! prefix locally pulls it from host over its PCIe lane; with it on, a
//! prefix another instance already promoted into its HBM rides the
//! NVLink fabric instead — the fleet-level payoff of the paper's
//! observation that aggregate intra-server bandwidth dwarfs any single
//! path.

use crate::config::{FleetConfig, ServingConfig};
use crate::metrics::Summary;
use crate::mma::{MmaConfig, SimWorld};
use crate::models::{qwen_7b_chat, ModelSpec};
use crate::roofline::h20;
use crate::serving::{Compute, Request, RequestId, RoutePolicy, ServingFleet};
use crate::sim::Time;
use crate::topology::{h20x8, NumaId};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::poisson_arrivals;

/// Serving config for fleet runs: aggregated (non-PD) mode so promoted
/// prefixes stay GPU-resident and peer-fetchable; pools wide enough that
/// admission, not capacity, governs the measured concurrency.
pub fn fleet_serving(rate_rps: f64) -> ServingConfig {
    ServingConfig {
        gpu_kv_blocks: 1 << 20, // clamped to HBM by the instance
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 512 * 1024,
        pd_disaggregation: false,
        arrival_rate_rps: rate_rps,
        ..Default::default()
    }
}

/// One fleet run's aggregate result.
#[derive(Clone, Debug)]
pub struct FleetRunResult {
    /// Mean TTFT over all requests, seconds.
    pub mean_ttft: f64,
    /// p99 TTFT, seconds.
    pub p99_ttft: f64,
    /// Host-tier prefix fetches issued across the fleet.
    pub host_fetches: u64,
    /// Peer-NVLink prefix fetches issued across the fleet.
    pub peer_fetches: u64,
    /// Requests routed to each instance.
    pub per_instance: Vec<u32>,
}

/// One open-loop fleet run: `n_docs` distinct host-resident documents of
/// `context` tokens, `turns` prefix-hit requests each, Poisson arrivals
/// at `serving.arrival_rate_rps` (the `--seed`-driven generator).
#[allow(clippy::too_many_arguments)]
pub fn fleet_run(
    model: &ModelSpec,
    context: u32,
    mma: MmaConfig,
    serving: ServingConfig,
    fleet: FleetConfig,
    n_docs: usize,
    turns: u32,
    seed: u64,
) -> FleetRunResult {
    assert!(
        serving.arrival_rate_rps > 0.0,
        "open-loop fleet run needs arrival_rate_rps > 0"
    );
    let mut rng = Rng::seed_from_u64(seed);
    let world = SimWorld::new(h20x8(), mma);
    let computes: Vec<Box<dyn Compute>> = (0..fleet.gpus)
        .map(|_| Box::new(h20()) as Box<dyn Compute>)
        .collect();
    let mut f = ServingFleet::new(
        fleet,
        serving.clone(),
        model.clone(),
        world,
        computes,
        NumaId(0),
    );
    let keys: Vec<u64> = (0..n_docs).map(|_| rng.next_u64() | 1).collect();
    for &k in &keys {
        f.seed_host_prefix(k, context);
    }
    let total = n_docs * turns.max(1) as usize;
    let arrivals = poisson_arrivals(&mut rng, Time::ZERO, serving.arrival_rate_rps, total);
    let reqs: Vec<Request> = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, at)| Request {
            id: RequestId(i as u64),
            arrival: at,
            prompt_tokens: context + 64,
            cached_prefix_tokens: context,
            prefix_key: keys[i % n_docs],
            output_tokens: 8,
            tenant: 0,
            class: None,
        })
        .collect();
    let out = f.run(reqs);
    let mut s = Summary::new();
    for o in &out {
        s.record(o.ttft_s());
    }
    let (host_fetches, peer_fetches) = f.fetch_counts();
    FleetRunResult {
        mean_ttft: s.mean(),
        p99_ttft: s.p99(),
        host_fetches,
        peer_fetches,
        per_instance: f.per_instance_counts(),
    }
}

/// The sweep: mean/p99 TTFT per fleet size × peer-fetch setting.
pub fn fleet_scaling(fast: bool, seed: u64) -> Table {
    let model = qwen_7b_chat();
    let context = if fast { 16_384 } else { 32_768 };
    // Doc count coprime to the fleet sizes, so round-robin keeps landing
    // the same document on *different* instances (the peer-fetch case).
    let n_docs = if fast { 5 } else { 9 };
    let turns = if fast { 2 } else { 3 };
    // Offered load well past a single instance's service rate (a native
    // prefix fetch alone is ~0.08 s at 16k / ~0.16 s at 32k), so the
    // single-instance queue is visible and the fleet's relief measurable.
    // The native policy isolates the host-PCIe vs peer-NVLink path
    // effect; the policy dimension has its own sweeps (`figure policy`,
    // `figure concurrency`).
    let rate = if fast { 20.0 } else { 10.0 };
    let sizes: &[u32] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let mut t = Table::new([
        "gpus",
        "peer-fetch",
        "mean TTFT (s)",
        "p99 TTFT (s)",
        "host fetches",
        "peer fetches",
    ]);
    for &n in sizes {
        for peer in [false, true] {
            let fleet = FleetConfig {
                gpus: n,
                router: RoutePolicy::RoundRobin,
                peer_fetch: peer,
                prefix_affinity: false,
            };
            let r = fleet_run(
                &model,
                context,
                MmaConfig::native(),
                fleet_serving(rate),
                fleet,
                n_docs,
                turns,
                seed,
            );
            t.row([
                format!("{n}"),
                if peer { "on" } else { "off" }.to_string(),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{}", r.host_fetches),
                format!("{}", r.peer_fetches),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = crate::figures::DEFAULT_SEED;

    fn run(gpus: u32, peer: bool) -> FleetRunResult {
        let fleet = FleetConfig {
            gpus,
            router: RoutePolicy::RoundRobin,
            peer_fetch: peer,
            prefix_affinity: false,
        };
        // Native policy + a rate past one instance's service rate: the
        // single-instance queue is visible, while second turns (arriving
        // ~5 inter-arrival gaps after their doc's first turn) still land
        // after the first turn's promotion, so peer hits actually occur.
        fleet_run(
            &qwen_7b_chat(),
            16_384,
            MmaConfig::native(),
            fleet_serving(20.0),
            fleet,
            5,
            2,
            SEED,
        )
    }

    #[test]
    fn scaling_the_fleet_cuts_ttft() {
        let one = run(1, true);
        let four = run(4, true);
        assert!(
            four.mean_ttft < one.mean_ttft,
            "fleet must relieve the single-GPU queue: n=1 {} vs n=4 {}",
            one.mean_ttft,
            four.mean_ttft
        );
        assert_eq!(four.per_instance.len(), 4);
        assert!(four.per_instance.iter().all(|&c| c > 0), "RR spreads load");
    }

    #[test]
    fn peer_fetch_replaces_host_fetches_and_helps_ttft() {
        let off = run(4, false);
        let on = run(4, true);
        assert_eq!(off.peer_fetches, 0, "switch off means no NVLink fetches");
        assert!(on.peer_fetches > 0, "repeat hits ride NVLink when on");
        assert!(
            on.host_fetches < off.host_fetches,
            "peer fetches replace host fetches: {} vs {}",
            on.host_fetches,
            off.host_fetches
        );
        assert!(
            on.mean_ttft <= off.mean_ttft,
            "NVLink fetches must not hurt TTFT: on {} vs off {}",
            on.mean_ttft,
            off.mean_ttft
        );
    }

    #[test]
    fn fleet_run_is_seed_reproducible() {
        let a = run(2, true);
        let b = run(2, true);
        assert_eq!(a.mean_ttft, b.mean_ttft);
        assert_eq!(a.per_instance, b.per_instance);
        assert_eq!((a.host_fetches, a.peer_fetches), (b.host_fetches, b.peer_fetches));
    }
}
