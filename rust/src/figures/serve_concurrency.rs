//! TTFT vs offered load per transfer policy — this repo's own sweep on
//! the event-driven serving engine. Poisson arrivals of host-tier prefix
//! hits whose KV fetches genuinely contend in the fabric (and whose
//! compute overlaps in-flight fetches), so the curve shows how each
//! policy degrades as concurrent serving load grows — the regime behind
//! the paper's Fig 2/12 claims.

use crate::config::ServingConfig;
use crate::metrics::Summary;
use crate::mma::{MmaConfig, SimWorld};
use crate::models::{qwen_7b_chat, ModelSpec};
use crate::roofline::h20;
use crate::serving::{Request, RequestId, ServingEngine};
use crate::sim::Time;
use crate::topology::{h20x8, GpuId, NumaId};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::poisson_arrivals;

/// Serving config for open-loop concurrency runs: pools big enough that
/// capacity effects don't interfere, batch budget wide enough that
/// admission (not the budget) sets the concurrency level.
pub fn open_loop_serving(rate_rps: f64) -> ServingConfig {
    ServingConfig {
        gpu_kv_blocks: 1 << 20,
        host_kv_blocks: 1 << 22,
        max_batch_tokens: 512 * 1024,
        arrival_rate_rps: rate_rps,
        ..Default::default()
    }
}

/// One open-loop run: `n` single-turn requests over distinct
/// host-resident prefixes of `context` tokens, Poisson arrivals at
/// `serving.arrival_rate_rps` (the `--seed`-driven generator). Returns
/// (mean TTFT, p99 TTFT) in seconds.
pub fn concurrency_run(
    model: &ModelSpec,
    context: u32,
    mma: MmaConfig,
    serving: ServingConfig,
    n: usize,
    seed: u64,
) -> (f64, f64) {
    assert!(
        serving.arrival_rate_rps > 0.0,
        "open-loop run needs arrival_rate_rps > 0"
    );
    let mut rng = Rng::seed_from_u64(seed);
    let world = SimWorld::new(h20x8(), mma);
    let mut eng = ServingEngine::new(
        serving.clone(),
        model.clone(),
        world,
        Box::new(h20()),
        GpuId(0),
        NumaId(0),
    );
    let arrivals = poisson_arrivals(&mut rng, Time::ZERO, serving.arrival_rate_rps, n);
    let mut reqs = Vec::with_capacity(n);
    for (i, at) in arrivals.into_iter().enumerate() {
        let key = rng.next_u64() | 1;
        eng.seed_host_prefix(key, context);
        reqs.push(Request {
            id: RequestId(i as u64),
            arrival: at,
            prompt_tokens: context + 64,
            cached_prefix_tokens: context,
            prefix_key: key,
            output_tokens: 8,
            tenant: 0,
            class: None,
        });
    }
    let out = eng.run(reqs);
    let mut s = Summary::new();
    for o in &out {
        s.record(o.ttft_s());
    }
    (s.mean(), s.p99())
}

/// The sweep: mean/p99 TTFT per policy × offered load.
pub fn serve_concurrency(fast: bool, seed: u64) -> Table {
    let model = qwen_7b_chat();
    let context = if fast { 16_384 } else { 32_768 };
    let n = if fast { 6 } else { 12 };
    let rates: &[f64] = if fast {
        &[0.5, 2.0]
    } else {
        &[0.5, 1.0, 2.0, 4.0]
    };
    let policies: [(&str, MmaConfig); 2] = [
        ("native", MmaConfig::native()),
        ("mma-greedy", MmaConfig::default()),
    ];
    let mut t = Table::new(["policy", "rate (req/s)", "mean TTFT (s)", "p99 TTFT (s)"]);
    for (name, cfg) in &policies {
        for &rate in rates {
            let (mean, p99) = concurrency_run(
                &model,
                context,
                cfg.clone(),
                open_loop_serving(rate),
                n,
                seed,
            );
            t.row([
                name.to_string(),
                format!("{rate:.1}"),
                format!("{mean:.3}"),
                format!("{p99:.3}"),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = crate::figures::DEFAULT_SEED;

    #[test]
    fn ttft_degrades_with_offered_load() {
        let m = qwen_7b_chat();
        let lo = concurrency_run(
            &m,
            16_384,
            MmaConfig::native(),
            open_loop_serving(0.2),
            4,
            SEED,
        );
        let hi = concurrency_run(
            &m,
            16_384,
            MmaConfig::native(),
            open_loop_serving(8.0),
            4,
            SEED,
        );
        assert!(
            hi.0 > lo.0 * 1.1,
            "mean TTFT must rise under load: {lo:?} vs {hi:?}"
        );
        assert!(hi.1 >= hi.0, "p99 at least the mean");
    }

    #[test]
    fn mma_beats_native_under_load() {
        let m = qwen_7b_chat();
        let nat = concurrency_run(
            &m,
            16_384,
            MmaConfig::native(),
            open_loop_serving(4.0),
            4,
            SEED,
        );
        let mma = concurrency_run(
            &m,
            16_384,
            MmaConfig::default(),
            open_loop_serving(4.0),
            4,
            SEED,
        );
        assert!(
            mma.0 < nat.0,
            "multipath fetches must lower loaded TTFT: mma {} vs native {}",
            mma.0,
            nat.0
        );
    }

    #[test]
    fn run_is_seed_reproducible() {
        let m = qwen_7b_chat();
        let mk = || {
            concurrency_run(
                &m,
                16_384,
                MmaConfig::native(),
                open_loop_serving(2.0),
                4,
                7,
            )
        };
        assert_eq!(mk(), mk(), "same seed must reproduce bit-exactly");
    }
}
