//! The serving fleet: N per-GPU [`ServingInstance`]s under one
//! event-driven [`Router`], all inside a single [`SimWorld`] event loop.
//!
//! One virtual clock. The fleet schedules request arrivals as world
//! timers; when a timer fires, the router places the request on an
//! instance (round-robin / least-loaded / prefix-affinity) — waking a
//! sleeping instance on demand without blocking (the wake's weight
//! transfers co-run with live serving traffic). Transfer and kernel
//! completion notices are dispatched to the owning instance, whose
//! scheduler advances mid-simulation. The single-GPU
//! [`crate::serving::ServingEngine`] is exactly the N=1 case of this
//! loop.
//!
//! The host prefix tier is fleet-shared ([`HostPrefixPool`], byte-
//! accounted through [`crate::memory::HostPool`]): a prefix one instance
//! fetched and promoted into its HBM can be fetched by a sibling
//! peer-to-peer over the NVLink fabric instead of from host over PCIe —
//! the `[fleet] peer_fetch` switch plus the transfer policy's
//! `prefer_peer_fetch` surface decide per request.

use super::instance::{split_peers, Compute, FleetShared, RequestOutcome, ServingInstance};
use super::model_registry::{ModelRegistry, PendingPhase, PhaseResult};
use super::prefix_cache::HostPrefixPool;
use super::router::Router;
use super::scheduler::{Request, RequestId};
use crate::config::{FleetConfig, ServingConfig};
use crate::memory::HbmAllocator;
use crate::mma::{Notice, SimWorld};
use crate::models::ModelSpec;
use crate::sim::Time;
use crate::topology::{GpuId, NumaId};
use crate::util::fxmap::FxHashMap;

/// Namespace for the fleet's arrival-timer tokens, so timers scheduled by
/// other consumers of the shared world are ignored instead of being
/// misread as arrivals ("SRVE" tag in the top half).
const ARRIVAL_TOKEN_BASE: u64 = 0x5352_5645 << 32;

/// N serving instances on one [`SimWorld`] clock.
pub struct ServingFleet {
    /// The shared world: fabric, GPUs, and the one virtual clock.
    pub world: SimWorld,
    /// Fleet knobs (`[fleet]` section / `mma serve --gpus`).
    pub cfg: FleetConfig,
    model: ModelSpec,
    instances: Vec<ServingInstance>,
    shared: FleetShared,
    router: Router,
    registry: ModelRegistry,
    pending_wakes: Vec<(usize, PendingPhase)>,
    /// Completed on-demand wakes: `(instance, phase cost)`.
    pub wake_costs: Vec<(usize, PhaseResult)>,
    hbm: HbmAllocator,
    arrivals: Vec<Request>,
    assignments: FxHashMap<u64, usize>,
}

impl ServingFleet {
    /// Assemble a fleet on GPUs `0..cfg.gpus`, one compute provider per
    /// instance. `world` carries the MMA/native transfer configuration.
    pub fn new(
        cfg: FleetConfig,
        serving: ServingConfig,
        model: ModelSpec,
        world: SimWorld,
        computes: Vec<Box<dyn Compute>>,
        host_numa: NumaId,
    ) -> ServingFleet {
        let gpus: Vec<GpuId> = (0..cfg.gpus).map(|i| GpuId(i as u8)).collect();
        ServingFleet::on_gpus(cfg, serving, model, world, computes, gpus, host_numa)
    }

    /// Assemble a fleet with explicit instance→GPU placement.
    pub fn on_gpus(
        mut cfg: FleetConfig,
        serving: ServingConfig,
        model: ModelSpec,
        mut world: SimWorld,
        computes: Vec<Box<dyn Compute>>,
        gpus: Vec<GpuId>,
        host_numa: NumaId,
    ) -> ServingFleet {
        assert!(!gpus.is_empty(), "a fleet needs at least one instance");
        assert_eq!(
            computes.len(),
            gpus.len(),
            "one compute provider per instance"
        );
        assert!(
            gpus.len() <= world.topo.gpu_count(),
            "fleet of {} on a {}-GPU server",
            gpus.len(),
            world.topo.gpu_count()
        );
        cfg.gpus = gpus.len() as u32;
        // Every instance's weights + KV pool carve from the same
        // per-GPU HBM accounting (satellite: no more bypass).
        let mut hbm = HbmAllocator::new(world.topo.gpu_count(), world.topo.hbm_bytes);
        let mut registry = ModelRegistry::new(host_numa);
        let mut instances = Vec::with_capacity(gpus.len());
        for (i, (gpu, compute)) in gpus.into_iter().zip(computes).enumerate() {
            registry.register(model.clone(), vec![gpu]);
            instances.push(ServingInstance::new(
                i as u8,
                serving.clone(),
                model.clone(),
                &mut world,
                &mut hbm,
                compute,
                gpu,
                host_numa,
            ));
        }
        let shared = FleetShared {
            host: HostPrefixPool::new(
                serving.kv_block_tokens,
                serving.host_kv_blocks as u64 * serving.kv_block_tokens as u64,
                model.kv_bytes_per_token().max(1),
                world.topo.numa_count,
                host_numa,
            ),
            peer_fetch: cfg.peer_fetch,
        };
        let router = Router::new(cfg.router, instances.len());
        ServingFleet {
            world,
            model,
            instances,
            shared,
            router,
            registry,
            pending_wakes: Vec::new(),
            wake_costs: Vec::new(),
            hbm,
            arrivals: Vec::new(),
            assignments: FxHashMap::default(),
            cfg,
        }
    }

    /// Current virtual time — the one shared [`SimWorld`] clock.
    pub fn now(&self) -> Time {
        self.world.now()
    }

    /// The model served.
    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// Name of the transfer policy every KV fetch / offload in this fleet
    /// runs under (from the [`SimWorld`]'s engine configuration).
    pub fn policy_name(&self) -> &'static str {
        self.world.policy_name()
    }

    /// Number of serving instances.
    pub fn instance_count(&self) -> usize {
        self.instances.len()
    }

    /// One instance, by fleet slot.
    pub fn instance(&self, i: usize) -> &ServingInstance {
        &self.instances[i]
    }

    /// The router (placement state, wake-event accounting).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The fleet-shared host prefix tier.
    pub fn host_tier(&self) -> &HostPrefixPool {
        &self.shared.host
    }

    /// Per-GPU HBM bytes in use (weights + clamped KV pools).
    pub fn hbm_used(&self, gpu: GpuId) -> u64 {
        self.hbm.used(gpu)
    }

    /// Which instance a routed request was placed on.
    pub fn assignment(&self, id: RequestId) -> Option<usize> {
        self.assignments.get(&id.0).copied()
    }

    /// Requests routed to each instance so far.
    pub fn per_instance_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.instances.len()];
        for &i in self.assignments.values() {
            counts[i] += 1;
        }
        counts
    }

    /// `(host, peer)` prefix fetches issued across the fleet.
    pub fn fetch_counts(&self) -> (u64, u64) {
        self.instances
            .iter()
            .fold((0, 0), |(h, p), i| (h + i.host_fetches, p + i.peer_fetches))
    }

    /// `(host, peer)` prefix-fetch bytes moved across the fleet.
    pub fn fetch_bytes(&self) -> (u64, u64) {
        self.instances.iter().fold((0, 0), |(h, p), i| {
            (h + i.host_fetch_bytes, p + i.peer_fetch_bytes)
        })
    }

    /// `(hits, misses)` of admitted prefills against the prefix tiers
    /// across the fleet (hits include zero-copy GPU-tier hits and joined
    /// in-flight fetches).
    pub fn prefix_hit_counts(&self) -> (u64, u64) {
        self.instances.iter().fold((0, 0), |(h, m), i| {
            (h + i.prefix_hits, m + i.prefix_misses)
        })
    }

    /// Pre-populate the shared host tier with a prefix (the state after a
    /// previous turn's KV was offloaded — §5.2.1 setup). Byte-accounted:
    /// over-seeding drops LRU entries instead of exceeding capacity.
    pub fn seed_host_prefix(&mut self, key: u64, tokens: u32) {
        self.shared.host.insert(key, tokens);
    }

    /// [`Self::seed_host_prefix`] under a tenant namespace: the entry is
    /// only visible to requests carrying the same `tenant` (trace replay
    /// seeds warm multi-tenant prefixes through this).
    pub fn seed_tenant_prefix(&mut self, tenant: u32, key: u64, tokens: u32) {
        self.shared
            .host
            .insert(super::scheduler::tenant_key(tenant, key), tokens);
    }

    /// Put an instance to sleep before a run (vLLM Sleep Mode Level 1):
    /// weights move D2H on the shared fabric; the next request routed to
    /// it triggers an on-demand, non-blocking wake.
    pub fn sleep_instance(&mut self, i: usize) {
        let phase = self.registry.start_sleep(&mut self.world, i);
        phase.wait(&mut self.world);
        self.instances[i].set_awake(false);
        self.router.set_awake(i, false);
    }

    /// Run `requests` to completion; returns outcomes in request order.
    /// Arrivals are scheduled as world timers and routed when they fire,
    /// so placement, on-demand wakes, and every instance's fetch/compute
    /// genuinely interleave on the shared fabric and clock.
    pub fn run(&mut self, requests: Vec<Request>) -> Vec<RequestOutcome> {
        self.run_with(requests, |_, _| {})
    }

    /// [`Self::run`] with a hook for *foreign* timers: any timer token
    /// outside the fleet's arrival namespace is handed to `on_timer`
    /// together with the shared world, instead of being silently skipped.
    /// This is how an external schedule co-runs with serving traffic on
    /// the one clock — trace replay schedules model-switch timers up
    /// front and drives [`ModelRegistry`] `start_wake`/`start_sleep` from
    /// the hook, so switch weight traffic contends with live fetches.
    pub fn run_with<F>(&mut self, requests: Vec<Request>, mut on_timer: F) -> Vec<RequestOutcome>
    where
        F: FnMut(&mut SimWorld, u64),
    {
        let ids: Vec<RequestId> = requests.iter().map(|r| r.id).collect();
        let mut sorted = requests;
        sorted.sort_by_key(|r| (r.arrival, r.id.0));
        let mut pending_arrivals = sorted.len();
        for r in sorted {
            let token = ARRIVAL_TOKEN_BASE | self.arrivals.len() as u64;
            self.world.schedule_timer(r.arrival, token);
            self.arrivals.push(r);
        }
        while !(pending_arrivals == 0 && self.instances.iter().all(|i| i.is_idle())) {
            let Some(notice) = self.world.next_notice() else {
                panic!("serving fleet stalled: world idle with work pending");
            };
            match notice {
                Notice::Timer(token) => {
                    let idx = (token ^ ARRIVAL_TOKEN_BASE) as usize;
                    if (token & ARRIVAL_TOKEN_BASE) != ARRIVAL_TOKEN_BASE
                        || idx >= self.arrivals.len()
                    {
                        // Someone else's timer on the shared world: the
                        // external schedule (if any) owns it.
                        on_timer(&mut self.world, token);
                        continue;
                    }
                    pending_arrivals -= 1;
                    let req = self.arrivals[idx].clone();
                    self.on_arrival(req);
                }
                Notice::TransferDone(tid) => {
                    self.poll_wakes();
                    self.dispatch_transfer(tid.0);
                }
                Notice::KernelDone(tag) => self.dispatch_kernel(tag),
            }
            self.drain_finished();
        }
        ids.iter()
            .map(|id| self.outcome(*id).expect("missing outcome").clone())
            .collect()
    }

    /// [`Self::run_with`] over an *iterator* of requests already sorted
    /// by `(arrival, id)` — the order [`Self::run_with`] sorts into and
    /// the order [`crate::workload::ArrivalMerger`] emits. Exactly one
    /// arrival timer is outstanding at a time and only one staged request
    /// is held, so fleet-side memory is O(1) in the trace length (plus
    /// the returned outcomes); `run_with` holds every request and its
    /// timer up front.
    ///
    /// The successor's timer is scheduled *before* the current arrival is
    /// handled, so same-timestamp arrivals keep their relative order and
    /// precede any events the current arrival generates — the same
    /// interleaving as up-front scheduling. (Residual caveat: an arrival
    /// whose timestamp collides to the exact nanosecond with a completion
    /// scheduled before it was staged can order differently than the
    /// up-front path; the streamed-vs-materialized replay equivalence
    /// tests pin the observable output byte-for-byte.)
    ///
    /// Returns outcomes in arrival order (the iteration order), not
    /// request-id order.
    pub fn run_streamed<I, F>(&mut self, requests: I, mut on_timer: F) -> Vec<RequestOutcome>
    where
        I: IntoIterator<Item = Request>,
        F: FnMut(&mut SimWorld, u64),
    {
        let mut rest = requests.into_iter();
        let mut ids: Vec<RequestId> = Vec::new();
        let mut next_token: u64 = ARRIVAL_TOKEN_BASE;
        let mut staged: Option<(u64, Request)> = None;
        if let Some(r) = rest.next() {
            self.world.schedule_timer(r.arrival, next_token);
            staged = Some((next_token, r));
            next_token += 1;
        }
        let mut last_key: Option<(Time, u64)> = None;
        while !(staged.is_none() && self.instances.iter().all(|i| i.is_idle())) {
            let Some(notice) = self.world.next_notice() else {
                panic!("serving fleet stalled: world idle with work pending");
            };
            match notice {
                Notice::Timer(token) => {
                    match staged.take() {
                        Some((t, req)) if t == token => {
                            let key = (req.arrival, req.id.0);
                            debug_assert!(
                                last_key.map_or(true, |l| l <= key),
                                "run_streamed requires (arrival, id)-sorted input"
                            );
                            last_key = Some(key);
                            // Stage the successor first: see above.
                            if let Some(nr) = rest.next() {
                                self.world.schedule_timer(nr.arrival, next_token);
                                staged = Some((next_token, nr));
                                next_token += 1;
                            }
                            ids.push(req.id);
                            self.on_arrival(req);
                        }
                        other => {
                            staged = other;
                            on_timer(&mut self.world, token);
                            continue;
                        }
                    }
                }
                Notice::TransferDone(tid) => {
                    self.poll_wakes();
                    self.dispatch_transfer(tid.0);
                }
                Notice::KernelDone(tag) => self.dispatch_kernel(tag),
            }
            self.drain_finished();
        }
        ids.iter()
            .map(|id| self.outcome(*id).expect("missing outcome").clone())
            .collect()
    }

    /// Outcome of a request served by whichever instance it was routed to.
    pub fn outcome(&self, id: RequestId) -> Option<&RequestOutcome> {
        let i = *self.assignments.get(&id.0)?;
        self.instances[i].outcome(id)
    }

    // ----- event handlers ----------------------------------------------

    /// An arrival timer fired: route mid-simulation and pump the target.
    fn on_arrival(&mut self, req: Request) {
        let affinity = if self.cfg.prefix_affinity && req.prefix_key != 0 {
            let key = req.cache_key();
            self.instances
                .iter()
                .position(|inst| inst.gpu_tier().peek(key).is_some())
        } else {
            None
        };
        // Residency lives in the router (synced on sleep/wake events), so
        // routing reads the incremental index instead of re-collecting and
        // re-scanning instance state per arrival.
        let (chosen, needs_wake) = self.router.route_next(affinity);
        self.assignments.insert(req.id.0, chosen);
        if needs_wake && !self.pending_wakes.iter().any(|(i, _)| *i == chosen) {
            // Non-blocking: the H2D weight reload contends with live
            // serving traffic; the request queues until the wake lands.
            // (A second request landing on an already-waking instance
            // just queues behind the in-flight wake.)
            let phase = self.registry.start_wake(&mut self.world, chosen);
            self.pending_wakes.push((chosen, phase));
        }
        self.instances[chosen].submit(req);
        self.pump_instance(chosen);
    }

    fn pump_instance(&mut self, i: usize) {
        let (inst, peers) = split_peers(&mut self.instances, i);
        inst.pump(&mut self.world, &mut self.shared, &peers);
    }

    fn dispatch_transfer(&mut self, tid: u32) {
        for i in 0..self.instances.len() {
            let (inst, peers) = split_peers(&mut self.instances, i);
            if inst.on_transfer_done(&mut self.world, &mut self.shared, &peers, tid) {
                return;
            }
        }
        // Not a serving fetch (registry / background traffic): ignored.
    }

    fn dispatch_kernel(&mut self, tag: u64) {
        for i in 0..self.instances.len() {
            let (inst, peers) = split_peers(&mut self.instances, i);
            if inst.on_kernel_done(&mut self.world, &mut self.shared, &peers, tag) {
                return;
            }
        }
    }

    /// Check in-flight wake phases; a completed wake marks its instance
    /// serving-ready and pumps it (queued arrivals admit immediately).
    fn poll_wakes(&mut self) {
        let mut i = 0;
        while i < self.pending_wakes.len() {
            if let Some(res) = self.pending_wakes[i].1.result(&self.world) {
                let (inst, _) = self.pending_wakes.swap_remove(i);
                self.wake_costs.push((inst, res));
                self.instances[inst].set_awake(true);
                self.router.set_awake(inst, true);
                self.pump_instance(inst);
            } else {
                i += 1;
            }
        }
    }

    /// Feed request completions back into the router's load accounting.
    fn drain_finished(&mut self) {
        for i in 0..self.instances.len() {
            for _rid in self.instances[i].take_finished() {
                self.router.done(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::qwen_7b_chat;
    use crate::serving::router::RoutePolicy;
    use crate::testkit::{fixed_computes, hit};
    use crate::topology::h20x8;

    fn computes(n: usize) -> Vec<Box<dyn Compute>> {
        fixed_computes(n, 0.05, 0.001)
    }

    fn fleet(n: u32, peer: bool, mma: MmaConfig) -> ServingFleet {
        crate::testkit::fleet(n, peer, mma, 0.05)
    }

    #[test]
    fn round_robin_spreads_requests_across_instances() {
        let mut f = fleet(4, false, MmaConfig::native());
        let reqs: Vec<Request> = (0..8).map(|i| hit(i, i, 1000, 0)).collect();
        let reqs = reqs
            .into_iter()
            .map(|mut r| {
                r.cached_prefix_tokens = 0;
                r
            })
            .collect();
        let out = f.run(reqs);
        assert_eq!(out.len(), 8);
        assert_eq!(f.per_instance_counts(), vec![2, 2, 2, 2]);
        for (i, o) in out.iter().enumerate() {
            assert_eq!(f.assignment(o.id), Some(i % 4), "arrival-order rotation");
            assert!(o.finished_at.is_some());
        }
    }

    #[test]
    fn parallel_instances_cut_queueing_versus_one() {
        // Four same-time cold prefills: one instance serializes them, four
        // instances run them concurrently on separate GPUs.
        let run = |n: u32| {
            let mut f = fleet(n, false, MmaConfig::native());
            let reqs: Vec<Request> = (0..4)
                .map(|i| Request {
                    cached_prefix_tokens: 0,
                    prefix_key: 0,
                    ..hit(i, 0, 8000, 0)
                })
                .collect();
            let out = f.run(reqs);
            out.iter().map(|o| o.ttft_s()).sum::<f64>() / out.len() as f64
        };
        let one = run(1);
        let four = run(4);
        assert!(
            four < 0.5 * one,
            "fleet must parallelize prefills: n=1 {one} vs n=4 {four}"
        );
    }

    #[test]
    fn peer_nvlink_fetch_beats_host_fetch() {
        // Request 1 promotes the prefix into gpu0's HBM; request 2 lands
        // on instance 1 and fetches it over NVLink (368 GB/s) instead of
        // host PCIe (53.6 GB/s) when peer fetching is on.
        let ctx = 32_768u32;
        let run = |peer: bool| {
            let mut f = fleet(2, peer, MmaConfig::native());
            f.seed_host_prefix(7, ctx);
            let out = f.run(vec![hit(1, 0, ctx, 7), hit(2, 3000, ctx, 7)]);
            let (host, peer_n) = f.fetch_counts();
            (out[1].ttft.fetch_s, host, peer_n)
        };
        let (host_fetch, h0, p0) = run(false);
        let (peer_fetch, h1, p1) = run(true);
        assert_eq!((h0, p0), (2, 0), "peer off: both turns fetch from host");
        assert_eq!((h1, p1), (1, 1), "peer on: second turn rides NVLink");
        assert!(
            peer_fetch < 0.25 * host_fetch,
            "NVLink fetch {peer_fetch} vs host fetch {host_fetch}"
        );
    }

    #[test]
    fn routed_request_wakes_sleeping_instance_mid_simulation() {
        let mut f = fleet(2, false, MmaConfig::native());
        f.sleep_instance(0);
        f.sleep_instance(1);
        let t0 = f.now();
        let out = f.run(vec![Request {
            arrival: t0,
            ..hit(1, 0, 1000, 0)
        }]);
        assert_eq!(out.len(), 1);
        assert!(out[0].finished_at.is_some());
        assert_eq!(f.router().wake_events, vec![0], "on-demand wake recorded");
        assert_eq!(f.wake_costs.len(), 1);
        let (inst, cost) = &f.wake_costs[0];
        assert_eq!(*inst, 0);
        assert!(cost.total() > Time::ZERO);
        // The wake delayed the first token past the pure compute time.
        assert!(
            out[0].ttft_s() > cost.transfer.as_secs_f64(),
            "TTFT {} must cover the wake transfer {}",
            out[0].ttft_s(),
            cost.transfer.as_secs_f64()
        );
        // Only the routed instance woke; the sibling stayed asleep.
        assert!(f.instance(0).awake());
        assert!(!f.instance(1).awake());
    }

    #[test]
    fn second_request_queues_behind_inflight_wake() {
        // Both requests land on the sleeping instance before its wake
        // completes: one physical wake, two wake-routed events, and both
        // requests finish once the weights are back.
        let mut f = fleet(1, false, MmaConfig::native());
        f.sleep_instance(0);
        let t0 = f.now();
        let out = f.run(vec![
            Request {
                arrival: t0,
                ..hit(1, 0, 1000, 0)
            },
            Request {
                arrival: t0,
                ..hit(2, 0, 1000, 0)
            },
        ]);
        assert!(out.iter().all(|o| o.finished_at.is_some()));
        assert_eq!(f.router().wake_events, vec![0, 0]);
        assert_eq!(f.wake_costs.len(), 1, "a single physical wake");
    }

    #[test]
    fn hbm_accounting_clamps_kv_pools() {
        // An absurd KV-pool request is clamped to what HBM holds next to
        // the weights, and the accounting shows both allocations.
        let serving = ServingConfig {
            gpu_kv_blocks: u32::MAX,
            ..Default::default()
        };
        let world = SimWorld::new(h20x8(), MmaConfig::native());
        let f = ServingFleet::new(
            FleetConfig::default(),
            serving,
            qwen_7b_chat(),
            world,
            computes(1),
            NumaId(0),
        );
        let model = qwen_7b_chat();
        let blocks = f.instance(0).kv_pool_blocks();
        assert!(blocks < u32::MAX, "pool clamped");
        let block_bytes = model.kv_bytes(16);
        let used = f.hbm_used(GpuId(0));
        assert_eq!(
            used,
            model.weight_bytes() + blocks as u64 * block_bytes,
            "weights + KV pool accounted"
        );
        assert!(used <= f.world.topo.hbm_bytes, "within HBM capacity");
        // The pool fills the GPU: one more block would not fit.
        assert!(used + block_bytes > f.world.topo.hbm_bytes);
    }

    #[test]
    fn tenants_never_share_cached_prefixes() {
        // Two tenants using the *same* document key: tenant 1's seeded
        // host-tier prefix is invisible to tenant 2, which prefills cold
        // (the tenant-tagged cache-key namespace).
        let mut f = fleet(1, false, MmaConfig::native());
        f.seed_tenant_prefix(1, 7, 16_384);
        let mut r1 = hit(1, 0, 16_384, 7);
        r1.tenant = 1;
        let mut r2 = hit(2, 5000, 16_384, 7);
        r2.tenant = 2;
        let out = f.run(vec![r1, r2]);
        assert!(out[0].ttft.fetch_s > 0.0, "tenant 1 fetches its prefix");
        assert_eq!(
            out[1].ttft.fetch_s, 0.0,
            "tenant 2 must not hit tenant 1's cache"
        );
        assert_eq!(f.prefix_hit_counts(), (1, 1));
        let (host, peer) = f.fetch_counts();
        assert_eq!((host, peer), (1, 0));
        let (hb, pb) = f.fetch_bytes();
        assert!(hb > 0 && pb == 0, "host bytes accounted: {hb}/{pb}");
    }

    #[test]
    fn run_with_hands_foreign_timers_to_the_hook() {
        // A timer outside the arrival namespace reaches the external
        // schedule hook (the surface trace replay drives model switches
        // through) instead of being silently skipped.
        let mut f = fleet(1, false, MmaConfig::native());
        f.world.schedule_timer(Time::from_ms(1), 0xBEEF);
        let mut seen = Vec::new();
        let out = f.run_with(
            vec![Request {
                cached_prefix_tokens: 0,
                ..hit(1, 2, 1000, 0)
            }],
            |_, tok| seen.push(tok),
        );
        assert_eq!(out.len(), 1);
        assert!(out[0].finished_at.is_some());
        assert_eq!(seen, vec![0xBEEF]);
    }

    #[test]
    fn run_streamed_matches_run_with() {
        // Same requests through both paths — including a same-timestamp
        // arrival pair and warm prefix fetches — must produce identical
        // outcomes, placements, and fetch accounting.
        let reqs = |t0: Time| {
            vec![
                Request {
                    arrival: t0 + Time::from_ms(5),
                    ..hit(0, 0, 8192, 9)
                },
                Request {
                    arrival: t0 + Time::from_ms(5),
                    ..hit(1, 0, 8192, 9)
                },
                Request {
                    arrival: t0 + Time::from_ms(40),
                    cached_prefix_tokens: 0,
                    prefix_key: 0,
                    ..hit(2, 0, 4000, 0)
                },
                Request {
                    arrival: t0 + Time::from_ms(90),
                    ..hit(3, 0, 8192, 9)
                },
            ]
        };
        let mut a = fleet(2, true, MmaConfig::native());
        a.seed_host_prefix(9, 8192);
        let base = a.run_with(reqs(a.now()), |_, _| {});
        let mut b = fleet(2, true, MmaConfig::native());
        b.seed_host_prefix(9, 8192);
        // Pre-sorted by (arrival, id) — run_streamed's input contract.
        let streamed = b.run_streamed(reqs(b.now()), |_, _| {});
        assert_eq!(base.len(), streamed.len());
        for (x, y) in base.iter().zip(&streamed) {
            assert_eq!(x.id, y.id, "arrival order == sorted order here");
            assert_eq!(x.first_token_at, y.first_token_at);
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.ttft.fetch_s, y.ttft.fetch_s);
        }
        assert_eq!(a.per_instance_counts(), b.per_instance_counts());
        assert_eq!(a.fetch_counts(), b.fetch_counts());
        assert_eq!(a.fetch_bytes(), b.fetch_bytes());
    }

    #[test]
    fn run_streamed_hands_foreign_timers_to_the_hook() {
        let mut f = fleet(1, false, MmaConfig::native());
        f.world.schedule_timer(Time::from_ms(1), 0xBEEF);
        let mut seen = Vec::new();
        let out = f.run_streamed(
            vec![Request {
                cached_prefix_tokens: 0,
                ..hit(1, 2, 1000, 0)
            }],
            |_, tok| seen.push(tok),
        );
        assert_eq!(out.len(), 1);
        assert!(out[0].finished_at.is_some());
        assert_eq!(seen, vec![0xBEEF]);
    }

    #[test]
    fn prefix_affinity_routes_to_the_holder() {
        let mk = |affinity: bool| {
            let cfg = FleetConfig {
                gpus: 2,
                router: RoutePolicy::RoundRobin,
                peer_fetch: false,
                prefix_affinity: affinity,
            };
            let serving = ServingConfig {
                pd_disaggregation: false,
                ..Default::default()
            };
            let world = SimWorld::new(h20x8(), MmaConfig::native());
            let mut f = ServingFleet::new(
                cfg,
                serving,
                qwen_7b_chat(),
                world,
                computes(2),
                NumaId(0),
            );
            f.seed_host_prefix(9, 8192);
            f.run(vec![hit(1, 0, 8192, 9), hit(2, 2000, 8192, 9)]);
            (f.assignment(RequestId(1)), f.assignment(RequestId(2)))
        };
        let (a, b) = mk(false);
        assert_ne!(a, b, "round-robin alternates without affinity");
        let (a, b) = mk(true);
        assert_eq!(a, b, "affinity returns the turn to the prefix holder");
    }
}
