//! Paged KV-cache manager (vLLM-style): fixed-size token blocks, per-GPU
//! free lists, per-sequence block tables with copy-on-reuse refcounts.

use crate::util::fxmap::FxHashMap;

/// Index of a KV block within its GPU's pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// Sequence identifier (serving-engine scoped).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeqId(pub u64);

/// One GPU's paged KV pool + the block tables of resident sequences.
#[derive(Debug)]
pub struct KvCacheManager {
    block_tokens: u32,
    free: Vec<BlockId>,
    refcount: Vec<u32>,
    tables: FxHashMap<u64, Vec<BlockId>>,
    total: u32,
}

impl KvCacheManager {
    /// Pool of `total_blocks` blocks of `block_tokens` tokens each.
    pub fn new(total_blocks: u32, block_tokens: u32) -> KvCacheManager {
        KvCacheManager {
            block_tokens,
            free: (0..total_blocks).rev().map(BlockId).collect(),
            refcount: vec![0; total_blocks as usize],
            tables: FxHashMap::default(),
            total: total_blocks,
        }
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// Blocks needed for `tokens`.
    pub fn blocks_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_tokens)
    }

    /// Free blocks available.
    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    /// Used blocks.
    pub fn used_blocks(&self) -> u32 {
        self.total - self.free_blocks()
    }

    /// Allocate a block table for a new sequence of `tokens`. Returns
    /// `None` (no partial allocation) if the pool can't fit it.
    pub fn alloc_seq(&mut self, seq: SeqId, tokens: u32) -> Option<&[BlockId]> {
        let need = self.blocks_for(tokens) as usize;
        if self.free.len() < need || self.tables.contains_key(&seq.0) {
            return None;
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refcount[b.0 as usize] = 1;
            blocks.push(b);
        }
        self.tables.insert(seq.0, blocks);
        Some(&self.tables[&seq.0])
    }

    /// Extend a sequence by `new_tokens` (decode growth). Returns false if
    /// out of blocks (caller must evict/offload).
    pub fn extend_seq(&mut self, seq: SeqId, old_tokens: u32, new_tokens: u32) -> bool {
        let have = self.blocks_for(old_tokens);
        let need = self.blocks_for(old_tokens + new_tokens);
        let extra = (need - have) as usize;
        if self.free.len() < extra {
            return false;
        }
        let table = self.tables.get_mut(&seq.0).expect("extend unknown seq");
        for _ in 0..extra {
            let b = self.free.pop().unwrap();
            self.refcount[b.0 as usize] = 1;
            table.push(b);
        }
        true
    }

    /// Share an existing sequence's prefix blocks into a new sequence
    /// (prefix-cache hit on GPU): bumps refcounts, no copies.
    pub fn fork_prefix(&mut self, from: SeqId, to: SeqId, prefix_blocks: u32) -> bool {
        let Some(src) = self.tables.get(&from.0) else {
            return false;
        };
        if self.tables.contains_key(&to.0) || src.len() < prefix_blocks as usize {
            return false;
        }
        let shared: Vec<BlockId> = src[..prefix_blocks as usize].to_vec();
        for b in &shared {
            self.refcount[b.0 as usize] += 1;
        }
        self.tables.insert(to.0, shared);
        true
    }

    /// Release a sequence; blocks return to the pool when refcounts drop
    /// to zero. Returns the number of blocks actually freed.
    pub fn free_seq(&mut self, seq: SeqId) -> u32 {
        let Some(blocks) = self.tables.remove(&seq.0) else {
            return 0;
        };
        let mut freed = 0;
        for b in blocks {
            let rc = &mut self.refcount[b.0 as usize];
            debug_assert!(*rc > 0);
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
                freed += 1;
            }
        }
        freed
    }

    /// Block table of a sequence.
    pub fn table(&self, seq: SeqId) -> Option<&[BlockId]> {
        self.tables.get(&seq.0).map(Vec::as_slice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn alloc_and_free_round_trip() {
        let mut kv = KvCacheManager::new(16, 16);
        assert_eq!(kv.blocks_for(33), 3);
        let t = kv.alloc_seq(SeqId(1), 33).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(kv.free_blocks(), 13);
        assert_eq!(kv.free_seq(SeqId(1)), 3);
        assert_eq!(kv.free_blocks(), 16);
    }

    #[test]
    fn no_partial_allocation() {
        let mut kv = KvCacheManager::new(4, 16);
        assert!(kv.alloc_seq(SeqId(1), 100).is_none(), "needs 7 > 4 blocks");
        assert_eq!(kv.free_blocks(), 4, "failed alloc must not leak");
    }

    #[test]
    fn extend_grows_table() {
        let mut kv = KvCacheManager::new(8, 16);
        kv.alloc_seq(SeqId(1), 16).unwrap();
        assert!(kv.extend_seq(SeqId(1), 16, 1)); // crosses into block 2
        assert_eq!(kv.table(SeqId(1)).unwrap().len(), 2);
        assert!(kv.extend_seq(SeqId(1), 17, 15)); // fills block 2, no new
        assert_eq!(kv.table(SeqId(1)).unwrap().len(), 2);
    }

    #[test]
    fn fork_shares_blocks_with_refcounts() {
        let mut kv = KvCacheManager::new(8, 16);
        kv.alloc_seq(SeqId(1), 64).unwrap(); // 4 blocks
        assert!(kv.fork_prefix(SeqId(1), SeqId(2), 2));
        assert_eq!(kv.free_blocks(), 4, "fork must not allocate");
        // Freeing the original keeps shared blocks alive.
        assert_eq!(kv.free_seq(SeqId(1)), 2);
        assert_eq!(kv.free_blocks(), 6);
        assert_eq!(kv.free_seq(SeqId(2)), 2);
        assert_eq!(kv.free_blocks(), 8);
    }

    #[test]
    fn property_block_conservation() {
        testkit::check("kv-conservation", |rng| {
            let total = 64;
            let mut kv = KvCacheManager::new(total, 16);
            let mut live: Vec<SeqId> = Vec::new();
            let mut next = 0u64;
            for _ in 0..100 {
                if live.is_empty() || rng.bool(0.55) {
                    let id = SeqId(next);
                    next += 1;
                    let tokens = rng.range_u64(1, 300) as u32;
                    if kv.alloc_seq(id, tokens).is_some() {
                        live.push(id);
                    }
                } else if rng.bool(0.3) && !live.is_empty() {
                    let from = *rng.choose(&live);
                    let id = SeqId(next);
                    next += 1;
                    let nb = kv.table(from).map(|t| t.len()).unwrap_or(0) as u32;
                    if nb > 0 && kv.fork_prefix(from, id, rng.range_u64(1, nb as u64 + 1) as u32) {
                        live.push(id);
                    }
                } else {
                    let i = rng.range_usize(0, live.len());
                    let id = live.swap_remove(i);
                    kv.free_seq(id);
                }
                assert!(kv.free_blocks() <= total);
            }
            for id in live {
                kv.free_seq(id);
            }
            assert_eq!(kv.free_blocks(), total, "blocks leaked");
        });
    }
}
