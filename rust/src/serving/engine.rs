//! The serving engine: ties the scheduler, prefix cache, paged KV, the
//! transfer fabric and the GPU execution model into one *event-driven*
//! serving loop running inside the [`SimWorld`] discrete-event simulation.
//!
//! There is a single virtual clock — [`SimWorld::now`]. Request arrivals
//! are world timers, prefix-cache KV fetches are `memcpy_async` transfers
//! whose completions surface as [`Notice::TransferDone`], and prefill /
//! decode compute are gpusim kernels (durations from a [`Compute`] model)
//! whose completions surface as [`Notice::KernelDone`]. The scheduler is
//! driven by these event callbacks, so in-flight fetches from concurrent
//! requests genuinely contend for max-min fabric bandwidth, fetches
//! overlap compute across requests (and within one request when
//! `fetch_chunks > 1`), and model-registry sleep/wake traffic co-runs with
//! live serving on the same fabric.
//!
//! TTFT decomposes as the paper measures it: queueing + prefix-cache KV
//! fetch (H2D) + prefill compute, every timestamp read off the world
//! clock.

use super::kv_cache::{KvCacheManager, SeqId};
use super::prefix_cache::{PrefixCache, Tier};
use super::scheduler::{Phase, Request, RequestId, Scheduler};
use crate::config::ServingConfig;
use crate::metrics::TtftBreakdown;
use crate::mma::{Notice, SimWorld, StreamHandle, TransferDesc};
use crate::models::ModelSpec;
use crate::roofline::GpuRoofline;
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};
use std::collections::{HashMap, VecDeque};

/// Compute-time provider: roofline for paper-scale models, real PJRT for
/// the live tiny model, fixed for unit tests.
pub trait Compute {
    /// Prefill `new_tokens` with `context` total attended tokens.
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64;
    /// One decode step at `context`.
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64;
}

impl Compute for GpuRoofline {
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        GpuRoofline::prefill_secs(self, m, new_tokens, context, tp)
    }
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        GpuRoofline::decode_secs_per_token(self, m, context, tp)
    }
}

/// Fixed per-call compute times (tests).
pub struct FixedCompute {
    /// Prefill seconds per call.
    pub prefill_s: f64,
    /// Decode seconds per step.
    pub decode_s: f64,
}

impl Compute for FixedCompute {
    fn prefill_secs(&mut self, _: &ModelSpec, _: u64, _: u64, _: u32) -> f64 {
        self.prefill_s
    }
    fn decode_secs(&mut self, _: &ModelSpec, _: u64, _: u32) -> f64 {
        self.decode_s
    }
}

/// Final per-request record.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: RequestId,
    /// Arrival time.
    pub arrival: Time,
    /// TTFT decomposition (queue / fetch / prefill component times). With
    /// `fetch_chunks > 1` fetch and prefill overlap, so the components can
    /// sum to more than [`Self::ttft_s`]; without chunking they sum
    /// exactly.
    pub ttft: TtftBreakdown,
    /// First token time (absolute, world clock).
    pub first_token_at: Time,
    /// All output tokens done (absolute, world clock).
    pub finished_at: Option<Time>,
}

impl RequestOutcome {
    /// End-to-end latency if finished.
    pub fn e2e(&self) -> Option<Time> {
        self.finished_at.map(|f| f.since(self.arrival))
    }

    /// Wall-clock time to first token (arrival → first token), seconds.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_at.since(self.arrival).as_secs_f64()
    }
}

/// Kernel-tag kinds (top byte of the gpusim kernel tag). Distinctive
/// bytes rather than 1/2 so tags from other consumers of the shared world
/// are unlikely to land in the engine's namespace; unknown kinds are
/// ignored, and both arms additionally tolerate tags that merely collide.
const TAG_PREFILL: u64 = 0xE5 << 56;
const TAG_DECODE_STEP: u64 = 0xE6 << 56;
const TAG_PAYLOAD: u64 = (1 << 56) - 1;

/// Namespace for this engine's arrival-timer tokens, so timers scheduled
/// by other consumers of the shared world are ignored instead of being
/// misread as arrivals ("SRVE" tag in the top half).
const ARRIVAL_TOKEN_BASE: u64 = 0x5352_5645 << 32;

/// Per-admitted-prefill bookkeeping, all timestamps off the world clock.
#[derive(Debug)]
struct PrefillJob {
    /// Tokens to prefill (scheduler suffix — the single source of truth).
    suffix: u32,
    /// Prefix tokens reused from the cache.
    reused: u32,
    /// Admission time (end of arrival queueing).
    sched_at: Time,
    /// First fetch chunk issued.
    fetch_started: Option<Time>,
    /// Last fetch chunk landed.
    fetch_done: Option<Time>,
    /// Outstanding fetch chunks.
    chunks_left: u32,
    /// Compute was released (pushed to the ready queue) already.
    compute_released: bool,
    /// When the job entered the ready queue.
    ready_at: Option<Time>,
    /// Prefill kernel start.
    kernel_start: Option<Time>,
    /// Prefill kernel completion.
    kernel_done: Option<Time>,
    /// Prefill kernel duration, seconds.
    prefill_s: f64,
    /// Stream carrying this job's fetch chunks (returned to the pool when
    /// the last chunk lands).
    fetch_stream: Option<StreamHandle>,
    /// Prefix key this job's own fetch is moving (primary fetcher only).
    fetch_key: Option<u64>,
}

/// The event-driven serving engine for one model on one GPU group.
pub struct ServingEngine {
    /// Serving knobs.
    pub cfg: ServingConfig,
    model: ModelSpec,
    sched: Scheduler,
    /// Prefix store (pre-populate for cache-hit experiments).
    pub prefix: PrefixCache,
    /// Paged GPU KV pool.
    pub kv: KvCacheManager,
    /// The shared world: fabric, GPUs, and the one virtual clock.
    pub world: SimWorld,
    compute: Box<dyn Compute>,
    prefill_gpu: GpuId,
    host_numa: NumaId,
    outcomes: HashMap<u64, RequestOutcome>,
    next_seq: u64,
    // --- event-loop state ---
    prefill_stream: StreamHandle,
    decode_stream: StreamHandle,
    arrivals: Vec<Request>,
    /// In-flight fetch chunk → owning request.
    inflight_fetch: HashMap<u32, RequestId>,
    jobs: HashMap<u64, PrefillJob>,
    /// Fetched (or pipeline-released) prefills waiting for the compute lane.
    ready_prefills: VecDeque<RequestId>,
    /// Idle fetch streams, recycled across requests (`StreamId` is a u16:
    /// creating one stream per request would wrap and alias stream 0).
    fetch_streams: Vec<StreamHandle>,
    /// Host-tier fetches in flight, by prefix key. A concurrent request
    /// hitting the same key *joins* the in-flight fetch (value = joiners)
    /// instead of seeing a prematurely-promoted GPU tier or re-fetching.
    inflight_prefix: HashMap<u64, Vec<RequestId>>,
    /// Suffix tokens of admitted-but-unfinished prefills (budget hold).
    inflight_prefill_tokens: u32,
    prefill_busy: bool,
    decode_busy: bool,
    /// Aggregated mode: alternate decode/prefill so neither lane starves.
    decode_ran_last: bool,
    decode_inflight: Vec<RequestId>,
}

impl ServingEngine {
    /// Assemble an engine. `world` carries the MMA/native transfer config.
    pub fn new(
        cfg: ServingConfig,
        model: ModelSpec,
        mut world: SimWorld,
        compute: Box<dyn Compute>,
        prefill_gpu: GpuId,
        host_numa: NumaId,
    ) -> ServingEngine {
        let kv = KvCacheManager::new(cfg.gpu_kv_blocks, cfg.kv_block_tokens);
        let prefix = PrefixCache::new(
            cfg.kv_block_tokens,
            cfg.gpu_kv_blocks as u64 * cfg.kv_block_tokens as u64,
            cfg.host_kv_blocks as u64 * cfg.kv_block_tokens as u64,
        );
        let prefill_stream = world.stream(prefill_gpu);
        let decode_stream = world.stream(prefill_gpu);
        ServingEngine {
            sched: Scheduler::new(cfg.clone()),
            kv,
            prefix,
            model: model.clone(),
            world,
            compute,
            prefill_gpu,
            host_numa,
            outcomes: HashMap::new(),
            next_seq: 0,
            prefill_stream,
            decode_stream,
            arrivals: Vec::new(),
            inflight_fetch: HashMap::new(),
            jobs: HashMap::new(),
            ready_prefills: VecDeque::new(),
            fetch_streams: Vec::new(),
            inflight_prefix: HashMap::new(),
            inflight_prefill_tokens: 0,
            prefill_busy: false,
            decode_busy: false,
            decode_ran_last: false,
            decode_inflight: Vec::new(),
            cfg,
        }
    }

    /// Pre-populate the prefix cache with a host-tier prefix (the state
    /// after a previous turn's KV was offloaded — §5.2.1 setup).
    pub fn seed_host_prefix(&mut self, key: u64, tokens: u32) {
        self.prefix.insert(key, tokens);
        self.prefix.offload(key);
    }

    /// Current virtual time — the one shared [`SimWorld`] clock.
    pub fn now(&self) -> Time {
        self.world.now()
    }

    /// The model served.
    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// Name of the transfer policy every KV fetch / offload in this engine
    /// runs under (from the [`SimWorld`]'s engine configuration).
    pub fn policy_name(&self) -> &'static str {
        self.world.policy_name()
    }

    /// Run `requests` to completion; returns outcomes in request order.
    /// Arrivals are scheduled as world timers, so anything else in flight
    /// on the same world (background loops, model sleep/wake transfers)
    /// co-runs with the serving traffic on the shared fabric.
    pub fn run(&mut self, requests: Vec<Request>) -> Vec<RequestOutcome> {
        // Outcomes are returned in the caller's submission order.
        let ids: Vec<RequestId> = requests.iter().map(|r| r.id).collect();
        let mut sorted = requests;
        sorted.sort_by_key(|r| (r.arrival, r.id.0));
        let mut pending_arrivals = sorted.len();
        for r in sorted {
            let token = ARRIVAL_TOKEN_BASE | self.arrivals.len() as u64;
            self.world.schedule_timer(r.arrival, token);
            self.arrivals.push(r);
        }
        while !(pending_arrivals == 0 && self.sched.is_idle() && self.jobs.is_empty()) {
            let Some(notice) = self.world.next_notice() else {
                panic!("serving engine stalled: world idle with work pending");
            };
            match notice {
                Notice::Timer(token) => {
                    let idx = (token ^ ARRIVAL_TOKEN_BASE) as usize;
                    if (token & ARRIVAL_TOKEN_BASE) != ARRIVAL_TOKEN_BASE
                        || idx >= self.arrivals.len()
                    {
                        continue; // someone else's timer on the shared world
                    }
                    pending_arrivals -= 1;
                    let req = self.arrivals[idx].clone();
                    self.sched.submit(req);
                    self.pump();
                }
                Notice::TransferDone(tid) => self.on_fetch_chunk_done(tid.0),
                Notice::KernelDone(tag) => self.on_kernel_done(tag),
            }
        }
        ids.iter()
            .map(|id| self.outcomes.get(&id.0).expect("missing outcome").clone())
            .collect()
    }

    /// Event-loop heartbeat: admit what fits, then fill idle compute lanes.
    fn pump(&mut self) {
        self.admit();
        if self.cfg.pd_disaggregation {
            // Separate GPU groups: both lanes advance independently.
            if !self.decode_busy {
                self.start_decode_step();
            }
            if !self.prefill_busy {
                self.start_next_prefill();
            }
        } else {
            // One GPU group: decodes and prefills serialize; alternate so
            // decodes keep priority without starving admitted prefills.
            if self.prefill_busy || self.decode_busy {
                return;
            }
            let has_decode = self.sched.decode_count() > 0;
            let has_prefill = !self.ready_prefills.is_empty();
            match (has_decode, has_prefill) {
                (true, true) => {
                    if self.decode_ran_last {
                        self.start_next_prefill();
                    } else {
                        self.start_decode_step();
                    }
                }
                (true, false) => self.start_decode_step(),
                (false, true) => self.start_next_prefill(),
                (false, false) => {}
            }
        }
    }

    /// Admit waiting requests under the in-flight token budget; resolve
    /// each suffix against the prefix cache (single source of truth) and
    /// issue host-tier KV fetches as async transfers.
    fn admit(&mut self) {
        let now = self.world.now();
        let decode_hold = if self.cfg.pd_disaggregation {
            0
        } else {
            self.sched.decode_count() as u32
        };
        let busy = self.inflight_prefill_tokens + decode_hold;
        let prefix = &self.prefix;
        let plan = self.sched.plan_prefills(busy, |r| {
            if r.prefix_key == 0 || r.cached_prefix_tokens == 0 {
                return 0;
            }
            prefix
                .peek(r.prefix_key)
                .map(|(tokens, _)| tokens.min(r.cached_prefix_tokens))
                .unwrap_or(0)
        });
        for (rid, suffix) in plan {
            let req = self.sched.sequence(rid).expect("admitted seq").req.clone();
            let reused = req.prompt_tokens - suffix;
            self.inflight_prefill_tokens += suffix.max(1);
            // KV blocks for the full sequence (best-effort, as the pool
            // model has no eviction path yet).
            let sid = SeqId(self.next_seq);
            self.next_seq += 1;
            let _ = self.kv.alloc_seq(sid, req.prompt_tokens + req.output_tokens);

            let mut job = PrefillJob {
                suffix,
                reused,
                sched_at: now,
                fetch_started: None,
                fetch_done: None,
                chunks_left: 0,
                compute_released: false,
                ready_at: None,
                kernel_start: None,
                kernel_done: None,
                prefill_s: 0.0,
                fetch_stream: None,
                fetch_key: None,
            };
            // Tier decision via the non-mutating peek: host→GPU promotion
            // is deferred to fetch *completion* so a concurrent same-key
            // request cannot observe a GPU tier whose bytes are still in
            // flight.
            let tier = if reused > 0 {
                self.prefix.peek(req.prefix_key).map(|(_, t)| t)
            } else {
                None
            };
            match tier {
                Some(Tier::Host) => {
                    if let Some(waiters) = self.inflight_prefix.get_mut(&req.prefix_key) {
                        // Same prefix already being fetched: join it and
                        // pay only the remaining wait.
                        waiters.push(rid);
                        job.fetch_started = Some(now);
                    } else {
                        // Primary fetcher: move KV pages host → GPU,
                        // chunked so later chunks can pipeline with
                        // prefill compute. A dedicated stream per fetch
                        // keeps concurrent requests' DMAs contending in
                        // the fabric instead of serializing on one queue.
                        self.inflight_prefix.insert(req.prefix_key, Vec::new());
                        let bytes = self.model.kv_bytes(reused as u64).max(1);
                        let chunks = (self.cfg.fetch_chunks.max(1) as u64).min(bytes) as u32;
                        let per = bytes / chunks as u64;
                        let fetch_stream = match self.fetch_streams.pop() {
                            Some(s) => s,
                            None => self.world.stream(self.prefill_gpu),
                        };
                        job.fetch_stream = Some(fetch_stream);
                        job.fetch_key = Some(req.prefix_key);
                        job.fetch_started = Some(now);
                        job.chunks_left = chunks;
                        for i in 0..chunks {
                            let sz = if i == chunks - 1 {
                                bytes - per * (chunks as u64 - 1)
                            } else {
                                per
                            };
                            let tid = self.world.memcpy_async(
                                fetch_stream,
                                TransferDesc::new(
                                    Direction::H2D,
                                    self.prefill_gpu,
                                    self.host_numa,
                                    sz,
                                ),
                            );
                            self.inflight_fetch.insert(tid.0, rid);
                        }
                    }
                }
                Some(Tier::Gpu) => {
                    // Resident hit: refresh LRU (no promotion involved).
                    self.prefix.lookup(req.prefix_key);
                    job.compute_released = true;
                    job.ready_at = Some(now);
                    self.ready_prefills.push_back(rid);
                }
                None => {
                    job.compute_released = true;
                    job.ready_at = Some(now);
                    self.ready_prefills.push_back(rid);
                }
            }
            self.jobs.insert(rid.0, job);
        }
    }

    /// A fetch chunk landed (ours or not — foreign transfers are ignored).
    fn on_fetch_chunk_done(&mut self, tid: u32) {
        let Some(rid) = self.inflight_fetch.remove(&tid) else {
            return; // not a serving fetch (registry / background traffic)
        };
        let now = self.world.now();
        let pipelined = self.cfg.fetch_chunks > 1;
        let (all_landed, done_key) = {
            let job = self.jobs.get_mut(&rid.0).expect("fetch for retired job");
            job.chunks_left -= 1;
            let all_landed = job.chunks_left == 0;
            let mut done_key = None;
            if all_landed {
                job.fetch_done = Some(now);
                done_key = job.fetch_key.take();
                if let Some(s) = job.fetch_stream.take() {
                    self.fetch_streams.push(s);
                }
            }
            // Release compute on the first chunk when pipelining, else
            // only once the whole prefix has landed.
            if !job.compute_released && (all_landed || pipelined) {
                job.compute_released = true;
                job.ready_at = Some(now);
                self.ready_prefills.push_back(rid);
            }
            (all_landed, done_key)
        };
        if let Some(key) = done_key {
            // The prefix KV is actually resident now: promote host → GPU
            // and release every same-key joiner that was waiting on this
            // in-flight fetch.
            self.prefix.lookup(key);
            if let Some(waiters) = self.inflight_prefix.remove(&key) {
                for w in waiters {
                    if let Some(job) = self.jobs.get_mut(&w.0) {
                        job.fetch_done = Some(now);
                        job.compute_released = true;
                        job.ready_at = Some(now);
                        self.ready_prefills.push_back(w);
                    }
                }
            }
        }
        if all_landed
            && self
                .jobs
                .get(&rid.0)
                .map_or(false, |j| j.kernel_done.is_some())
        {
            self.finish_prefill(rid);
        }
        self.pump();
    }

    /// A tagged serving kernel finished.
    fn on_kernel_done(&mut self, tag: u64) {
        match tag & !TAG_PAYLOAD {
            TAG_PREFILL => {
                let rid = RequestId(tag & TAG_PAYLOAD);
                let now = self.world.now();
                let Some(job) = self.jobs.get_mut(&rid.0) else {
                    return; // foreign kernel tag colliding with our kind byte
                };
                self.prefill_busy = false;
                job.kernel_done = Some(now);
                if job.chunks_left == 0 {
                    self.finish_prefill(rid);
                }
                self.pump();
            }
            TAG_DECODE_STEP => {
                if tag != TAG_DECODE_STEP || !self.decode_busy {
                    return; // not the decode step this engine launched
                }
                self.decode_busy = false;
                let now = self.world.now();
                let batch = std::mem::take(&mut self.decode_inflight);
                for id in batch {
                    if self.sched.decode_tick(id) {
                        if let Some(o) = self.outcomes.get_mut(&id.0) {
                            o.finished_at = Some(now);
                        }
                    }
                }
                self.pump();
            }
            _ => {}
        }
    }

    /// Launch the next ready prefill as a kernel on the prefill stream.
    fn start_next_prefill(&mut self) {
        let Some(rid) = self.ready_prefills.pop_front() else {
            return;
        };
        let now = self.world.now();
        let prompt = self
            .sched
            .sequence(rid)
            .expect("ready seq")
            .req
            .prompt_tokens;
        let job = self.jobs.get_mut(&rid.0).expect("ready job");
        let prefill_s = self.compute.prefill_secs(
            &self.model,
            job.suffix.max(1) as u64,
            prompt as u64,
            self.cfg.tp,
        );
        job.kernel_start = Some(now);
        job.prefill_s = prefill_s;
        self.world.enqueue_kernel_tagged(
            self.prefill_stream,
            Time::from_secs_f64(prefill_s),
            "prefill",
            TAG_PREFILL | rid.0,
        );
        self.prefill_busy = true;
        self.decode_ran_last = false;
    }

    /// Launch one batched decode step for every running decode sequence.
    fn start_decode_step(&mut self) {
        let decodes = self.sched.running_decodes();
        if decodes.is_empty() {
            return;
        }
        // Context grows as sequences generate: prompt + produced so far.
        let max_ctx = decodes
            .iter()
            .filter_map(|id| self.sched.sequence(*id))
            .map(|s| {
                let produced = match s.phase {
                    Phase::Decode { produced } => produced,
                    _ => 0,
                };
                s.req.prompt_tokens as u64 + produced as u64
            })
            .max()
            .unwrap_or(1);
        let decode_s = self.compute.decode_secs(&self.model, max_ctx.max(1), self.cfg.tp);
        self.world.enqueue_kernel_tagged(
            self.decode_stream,
            Time::from_secs_f64(decode_s),
            "decode",
            TAG_DECODE_STEP,
        );
        self.decode_busy = true;
        self.decode_inflight = decodes;
        self.decode_ran_last = true;
    }

    /// Both the KV fetch and the prefill kernel are done: the first token
    /// exists *now*; record the outcome and move the sequence to decode.
    fn finish_prefill(&mut self, rid: RequestId) {
        let now = self.world.now();
        let job = self.jobs.remove(&rid.0).expect("finishing retired job");
        let req = self.sched.sequence(rid).expect("finished seq").req.clone();
        let fetch_s = match (job.fetch_started, job.fetch_done) {
            (Some(a), Some(b)) => b.since(a).as_secs_f64(),
            _ => 0.0,
        };
        // Queueing = arrival → admission, plus waiting for the compute
        // lane after the fetch released this job.
        let lane_wait = match (job.ready_at, job.kernel_start) {
            (Some(a), Some(b)) => b.since(a).as_secs_f64(),
            _ => 0.0,
        };
        let queue_s = job.sched_at.since(req.arrival).as_secs_f64() + lane_wait;
        self.outcomes.insert(
            rid.0,
            RequestOutcome {
                id: rid,
                arrival: req.arrival,
                ttft: TtftBreakdown {
                    queue_s,
                    fetch_s,
                    prefill_s: job.prefill_s,
                },
                first_token_at: now,
                finished_at: None,
            },
        );
        self.inflight_prefill_tokens -= job.suffix.max(1);
        // Cache the full prompt for future turns. Under prefill/decode
        // disaggregation (the paper's LMCache setup), the prefill node's
        // KV is offloaded to the host store right away — every later hit
        // pays the H2D fetch.
        if req.prefix_key != 0 {
            self.prefix.insert(req.prefix_key, req.prompt_tokens);
            if self.cfg.pd_disaggregation {
                self.prefix.offload(req.prefix_key);
            }
        }
        self.sched.prefill_done(rid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::qwen_7b_chat;
    use crate::topology::h20x8;

    fn engine(mma: MmaConfig, compute: Box<dyn Compute>) -> ServingEngine {
        engine_cfg(ServingConfig::default(), mma, compute)
    }

    fn engine_cfg(
        cfg: ServingConfig,
        mma: MmaConfig,
        compute: Box<dyn Compute>,
    ) -> ServingEngine {
        let world = SimWorld::new(h20x8(), mma);
        ServingEngine::new(cfg, qwen_7b_chat(), world, compute, GpuId(0), NumaId(0))
    }

    fn req(id: u64, arrival_ms: u64, prompt: u32, cached: u32, key: u64) -> Request {
        Request {
            id: RequestId(id),
            arrival: Time::from_ms(arrival_ms),
            prompt_tokens: prompt,
            cached_prefix_tokens: cached,
            prefix_key: key,
            output_tokens: 2,
        }
    }

    #[test]
    fn cold_request_has_no_fetch() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.1,
                decode_s: 0.01,
            }),
        );
        let out = e.run(vec![req(1, 0, 1000, 0, 0)]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].ttft.fetch_s, 0.0);
        assert!((out[0].ttft.prefill_s - 0.1).abs() < 1e-9);
        assert!((out[0].ttft_s() - 0.1).abs() < 1e-9, "ttft {}", out[0].ttft_s());
        assert!(out[0].finished_at.is_some());
    }

    #[test]
    fn host_prefix_hit_pays_fetch_and_mma_shrinks_it() {
        let run = |mma: MmaConfig| {
            let mut e = engine(
                mma,
                Box::new(FixedCompute {
                    prefill_s: 0.05,
                    decode_s: 0.005,
                }),
            );
            e.seed_host_prefix(77, 65536);
            let out = e.run(vec![req(1, 0, 65536 + 128, 65536, 77)]);
            out[0].ttft
        };
        let native = run(MmaConfig::native());
        let mma = run(MmaConfig::default());
        // 64k tokens * 0.5 MB/token(I8: 0.25) ≈ 17 GB; native ≈ 0.32 s.
        assert!(native.fetch_s > 0.25, "native fetch {}", native.fetch_s);
        assert!(
            native.fetch_s > 3.0 * mma.fetch_s,
            "mma fetch {} vs native {}",
            mma.fetch_s,
            native.fetch_s
        );
        // Fig 2 regime: fetch dominates TTFT on a 64k native hit.
        assert!(native.fetch_fraction() > 0.5, "{}", native.fetch_fraction());
    }

    #[test]
    fn second_turn_hits_gpu_tier_for_free() {
        // Aggregated (non-PD) mode retains prefill KV on the GPU, so a
        // second turn reuses blocks without any fetch.
        let mut e = engine_cfg(
            ServingConfig {
                pd_disaggregation: false,
                ..Default::default()
            },
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.05,
                decode_s: 0.005,
            }),
        );
        e.seed_host_prefix(9, 16384);
        let out = e.run(vec![
            req(1, 0, 16384 + 64, 16384, 9),
            req(2, 2000, 16384 + 64, 16384, 9),
        ]);
        assert!(out[0].ttft.fetch_s > 0.0, "turn 1 fetches from host");
        assert_eq!(out[1].ttft.fetch_s, 0.0, "turn 2 hits the GPU tier");
    }

    #[test]
    fn queueing_time_is_attributed() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.5,
                decode_s: 0.001,
            }),
        );
        // Two large prefills that cannot batch together (budget 8192).
        let out = e.run(vec![req(1, 0, 8000, 0, 0), req(2, 0, 8000, 0, 0)]);
        assert!(out[0].ttft.queue_s < 1e-6);
        assert!(
            out[1].ttft.queue_s >= 0.5,
            "second prefill queued {}",
            out[1].ttft.queue_s
        );
        // Components account for the full TTFT when nothing overlaps.
        for o in &out {
            assert!((o.ttft.total() - o.ttft_s()).abs() < 1e-9);
        }
    }

    #[test]
    fn outcomes_follow_request_order() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.01,
                decode_s: 0.001,
            }),
        );
        let out = e.run(vec![req(3, 5, 100, 0, 0), req(1, 0, 100, 0, 0)]);
        assert_eq!(out[0].id, RequestId(3));
        assert_eq!(out[1].id, RequestId(1));
    }

    #[test]
    fn all_timestamps_come_from_the_world_clock() {
        // After a run, the engine clock IS the world clock, and the last
        // event (final decode completion) defines both.
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.1,
                decode_s: 0.05,
            }),
        );
        let out = e.run(vec![req(1, 7, 500, 0, 0)]);
        assert_eq!(e.now(), e.world.now());
        assert_eq!(out[0].finished_at.unwrap(), e.world.now());
        // arrival(7ms) + prefill(0.1) + 2 decode steps(0.05 each)
        let want = 0.007 + 0.1 + 2.0 * 0.05;
        assert!((e.now().as_secs_f64() - want).abs() < 1e-9, "{:?}", e.now());
    }

    #[test]
    fn chunked_fetch_pipelines_with_prefill() {
        // fetch_chunks > 1 releases prefill compute after the first chunk,
        // so TTFT ≈ max(fetch, first_chunk + prefill) instead of the sum.
        let run = |chunks: u32| {
            let mut e = engine_cfg(
                ServingConfig {
                    fetch_chunks: chunks,
                    ..Default::default()
                },
                MmaConfig::native(),
                Box::new(FixedCompute {
                    prefill_s: 0.2,
                    decode_s: 0.001,
                }),
            );
            e.seed_host_prefix(5, 32768);
            let out = e.run(vec![req(1, 0, 32768 + 64, 32768, 5)]);
            out[0].ttft_s()
        };
        let serial = run(1);
        let pipelined = run(8);
        assert!(
            pipelined < 0.9 * serial,
            "pipelined {pipelined} vs serial {serial}"
        );
    }

    #[test]
    fn same_key_concurrent_hit_joins_inflight_fetch() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.05,
                decode_s: 0.001,
            }),
        );
        e.seed_host_prefix(7, 32768);
        let out = e.run(vec![
            req(1, 0, 32768 + 64, 32768, 7),
            req(2, 0, 32768 + 64, 32768, 7),
        ]);
        // Only one physical fetch moved the prefix; the second request
        // joined it (paying the in-flight wait) rather than observing a
        // prematurely promoted GPU tier or issuing a duplicate fetch.
        let fetch_bytes = qwen_7b_chat().kv_bytes(32768);
        let n_fetches = e
            .world
            .transfers
            .iter()
            .filter(|r| r.desc.bytes == fetch_bytes)
            .count();
        assert_eq!(n_fetches, 1, "joiner must not re-fetch");
        assert!(
            out[1].ttft.fetch_s > 0.9 * out[0].ttft.fetch_s,
            "joiner pays the shared wait: {} vs {}",
            out[1].ttft.fetch_s,
            out[0].ttft.fetch_s
        );
    }

    #[test]
    fn decode_slows_as_context_grows() {
        // Decode context must include tokens generated so far: with a
        // context-proportional decode model, later steps take longer.
        struct CtxCompute;
        impl Compute for CtxCompute {
            fn prefill_secs(&mut self, _: &ModelSpec, _: u64, _: u64, _: u32) -> f64 {
                0.001
            }
            fn decode_secs(&mut self, _: &ModelSpec, context: u64, _: u32) -> f64 {
                context as f64 * 1e-4
            }
        }
        let world = SimWorld::new(h20x8(), MmaConfig::native());
        let mut e = ServingEngine::new(
            ServingConfig::default(),
            qwen_7b_chat(),
            world,
            Box::new(CtxCompute),
            GpuId(0),
            NumaId(0),
        );
        let mut r = req(1, 0, 100, 0, 0);
        r.output_tokens = 10;
        let out = e.run(vec![r]);
        // Steps at context 100, 101, ..., 109 → sum = 1045 * 1e-4.
        let decode_total = out[0]
            .finished_at
            .unwrap()
            .since(out[0].first_token_at)
            .as_secs_f64();
        let want: f64 = (100..110).map(|c| c as f64 * 1e-4).sum();
        assert!(
            (decode_total - want).abs() < 1e-9,
            "decode {decode_total} vs {want}"
        );
    }
}
