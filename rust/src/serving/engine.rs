//! The serving engine: ties the scheduler, prefix cache, paged KV, the
//! transfer fabric (via [`SimWorld`]) and a compute model into one
//! virtual-time serving loop. TTFT decomposes exactly as the paper
//! measures it: queueing + prefix-cache KV fetch (H2D) + prefill compute.

use super::kv_cache::{KvCacheManager, SeqId};
use super::prefix_cache::{PrefixCache, Tier};
use super::scheduler::{Request, RequestId, Scheduler};
use crate::config::ServingConfig;
use crate::metrics::TtftBreakdown;
use crate::mma::{SimWorld, TransferDesc};
use crate::models::ModelSpec;
use crate::roofline::GpuRoofline;
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};
use std::collections::HashMap;

/// Compute-time provider: roofline for paper-scale models, real PJRT for
/// the live tiny model, fixed for unit tests.
pub trait Compute {
    /// Prefill `new_tokens` with `context` total attended tokens.
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64;
    /// One decode step at `context`.
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64;
}

impl Compute for GpuRoofline {
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        GpuRoofline::prefill_secs(self, m, new_tokens, context, tp)
    }
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        GpuRoofline::decode_secs_per_token(self, m, context, tp)
    }
}

/// Fixed per-call compute times (tests).
pub struct FixedCompute {
    /// Prefill seconds per call.
    pub prefill_s: f64,
    /// Decode seconds per step.
    pub decode_s: f64,
}

impl Compute for FixedCompute {
    fn prefill_secs(&mut self, _: &ModelSpec, _: u64, _: u64, _: u32) -> f64 {
        self.prefill_s
    }
    fn decode_secs(&mut self, _: &ModelSpec, _: u64, _: u32) -> f64 {
        self.decode_s
    }
}

/// Final per-request record.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: RequestId,
    /// Arrival time.
    pub arrival: Time,
    /// TTFT decomposition (queue / fetch / prefill).
    pub ttft: TtftBreakdown,
    /// First token time (absolute).
    pub first_token_at: Time,
    /// All output tokens done (absolute).
    pub finished_at: Option<Time>,
}

impl RequestOutcome {
    /// End-to-end latency if finished.
    pub fn e2e(&self) -> Option<Time> {
        self.finished_at.map(|f| f.since(self.arrival))
    }
}

/// The virtual-time serving engine for one model on one GPU group.
pub struct ServingEngine {
    /// Serving knobs.
    pub cfg: ServingConfig,
    model: ModelSpec,
    sched: Scheduler,
    /// Prefix store (pre-populate for cache-hit experiments).
    pub prefix: PrefixCache,
    /// Paged GPU KV pool.
    pub kv: KvCacheManager,
    /// The transfer clock (shared fabric).
    pub world: SimWorld,
    compute: Box<dyn Compute>,
    prefill_gpu: GpuId,
    host_numa: NumaId,
    clock: Time,
    outcomes: HashMap<u64, RequestOutcome>,
    next_seq: u64,
}

impl ServingEngine {
    /// Assemble an engine. `world` carries the MMA/native transfer config.
    pub fn new(
        cfg: ServingConfig,
        model: ModelSpec,
        world: SimWorld,
        compute: Box<dyn Compute>,
        prefill_gpu: GpuId,
        host_numa: NumaId,
    ) -> ServingEngine {
        let kv = KvCacheManager::new(cfg.gpu_kv_blocks, cfg.kv_block_tokens);
        let prefix = PrefixCache::new(
            cfg.kv_block_tokens,
            cfg.gpu_kv_blocks as u64 * cfg.kv_block_tokens as u64,
            cfg.host_kv_blocks as u64 * cfg.kv_block_tokens as u64,
        );
        ServingEngine {
            sched: Scheduler::new(cfg.clone()),
            kv,
            prefix,
            model: model.clone(),
            world,
            compute,
            prefill_gpu,
            host_numa,
            clock: Time::ZERO,
            outcomes: HashMap::new(),
            cfg,
            next_seq: 0,
        }
    }

    /// Pre-populate the prefix cache with a host-tier prefix (the state
    /// after a previous turn's KV was offloaded — §5.2.1 setup).
    pub fn seed_host_prefix(&mut self, key: u64, tokens: u32) {
        self.prefix.insert(key, tokens);
        self.prefix.offload(key);
    }

    /// Current serving clock.
    pub fn now(&self) -> Time {
        self.clock
    }

    /// The model served.
    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// Name of the transfer policy every KV fetch / offload in this engine
    /// runs under (from the [`SimWorld`]'s engine configuration).
    pub fn policy_name(&self) -> &'static str {
        self.world.policy_name()
    }

    /// Run `requests` to completion; returns outcomes in request order.
    pub fn run(&mut self, mut requests: Vec<Request>) -> Vec<RequestOutcome> {
        // Outcomes are returned in the caller's submission order.
        let ids: Vec<RequestId> = requests.iter().map(|r| r.id).collect();
        requests.sort_by_key(|r| (r.arrival, r.id.0));
        let mut pending: std::collections::VecDeque<Request> = requests.into();

        loop {
            // Admit arrivals that have happened.
            while pending
                .front()
                .map(|r| r.arrival <= self.clock)
                .unwrap_or(false)
            {
                self.sched.submit(pending.pop_front().unwrap());
            }
            if self.sched.is_idle() {
                match pending.front() {
                    Some(r) => {
                        self.clock = r.arrival; // jump to next arrival
                        continue;
                    }
                    None => break,
                }
            }
            self.step();
        }
        ids.iter()
            .map(|id| self.outcomes.get(&id.0).expect("missing outcome").clone())
            .collect()
    }

    /// One engine step: plan, execute prefills (with KV fetches) and one
    /// decode tick for every running decode sequence.
    fn step(&mut self) {
        let step_start = self.clock;
        let plan = self.sched.plan_step();
        debug_assert!(
            !(plan.prefills.is_empty() && plan.decodes.is_empty()),
            "scheduler stalled"
        );

        // --- Prefill lane -------------------------------------------------
        let mut prefill_lane_s = 0.0;
        for (id, suffix) in &plan.prefills {
            let seq = self.sched.sequence(*id).expect("planned seq").req.clone();
            // Prefix-cache consultation.
            let mut fetch_s = 0.0;
            let mut reused: u32 = 0;
            if seq.prefix_key != 0 && seq.cached_prefix_tokens > 0 {
                if let Some((tokens, tier)) = self.prefix.lookup(seq.prefix_key) {
                    reused = tokens.min(seq.cached_prefix_tokens);
                    if tier == Tier::Host {
                        // Fetch KV pages host → GPU before decode can start.
                        let bytes = self.model.kv_bytes(reused as u64).max(1);
                        let t = self.world.memcpy_sync(TransferDesc::new(
                            Direction::H2D,
                            self.prefill_gpu,
                            self.host_numa,
                            bytes,
                        ));
                        let t0 = self.world.now();
                        let done = self.world.run_until_transfer(t);
                        fetch_s = done.since(t0).as_secs_f64();
                    }
                }
            }
            // KV blocks for the full sequence.
            let sid = SeqId(self.next_seq);
            self.next_seq += 1;
            let _ = self.kv.alloc_seq(sid, seq.prompt_tokens + seq.output_tokens);

            let new_tokens = (seq.prompt_tokens - reused).max(*suffix.min(&seq.prompt_tokens)) as u64;
            let prefill_s = self.compute.prefill_secs(
                &self.model,
                new_tokens.max(1),
                seq.prompt_tokens as u64,
                self.cfg.tp,
            );
            prefill_lane_s += fetch_s + prefill_s;

            let queue_s = step_start.since(seq.arrival).as_secs_f64();
            let ttft = TtftBreakdown {
                queue_s,
                fetch_s,
                prefill_s,
            };
            let first_token_at = step_start + Time::from_secs_f64(prefill_lane_s);
            self.outcomes.insert(
                id.0,
                RequestOutcome {
                    id: *id,
                    arrival: seq.arrival,
                    ttft,
                    first_token_at,
                    finished_at: None,
                },
            );
            // Cache the full prompt for future turns. Under prefill/decode
            // disaggregation (the paper's LMCache setup), the prefill
            // node's KV is offloaded to the host store right away — every
            // later hit pays the H2D fetch.
            if seq.prefix_key != 0 {
                self.prefix.insert(seq.prefix_key, seq.prompt_tokens);
                if self.cfg.pd_disaggregation {
                    self.prefix.offload(seq.prefix_key);
                }
            }
            self.sched.prefill_done(*id);
        }

        // --- Decode lane ---------------------------------------------------
        let mut decode_lane_s = 0.0;
        if !plan.decodes.is_empty() {
            // Batched decode: one step serves every running sequence.
            let max_ctx = plan
                .decodes
                .iter()
                .filter_map(|id| self.sched.sequence(*id))
                .map(|s| s.req.prompt_tokens as u64)
                .max()
                .unwrap_or(1);
            decode_lane_s = self.compute.decode_secs(&self.model, max_ctx, self.cfg.tp);
            for id in &plan.decodes {
                if self.sched.decode_tick(*id) {
                    let done_at = step_start + Time::from_secs_f64(decode_lane_s);
                    if let Some(o) = self.outcomes.get_mut(&id.0) {
                        o.finished_at = Some(done_at);
                    }
                }
            }
        }

        // PD disaggregation: prefill and decode groups advance in parallel;
        // aggregated: they serialize on the same GPUs.
        let step_s = if self.cfg.pd_disaggregation {
            prefill_lane_s.max(decode_lane_s)
        } else {
            prefill_lane_s + decode_lane_s
        };
        self.clock = step_start + Time::from_secs_f64(step_s.max(1e-6));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::qwen_7b_chat;
    use crate::topology::h20x8;

    fn engine(mma: MmaConfig, compute: Box<dyn Compute>) -> ServingEngine {
        let world = SimWorld::new(h20x8(), mma);
        ServingEngine::new(
            ServingConfig::default(),
            qwen_7b_chat(),
            world,
            compute,
            GpuId(0),
            NumaId(0),
        )
    }

    fn req(id: u64, arrival_ms: u64, prompt: u32, cached: u32, key: u64) -> Request {
        Request {
            id: RequestId(id),
            arrival: Time::from_ms(arrival_ms),
            prompt_tokens: prompt,
            cached_prefix_tokens: cached,
            prefix_key: key,
            output_tokens: 2,
        }
    }

    #[test]
    fn cold_request_has_no_fetch() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.1,
                decode_s: 0.01,
            }),
        );
        let out = e.run(vec![req(1, 0, 1000, 0, 0)]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].ttft.fetch_s, 0.0);
        assert!((out[0].ttft.prefill_s - 0.1).abs() < 1e-9);
        assert!(out[0].finished_at.is_some());
    }

    #[test]
    fn host_prefix_hit_pays_fetch_and_mma_shrinks_it() {
        let run = |mma: MmaConfig| {
            let mut e = engine(
                mma,
                Box::new(FixedCompute {
                    prefill_s: 0.05,
                    decode_s: 0.005,
                }),
            );
            e.seed_host_prefix(77, 65536);
            let out = e.run(vec![req(1, 0, 65536 + 128, 65536, 77)]);
            out[0].ttft
        };
        let native = run(MmaConfig::native());
        let mma = run(MmaConfig::default());
        // 64k tokens * 0.5 MB/token(I8: 0.25) ≈ 17 GB; native ≈ 0.32 s.
        assert!(native.fetch_s > 0.25, "native fetch {}", native.fetch_s);
        assert!(
            native.fetch_s > 3.0 * mma.fetch_s,
            "mma fetch {} vs native {}",
            mma.fetch_s,
            native.fetch_s
        );
        // Fig 2 regime: fetch dominates TTFT on a 64k native hit.
        assert!(native.fetch_fraction() > 0.5, "{}", native.fetch_fraction());
    }

    #[test]
    fn second_turn_hits_gpu_tier_for_free() {
        // Aggregated (non-PD) mode retains prefill KV on the GPU, so a
        // second turn reuses blocks without any fetch.
        let world = SimWorld::new(h20x8(), MmaConfig::native());
        let mut e = ServingEngine::new(
            ServingConfig {
                pd_disaggregation: false,
                ..Default::default()
            },
            qwen_7b_chat(),
            world,
            Box::new(FixedCompute {
                prefill_s: 0.05,
                decode_s: 0.005,
            }),
            GpuId(0),
            NumaId(0),
        );
        e.seed_host_prefix(9, 16384);
        let out = e.run(vec![
            req(1, 0, 16384 + 64, 16384, 9),
            req(2, 2000, 16384 + 64, 16384, 9),
        ]);
        assert!(out[0].ttft.fetch_s > 0.0, "turn 1 fetches from host");
        assert_eq!(out[1].ttft.fetch_s, 0.0, "turn 2 hits the GPU tier");
    }

    #[test]
    fn queueing_time_is_attributed() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.5,
                decode_s: 0.001,
            }),
        );
        // Two large prefills that cannot batch together (budget 8192).
        let out = e.run(vec![req(1, 0, 8000, 0, 0), req(2, 0, 8000, 0, 0)]);
        assert!(out[0].ttft.queue_s < 1e-6);
        assert!(
            out[1].ttft.queue_s >= 0.5,
            "second prefill queued {}",
            out[1].ttft.queue_s
        );
    }

    #[test]
    fn outcomes_follow_request_order() {
        let mut e = engine(
            MmaConfig::native(),
            Box::new(FixedCompute {
                prefill_s: 0.01,
                decode_s: 0.001,
            }),
        );
        let out = e.run(vec![req(3, 5, 100, 0, 0), req(1, 0, 100, 0, 0)]);
        assert_eq!(out[0].id, RequestId(3));
        assert_eq!(out[1].id, RequestId(1));
    }
}
