//! The single-GPU serving engine: exactly the N=1 case of the
//! [`ServingFleet`].
//!
//! Everything the engine used to implement directly — the event-driven
//! loop on the one [`SimWorld`] clock, arrivals as world timers, prefix
//! KV fetches as contending `memcpy_async` transfers, tagged
//! prefill/decode kernels, chunked fetch/compute pipelining, same-key
//! fetch joining — now lives in [`ServingInstance`] + [`ServingFleet`];
//! this type pins one instance to one GPU and keeps the historical
//! construction surface for tests, figures, and the closed-loop
//! `mma serve` path.
//!
//! TTFT decomposes as the paper measures it: queueing + prefix-cache KV
//! fetch (H2D) + prefill compute, every timestamp read off the world
//! clock.

use super::fleet::ServingFleet;
use super::instance::{Compute, RequestOutcome};
use super::scheduler::Request;
use crate::config::{FleetConfig, ServingConfig};
use crate::mma::SimWorld;
use crate::models::ModelSpec;
use crate::sim::Time;
use crate::topology::{GpuId, NumaId};

/// A one-instance [`ServingFleet`] pinned to a specific GPU.
pub struct ServingEngine {
    fleet: ServingFleet,
}

impl ServingEngine {
    /// Assemble an engine. `world` carries the MMA/native transfer config.
    pub fn new(
        cfg: ServingConfig,
        model: ModelSpec,
        world: SimWorld,
        compute: Box<dyn Compute>,
        prefill_gpu: GpuId,
        host_numa: NumaId,
    ) -> ServingEngine {
        let fleet = ServingFleet::on_gpus(
            FleetConfig::default(),
            cfg,
            model,
            world,
            vec![compute],
            vec![prefill_gpu],
            host_numa,
        );
        ServingEngine { fleet }
    }

    /// Pre-populate the host prefix tier (the state after a previous
    /// turn's KV was offloaded — §5.2.1 setup). Byte-accounted through
    /// the fleet's shared [`crate::serving::HostPrefixPool`].
    pub fn seed_host_prefix(&mut self, key: u64, tokens: u32) {
        self.fleet.seed_host_prefix(key, tokens);
    }

    /// Current virtual time — the one shared [`SimWorld`] clock.
    pub fn now(&self) -> Time {
        self.fleet.now()
    }

    /// The model served.
    pub fn model(&self) -> &ModelSpec {
        self.fleet.model()
    }

    /// Name of the transfer policy every KV fetch / offload in this
    /// engine runs under.
    pub fn policy_name(&self) -> &'static str {
        self.fleet.policy_name()
    }

    /// The shared world: fabric, GPUs, and the one virtual clock.
    pub fn world(&self) -> &SimWorld {
        &self.fleet.world
    }

    /// Mutable access to the shared world (co-running registry phases,
    /// background loops, sampling).
    pub fn world_mut(&mut self) -> &mut SimWorld {
        &mut self.fleet.world
    }

    /// The underlying one-instance fleet.
    pub fn fleet(&self) -> &ServingFleet {
        &self.fleet
    }

    /// Run `requests` to completion; returns outcomes in request order.
    /// Arrivals are scheduled as world timers, so anything else in flight
    /// on the same world (background loops, model sleep/wake transfers)
    /// co-runs with the serving traffic on the shared fabric.
    pub fn run(&mut self, requests: Vec<Request>) -> Vec<RequestOutcome> {
        self.fleet.run(requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::{qwen_7b_chat, ModelSpec};
    use crate::serving::scheduler::RequestId;
    use crate::testkit::{engine as engine_cfg, fixed, request as req};
    use crate::topology::h20x8;

    fn engine(mma: MmaConfig, compute: Box<dyn Compute>) -> ServingEngine {
        engine_cfg(ServingConfig::default(), mma, compute)
    }

    #[test]
    fn cold_request_has_no_fetch() {
        let mut e = engine(
            MmaConfig::native(),
            fixed(0.1, 0.01),
        );
        let out = e.run(vec![req(1, 0, 1000, 0, 0)]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].ttft.fetch_s, 0.0);
        assert!((out[0].ttft.prefill_s - 0.1).abs() < 1e-9);
        assert!((out[0].ttft_s() - 0.1).abs() < 1e-9, "ttft {}", out[0].ttft_s());
        assert!(out[0].finished_at.is_some());
    }

    #[test]
    fn host_prefix_hit_pays_fetch_and_mma_shrinks_it() {
        let run = |mma: MmaConfig| {
            let mut e = engine(
                mma,
                fixed(0.05, 0.005),
            );
            e.seed_host_prefix(77, 65536);
            let out = e.run(vec![req(1, 0, 65536 + 128, 65536, 77)]);
            out[0].ttft
        };
        let native = run(MmaConfig::native());
        let mma = run(MmaConfig::default());
        // 64k tokens * 0.5 MB/token(I8: 0.25) ≈ 17 GB; native ≈ 0.32 s.
        assert!(native.fetch_s > 0.25, "native fetch {}", native.fetch_s);
        assert!(
            native.fetch_s > 3.0 * mma.fetch_s,
            "mma fetch {} vs native {}",
            mma.fetch_s,
            native.fetch_s
        );
        // Fig 2 regime: fetch dominates TTFT on a 64k native hit.
        assert!(native.fetch_fraction() > 0.5, "{}", native.fetch_fraction());
    }

    #[test]
    fn second_turn_hits_gpu_tier_for_free() {
        // Aggregated (non-PD) mode retains prefill KV on the GPU, so a
        // second turn reuses blocks without any fetch.
        let mut e = engine_cfg(
            ServingConfig {
                pd_disaggregation: false,
                ..Default::default()
            },
            MmaConfig::native(),
            fixed(0.05, 0.005),
        );
        e.seed_host_prefix(9, 16384);
        let out = e.run(vec![
            req(1, 0, 16384 + 64, 16384, 9),
            req(2, 2000, 16384 + 64, 16384, 9),
        ]);
        assert!(out[0].ttft.fetch_s > 0.0, "turn 1 fetches from host");
        assert_eq!(out[1].ttft.fetch_s, 0.0, "turn 2 hits the GPU tier");
    }

    #[test]
    fn queueing_time_is_attributed() {
        let mut e = engine(
            MmaConfig::native(),
            fixed(0.5, 0.001),
        );
        // Two large prefills that cannot batch together (budget 8192).
        let out = e.run(vec![req(1, 0, 8000, 0, 0), req(2, 0, 8000, 0, 0)]);
        assert!(out[0].ttft.queue_s < 1e-6);
        assert!(
            out[1].ttft.queue_s >= 0.5,
            "second prefill queued {}",
            out[1].ttft.queue_s
        );
        // Components account for the full TTFT when nothing overlaps.
        for o in &out {
            assert!((o.ttft.total() - o.ttft_s()).abs() < 1e-9);
        }
    }

    #[test]
    fn outcomes_follow_request_order() {
        let mut e = engine(
            MmaConfig::native(),
            fixed(0.01, 0.001),
        );
        let out = e.run(vec![req(3, 5, 100, 0, 0), req(1, 0, 100, 0, 0)]);
        assert_eq!(out[0].id, RequestId(3));
        assert_eq!(out[1].id, RequestId(1));
    }

    #[test]
    fn all_timestamps_come_from_the_world_clock() {
        // After a run, the engine clock IS the world clock, and the last
        // event (final decode completion) defines both.
        let mut e = engine(
            MmaConfig::native(),
            fixed(0.1, 0.05),
        );
        let out = e.run(vec![req(1, 7, 500, 0, 0)]);
        assert_eq!(e.now(), e.world().now());
        assert_eq!(out[0].finished_at.unwrap(), e.world().now());
        // arrival(7ms) + prefill(0.1) + 2 decode steps(0.05 each)
        let want = 0.007 + 0.1 + 2.0 * 0.05;
        assert!((e.now().as_secs_f64() - want).abs() < 1e-9, "{:?}", e.now());
    }

    #[test]
    fn chunked_fetch_pipelines_with_prefill() {
        // fetch_chunks > 1 releases prefill compute after the first chunk,
        // so TTFT ≈ max(fetch, first_chunk + prefill) instead of the sum.
        let run = |chunks: u32| {
            let mut e = engine_cfg(
                ServingConfig {
                    fetch_chunks: chunks,
                    ..Default::default()
                },
                MmaConfig::native(),
                fixed(0.2, 0.001),
            );
            e.seed_host_prefix(5, 32768);
            let out = e.run(vec![req(1, 0, 32768 + 64, 32768, 5)]);
            out[0].ttft_s()
        };
        let serial = run(1);
        let pipelined = run(8);
        assert!(
            pipelined < 0.9 * serial,
            "pipelined {pipelined} vs serial {serial}"
        );
    }

    #[test]
    fn same_key_concurrent_hit_joins_inflight_fetch() {
        let mut e = engine(
            MmaConfig::native(),
            fixed(0.05, 0.001),
        );
        e.seed_host_prefix(7, 32768);
        let out = e.run(vec![
            req(1, 0, 32768 + 64, 32768, 7),
            req(2, 0, 32768 + 64, 32768, 7),
        ]);
        // Only one physical fetch moved the prefix; the second request
        // joined it (paying the in-flight wait) rather than observing a
        // prematurely promoted GPU tier or issuing a duplicate fetch.
        let fetch_bytes = qwen_7b_chat().kv_bytes(32768);
        let n_fetches = e
            .world()
            .transfers
            .iter()
            .filter(|r| r.desc.bytes == fetch_bytes)
            .count();
        assert_eq!(n_fetches, 1, "joiner must not re-fetch");
        assert!(
            out[1].ttft.fetch_s > 0.9 * out[0].ttft.fetch_s,
            "joiner pays the shared wait: {} vs {}",
            out[1].ttft.fetch_s,
            out[0].ttft.fetch_s
        );
    }

    #[test]
    fn decode_slows_as_context_grows() {
        // Decode context must include tokens generated so far: with a
        // context-proportional decode model, later steps take longer.
        struct CtxCompute;
        impl Compute for CtxCompute {
            fn prefill_secs(&mut self, _: &ModelSpec, _: u64, _: u64, _: u32) -> f64 {
                0.001
            }
            fn decode_secs(&mut self, _: &ModelSpec, context: u64, _: u32) -> f64 {
                context as f64 * 1e-4
            }
        }
        let world = SimWorld::new(h20x8(), MmaConfig::native());
        let mut e = ServingEngine::new(
            ServingConfig::default(),
            qwen_7b_chat(),
            world,
            Box::new(CtxCompute),
            GpuId(0),
            NumaId(0),
        );
        let mut r = req(1, 0, 100, 0, 0);
        r.output_tokens = 10;
        let out = e.run(vec![r]);
        // Steps at context 100, 101, ..., 109 → sum = 1045 * 1e-4.
        let decode_total = out[0]
            .finished_at
            .unwrap()
            .since(out[0].first_token_at)
            .as_secs_f64();
        let want: f64 = (100..110).map(|c| c as f64 * 1e-4).sum();
        assert!(
            (decode_total - want).abs() < 1e-9,
            "decode {decode_total} vs {want}"
        );
    }

    #[test]
    fn seeding_beyond_host_capacity_drops_lru() {
        // The host tier is byte-accounted: over-seeding cannot exceed the
        // configured capacity (satellite: no more allocator bypass).
        let mut e = engine_cfg(
            ServingConfig {
                host_kv_blocks: 2048, // 32768 tokens of host tier
                ..Default::default()
            },
            MmaConfig::native(),
            fixed(0.01, 0.001),
        );
        let cap_bytes = qwen_7b_chat().kv_bytes(2048 * 16);
        for key in 1..=8u64 {
            e.seed_host_prefix(key, 16384); // 8 × 16k tokens ≫ capacity
            assert!(
                e.fleet().host_tier().used_bytes() <= cap_bytes,
                "host tier exceeded configured capacity"
            );
        }
        assert_eq!(e.fleet().host_tier().len(), 2, "LRU seeds dropped");
        assert_eq!(e.fleet().host_tier().peek(8), Some(16384));
        assert_eq!(e.fleet().host_tier().peek(1), None);
    }
}
