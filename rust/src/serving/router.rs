//! Request router / frontend: maps incoming requests to serving instances.
//!
//! Event-driven: the router holds no clock and never blocks. The
//! [`crate::serving::ServingFleet`] calls [`Router::route_next`] when an
//! arrival timer fires and [`Router::done`] when a completion notice
//! retires a request, so every placement decision happens mid-simulation
//! on the one [`crate::mma::SimWorld`] event loop. Routing to a sleeping
//! instance does not wait for the wake: the router reports `needs_wake`
//! and the fleet starts a non-blocking wake whose weight transfers co-run
//! with live serving traffic (the control plane whose switch latency
//! Fig 13 measures).
//!
//! Residency is router state, not a per-arrival argument: the fleet calls
//! [`Router::set_awake`] on sleep/wake transitions, and the least-loaded
//! pick reads an incrementally-maintained index (a lazy-deletion min-heap
//! over `(load, instance)`) instead of scanning every instance per
//! arrival — O(log n) amortized per event and allocation-free at steady
//! state.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Placement policy across the instances of a fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate across awake instances.
    RoundRobin,
    /// Pick the awake instance with the fewest in-flight requests.
    LeastLoaded,
}

impl RoutePolicy {
    /// Canonical name (the spelling `parse` accepts and reports print).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }

    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Router over a fleet's serving instances.
pub struct Router {
    policy: RoutePolicy,
    inflight: Vec<u32>,
    /// Residency per instance, updated by [`Router::set_awake`]
    /// (instances start awake).
    awake: Vec<bool>,
    awake_count: usize,
    /// Incremental least-loaded index: min-heap of `(load, instance)`
    /// snapshots with lazy deletion. Invariant: every awake instance has
    /// an entry carrying its *current* load (pushed on route/done/wake);
    /// entries whose load or residency no longer matches are stale and
    /// popped on sight. Loads are small and churn is per-request, so the
    /// heap stays shallow and reuses its buffer — no per-arrival scan,
    /// no per-arrival allocation.
    heap: BinaryHeap<Reverse<(u32, usize)>>,
    rr_next: usize,
    /// Instances that received a request while asleep (on-demand wake
    /// triggers), in routing order.
    pub wake_events: Vec<usize>,
}

impl Router {
    /// Router for `instances` serving slots (all initially awake).
    pub fn new(policy: RoutePolicy, instances: usize) -> Router {
        Router {
            policy,
            inflight: vec![0; instances],
            awake: vec![true; instances],
            awake_count: instances,
            heap: (0..instances).map(|i| Reverse((0, i))).collect(),
            rr_next: 0,
            wake_events: Vec::new(),
        }
    }

    /// Record instance `instance` going to sleep / waking up. Waking
    /// refreshes its index entry; sleeping just strands stale entries for
    /// the lazy pop. Idempotent.
    pub fn set_awake(&mut self, instance: usize, awake: bool) {
        if self.awake[instance] == awake {
            return;
        }
        self.awake[instance] = awake;
        if awake {
            self.awake_count += 1;
            self.heap.push(Reverse((self.inflight[instance], instance)));
        } else {
            self.awake_count -= 1;
        }
    }

    /// Route one request using the router's own residency state (see
    /// [`Router::set_awake`]). `affinity` is the instance already holding
    /// the request's prefix GPU-resident (prefix-affinity routing),
    /// honored when awake. If every instance is asleep the pick falls
    /// back to the placement policy over all instances and `needs_wake`
    /// is true — the caller starts a non-blocking wake and the request
    /// queues behind it. Returns `(instance, needs_wake)`.
    pub fn route_next(&mut self, affinity: Option<usize>) -> (usize, bool) {
        assert!(!self.inflight.is_empty());
        let chosen = match affinity.filter(|&a| self.awake[a]) {
            Some(a) => a,
            None => match self.policy {
                RoutePolicy::RoundRobin => self.pick_round_robin(),
                RoutePolicy::LeastLoaded => self.pick_least_loaded(),
            },
        };
        let needs_wake = !self.awake[chosen];
        if needs_wake {
            self.wake_events.push(chosen);
        }
        self.inflight[chosen] += 1;
        if self.awake[chosen] {
            self.heap.push(Reverse((self.inflight[chosen], chosen)));
        }
        (chosen, needs_wake)
    }

    /// Legacy arrival API: sync residency from `awake`, then route. Kept
    /// for callers that track residency themselves; new code should use
    /// [`Router::set_awake`] + [`Router::route_next`].
    pub fn route(&mut self, affinity: Option<usize>, awake: &[bool]) -> (usize, bool) {
        assert_eq!(awake.len(), self.inflight.len());
        assert!(!awake.is_empty());
        for (i, &a) in awake.iter().enumerate() {
            self.set_awake(i, a);
        }
        self.route_next(affinity)
    }

    /// A request finished on `instance`.
    pub fn done(&mut self, instance: usize) {
        debug_assert!(self.inflight[instance] > 0);
        self.inflight[instance] -= 1;
        if self.awake[instance] {
            self.heap.push(Reverse((self.inflight[instance], instance)));
        }
    }

    /// Current load of an instance.
    pub fn load(&self, instance: usize) -> u32 {
        self.inflight[instance]
    }

    /// Lowest `(load, index)` among awake instances via the lazy heap;
    /// full scan over everyone only in the all-asleep fallback.
    fn pick_least_loaded(&mut self) -> usize {
        while let Some(&Reverse((load, i))) = self.heap.peek() {
            if self.awake[i] && self.inflight[i] == load {
                return i;
            }
            self.heap.pop();
        }
        debug_assert_eq!(self.awake_count, 0);
        (0..self.inflight.len())
            .min_by_key(|&i| (self.inflight[i], i))
            .expect("router has instances")
    }

    /// The `rr_next`-th awake instance (all instances when none are
    /// awake) — the same rotation the old materialized ready-list
    /// produced, without building it.
    fn pick_round_robin(&mut self) -> usize {
        let n = self.inflight.len();
        let pick = if self.awake_count == 0 {
            self.rr_next % n
        } else {
            let mut k = self.rr_next % self.awake_count;
            let mut found = 0;
            for (i, &a) in self.awake.iter().enumerate() {
                if a {
                    if k == 0 {
                        found = i;
                        break;
                    }
                    k -= 1;
                }
            }
            found
        };
        self.rr_next += 1;
        pick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_awake(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    #[test]
    fn round_robin_rotation_order() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = all_awake(3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(None, &awake).0).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "strict rotation");
    }

    #[test]
    fn round_robin_skips_sleeping_instances() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = vec![true, false, true];
        let picks: Vec<usize> = (0..4).map(|_| r.route(None, &awake).0).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "rotation over awake only");
        assert!(r.wake_events.is_empty());
    }

    #[test]
    fn least_loaded_prefers_idle_and_breaks_ties_low() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 3);
        let awake = all_awake(3);
        // Equal loads: the tie breaks toward the lowest index.
        assert_eq!(r.route(None, &awake).0, 0);
        assert_eq!(r.route(None, &awake).0, 1, "0 is now loaded");
        assert_eq!(r.route(None, &awake).0, 2);
        // 1 drains first: it becomes the unique minimum.
        r.done(1);
        assert_eq!(r.route(None, &awake).0, 1);
        // All tied again at load 1 → lowest index wins the tie.
        r.done(0);
        r.done(1);
        r.done(2);
        assert_eq!(r.load(0), 0);
        assert_eq!(r.route(None, &awake).0, 0);
    }

    #[test]
    fn wake_events_account_sleeping_routes() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        let asleep = vec![false, false];
        let (i, wake) = r.route(None, &asleep);
        assert_eq!(i, 0, "policy pick over all instances when none awake");
        assert!(wake, "landing on a sleeping instance needs a wake");
        assert_eq!(r.wake_events, vec![0]);
        // A later route to an awake instance records nothing.
        let (j, wake2) = r.route(None, &[true, false]);
        assert_eq!(j, 0);
        assert!(!wake2);
        assert_eq!(r.wake_events.len(), 1);
        assert_eq!(r.load(0), 2);
    }

    #[test]
    fn prefix_affinity_overrides_rotation() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = all_awake(3);
        assert_eq!(r.route(Some(2), &awake).0, 2, "affinity wins");
        assert_eq!(r.route(Some(2), &awake).0, 2, "and keeps winning");
        // A sleeping affinity target falls back to the policy.
        let (i, wake) = r.route(Some(1), &[true, false, true]);
        assert_ne!(i, 1);
        assert!(!wake);
    }

    #[test]
    fn set_awake_drives_routing_without_slices() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 3);
        r.set_awake(0, false);
        assert_eq!(r.route_next(None).0, 1);
        r.set_awake(1, false);
        r.set_awake(2, false);
        // All asleep: fallback picks the global least-loaded and wakes it.
        let (i, wake) = r.route_next(None);
        assert_eq!(i, 0);
        assert!(wake);
        // Waking an instance puts it back in the index immediately.
        r.set_awake(2, true);
        assert_eq!(r.route_next(None), (2, false));
    }

    #[test]
    fn route_policy_parse_roundtrips() {
        for p in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("ll"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    /// Full-scan reference: the exact pre-index algorithm (materialized
    /// ready list, `min_by_key` / modular rotation over it).
    fn oracle(
        policy: RoutePolicy,
        rr: &mut usize,
        loads: &[u32],
        awake: &[bool],
        affinity: Option<usize>,
    ) -> usize {
        if let Some(a) = affinity.filter(|&a| awake[a]) {
            return a;
        }
        let ready: Vec<usize> = (0..loads.len()).filter(|&i| awake[i]).collect();
        let pool = if ready.is_empty() {
            (0..loads.len()).collect()
        } else {
            ready
        };
        match policy {
            RoutePolicy::RoundRobin => {
                let i = pool[*rr % pool.len()];
                *rr += 1;
                i
            }
            RoutePolicy::LeastLoaded => {
                *pool.iter().min_by_key(|&&i| (loads[i], i)).unwrap()
            }
        }
    }

    #[test]
    fn property_incremental_index_matches_full_scan_under_churn() {
        // Randomized route/done/sleep/wake churn: after every event the
        // incremental index must agree with a fresh full scan (the oracle
        // replays the old router algorithm exactly, including rotation
        // state and all-asleep fallback).
        crate::testkit::check("router_index_oracle", |rng| {
            let n = rng.range_usize(1, 9);
            let policy = if rng.bool(0.5) {
                RoutePolicy::LeastLoaded
            } else {
                RoutePolicy::RoundRobin
            };
            let mut r = Router::new(policy, n);
            let mut awake = vec![true; n];
            let mut loads = vec![0u32; n];
            let mut rr = 0usize;
            for _ in 0..rng.range_usize(10, 200) {
                match rng.range_u64(0, 4) {
                    0 => {
                        let i = rng.range_usize(0, n);
                        let a = rng.bool(0.5);
                        awake[i] = a;
                        r.set_awake(i, a);
                    }
                    1 => {
                        let loaded: Vec<usize> = (0..n).filter(|&i| loads[i] > 0).collect();
                        if let Some(&i) = (!loaded.is_empty()).then(|| rng.choose(&loaded)) {
                            loads[i] -= 1;
                            r.done(i);
                        }
                    }
                    _ => {
                        let affinity = rng.bool(0.3).then(|| rng.range_usize(0, n));
                        let expect = oracle(policy, &mut rr, &loads, &awake, affinity);
                        let (got, needs_wake) = r.route_next(affinity);
                        assert_eq!(got, expect, "index diverged from full scan");
                        assert_eq!(needs_wake, !awake[got]);
                        loads[got] += 1;
                    }
                }
                for i in 0..n {
                    assert_eq!(r.load(i), loads[i]);
                }
            }
        });
    }
}
