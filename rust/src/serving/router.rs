//! Request router / frontend: maps incoming requests to serving instances.
//!
//! Event-driven: the router holds no clock and never blocks. The
//! [`crate::serving::ServingFleet`] calls [`Router::route`] when an
//! arrival timer fires and [`Router::done`] when a completion notice
//! retires a request, so every placement decision happens mid-simulation
//! on the one [`crate::mma::SimWorld`] event loop. Routing to a sleeping
//! instance does not wait for the wake: the router reports `needs_wake`
//! and the fleet starts a non-blocking wake whose weight transfers co-run
//! with live serving traffic (the control plane whose switch latency
//! Fig 13 measures).

/// Placement policy across the instances of a fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate across awake instances.
    RoundRobin,
    /// Pick the awake instance with the fewest in-flight requests.
    LeastLoaded,
}

impl RoutePolicy {
    /// Canonical name (the spelling `parse` accepts and reports print).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }

    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Router over a fleet's serving instances.
pub struct Router {
    policy: RoutePolicy,
    inflight: Vec<u32>,
    rr_next: usize,
    /// Instances that received a request while asleep (on-demand wake
    /// triggers), in routing order.
    pub wake_events: Vec<usize>,
}

impl Router {
    /// Router for `instances` serving slots.
    pub fn new(policy: RoutePolicy, instances: usize) -> Router {
        Router {
            policy,
            inflight: vec![0; instances],
            rr_next: 0,
            wake_events: Vec::new(),
        }
    }

    /// Route one request. `awake[i]` is instance `i`'s residency;
    /// `affinity` is the instance already holding the request's prefix
    /// GPU-resident (prefix-affinity routing), honored when awake.
    /// If every instance is asleep the pick falls back to the placement
    /// policy over all instances and `needs_wake` is true — the caller
    /// starts a non-blocking wake and the request queues behind it.
    /// Returns `(instance, needs_wake)`.
    pub fn route(&mut self, affinity: Option<usize>, awake: &[bool]) -> (usize, bool) {
        assert_eq!(awake.len(), self.inflight.len());
        assert!(!awake.is_empty());
        let chosen = match affinity.filter(|&a| awake[a]) {
            Some(a) => a,
            None => {
                let ready: Vec<usize> = (0..awake.len()).filter(|&i| awake[i]).collect();
                let pool = if ready.is_empty() {
                    (0..awake.len()).collect()
                } else {
                    ready
                };
                match self.policy {
                    RoutePolicy::RoundRobin => {
                        let i = pool[self.rr_next % pool.len()];
                        self.rr_next += 1;
                        i
                    }
                    RoutePolicy::LeastLoaded => *pool
                        .iter()
                        .min_by_key(|&&i| (self.inflight[i], i))
                        .unwrap(),
                }
            }
        };
        let needs_wake = !awake[chosen];
        if needs_wake {
            self.wake_events.push(chosen);
        }
        self.inflight[chosen] += 1;
        (chosen, needs_wake)
    }

    /// A request finished on `instance`.
    pub fn done(&mut self, instance: usize) {
        debug_assert!(self.inflight[instance] > 0);
        self.inflight[instance] -= 1;
    }

    /// Current load of an instance.
    pub fn load(&self, instance: usize) -> u32 {
        self.inflight[instance]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_awake(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    #[test]
    fn round_robin_rotation_order() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = all_awake(3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(None, &awake).0).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "strict rotation");
    }

    #[test]
    fn round_robin_skips_sleeping_instances() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = vec![true, false, true];
        let picks: Vec<usize> = (0..4).map(|_| r.route(None, &awake).0).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "rotation over awake only");
        assert!(r.wake_events.is_empty());
    }

    #[test]
    fn least_loaded_prefers_idle_and_breaks_ties_low() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 3);
        let awake = all_awake(3);
        // Equal loads: the tie breaks toward the lowest index.
        assert_eq!(r.route(None, &awake).0, 0);
        assert_eq!(r.route(None, &awake).0, 1, "0 is now loaded");
        assert_eq!(r.route(None, &awake).0, 2);
        // 1 drains first: it becomes the unique minimum.
        r.done(1);
        assert_eq!(r.route(None, &awake).0, 1);
        // All tied again at load 1 → lowest index wins the tie.
        r.done(0);
        r.done(1);
        r.done(2);
        assert_eq!(r.load(0), 0);
        assert_eq!(r.route(None, &awake).0, 0);
    }

    #[test]
    fn wake_events_account_sleeping_routes() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        let asleep = vec![false, false];
        let (i, wake) = r.route(None, &asleep);
        assert_eq!(i, 0, "policy pick over all instances when none awake");
        assert!(wake, "landing on a sleeping instance needs a wake");
        assert_eq!(r.wake_events, vec![0]);
        // A later route to an awake instance records nothing.
        let (j, wake2) = r.route(None, &[true, false]);
        assert_eq!(j, 0);
        assert!(!wake2);
        assert_eq!(r.wake_events.len(), 1);
        assert_eq!(r.load(0), 2);
    }

    #[test]
    fn prefix_affinity_overrides_rotation() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3);
        let awake = all_awake(3);
        assert_eq!(r.route(Some(2), &awake).0, 2, "affinity wins");
        assert_eq!(r.route(Some(2), &awake).0, 2, "and keeps winning");
        // A sleeping affinity target falls back to the policy.
        let (i, wake) = r.route(Some(1), &[true, false, true]);
        assert_ne!(i, 1);
        assert!(!wake);
    }

    #[test]
    fn route_policy_parse_roundtrips() {
        for p in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("ll"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }
}
