//! Request router / frontend: maps incoming requests to model instances,
//! waking sleeping models on demand (the vLLM-router-style control plane
//! whose switch latency Fig 13 measures).

use super::model_registry::{ModelRegistry, ModelState, PhaseResult};
use crate::mma::SimWorld;
use crate::sim::Time;

/// Routing policy across replicas of the same model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Rotate across ready instances.
    RoundRobin,
    /// Pick the instance with the fewest in-flight requests.
    LeastLoaded,
}

/// Router over the instances of a [`ModelRegistry`].
pub struct Router {
    policy: Policy,
    inflight: Vec<u32>,
    rr_next: usize,
    /// Wake latency paid per on-demand wake, recorded for reporting.
    pub wake_events: Vec<(usize, PhaseResult)>,
}

impl Router {
    /// Router for `instances` model slots.
    pub fn new(policy: Policy, instances: usize) -> Router {
        Router {
            policy,
            inflight: vec![0; instances],
            rr_next: 0,
            wake_events: Vec::new(),
        }
    }

    /// Route a request for model instance-set `candidates` (replica ids).
    /// If every candidate is asleep, the first is woken on demand (cost
    /// recorded and returned). Returns `(instance, wake_cost)`.
    pub fn route(
        &mut self,
        world: &mut SimWorld,
        registry: &mut ModelRegistry,
        candidates: &[usize],
    ) -> (usize, Option<Time>) {
        assert!(!candidates.is_empty());
        let ready: Vec<usize> = candidates
            .iter()
            .copied()
            .filter(|&i| registry.instance(i).state == ModelState::Active)
            .collect();
        let (chosen, wake) = if ready.is_empty() {
            // Cold hit: wake on demand.
            let target = candidates[0];
            let phase = registry.wake(world, target);
            self.wake_events.push((target, phase));
            (target, Some(phase.total()))
        } else {
            let pick = match self.policy {
                Policy::RoundRobin => {
                    let i = ready[self.rr_next % ready.len()];
                    self.rr_next += 1;
                    i
                }
                Policy::LeastLoaded => *ready
                    .iter()
                    .min_by_key(|&&i| self.inflight[i])
                    .unwrap(),
            };
            (pick, None)
        };
        self.inflight[chosen] += 1;
        (chosen, wake)
    }

    /// A request finished on `instance`.
    pub fn done(&mut self, instance: usize) {
        debug_assert!(self.inflight[instance] > 0);
        self.inflight[instance] -= 1;
    }

    /// Current load of an instance.
    pub fn load(&self, instance: usize) -> u32 {
        self.inflight[instance]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::qwen3_0_6b;
    use crate::topology::{h20x8, GpuId, NumaId};

    fn setup() -> (SimWorld, ModelRegistry) {
        let world = SimWorld::new(h20x8(), MmaConfig::default());
        let mut reg = ModelRegistry::new(NumaId(0));
        reg.register(qwen3_0_6b(), vec![GpuId(0)]);
        reg.register(qwen3_0_6b(), vec![GpuId(1)]);
        (world, reg)
    }

    #[test]
    fn round_robin_rotates() {
        let (mut w, mut reg) = setup();
        let mut r = Router::new(Policy::RoundRobin, 2);
        let (a, _) = r.route(&mut w, &mut reg, &[0, 1]);
        let (b, _) = r.route(&mut w, &mut reg, &[0, 1]);
        assert_ne!(a, b);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let (mut w, mut reg) = setup();
        let mut r = Router::new(Policy::LeastLoaded, 2);
        let (a, _) = r.route(&mut w, &mut reg, &[0, 1]);
        let (b, _) = r.route(&mut w, &mut reg, &[0, 1]);
        assert_ne!(a, b, "second request must go to the idle replica");
        r.done(a);
        let (c, _) = r.route(&mut w, &mut reg, &[0, 1]);
        assert_eq!(c, a);
    }

    #[test]
    fn wake_on_demand_pays_switch_latency() {
        let (mut w, mut reg) = setup();
        reg.sleep(&mut w, 0);
        reg.sleep(&mut w, 1);
        let mut r = Router::new(Policy::RoundRobin, 2);
        let (i, wake) = r.route(&mut w, &mut reg, &[0, 1]);
        assert_eq!(i, 0);
        let wake = wake.expect("must report wake cost");
        assert!(wake > Time::ZERO);
        assert_eq!(reg.instance(0).state, ModelState::Active);
        assert_eq!(r.wake_events.len(), 1);
        // Next request routes without waking.
        let (_, wake2) = r.route(&mut w, &mut reg, &[0, 1]);
        assert!(wake2.is_none());
    }
}
