//! Model registry with vLLM Sleep Mode (Level 1) semantics: an idle model
//! releases its GPU weights to pinned host memory (*fall asleep*, D2H) and
//! reloads them on demand (*wake up*, H2D). Both phases are dominated by
//! weight transfer as models grow (Fig 3); MMA cuts them 1.12–2.48×
//! (Fig 13).

use crate::gpusim::TransferId;
use crate::mma::{SimWorld, TransferClass, TransferDesc};
use crate::models::ModelSpec;
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};

/// Residency state of a registered model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelState {
    /// Weights on GPU, serving-ready.
    Active,
    /// Weights in pinned host memory.
    Asleep,
}

/// One registered model instance.
#[derive(Clone, Debug)]
pub struct Instance {
    /// Architecture (weight bytes derive from it).
    pub spec: ModelSpec,
    /// GPU set the model serves on (TP group).
    pub gpus: Vec<GpuId>,
    /// Current residency.
    pub state: ModelState,
}

/// Outcome of a sleep/wake phase.
#[derive(Clone, Copy, Debug)]
pub struct PhaseResult {
    /// Pure transfer time (from the fabric).
    pub transfer: Time,
    /// Non-transfer overhead (allocator, CUDA context, bookkeeping).
    pub overhead: Time,
}

impl PhaseResult {
    /// Total wall-clock of the phase.
    pub fn total(&self) -> Time {
        self.transfer + self.overhead
    }
    /// Fraction of the phase spent on data transfer (the Fig 3 metric).
    pub fn transfer_fraction(&self) -> f64 {
        let t = self.total().as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.transfer.as_secs_f64() / t
        }
    }
}

/// An in-flight sleep/wake phase: its per-tensor transfers have been
/// submitted to the world and co-run with whatever else is on the fabric
/// (live serving fetches, background loops). Await with [`Self::wait`] or
/// poll with [`Self::result`].
#[derive(Clone, Debug)]
pub struct PendingPhase {
    ids: Vec<TransferId>,
    started: Time,
    overhead: Time,
}

impl PendingPhase {
    /// The phase outcome, if all transfers completed (poll with
    /// `result(world).is_some()` to check doneness without blocking).
    pub fn result(&self, world: &SimWorld) -> Option<PhaseResult> {
        let mut done = self.started;
        for t in &self.ids {
            done = done.max(world.rec(*t).completed?);
        }
        Some(PhaseResult {
            transfer: done.since(self.started),
            overhead: self.overhead,
        })
    }

    /// Run the world until the phase completes and return its outcome.
    pub fn wait(&self, world: &mut SimWorld) -> PhaseResult {
        world.run_until_transfers(&self.ids);
        self.result(world).expect("phase transfers complete")
    }
}

/// Registry of model instances sharing one server.
pub struct ModelRegistry {
    instances: Vec<Instance>,
    host_numa: NumaId,
    /// QoS class stamped on weight transfers. Defaults to
    /// [`TransferClass::Bulk`]: sleep/wake weight movement is
    /// throughput-bound, and under QoS it yields shared-link bandwidth to
    /// latency-critical serving fetches (weighted fabric shares + engine
    /// issue order) instead of trampling them.
    pub transfer_class: TransferClass,
}

/// Non-transfer sleep/wake overhead: allocator traversal, CUDA bookkeeping,
/// framework Python. Grows mildly with parameter count; calibrated so the
/// transfer share matches Fig 3 (~40–50% at 0.6B, >95% at 32B).
pub fn phase_overhead(spec: &ModelSpec) -> Time {
    let n_tensors = spec.tensor_sizes().len() as f64;
    Time::from_secs_f64(0.020 + 50e-6 * n_tensors + spec.params as f64 * 0.55e-12)
}

impl ModelRegistry {
    /// Empty registry staging host buffers on `host_numa`.
    pub fn new(host_numa: NumaId) -> ModelRegistry {
        ModelRegistry {
            instances: Vec::new(),
            host_numa,
            transfer_class: TransferClass::Bulk,
        }
    }

    /// Register an active model on a GPU set. Returns its index.
    pub fn register(&mut self, spec: ModelSpec, gpus: Vec<GpuId>) -> usize {
        assert!(!gpus.is_empty());
        self.instances.push(Instance {
            spec,
            gpus,
            state: ModelState::Active,
        });
        self.instances.len() - 1
    }

    /// Instance accessor.
    pub fn instance(&self, idx: usize) -> &Instance {
        &self.instances[idx]
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.instances.len()
    }

    /// True if no models registered.
    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Submit one instance's weight movement tensor-by-tensor in `dir`
    /// (vLLM walks the state dict, issuing one async copy per tensor on
    /// each GPU's stream). Per-tensor sizes decide which copies multipath
    /// helps — small tensors fall back to native (§3.2). Non-blocking: the
    /// transfers contend with live serving traffic on the shared fabric.
    fn issue_weight_copies(
        &self,
        world: &mut SimWorld,
        idx: usize,
        dir: Direction,
    ) -> Vec<TransferId> {
        let inst = &self.instances[idx];
        let tp = inst.gpus.len() as u64;
        let mut ids = Vec::new();
        for &g in &inst.gpus {
            let s = world.stream(g);
            for tensor in inst.spec.tensor_sizes() {
                let shard = (tensor / tp).max(1);
                ids.push(world.memcpy_async(
                    s,
                    TransferDesc {
                        class: self.transfer_class,
                        ..TransferDesc::new(dir, g, self.host_numa, shard)
                    },
                ));
            }
        }
        ids
    }

    /// Begin falling asleep: submit the D2H copy of every weight tensor
    /// and return without draining the world, so the phase co-runs with
    /// anything else on the fabric.
    pub fn start_sleep(&mut self, world: &mut SimWorld, idx: usize) -> PendingPhase {
        assert_eq!(
            self.instances[idx].state,
            ModelState::Active,
            "sleep on non-active model"
        );
        let started = world.now();
        let ids = self.issue_weight_copies(world, idx, Direction::D2H);
        self.instances[idx].state = ModelState::Asleep;
        PendingPhase {
            ids,
            started,
            overhead: phase_overhead(&self.instances[idx].spec),
        }
    }

    /// Begin waking up: submit the H2D reload of every weight tensor (see
    /// [`Self::start_sleep`] for the co-running semantics).
    pub fn start_wake(&mut self, world: &mut SimWorld, idx: usize) -> PendingPhase {
        assert_eq!(
            self.instances[idx].state,
            ModelState::Asleep,
            "wake on non-asleep model"
        );
        let started = world.now();
        let ids = self.issue_weight_copies(world, idx, Direction::H2D);
        self.instances[idx].state = ModelState::Active;
        PendingPhase {
            ids,
            started,
            overhead: phase_overhead(&self.instances[idx].spec),
        }
    }

    /// Fall asleep and block until every tensor landed (virtual time).
    pub fn sleep(&mut self, world: &mut SimWorld, idx: usize) -> PhaseResult {
        let p = self.start_sleep(world, idx);
        p.wait(world)
    }

    /// Wake up and block until every tensor landed (virtual time).
    pub fn wake(&mut self, world: &mut SimWorld, idx: usize) -> PhaseResult {
        let p = self.start_wake(world, idx);
        p.wait(world)
    }

    /// Model switching: put `from` to sleep, then wake `to` on the freed
    /// GPUs. Returns (sleep phase, wake phase).
    pub fn switch(
        &mut self,
        world: &mut SimWorld,
        from: usize,
        to: usize,
    ) -> (PhaseResult, PhaseResult) {
        let s = self.sleep(world, from);
        let w = self.wake(world, to);
        (s, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mma::MmaConfig;
    use crate::models::{qwen3_0_6b, qwen3_32b};
    use crate::topology::h20x8;

    fn world(cfg: MmaConfig) -> SimWorld {
        SimWorld::new(h20x8(), cfg)
    }

    #[test]
    fn sleep_wake_round_trip_native() {
        let mut w = world(MmaConfig::native());
        let mut reg = ModelRegistry::new(NumaId(0));
        let m = reg.register(qwen3_0_6b(), vec![GpuId(0)]);
        let s = reg.sleep(&mut w, m);
        assert_eq!(reg.instance(m).state, ModelState::Asleep);
        // ~1.5 GB of tensors over ~53.6 GB/s ≈ 28 ms + per-tensor launches.
        let ms = s.transfer.as_ms_f64();
        assert!((22.0..45.0).contains(&ms), "sleep transfer {ms} ms");
        // Fig 3 anchor: transfer share ≈ 40-60% at 0.6B.
        let frac = s.transfer_fraction();
        assert!((0.35..0.65).contains(&frac), "transfer fraction {frac}");
        let wk = reg.wake(&mut w, m);
        assert_eq!(reg.instance(m).state, ModelState::Active);
        assert!(wk.transfer.as_ms_f64() < 45.0);
    }

    #[test]
    fn large_model_is_transfer_dominated() {
        let mut w = world(MmaConfig::native());
        let mut reg = ModelRegistry::new(NumaId(0));
        let m = reg.register(qwen3_32b(), vec![GpuId(0)]);
        let s = reg.sleep(&mut w, m);
        // 65.6 GB / 53.6 GB/s ≈ 1.22 s, >95% of the phase (Fig 3).
        assert!(s.transfer.as_secs_f64() > 1.0);
        assert!(s.transfer_fraction() > 0.93, "{}", s.transfer_fraction());
    }

    #[test]
    fn mma_speeds_up_wake() {
        let mut wn = world(MmaConfig::native());
        let mut rn = ModelRegistry::new(NumaId(0));
        let a = rn.register(qwen3_32b(), vec![GpuId(0)]);
        rn.sleep(&mut wn, a);
        let native = rn.wake(&mut wn, a).transfer;

        let mut wm = world(MmaConfig::default());
        let mut rm = ModelRegistry::new(NumaId(0));
        let b = rm.register(qwen3_32b(), vec![GpuId(0)]);
        rm.sleep(&mut wm, b);
        let mma = rm.wake(&mut wm, b).transfer;
        let speedup = native.as_secs_f64() / mma.as_secs_f64();
        // Per-tensor movement caps the achievable multipath gain well
        // below the 8 GB-microbench 4.6x (Fig 13's regime).
        assert!((2.2..3.8).contains(&speedup), "wake speedup {speedup}");
    }

    #[test]
    fn switch_changes_both_states() {
        let mut w = world(MmaConfig::default());
        let mut reg = ModelRegistry::new(NumaId(0));
        let a = reg.register(qwen3_0_6b(), vec![GpuId(0)]);
        let b = reg.register(qwen3_0_6b(), vec![GpuId(0)]);
        reg.sleep(&mut w, b);
        let (s, wk) = reg.switch(&mut w, a, b);
        assert_eq!(reg.instance(a).state, ModelState::Asleep);
        assert_eq!(reg.instance(b).state, ModelState::Active);
        assert!(s.total() > Time::ZERO && wk.total() > Time::ZERO);
    }

    #[test]
    #[should_panic(expected = "sleep on non-active")]
    fn double_sleep_panics() {
        let mut w = world(MmaConfig::native());
        let mut reg = ModelRegistry::new(NumaId(0));
        let m = reg.register(qwen3_0_6b(), vec![GpuId(0)]);
        reg.sleep(&mut w, m);
        reg.sleep(&mut w, m);
    }
}
