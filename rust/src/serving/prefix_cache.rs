//! Prefix-cache tiers (LMCache-style), split for the fleet architecture.
//!
//! Prefixes are indexed by a rolling content hash over token blocks. Each
//! [`crate::serving::ServingInstance`] owns a [`GpuPrefixTier`] — the
//! prefixes resident in *its* GPU's KV blocks — while the whole fleet
//! shares one [`HostPrefixPool`]: the pinned-host offload tier every
//! instance fetches from (the H2D transfer that dominates TTFT in Fig 2
//! and that MMA accelerates in Fig 12). Because the host tier is shared,
//! promoting a prefix into one instance's HBM *copies* rather than moves:
//! siblings can still host-fetch it, or fetch it peer-to-peer over NVLink
//! from the holder's HBM.
//!
//! The host tier's occupancy is enforced in bytes through
//! [`crate::memory::HostPool`], so seeding and offloads can never exceed
//! the configured pinned-host capacity — over-pressure drops LRU entries.

use crate::memory::{HostAlloc, HostPool};
use crate::topology::NumaId;
use crate::util::rng::Rng;
use crate::util::fxmap::FxHashMap;

/// Rolling hash of a token prefix (block-aligned chain hash, as LMCache
/// keys chunks by content).
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset
    for t in tokens {
        h ^= *t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Outcome of a [`GpuPrefixTier::insert`].
#[derive(Debug, Default)]
pub struct GpuInsert {
    /// The new entry is resident (false: larger than the whole tier).
    pub inserted: bool,
    /// LRU entries demoted to make room, as `(key, tokens)` — the caller
    /// offloads them to the shared host tier.
    pub evicted: Vec<(u64, u32)>,
}

/// Prefixes resident in one GPU's KV blocks (per serving instance).
/// Token-capacity LRU; a hit is zero-copy block sharing.
#[derive(Debug)]
pub struct GpuPrefixTier {
    block_tokens: u32,
    capacity_tokens: u64,
    used: u64,
    entries: FxHashMap<u64, (u32, u64)>, // key → (tokens, last_use)
    clock: u64,
}

impl GpuPrefixTier {
    /// Tier of `capacity_tokens` (block-aligned internally).
    pub fn new(block_tokens: u32, capacity_tokens: u64) -> GpuPrefixTier {
        GpuPrefixTier {
            block_tokens: block_tokens.max(1),
            capacity_tokens,
            used: 0,
            entries: FxHashMap::default(),
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Round tokens up to block granularity.
    fn rounded(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64
    }

    /// Tokens of a resident prefix, without touching LRU state.
    pub fn peek(&self, key: u64) -> Option<u32> {
        self.entries.get(&key).map(|(t, _)| *t)
    }

    /// Refresh a resident prefix's LRU position; false if absent.
    pub fn touch(&mut self, key: u64) -> bool {
        let now = self.tick();
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.1 = now;
                true
            }
            None => false,
        }
    }

    /// Insert (or refresh) a prefix. Existing entries only refresh — an
    /// insert never resizes or moves an entry. May demote LRU entries to
    /// make room (returned for host offload); a prefix larger than the
    /// whole tier is not inserted (`inserted == false`, nothing evicted).
    pub fn insert(&mut self, key: u64, tokens: u32) -> GpuInsert {
        let now = self.tick();
        if let Some(e) = self.entries.get_mut(&key) {
            e.1 = now;
            return GpuInsert {
                inserted: true,
                evicted: Vec::new(),
            };
        }
        let size = self.rounded(tokens);
        if size > self.capacity_tokens {
            return GpuInsert::default();
        }
        let mut evicted = Vec::new();
        while self.used + size > self.capacity_tokens {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, (_, at))| *at)
                .map(|(k, _)| *k)
                .expect("used > 0 implies a resident entry");
            let (t, _) = self.entries.remove(&lru).unwrap();
            self.used -= self.rounded(t);
            evicted.push((lru, t));
        }
        self.used += size;
        self.entries.insert(key, (tokens, now));
        GpuInsert {
            inserted: true,
            evicted,
        }
    }

    /// Remove a prefix (explicit offload); returns its tokens.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let (tokens, _) = self.entries.remove(&key)?;
        self.used -= self.rounded(tokens);
        Some(tokens)
    }

    /// Tokens resident (block-aligned accounting).
    pub fn used_tokens(&self) -> u64 {
        self.used
    }

    /// Configured capacity in tokens.
    pub fn capacity_tokens(&self) -> u64 {
        self.capacity_tokens
    }

    /// Number of resident prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[derive(Debug)]
struct HostEntry {
    tokens: u32,
    alloc: HostAlloc,
    last_use: u64,
}

/// The fleet-shared pinned-host prefix tier. Every byte is accounted
/// through a [`HostPool`], so occupancy cannot exceed the configured
/// capacity: inserts under pressure drop LRU entries, and an entry larger
/// than the whole tier is skipped rather than cached.
#[derive(Debug)]
pub struct HostPrefixPool {
    block_tokens: u32,
    bytes_per_token: u64,
    numa: NumaId,
    pool: HostPool,
    entries: FxHashMap<u64, HostEntry>,
    clock: u64,
}

impl HostPrefixPool {
    /// Pool of `capacity_tokens` (block-aligned) on `numa`, with bytes
    /// accounted at `bytes_per_token` (the model's per-token KV size).
    pub fn new(
        block_tokens: u32,
        capacity_tokens: u64,
        bytes_per_token: u64,
        numa_count: u8,
        numa: NumaId,
    ) -> HostPrefixPool {
        let bpt = bytes_per_token.max(1);
        HostPrefixPool {
            block_tokens: block_tokens.max(1),
            bytes_per_token: bpt,
            numa,
            pool: HostPool::new(numa_count.max(1), capacity_tokens.saturating_mul(bpt)),
            entries: FxHashMap::default(),
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn bytes_for(&self, tokens: u32) -> u64 {
        let rounded =
            (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64;
        (rounded * self.bytes_per_token).max(1)
    }

    fn drop_lru(&mut self) -> bool {
        let Some(k) = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_use)
            .map(|(k, _)| *k)
        else {
            return false;
        };
        let e = self.entries.remove(&k).unwrap();
        self.pool.free(e.alloc);
        true
    }

    /// Insert (or refresh) a prefix. Allocates its KV bytes from the
    /// backing [`HostPool`], dropping LRU entries under pressure; returns
    /// false when the prefix cannot fit even in an empty tier.
    pub fn insert(&mut self, key: u64, tokens: u32) -> bool {
        let now = self.tick();
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = now;
            return true;
        }
        let bytes = self.bytes_for(tokens);
        loop {
            if let Some(alloc) = self.pool.alloc(self.numa, bytes) {
                self.entries.insert(
                    key,
                    HostEntry {
                        tokens,
                        alloc,
                        last_use: now,
                    },
                );
                return true;
            }
            if !self.drop_lru() {
                return false; // larger than the whole tier: skip caching
            }
        }
    }

    /// Tokens of a host-resident prefix, without touching LRU state.
    pub fn peek(&self, key: u64) -> Option<u32> {
        self.entries.get(&key).map(|e| e.tokens)
    }

    /// Refresh a host entry's LRU position; false if absent.
    pub fn touch(&mut self, key: u64) -> bool {
        let now = self.tick();
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_use = now;
                true
            }
            None => false,
        }
    }

    /// Drop a prefix, freeing its bytes; returns its tokens.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let e = self.entries.remove(&key)?;
        self.pool.free(e.alloc);
        Some(e.tokens)
    }

    /// Bytes currently pinned (from the backing [`HostPool`] accounting).
    pub fn used_bytes(&self) -> u64 {
        self.pool.used(self.numa)
    }

    /// Bytes still available under the configured capacity.
    pub fn available_bytes(&self) -> u64 {
        self.pool.available(self.numa)
    }

    /// Number of cached prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fill with `n` synthetic prefixes of `tokens` each (workload setup).
    pub fn populate(&mut self, rng: &mut Rng, n: usize, tokens: u32) -> Vec<u64> {
        (0..n)
            .map(|_| {
                let key = rng.next_u64();
                self.insert(key, tokens);
                key
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn host(capacity_tokens: u64) -> HostPrefixPool {
        // 1 byte per token keeps the arithmetic transparent in tests.
        HostPrefixPool::new(16, capacity_tokens, 1, 1, NumaId(0))
    }

    #[test]
    fn hash_is_prefix_sensitive() {
        let a = prefix_hash(&[1, 2, 3]);
        let b = prefix_hash(&[1, 2, 4]);
        let c = prefix_hash(&[1, 2, 3]);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn gpu_tier_insert_then_hit() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        assert!(g.insert(42, 1000).inserted);
        assert_eq!(g.peek(42), Some(1000));
        assert_eq!(g.peek(43), None);
        assert!(g.touch(42));
        assert!(!g.touch(43));
    }

    #[test]
    fn gpu_tier_demotes_lru_under_pressure() {
        // 2×1024 tokens fit; the third insert evicts the LRU entry.
        let mut g = GpuPrefixTier::new(16, 2048);
        g.insert(1, 1024);
        g.insert(2, 1024);
        g.touch(1); // 2 is now LRU
        let out = g.insert(3, 1024);
        assert!(out.inserted);
        assert_eq!(out.evicted, vec![(2, 1024)]);
        assert_eq!(g.peek(2), None);
        assert_eq!(g.peek(1), Some(1024));
    }

    #[test]
    fn gpu_tier_oversized_entry_not_inserted() {
        let mut g = GpuPrefixTier::new(16, 1024);
        g.insert(1, 512);
        let out = g.insert(2, 4096);
        assert!(!out.inserted);
        assert!(out.evicted.is_empty(), "no pointless evictions");
        assert_eq!(g.peek(1), Some(512), "resident entry untouched");
    }

    #[test]
    fn gpu_tier_accounting_block_aligned() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        g.insert(1, 17); // rounds to 32
        assert_eq!(g.used_tokens(), 32);
        assert_eq!(g.remove(1), Some(17));
        assert_eq!(g.used_tokens(), 0);
    }

    #[test]
    fn gpu_tier_reinsert_refreshes_without_resizing() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        g.insert(1, 1000);
        let out = g.insert(1, 5000); // existing key: refresh only
        assert!(out.inserted);
        assert_eq!(g.peek(1), Some(1000), "insert never resizes an entry");
        assert_eq!(g.used_tokens(), 1008);
    }

    #[test]
    fn host_pool_enforces_byte_capacity() {
        // Capacity 2048 tokens × 1 B/token: the third 1024-token prefix
        // drops the LRU, and occupancy never exceeds the HostPool cap.
        let mut h = host(2048);
        assert!(h.insert(1, 1024));
        assert!(h.insert(2, 1024));
        assert_eq!(h.used_bytes(), 2048);
        assert!(h.insert(3, 1024)); // drops key 1 (LRU)
        assert_eq!(h.peek(1), None);
        assert_eq!(h.len(), 2);
        assert!(h.used_bytes() <= 2048, "over capacity: {}", h.used_bytes());
    }

    #[test]
    fn host_pool_skips_oversized_entries() {
        let mut h = host(1024);
        assert!(h.insert(1, 512));
        assert!(!h.insert(2, 4096), "larger than the whole tier");
        assert_eq!(h.peek(1), Some(512), "resident entries survive");
    }

    #[test]
    fn host_pool_remove_frees_bytes() {
        let mut h = host(1 << 20);
        h.insert(7, 512);
        assert_eq!(h.used_bytes(), 512);
        assert_eq!(h.remove(7), Some(512));
        assert_eq!(h.used_bytes(), 0);
        assert_eq!(h.remove(7), None);
    }

    #[test]
    fn host_pool_refresh_keeps_one_allocation() {
        let mut h = host(1 << 20);
        h.insert(7, 512);
        assert!(h.insert(7, 9999), "refresh, not re-alloc");
        assert_eq!(h.used_bytes(), 512);
        assert_eq!(h.peek(7), Some(512));
    }

    #[test]
    fn populate_seeds_n_entries() {
        let mut h = host(1 << 20);
        let mut rng = Rng::seed_from_u64(3);
        let keys = h.populate(&mut rng, 8, 100);
        assert_eq!(keys.len(), 8);
        assert_eq!(h.len(), 8);
    }
}
