//! Prefix cache with a host offload tier (LMCache-style).
//!
//! Prefixes are indexed by a rolling content hash over token blocks. Hot
//! prefixes live in GPU KV blocks; evicted ones move to pinned host memory
//! and are *fetched back* on a hit — the H2D transfer that dominates TTFT
//! in Fig 2 and that MMA accelerates in Fig 12.

use crate::util::rng::Rng;
use std::collections::HashMap;

/// Where a cached prefix currently resides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Resident in GPU KV blocks (hit = zero-copy block sharing).
    Gpu,
    /// Offloaded to pinned host DRAM (hit = H2D fetch of the KV bytes).
    Host,
}

#[derive(Clone, Debug)]
struct Entry {
    tokens: u32,
    tier: Tier,
    last_use: u64,
}

/// Content-addressed prefix store with two tiers and LRU demotion.
#[derive(Debug)]
pub struct PrefixCache {
    block_tokens: u32,
    gpu_capacity_tokens: u64,
    host_capacity_tokens: u64,
    gpu_used: u64,
    host_used: u64,
    entries: HashMap<u64, Entry>,
    clock: u64,
}

/// Rolling hash of a token prefix (block-aligned chain hash, as LMCache
/// keys chunks by content).
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset
    for t in tokens {
        h ^= *t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl PrefixCache {
    /// Capacities are in tokens (block-aligned internally).
    pub fn new(block_tokens: u32, gpu_capacity_tokens: u64, host_capacity_tokens: u64) -> Self {
        PrefixCache {
            block_tokens,
            gpu_capacity_tokens,
            host_capacity_tokens,
            gpu_used: 0,
            host_used: 0,
            entries: HashMap::new(),
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Round tokens up to block granularity.
    fn rounded(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64
    }

    /// Insert (or refresh) a prefix of `tokens` under `key`, initially on
    /// GPU. May demote LRU entries to host, and drop LRU host entries.
    pub fn insert(&mut self, key: u64, tokens: u32) {
        let now = self.tick();
        let size = self.rounded(tokens);
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = now;
            return;
        }
        // Make room on GPU.
        while self.gpu_used + size > self.gpu_capacity_tokens {
            if !self.demote_lru_gpu() {
                break;
            }
        }
        if self.gpu_used + size > self.gpu_capacity_tokens {
            // Doesn't fit on GPU at all: insert directly into host tier.
            self.host_insert(key, tokens, now);
            return;
        }
        self.gpu_used += size;
        self.entries.insert(
            key,
            Entry {
                tokens,
                tier: Tier::Gpu,
                last_use: now,
            },
        );
    }

    fn host_insert(&mut self, key: u64, tokens: u32, now: u64) {
        let size = self.rounded(tokens);
        while self.host_used + size > self.host_capacity_tokens {
            if !self.drop_lru_host() {
                return; // larger than the whole tier: skip caching
            }
        }
        self.host_used += size;
        self.entries.insert(
            key,
            Entry {
                tokens,
                tier: Tier::Host,
                last_use: now,
            },
        );
    }

    fn lru_in_tier(&self, tier: Tier) -> Option<u64> {
        self.entries
            .iter()
            .filter(|(_, e)| e.tier == tier)
            .min_by_key(|(_, e)| e.last_use)
            .map(|(k, _)| *k)
    }

    /// Demote the LRU GPU entry to host. Returns false if none.
    fn demote_lru_gpu(&mut self) -> bool {
        let Some(k) = self.lru_in_tier(Tier::Gpu) else {
            return false;
        };
        let e = self.entries.remove(&k).unwrap();
        let size = self.rounded(e.tokens);
        self.gpu_used -= size;
        self.host_insert(k, e.tokens, e.last_use);
        true
    }

    fn drop_lru_host(&mut self) -> bool {
        let Some(k) = self.lru_in_tier(Tier::Host) else {
            return false;
        };
        let e = self.entries.remove(&k).unwrap();
        self.host_used -= self.rounded(e.tokens);
        true
    }

    /// Force-offload a specific prefix to host (explicit eviction path,
    /// e.g. when the serving engine reclaims GPU KV blocks).
    pub fn offload(&mut self, key: u64) -> bool {
        match self.entries.get(&key) {
            Some(e) if e.tier == Tier::Gpu => {
                let e = self.entries.remove(&key).unwrap();
                self.gpu_used -= self.rounded(e.tokens);
                self.host_insert(key, e.tokens, e.last_use);
                true
            }
            _ => false,
        }
    }

    /// Non-mutating lookup: tokens and tier without the LRU refresh or
    /// host→GPU promotion of [`Self::lookup`]. Used at admission time to
    /// resolve the prefill suffix before committing to the fetch.
    pub fn peek(&self, key: u64) -> Option<(u32, Tier)> {
        self.entries.get(&key).map(|e| (e.tokens, e.tier))
    }

    /// Look up a prefix. On a hit, refreshes LRU and (for host hits)
    /// promotes it back to the GPU tier — the caller is responsible for
    /// issuing the actual KV fetch transfer of `tokens` worth of KV bytes.
    pub fn lookup(&mut self, key: u64) -> Option<(u32, Tier)> {
        let now = self.tick();
        let (tokens, tier) = {
            let e = self.entries.get_mut(&key)?;
            e.last_use = now;
            (e.tokens, e.tier)
        };
        if tier == Tier::Host {
            // Promote: host → GPU (caller performs the H2D fetch).
            let size = self.rounded(tokens);
            self.host_used -= size;
            self.entries.remove(&key);
            while self.gpu_used + size > self.gpu_capacity_tokens {
                if !self.demote_lru_gpu() {
                    break;
                }
            }
            if self.gpu_used + size <= self.gpu_capacity_tokens {
                self.gpu_used += size;
                self.entries.insert(
                    key,
                    Entry {
                        tokens,
                        tier: Tier::Gpu,
                        last_use: now,
                    },
                );
            } else {
                // Could not promote (GPU tier too small): stays on host.
                self.host_used += size;
                self.entries.insert(
                    key,
                    Entry {
                        tokens,
                        tier: Tier::Host,
                        last_use: now,
                    },
                );
            }
        }
        Some((tokens, tier))
    }

    /// Tokens resident per tier (GPU, host).
    pub fn usage(&self) -> (u64, u64) {
        (self.gpu_used, self.host_used)
    }

    /// Number of cached prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fill with `n` synthetic prefixes of `tokens` each (workload setup).
    pub fn populate(&mut self, rng: &mut Rng, n: usize, tokens: u32) -> Vec<u64> {
        (0..n)
            .map(|_| {
                let key = rng.next_u64();
                self.insert(key, tokens);
                key
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_prefix_sensitive() {
        let a = prefix_hash(&[1, 2, 3]);
        let b = prefix_hash(&[1, 2, 4]);
        let c = prefix_hash(&[1, 2, 3]);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn insert_then_gpu_hit() {
        let mut pc = PrefixCache::new(16, 1 << 20, 1 << 24);
        pc.insert(42, 1000);
        assert_eq!(pc.lookup(42), Some((1000, Tier::Gpu)));
        assert_eq!(pc.lookup(43), None);
    }

    #[test]
    fn gpu_pressure_demotes_to_host_and_hit_promotes() {
        // GPU holds 2x1024 tokens; third insert demotes the LRU.
        let mut pc = PrefixCache::new(16, 2048, 1 << 20);
        pc.insert(1, 1024);
        pc.insert(2, 1024);
        pc.insert(3, 1024); // demotes key 1
        assert_eq!(pc.lookup(1).unwrap().1, Tier::Host, "LRU went to host");
        // That lookup promoted key 1 back to GPU (demoting key 2).
        assert_eq!(pc.lookup(1).unwrap().1, Tier::Gpu);
        assert_eq!(pc.lookup(2).unwrap().1, Tier::Host);
    }

    #[test]
    fn host_tier_drops_lru_when_full() {
        let mut pc = PrefixCache::new(16, 1024, 2048);
        pc.insert(1, 1024);
        pc.insert(2, 1024); // 1 → host
        pc.insert(3, 1024); // 2 → host
        pc.insert(4, 1024); // 3 → host, host full → drop LRU (1)
        assert_eq!(pc.lookup(1), None, "oldest host entry dropped");
        assert_eq!(pc.len(), 3);
    }

    #[test]
    fn explicit_offload() {
        let mut pc = PrefixCache::new(16, 1 << 20, 1 << 20);
        pc.insert(7, 512);
        assert!(pc.offload(7));
        assert_eq!(pc.lookup(7).unwrap().1, Tier::Host);
        assert!(!pc.offload(999));
    }

    #[test]
    fn usage_accounting_block_aligned() {
        let mut pc = PrefixCache::new(16, 1 << 20, 1 << 20);
        pc.insert(1, 17); // rounds to 32
        assert_eq!(pc.usage(), (32, 0));
        pc.offload(1);
        assert_eq!(pc.usage(), (0, 32));
    }
}
