//! Prefix-cache tiers (LMCache-style), split for the fleet architecture.
//!
//! Prefixes are indexed by a rolling content hash over token blocks. Each
//! [`crate::serving::ServingInstance`] owns a [`GpuPrefixTier`] — the
//! prefixes resident in *its* GPU's KV blocks — while the whole fleet
//! shares one [`HostPrefixPool`]: the pinned-host offload tier every
//! instance fetches from (the H2D transfer that dominates TTFT in Fig 2
//! and that MMA accelerates in Fig 12). Because the host tier is shared,
//! promoting a prefix into one instance's HBM *copies* rather than moves:
//! siblings can still host-fetch it, or fetch it peer-to-peer over NVLink
//! from the holder's HBM.
//!
//! The host tier's occupancy is enforced in bytes through
//! [`crate::memory::HostPool`], so seeding and offloads can never exceed
//! the configured pinned-host capacity — over-pressure drops LRU entries.
//!
//! # Eviction is O(1), and why the order is pinned
//!
//! Both tiers keep recency in a slab-backed intrusive list
//! ([`crate::util::lru::LruList`]): touch, insert, and evict are O(1),
//! where the retired implementation scanned every entry per eviction
//! (`min_by_key` over a use-clock — O(n) per demotion, O(n²) under
//! sustained pressure). The retired scans are kept verbatim in
//! [`oracle`], and randomized-churn property tests assert the two
//! eviction orders are *identical*, not merely equivalent.
//!
//! That identity holds because the old order had no real ties to break:
//! the use-clock ticked on every touch/insert, so every resident entry
//! carried a unique `last_use` and `min_by_key` was a total order over
//! strict recency — exactly the list's tail-first order. Map iteration
//! order never mattered and still doesn't; replay output is byte-for-byte
//! unchanged. (If a future change ever makes two entries share a
//! recency slot — e.g. batch seeding without ticks — the order must be
//! re-pinned explicitly; see `oracle_clock_is_strictly_monotone`.)

use crate::memory::{HostAlloc, HostPool};
use crate::topology::NumaId;
use crate::util::fxmap::FxHashMap;
use crate::util::lru::LruList;
use crate::util::rng::Rng;

/// Rolling hash of a token prefix (block-aligned chain hash, as LMCache
/// keys chunks by content).
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset
    for t in tokens {
        h ^= *t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Outcome of a [`GpuPrefixTier::insert`].
#[derive(Debug, Default)]
pub struct GpuInsert {
    /// The new entry is resident (false: larger than the whole tier).
    pub inserted: bool,
    /// LRU entries demoted to make room, as `(key, tokens)` — the caller
    /// offloads them to the shared host tier.
    pub evicted: Vec<(u64, u32)>,
}

/// One resident GPU-tier entry (payload slab parallel to the LRU list).
#[derive(Debug, Clone, Copy, Default)]
struct GpuSlot {
    key: u64,
    tokens: u32,
}

/// Prefixes resident in one GPU's KV blocks (per serving instance).
/// Token-capacity LRU; a hit is zero-copy block sharing. All operations
/// O(1) — see the module docs for the eviction-order contract.
#[derive(Debug)]
pub struct GpuPrefixTier {
    block_tokens: u32,
    capacity_tokens: u64,
    used: u64,
    index: FxHashMap<u64, u32>, // key → LRU slot
    slots: Vec<GpuSlot>,        // slot → payload
    lru: LruList,
}

impl GpuPrefixTier {
    /// Tier of `capacity_tokens` (block-aligned internally).
    pub fn new(block_tokens: u32, capacity_tokens: u64) -> GpuPrefixTier {
        GpuPrefixTier {
            block_tokens: block_tokens.max(1),
            capacity_tokens,
            used: 0,
            index: FxHashMap::default(),
            slots: Vec::new(),
            lru: LruList::new(),
        }
    }

    /// Round tokens up to block granularity.
    fn rounded(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64
    }

    fn set_slot(&mut self, slot: u32, key: u64, tokens: u32) {
        let s = GpuSlot { key, tokens };
        if slot as usize == self.slots.len() {
            self.slots.push(s);
        } else {
            self.slots[slot as usize] = s;
        }
    }

    /// Tokens of a resident prefix, without touching LRU state.
    pub fn peek(&self, key: u64) -> Option<u32> {
        self.index
            .get(&key)
            .map(|&slot| self.slots[slot as usize].tokens)
    }

    /// Refresh a resident prefix's LRU position; false if absent.
    pub fn touch(&mut self, key: u64) -> bool {
        match self.index.get(&key) {
            Some(&slot) => {
                self.lru.touch(slot);
                true
            }
            None => false,
        }
    }

    /// Insert (or refresh) a prefix. Existing entries only refresh — an
    /// insert never resizes or moves an entry. May demote LRU entries to
    /// make room (returned for host offload); a prefix larger than the
    /// whole tier is not inserted (`inserted == false`, nothing evicted).
    pub fn insert(&mut self, key: u64, tokens: u32) -> GpuInsert {
        if let Some(&slot) = self.index.get(&key) {
            self.lru.touch(slot);
            return GpuInsert {
                inserted: true,
                evicted: Vec::new(),
            };
        }
        let size = self.rounded(tokens);
        if size > self.capacity_tokens {
            return GpuInsert::default();
        }
        let mut evicted = Vec::new();
        while self.used + size > self.capacity_tokens {
            let victim = self
                .lru
                .tail()
                .expect("used > 0 implies a resident entry");
            let GpuSlot { key: k, tokens: t } = self.slots[victim as usize];
            self.lru.remove(victim);
            self.index.remove(&k);
            self.used -= self.rounded(t);
            evicted.push((k, t));
        }
        self.used += size;
        let slot = self.lru.push_front();
        self.set_slot(slot, key, tokens);
        self.index.insert(key, slot);
        GpuInsert {
            inserted: true,
            evicted,
        }
    }

    /// Remove a prefix (explicit offload); returns its tokens.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let slot = self.index.remove(&key)?;
        let tokens = self.slots[slot as usize].tokens;
        self.lru.remove(slot);
        self.used -= self.rounded(tokens);
        Some(tokens)
    }

    /// Tokens resident (block-aligned accounting).
    pub fn used_tokens(&self) -> u64 {
        self.used
    }

    /// Configured capacity in tokens.
    pub fn capacity_tokens(&self) -> u64 {
        self.capacity_tokens
    }

    /// Number of resident prefixes.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
}

/// One resident host-tier entry (payload slab parallel to the LRU list).
#[derive(Debug, Clone, Copy)]
struct HostSlot {
    key: u64,
    tokens: u32,
    alloc: HostAlloc,
}

/// The fleet-shared pinned-host prefix tier. Every byte is accounted
/// through a [`HostPool`], so occupancy cannot exceed the configured
/// capacity: inserts under pressure drop LRU entries, and an entry larger
/// than the whole tier is skipped rather than cached. All operations
/// O(1) — see the module docs for the eviction-order contract.
#[derive(Debug)]
pub struct HostPrefixPool {
    block_tokens: u32,
    bytes_per_token: u64,
    numa: NumaId,
    pool: HostPool,
    index: FxHashMap<u64, u32>, // key → LRU slot
    slots: Vec<HostSlot>,       // slot → payload
    lru: LruList,
}

impl HostPrefixPool {
    /// Pool of `capacity_tokens` (block-aligned) on `numa`, with bytes
    /// accounted at `bytes_per_token` (the model's per-token KV size).
    pub fn new(
        block_tokens: u32,
        capacity_tokens: u64,
        bytes_per_token: u64,
        numa_count: u8,
        numa: NumaId,
    ) -> HostPrefixPool {
        let bpt = bytes_per_token.max(1);
        HostPrefixPool {
            block_tokens: block_tokens.max(1),
            bytes_per_token: bpt,
            numa,
            pool: HostPool::new(numa_count.max(1), capacity_tokens.saturating_mul(bpt)),
            index: FxHashMap::default(),
            slots: Vec::new(),
            lru: LruList::new(),
        }
    }

    fn bytes_for(&self, tokens: u32) -> u64 {
        let rounded =
            (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64;
        (rounded * self.bytes_per_token).max(1)
    }

    fn set_slot(&mut self, slot: u32, s: HostSlot) {
        if slot as usize == self.slots.len() {
            self.slots.push(s);
        } else {
            self.slots[slot as usize] = s;
        }
    }

    fn drop_lru(&mut self) -> bool {
        let Some(victim) = self.lru.tail() else {
            return false;
        };
        let HostSlot { key, alloc, .. } = self.slots[victim as usize];
        self.lru.remove(victim);
        self.index.remove(&key);
        self.pool.free(alloc);
        true
    }

    /// Insert (or refresh) a prefix. Allocates its KV bytes from the
    /// backing [`HostPool`], dropping LRU entries under pressure; returns
    /// false when the prefix cannot fit even in an empty tier.
    pub fn insert(&mut self, key: u64, tokens: u32) -> bool {
        if let Some(&slot) = self.index.get(&key) {
            self.lru.touch(slot);
            return true;
        }
        let bytes = self.bytes_for(tokens);
        loop {
            if let Some(alloc) = self.pool.alloc(self.numa, bytes) {
                let slot = self.lru.push_front();
                self.set_slot(slot, HostSlot { key, tokens, alloc });
                self.index.insert(key, slot);
                return true;
            }
            if !self.drop_lru() {
                return false; // larger than the whole tier: skip caching
            }
        }
    }

    /// Tokens of a host-resident prefix, without touching LRU state.
    pub fn peek(&self, key: u64) -> Option<u32> {
        self.index
            .get(&key)
            .map(|&slot| self.slots[slot as usize].tokens)
    }

    /// Refresh a host entry's LRU position; false if absent.
    pub fn touch(&mut self, key: u64) -> bool {
        match self.index.get(&key) {
            Some(&slot) => {
                self.lru.touch(slot);
                true
            }
            None => false,
        }
    }

    /// Drop a prefix, freeing its bytes; returns its tokens.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let slot = self.index.remove(&key)?;
        let HostSlot { tokens, alloc, .. } = self.slots[slot as usize];
        self.lru.remove(slot);
        self.pool.free(alloc);
        Some(tokens)
    }

    /// Bytes currently pinned (from the backing [`HostPool`] accounting).
    pub fn used_bytes(&self) -> u64 {
        self.pool.used(self.numa)
    }

    /// Bytes still available under the configured capacity.
    pub fn available_bytes(&self) -> u64 {
        self.pool.available(self.numa)
    }

    /// Number of cached prefixes.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Fill with `n` synthetic prefixes of `tokens` each (workload setup).
    pub fn populate(&mut self, rng: &mut Rng, n: usize, tokens: u32) -> Vec<u64> {
        (0..n)
            .map(|_| {
                let key = rng.next_u64();
                self.insert(key, tokens);
                key
            })
            .collect()
    }
}

/// The retired O(n)-scan tiers, kept verbatim as reference oracles for
/// the O(1) implementations above. Every operation ticks a strictly
/// monotone use-clock, so `min_by_key(last_use)` is a total order —
/// the property that makes the LRU-list rewrite byte-identical (see the
/// module docs). Used only by property tests; never on a hot path.
pub mod oracle {
    use super::*;

    /// The retired scan-eviction GPU tier ([`GpuPrefixTier`]'s oracle).
    #[derive(Debug)]
    pub struct ScanGpuTier {
        block_tokens: u32,
        capacity_tokens: u64,
        used: u64,
        entries: FxHashMap<u64, (u32, u64)>, // key → (tokens, last_use)
        clock: u64,
    }

    impl ScanGpuTier {
        /// Tier of `capacity_tokens` (block-aligned internally).
        pub fn new(block_tokens: u32, capacity_tokens: u64) -> ScanGpuTier {
            ScanGpuTier {
                block_tokens: block_tokens.max(1),
                capacity_tokens,
                used: 0,
                entries: FxHashMap::default(),
                clock: 0,
            }
        }

        fn tick(&mut self) -> u64 {
            self.clock += 1;
            self.clock
        }

        fn rounded(&self, tokens: u32) -> u64 {
            (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64
        }

        /// The strictly-monotone use-clock (exposed so tests can pin the
        /// no-ties invariant the O(1) rewrite relies on).
        pub fn clock(&self) -> u64 {
            self.clock
        }

        /// Tokens of a resident prefix, without touching LRU state.
        pub fn peek(&self, key: u64) -> Option<u32> {
            self.entries.get(&key).map(|(t, _)| *t)
        }

        /// Refresh a resident prefix's LRU position; false if absent.
        pub fn touch(&mut self, key: u64) -> bool {
            let now = self.tick();
            match self.entries.get_mut(&key) {
                Some(e) => {
                    e.1 = now;
                    true
                }
                None => false,
            }
        }

        /// Insert (or refresh) a prefix; the retired full-scan eviction.
        pub fn insert(&mut self, key: u64, tokens: u32) -> GpuInsert {
            let now = self.tick();
            if let Some(e) = self.entries.get_mut(&key) {
                e.1 = now;
                return GpuInsert {
                    inserted: true,
                    evicted: Vec::new(),
                };
            }
            let size = self.rounded(tokens);
            if size > self.capacity_tokens {
                return GpuInsert::default();
            }
            let mut evicted = Vec::new();
            while self.used + size > self.capacity_tokens {
                let lru = self
                    .entries
                    .iter()
                    .min_by_key(|(_, (_, at))| *at)
                    .map(|(k, _)| *k)
                    .expect("used > 0 implies a resident entry");
                let (t, _) = self.entries.remove(&lru).unwrap();
                self.used -= self.rounded(t);
                evicted.push((lru, t));
            }
            self.used += size;
            self.entries.insert(key, (tokens, now));
            GpuInsert {
                inserted: true,
                evicted,
            }
        }

        /// Remove a prefix; returns its tokens.
        pub fn remove(&mut self, key: u64) -> Option<u32> {
            let (tokens, _) = self.entries.remove(&key)?;
            self.used -= self.rounded(tokens);
            Some(tokens)
        }

        /// Tokens resident (block-aligned accounting).
        pub fn used_tokens(&self) -> u64 {
            self.used
        }

        /// Number of resident prefixes.
        pub fn len(&self) -> usize {
            self.entries.len()
        }
    }

    #[derive(Debug)]
    struct ScanHostEntry {
        tokens: u32,
        alloc: HostAlloc,
        last_use: u64,
    }

    /// The retired scan-eviction host tier ([`HostPrefixPool`]'s oracle).
    #[derive(Debug)]
    pub struct ScanHostPool {
        block_tokens: u32,
        bytes_per_token: u64,
        numa: NumaId,
        pool: HostPool,
        entries: FxHashMap<u64, ScanHostEntry>,
        clock: u64,
    }

    impl ScanHostPool {
        /// Pool of `capacity_tokens` on `numa` at `bytes_per_token`.
        pub fn new(
            block_tokens: u32,
            capacity_tokens: u64,
            bytes_per_token: u64,
            numa_count: u8,
            numa: NumaId,
        ) -> ScanHostPool {
            let bpt = bytes_per_token.max(1);
            ScanHostPool {
                block_tokens: block_tokens.max(1),
                bytes_per_token: bpt,
                numa,
                pool: HostPool::new(numa_count.max(1), capacity_tokens.saturating_mul(bpt)),
                entries: FxHashMap::default(),
                clock: 0,
            }
        }

        fn tick(&mut self) -> u64 {
            self.clock += 1;
            self.clock
        }

        fn bytes_for(&self, tokens: u32) -> u64 {
            let rounded =
                (tokens as u64).div_ceil(self.block_tokens as u64) * self.block_tokens as u64;
            (rounded * self.bytes_per_token).max(1)
        }

        fn drop_lru(&mut self) -> Option<u64> {
            let k = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| *k)?;
            let e = self.entries.remove(&k).unwrap();
            self.pool.free(e.alloc);
            Some(k)
        }

        /// Insert (or refresh) a prefix; the retired full-scan eviction.
        pub fn insert(&mut self, key: u64, tokens: u32) -> bool {
            let now = self.tick();
            if let Some(e) = self.entries.get_mut(&key) {
                e.last_use = now;
                return true;
            }
            let bytes = self.bytes_for(tokens);
            loop {
                if let Some(alloc) = self.pool.alloc(self.numa, bytes) {
                    self.entries.insert(
                        key,
                        ScanHostEntry {
                            tokens,
                            alloc,
                            last_use: now,
                        },
                    );
                    return true;
                }
                if self.drop_lru().is_none() {
                    return false;
                }
            }
        }

        /// Tokens of a host-resident prefix, without touching LRU state.
        pub fn peek(&self, key: u64) -> Option<u32> {
            self.entries.get(&key).map(|e| e.tokens)
        }

        /// Refresh a host entry's LRU position; false if absent.
        pub fn touch(&mut self, key: u64) -> bool {
            let now = self.tick();
            match self.entries.get_mut(&key) {
                Some(e) => {
                    e.last_use = now;
                    true
                }
                None => false,
            }
        }

        /// Drop a prefix, freeing its bytes; returns its tokens.
        pub fn remove(&mut self, key: u64) -> Option<u32> {
            let e = self.entries.remove(&key)?;
            self.pool.free(e.alloc);
            Some(e.tokens)
        }

        /// Bytes currently pinned.
        pub fn used_bytes(&self) -> u64 {
            self.pool.used(self.numa)
        }

        /// Number of cached prefixes.
        pub fn len(&self) -> usize {
            self.entries.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::oracle::{ScanGpuTier, ScanHostPool};
    use super::*;

    fn host(capacity_tokens: u64) -> HostPrefixPool {
        // 1 byte per token keeps the arithmetic transparent in tests.
        HostPrefixPool::new(16, capacity_tokens, 1, 1, NumaId(0))
    }

    #[test]
    fn hash_is_prefix_sensitive() {
        let a = prefix_hash(&[1, 2, 3]);
        let b = prefix_hash(&[1, 2, 4]);
        let c = prefix_hash(&[1, 2, 3]);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn gpu_tier_insert_then_hit() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        assert!(g.insert(42, 1000).inserted);
        assert_eq!(g.peek(42), Some(1000));
        assert_eq!(g.peek(43), None);
        assert!(g.touch(42));
        assert!(!g.touch(43));
    }

    #[test]
    fn gpu_tier_demotes_lru_under_pressure() {
        // 2×1024 tokens fit; the third insert evicts the LRU entry.
        let mut g = GpuPrefixTier::new(16, 2048);
        g.insert(1, 1024);
        g.insert(2, 1024);
        g.touch(1); // 2 is now LRU
        let out = g.insert(3, 1024);
        assert!(out.inserted);
        assert_eq!(out.evicted, vec![(2, 1024)]);
        assert_eq!(g.peek(2), None);
        assert_eq!(g.peek(1), Some(1024));
    }

    #[test]
    fn gpu_tier_oversized_entry_not_inserted() {
        let mut g = GpuPrefixTier::new(16, 1024);
        g.insert(1, 512);
        let out = g.insert(2, 4096);
        assert!(!out.inserted);
        assert!(out.evicted.is_empty(), "no pointless evictions");
        assert_eq!(g.peek(1), Some(512), "resident entry untouched");
    }

    #[test]
    fn gpu_tier_accounting_block_aligned() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        g.insert(1, 17); // rounds to 32
        assert_eq!(g.used_tokens(), 32);
        assert_eq!(g.remove(1), Some(17));
        assert_eq!(g.used_tokens(), 0);
    }

    #[test]
    fn gpu_tier_reinsert_refreshes_without_resizing() {
        let mut g = GpuPrefixTier::new(16, 1 << 20);
        g.insert(1, 1000);
        let out = g.insert(1, 5000); // existing key: refresh only
        assert!(out.inserted);
        assert_eq!(g.peek(1), Some(1000), "insert never resizes an entry");
        assert_eq!(g.used_tokens(), 1008);
    }

    #[test]
    fn host_pool_enforces_byte_capacity() {
        // Capacity 2048 tokens × 1 B/token: the third 1024-token prefix
        // drops the LRU, and occupancy never exceeds the HostPool cap.
        let mut h = host(2048);
        assert!(h.insert(1, 1024));
        assert!(h.insert(2, 1024));
        assert_eq!(h.used_bytes(), 2048);
        assert!(h.insert(3, 1024)); // drops key 1 (LRU)
        assert_eq!(h.peek(1), None);
        assert_eq!(h.len(), 2);
        assert!(h.used_bytes() <= 2048, "over capacity: {}", h.used_bytes());
    }

    #[test]
    fn host_pool_skips_oversized_entries() {
        let mut h = host(1024);
        assert!(h.insert(1, 512));
        assert!(!h.insert(2, 4096), "larger than the whole tier");
        assert_eq!(h.peek(1), Some(512), "resident entries survive");
    }

    #[test]
    fn host_pool_remove_frees_bytes() {
        let mut h = host(1 << 20);
        h.insert(7, 512);
        assert_eq!(h.used_bytes(), 512);
        assert_eq!(h.remove(7), Some(512));
        assert_eq!(h.used_bytes(), 0);
        assert_eq!(h.remove(7), None);
    }

    #[test]
    fn host_pool_refresh_keeps_one_allocation() {
        let mut h = host(1 << 20);
        h.insert(7, 512);
        assert!(h.insert(7, 9999), "refresh, not re-alloc");
        assert_eq!(h.used_bytes(), 512);
        assert_eq!(h.peek(7), Some(512));
    }

    #[test]
    fn populate_seeds_n_entries() {
        let mut h = host(1 << 20);
        let mut rng = Rng::seed_from_u64(3);
        let keys = h.populate(&mut rng, 8, 100);
        assert_eq!(keys.len(), 8);
        assert_eq!(h.len(), 8);
    }

    // ----- oracle equivalence (the tentpole's property tests) -----------

    /// Randomized op script both implementations run in lockstep.
    fn op_script(seed: u64, ops: usize, key_space: u64) -> Vec<(u8, u64, u32)> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..ops)
            .map(|_| {
                let op = rng.range_u64(0, 10) as u8; // weighted toward insert
                let key = rng.range_u64(1, key_space + 1);
                let tokens = rng.range_u64(1, 2049) as u32;
                (op, key, tokens)
            })
            .collect()
    }

    #[test]
    fn gpu_tier_matches_scan_oracle_under_randomized_churn() {
        // Small capacity vs key space ⇒ constant eviction pressure. The
        // O(1) list and the O(n) scan must agree on *everything*: insert
        // outcomes (including the exact eviction sequence), touch/peek
        // results, removal results, and accounting — on every step.
        for seed in [1u64, 0xfeed, 0xb008] {
            let mut fast = GpuPrefixTier::new(16, 8 * 1024);
            let mut slow = ScanGpuTier::new(16, 8 * 1024);
            for (op, key, tokens) in op_script(seed, 3000, 24) {
                match op {
                    0..=5 => {
                        let a = fast.insert(key, tokens);
                        let b = slow.insert(key, tokens);
                        assert_eq!(a.inserted, b.inserted, "seed {seed}");
                        assert_eq!(a.evicted, b.evicted, "seed {seed}: eviction order");
                    }
                    6..=7 => assert_eq!(fast.touch(key), slow.touch(key), "seed {seed}"),
                    8 => assert_eq!(fast.remove(key), slow.remove(key), "seed {seed}"),
                    _ => assert_eq!(fast.peek(key), slow.peek(key), "seed {seed}"),
                }
                assert_eq!(fast.used_tokens(), slow.used_tokens(), "seed {seed}");
                assert_eq!(fast.len(), slow.len(), "seed {seed}");
            }
            assert!(!fast.is_empty(), "churn should leave residents");
        }
    }

    #[test]
    fn host_pool_matches_scan_oracle_under_randomized_churn() {
        for seed in [2u64, 0xcafe, 0xb008] {
            let mut fast = HostPrefixPool::new(16, 8 * 1024, 1, 1, NumaId(0));
            let mut slow = ScanHostPool::new(16, 8 * 1024, 1, 1, NumaId(0));
            for (op, key, tokens) in op_script(seed, 3000, 24) {
                match op {
                    0..=5 => {
                        assert_eq!(fast.insert(key, tokens), slow.insert(key, tokens));
                    }
                    6..=7 => assert_eq!(fast.touch(key), slow.touch(key), "seed {seed}"),
                    8 => assert_eq!(fast.remove(key), slow.remove(key), "seed {seed}"),
                    _ => assert_eq!(fast.peek(key), slow.peek(key), "seed {seed}"),
                }
                assert_eq!(fast.used_bytes(), slow.used_bytes(), "seed {seed}");
                assert_eq!(fast.len(), slow.len(), "seed {seed}");
            }
        }
    }

    #[test]
    fn oracle_clock_is_strictly_monotone() {
        // The pinned tie-break: the retired scan never had ties to break,
        // because every touch/insert ticked the clock exactly once —
        // `last_use` values are unique, so `min_by_key` is a total order
        // identical to strict recency. This is the invariant that makes
        // the LRU-list eviction order (and replay output) byte-identical.
        let mut t = ScanGpuTier::new(16, 1 << 20);
        let mut last = t.clock();
        for i in 0..100u64 {
            t.insert(i + 1, 64);
            assert_eq!(t.clock(), last + 1, "one tick per op, never reused");
            last = t.clock();
            t.touch((i % 7) + 1);
            assert_eq!(t.clock(), last + 1);
            last = t.clock();
        }
    }
}
