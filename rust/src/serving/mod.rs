//! vLLM-like serving layer: the host system whose transfer paths MMA
//! accelerates. A fleet of per-GPU serving instances — each with paged KV
//! caching, a GPU prefix tier, and a continuous-batching prefill/decode
//! scheduler — runs under an event-driven request router on one
//! [`crate::mma::SimWorld`] clock, over a fleet-shared pinned-host prefix
//! tier (LMCache-style) and a sleep/wake model registry (vLLM Sleep Mode
//! Level 1) — everything §5.2's end-to-end experiments exercise, scaled
//! across the whole server.

pub mod engine;
pub mod fleet;
pub mod instance;
pub mod kv_cache;
pub mod model_registry;
pub mod prefix_cache;
pub mod router;
pub mod scheduler;

pub use engine::ServingEngine;
pub use fleet::ServingFleet;
pub use instance::{
    compute_from, Compute, FixedCompute, LegacyCosts, RequestOutcome, ServingInstance, StepRecord,
};
pub use kv_cache::{BlockId, KvCacheManager};
pub use model_registry::{ModelRegistry, ModelState, PendingPhase};
pub use prefix_cache::{GpuPrefixTier, HostPrefixPool};
pub use router::{RoutePolicy, Router};
pub use scheduler::{tenant_key, BatchFormer, Request, RequestId, Scheduler, StepPlan};
