//! vLLM-like serving layer: the host system whose transfer paths MMA
//! accelerates. Provides paged KV caching with a host offload tier and
//! prefix reuse (LMCache-style), a sleep/wake model registry (vLLM Sleep
//! Mode Level 1), a continuous-batching prefill/decode scheduler, and a
//! request router — everything §5.2's end-to-end experiments exercise.

pub mod engine;
pub mod kv_cache;
pub mod model_registry;
pub mod prefix_cache;
pub mod router;
pub mod scheduler;

pub use engine::{Compute, FixedCompute, RequestOutcome, ServingEngine};
pub use kv_cache::{BlockId, KvCacheManager};
pub use model_registry::{ModelRegistry, ModelState, PendingPhase};
pub use prefix_cache::{PrefixCache, Tier};
pub use router::Router;
pub use scheduler::{Request, RequestId, Scheduler};
