//! Prefill/decode scheduler with continuous batching.
//!
//! vLLM-style: a FCFS waiting queue, a running set, and per-step batch
//! assembly under token and sequence budgets. Prefill/decode
//! disaggregation (the paper evaluates under LMCache+vLLM with PD
//! disaggregation) assigns prefill and decode phases to distinct GPU
//! groups; in aggregated mode decode sequences get priority and prefills
//! fill the remaining token budget.

use crate::config::ServingConfig;
use crate::mma::TransferClass;
use crate::sim::Time;
use std::collections::VecDeque;

/// Request identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestId(pub u64);

/// Namespace a prefix-cache key under a tenant, so two tenants using the
/// same document key never share (or even observe) each other's cached
/// KV. Tenant 0 is the default single-tenant namespace and maps keys
/// through unchanged, which keeps every pre-multi-tenant caller and trace
/// bit-identical; key 0 stays 0 (no cached prefix) for every tenant.
pub fn tenant_key(tenant: u32, key: u64) -> u64 {
    if tenant == 0 || key == 0 {
        return key;
    }
    // splitmix64 finalizer over (tenant, key); | 1 keeps the result
    // nonzero so a tagged key can never alias the "no prefix" sentinel.
    let mut z = key ^ (tenant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) | 1
}

/// A serving request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Id.
    pub id: RequestId,
    /// Arrival time.
    pub arrival: Time,
    /// Full prompt length in tokens.
    pub prompt_tokens: u32,
    /// Of which a cached prefix of this many tokens may be reused.
    pub cached_prefix_tokens: u32,
    /// Prefix-cache key (0 = no cached prefix), scoped to `tenant`.
    pub prefix_key: u64,
    /// Output tokens to generate.
    pub output_tokens: u32,
    /// Tenant the request belongs to (0 = the default namespace). Prefix
    /// lookups go through [`Request::cache_key`], so tenants never share
    /// cached KV even when their document keys collide.
    pub tenant: u32,
    /// QoS class the request's KV fetch should carry; `None` = the
    /// serving default ([`TransferClass::LatencyCritical`]).
    pub class: Option<TransferClass>,
}

impl Request {
    /// Tenant-tagged prefix-cache key — the key every prefix tier
    /// (GPU, host, peer) is actually indexed by.
    pub fn cache_key(&self) -> u64 {
        tenant_key(self.tenant, self.prefix_key)
    }

    /// QoS class of the request's prefix-KV fetch.
    pub fn fetch_class(&self) -> TransferClass {
        self.class.unwrap_or(TransferClass::LatencyCritical)
    }
}

/// Phase a scheduled sequence is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Needs prefill of `suffix` tokens (after prefix reuse).
    Prefill {
        /// Tokens that must actually be prefilled.
        suffix: u32,
    },
    /// Generating; `produced` of `total` output tokens done.
    Decode {
        /// Tokens generated so far.
        produced: u32,
    },
}

/// A running sequence.
#[derive(Clone, Debug)]
pub struct Sequence {
    /// The request behind it.
    pub req: Request,
    /// Current phase.
    pub phase: Phase,
}

/// The scheduler.
pub struct Scheduler {
    cfg: ServingConfig,
    waiting: VecDeque<Request>,
    running: Vec<Sequence>,
}

impl Scheduler {
    /// New scheduler under `cfg` budgets.
    pub fn new(cfg: ServingConfig) -> Scheduler {
        Scheduler {
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Enqueue an arrival.
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Requests waiting to be scheduled.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently running.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Any work left?
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Ids of every running decode sequence, in admission order.
    pub fn running_decodes(&self) -> Vec<RequestId> {
        self.running
            .iter()
            .filter(|s| matches!(s.phase, Phase::Decode { .. }))
            .map(|s| s.req.id)
            .collect()
    }

    /// Number of running decode sequences (allocation-free; the event
    /// loop polls this on every notice).
    pub fn decode_count(&self) -> usize {
        self.running
            .iter()
            .filter(|s| matches!(s.phase, Phase::Decode { .. }))
            .count()
    }

    /// Admit prefills from the FCFS queue. `busy_tokens` is the token
    /// budget already committed elsewhere (in-flight prefill suffixes,
    /// plus one token per running decode in aggregated mode). `resolve`
    /// maps a request to the prefix tokens actually reusable from the
    /// cache *right now*; the suffix derived from it is the single source
    /// of truth for both the batch-budget cost and the tokens the engine
    /// will prefill (no separate engine-side reuse computation). Admission
    /// stops at the first request that no longer fits (FCFS: no queue
    /// jumping); a request larger than the whole budget is still admitted
    /// once nothing else is committed, so oversized prompts cannot stall.
    pub fn plan_prefills(
        &mut self,
        busy_tokens: u32,
        mut resolve: impl FnMut(&Request) -> u32,
    ) -> Vec<(RequestId, u32)> {
        let mut out = Vec::new();
        let mut tokens_used = busy_tokens;
        let seq_cap = self.seq_cap();
        while let Some(front) = self.waiting.front() {
            if self.running.len() >= seq_cap {
                break;
            }
            let reused = resolve(front).min(front.prompt_tokens);
            let suffix = front.prompt_tokens - reused;
            let cost = suffix.max(1);
            if tokens_used.saturating_add(cost) > self.cfg.max_batch_tokens && tokens_used > 0 {
                break; // batch full; keep FCFS order
            }
            let req = self.waiting.pop_front().unwrap();
            tokens_used += cost;
            out.push((req.id, suffix));
            self.running.push(Sequence {
                req,
                phase: Phase::Prefill { suffix },
            });
        }
        out
    }

    /// Concurrent-sequence cap: `max_batch_seqs`, additionally bounded by
    /// the `max_concurrency` admission knob when set (> 0).
    fn seq_cap(&self) -> usize {
        let cap = self.cfg.max_batch_seqs;
        let cap = if self.cfg.max_concurrency > 0 {
            cap.min(self.cfg.max_concurrency)
        } else {
            cap
        };
        cap as usize
    }

    /// Mark a prefill finished: the sequence moves to decode.
    pub fn prefill_done(&mut self, id: RequestId) {
        let s = self
            .running
            .iter_mut()
            .find(|s| s.req.id == id)
            .expect("prefill_done for unknown sequence");
        debug_assert!(matches!(s.phase, Phase::Prefill { .. }));
        s.phase = Phase::Decode { produced: 0 };
    }

    /// Advance a decode by one token; returns true when the sequence
    /// finished and was retired.
    pub fn decode_tick(&mut self, id: RequestId) -> bool {
        let idx = self
            .running
            .iter()
            .position(|s| s.req.id == id)
            .expect("decode_tick for unknown sequence");
        let done = {
            let s = &mut self.running[idx];
            let Phase::Decode { produced } = &mut s.phase else {
                panic!("decode_tick on prefill sequence");
            };
            *produced += 1;
            *produced >= s.req.output_tokens
        };
        if done {
            self.running.swap_remove(idx);
        }
        done
    }

    /// Read access to a running sequence.
    pub fn sequence(&self, id: RequestId) -> Option<&Sequence> {
        self.running.iter().find(|s| s.req.id == id)
    }
}

/// One fused continuous-batching iteration, as formed by
/// [`BatchFormer::form`]: every running decode leg (one output token
/// each) plus the chunked-prefill legs that fit the remaining token
/// budget, in FCFS order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StepPlan {
    /// Prefill legs: `(request, tokens computed this step)`, FCFS order.
    pub prefills: Vec<(RequestId, u32)>,
    /// Decode legs in admission order, one output token per leg.
    pub decodes: Vec<RequestId>,
}

impl StepPlan {
    /// Nothing runnable this iteration.
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }

    /// Total prefill tokens scheduled this step.
    pub fn prefill_tokens(&self) -> u32 {
        self.prefills.iter().map(|&(_, t)| t).sum()
    }
}

/// The iteration-level batch former behind `[batching] enabled`: each
/// step takes the whole running decode batch first (one token per
/// sequence against the budget), then fills the rest of the
/// `max_batch_tokens` budget with prefill work in FCFS order, `chunk_tokens`
/// at a time. Join/leave happens at step boundaries because the caller
/// re-forms the plan after every step completes.
///
/// Degenerate settings reproduce the seed scheduler exactly: with
/// chunking off a prefill leg is its whole remaining suffix (admitted
/// even when oversized, so big prompts cannot stall — the same no-stall
/// rule as [`Scheduler::plan_prefills`]), which is what the per-request
/// path runs as one kernel.
#[derive(Clone, Copy, Debug)]
pub struct BatchFormer {
    /// Token budget per step (`[serving] max_batch_tokens`).
    pub max_batch_tokens: u32,
    /// Chunked-prefill chunk size (`[batching] chunk_tokens`); 0 = each
    /// leg takes its whole remaining suffix.
    pub chunk_tokens: u32,
}

impl BatchFormer {
    /// Form one step from the running decode set and the ready prefill
    /// queue (`(request, remaining suffix tokens)`, FCFS order; remaining
    /// must be >= 1 — zero-suffix prefills cost one token, as in
    /// [`Scheduler::plan_prefills`]).
    pub fn form(
        &self,
        decodes: Vec<RequestId>,
        ready_prefills: impl IntoIterator<Item = (RequestId, u32)>,
    ) -> StepPlan {
        let mut used = u32::try_from(decodes.len()).unwrap_or(u32::MAX);
        let mut prefills = Vec::new();
        for (rid, remaining) in ready_prefills {
            debug_assert!(remaining >= 1, "prefill legs cost at least one token");
            let left = self.max_batch_tokens.saturating_sub(used);
            if left == 0 {
                break;
            }
            let mut take = remaining.max(1);
            if self.chunk_tokens > 0 {
                take = take.min(self.chunk_tokens);
            }
            if take > left {
                if used > 0 {
                    break; // step full; keep FCFS order
                }
                if self.chunk_tokens > 0 {
                    take = left; // budget-true chunking
                }
                // chunking off + empty step: the oversized whole prompt is
                // still admitted (no-stall rule).
            }
            used = used.saturating_add(take);
            prefills.push((rid, take));
        }
        StepPlan { prefills, decodes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tokens: u32, seqs: u32, pd: bool) -> ServingConfig {
        ServingConfig {
            max_batch_tokens: tokens,
            max_batch_seqs: seqs,
            pd_disaggregation: pd,
            ..Default::default()
        }
    }

    fn req(id: u64, prompt: u32, cached: u32, out: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: Time::ZERO,
            prompt_tokens: prompt,
            cached_prefix_tokens: cached,
            prefix_key: 0,
            output_tokens: out,
            tenant: 0,
            class: None,
        }
    }

    #[test]
    fn tenant_keys_namespace_without_breaking_the_default() {
        // Tenant 0 is the identity (pre-multi-tenant behavior), key 0 is
        // preserved (no-prefix sentinel), and distinct tenants sharing a
        // document key land on distinct, nonzero cache keys.
        assert_eq!(tenant_key(0, 7), 7);
        assert_eq!(tenant_key(0, 0), 0);
        assert_eq!(tenant_key(3, 0), 0);
        let a = tenant_key(1, 7);
        let b = tenant_key(2, 7);
        assert_ne!(a, 7);
        assert_ne!(a, b);
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        // Deterministic: same (tenant, key) always maps the same way.
        assert_eq!(tenant_key(1, 7), a);
        let r = Request {
            id: RequestId(1),
            arrival: Time::ZERO,
            prompt_tokens: 10,
            cached_prefix_tokens: 5,
            prefix_key: 7,
            output_tokens: 1,
            tenant: 1,
            class: None,
        };
        assert_eq!(r.cache_key(), a);
        assert_eq!(r.fetch_class(), crate::mma::TransferClass::LatencyCritical);
        let bulk = Request {
            class: Some(crate::mma::TransferClass::Bulk),
            ..r
        };
        assert_eq!(bulk.fetch_class(), crate::mma::TransferClass::Bulk);
    }

    /// Admit with the request's own claimed prefix as the resolver (what
    /// the engine does, with the cache as the source).
    fn plan(s: &mut Scheduler, busy: u32) -> Vec<(RequestId, u32)> {
        s.plan_prefills(busy, |r| r.cached_prefix_tokens)
    }

    #[test]
    fn fcfs_admission_under_token_budget() {
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 600, 0, 4));
        s.submit(req(2, 600, 0, 4));
        s.submit(req(3, 100, 0, 4));
        // 600 fits; +600 exceeds → stop (FCFS: 3 must not jump the queue).
        assert_eq!(plan(&mut s, 0), vec![(RequestId(1), 600)]);
        assert_eq!(s.waiting_len(), 2);
        assert_eq!(plan(&mut s, 0)[0].0, RequestId(2));
    }

    #[test]
    fn cached_prefix_reduces_prefill_cost() {
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 900, 800, 4)); // suffix 100
        s.submit(req(2, 900, 0, 4)); // suffix 900
        let prefills = plan(&mut s, 0);
        // Both fit: 100 + 900 = 1000.
        assert_eq!(prefills.len(), 2);
        assert_eq!(prefills[0], (RequestId(1), 100));
    }

    #[test]
    fn decode_priority_in_aggregated_mode() {
        let mut s = Scheduler::new(cfg(100, 64, false));
        s.submit(req(1, 50, 0, 2));
        assert_eq!(plan(&mut s, 0).len(), 1);
        s.prefill_done(RequestId(1));
        s.submit(req(2, 100, 0, 2));
        // Aggregated mode: the running decode's token counts against the
        // budget, so the 100-token prefill no longer fits (1 + 100 > 100).
        assert_eq!(s.running_decodes(), vec![RequestId(1)]);
        let busy = s.decode_count() as u32;
        assert!(plan(&mut s, busy).is_empty());
        // In PD mode decodes hold no budget, so the prefill is admitted.
        let mut s2 = Scheduler::new(cfg(100, 64, true));
        s2.submit(req(1, 50, 0, 2));
        plan(&mut s2, 0);
        s2.prefill_done(RequestId(1));
        s2.submit(req(2, 100, 0, 2));
        assert_eq!(s2.decode_count(), 1);
        assert_eq!(plan(&mut s2, 0).len(), 1);
    }

    #[test]
    fn sequence_budget_respected() {
        let mut s = Scheduler::new(cfg(10_000, 2, true));
        for i in 0..5 {
            s.submit(req(i, 10, 0, 2));
        }
        assert_eq!(plan(&mut s, 0).len(), 2);
        assert_eq!(s.running_len(), 2);
    }

    #[test]
    fn resolver_is_suffix_source_of_truth() {
        // The cache may hold fewer reusable tokens than the request
        // claims; the resolved value drives both the budget cost and the
        // suffix stored on the sequence.
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 900, 800, 4)); // claims 800 cached…
        let plan = s.plan_prefills(0, |_| 100); // …but only 100 are there
        assert_eq!(plan, vec![(RequestId(1), 800)]);
        match s.sequence(RequestId(1)).unwrap().phase {
            Phase::Prefill { suffix } => assert_eq!(suffix, 800),
            _ => panic!("admitted sequence must be in prefill"),
        }
    }

    #[test]
    fn busy_tokens_and_concurrency_gate_admission() {
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 600, 0, 4));
        assert!(
            s.plan_prefills(700, |r| r.cached_prefix_tokens).is_empty(),
            "in-flight work holds the budget"
        );
        assert_eq!(s.plan_prefills(0, |r| r.cached_prefix_tokens).len(), 1);

        let mut s2 = Scheduler::new(ServingConfig {
            max_concurrency: 1,
            ..cfg(10_000, 64, true)
        });
        for i in 0..3 {
            s2.submit(req(i, 10, 0, 2));
        }
        let plan = s2.plan_prefills(0, |r| r.cached_prefix_tokens);
        assert_eq!(plan.len(), 1, "max_concurrency caps admission");
    }

    #[test]
    fn decode_until_retirement() {
        let mut s = Scheduler::new(cfg(1000, 8, true));
        s.submit(req(1, 10, 0, 3));
        plan(&mut s, 0);
        s.prefill_done(RequestId(1));
        assert!(!s.decode_tick(RequestId(1)));
        assert!(!s.decode_tick(RequestId(1)));
        assert!(s.decode_tick(RequestId(1)), "third token retires");
        assert!(s.is_idle());
    }

    fn former(budget: u32, chunk: u32) -> BatchFormer {
        BatchFormer {
            max_batch_tokens: budget,
            chunk_tokens: chunk,
        }
    }

    #[test]
    fn former_fills_budget_after_decodes() {
        // 3 decode legs cost one token each; 97 tokens left for prefill.
        let plan = former(100, 0).form(
            vec![RequestId(10), RequestId(11), RequestId(12)],
            vec![(RequestId(1), 50), (RequestId(2), 47), (RequestId(3), 1)],
        );
        assert_eq!(plan.decodes.len(), 3);
        assert_eq!(
            plan.prefills,
            vec![(RequestId(1), 50), (RequestId(2), 47)],
            "FCFS until the budget is exhausted; no skipping to fit 3"
        );
        assert_eq!(plan.prefill_tokens(), 97);
        assert!(!plan.is_empty());
    }

    #[test]
    fn former_chunks_prefill_to_chunk_tokens() {
        let plan = former(10_000, 512).form(vec![], vec![(RequestId(1), 4_096), (RequestId(2), 100)]);
        assert_eq!(
            plan.prefills,
            vec![(RequestId(1), 512), (RequestId(2), 100)],
            "a long prefill advances one chunk per step"
        );
    }

    #[test]
    fn former_clamps_chunk_to_remaining_budget() {
        // Chunked mode stays budget-true even on an otherwise-empty step.
        let plan = former(300, 512).form(vec![], vec![(RequestId(1), 4_096)]);
        assert_eq!(plan.prefills, vec![(RequestId(1), 300)]);
    }

    #[test]
    fn former_admits_oversized_whole_prompt_when_unchunked() {
        // Chunking off: a prompt larger than the whole budget still runs
        // (the per-request scheduler's no-stall rule), but only alone.
        let plan = former(100, 0).form(vec![], vec![(RequestId(1), 5_000), (RequestId(2), 10)]);
        assert_eq!(plan.prefills, vec![(RequestId(1), 5_000)]);
        let busy = former(100, 0).form(vec![RequestId(9)], vec![(RequestId(1), 5_000)]);
        assert!(busy.prefills.is_empty(), "not when decodes hold budget");
        assert_eq!(busy.decodes, vec![RequestId(9)]);
    }

    #[test]
    fn former_batch1_chunk_off_degenerates_to_the_oracle() {
        // The oracle precondition: with one sequence alive the step is
        // either the whole remaining suffix or the one decode leg —
        // exactly what the per-request scheduler runs.
        let p = former(8_192, 0).form(vec![], vec![(RequestId(1), 1_234)]);
        assert_eq!(p.prefills, vec![(RequestId(1), 1_234)]);
        assert!(p.decodes.is_empty());
        let d = former(8_192, 0).form(vec![RequestId(1)], vec![]);
        assert!(d.prefills.is_empty());
        assert_eq!(d.decodes, vec![RequestId(1)]);
        assert!(former(8_192, 0).form(vec![], vec![]).is_empty());
    }

    #[test]
    fn former_never_exceeds_budget_property() {
        // Randomized: tokens used (decodes + prefill legs) never exceed
        // the budget unless a single unchunked oversized leg invoked the
        // no-stall rule; FCFS prefix order is always preserved.
        crate::testkit::check("batch-former-budget", |rng| {
            let budget = rng.range_u64(1, 4_096) as u32;
            let chunk = rng.range_u64(0, 1_024) as u32;
            let decodes: Vec<RequestId> =
                (0..rng.range_u64(0, 64)).map(RequestId).collect();
            let ready: Vec<(RequestId, u32)> = (0..rng.range_u64(0, 32))
                .map(|i| (RequestId(100 + i), rng.range_u64(1, 8_192) as u32))
                .collect();
            let plan = former(budget, chunk).form(decodes.clone(), ready.clone());
            assert_eq!(plan.decodes, decodes);
            let used = plan.decodes.len() as u64 + plan.prefill_tokens() as u64;
            let oversized_alone = chunk == 0
                && plan.decodes.is_empty()
                && plan.prefills.len() == 1
                && plan.prefills[0].1 as u64 > budget as u64;
            assert!(
                used <= budget as u64 || oversized_alone,
                "used {used} over budget {budget} (chunk {chunk})"
            );
            // FCFS: the planned legs are a prefix of the ready queue,
            // each taking no more than its remaining tokens.
            for (planned, ready) in plan.prefills.iter().zip(&ready) {
                assert_eq!(planned.0, ready.0);
                assert!(planned.1 <= ready.1);
                if chunk > 0 {
                    assert!(planned.1 <= chunk);
                }
            }
        });
    }
}
