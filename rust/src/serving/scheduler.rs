//! Prefill/decode scheduler with continuous batching.
//!
//! vLLM-style: a FCFS waiting queue, a running set, and per-step batch
//! assembly under token and sequence budgets. Prefill/decode
//! disaggregation (the paper evaluates under LMCache+vLLM with PD
//! disaggregation) assigns prefill and decode phases to distinct GPU
//! groups; in aggregated mode decode sequences get priority and prefills
//! fill the remaining token budget.

use crate::config::ServingConfig;
use crate::sim::Time;
use std::collections::VecDeque;

/// Request identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestId(pub u64);

/// A serving request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Id.
    pub id: RequestId,
    /// Arrival time.
    pub arrival: Time,
    /// Full prompt length in tokens.
    pub prompt_tokens: u32,
    /// Of which a cached prefix of this many tokens may be reused.
    pub cached_prefix_tokens: u32,
    /// Prefix-cache key (0 = no cached prefix).
    pub prefix_key: u64,
    /// Output tokens to generate.
    pub output_tokens: u32,
}

/// Phase a scheduled sequence is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Needs prefill of `suffix` tokens (after prefix reuse).
    Prefill {
        /// Tokens that must actually be prefilled.
        suffix: u32,
    },
    /// Generating; `produced` of `total` output tokens done.
    Decode {
        /// Tokens generated so far.
        produced: u32,
    },
}

/// A running sequence.
#[derive(Clone, Debug)]
pub struct Sequence {
    /// The request behind it.
    pub req: Request,
    /// Current phase.
    pub phase: Phase,
}

/// One scheduling step's work assignment.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Requests entering prefill this step: (id, suffix tokens).
    pub prefills: Vec<(RequestId, u32)>,
    /// Sequences advancing one decode token.
    pub decodes: Vec<RequestId>,
}

/// The scheduler.
pub struct Scheduler {
    cfg: ServingConfig,
    waiting: VecDeque<Request>,
    running: Vec<Sequence>,
}

impl Scheduler {
    /// New scheduler under `cfg` budgets.
    pub fn new(cfg: ServingConfig) -> Scheduler {
        Scheduler {
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Enqueue an arrival.
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Requests waiting to be scheduled.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently running.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Any work left?
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Assemble the next step: decodes first (latency-sensitive), then
    /// admit prefills into the remaining token budget. In PD-disaggregated
    /// mode prefills don't compete with decodes for the budget (separate
    /// GPU groups), so prefills are admitted up to the full budget.
    pub fn plan_step(&mut self) -> StepPlan {
        let mut plan = StepPlan::default();
        let mut tokens_used = 0u32;

        // Decodes: one token per running decode sequence.
        for s in &self.running {
            if matches!(s.phase, Phase::Decode { .. }) {
                plan.decodes.push(s.req.id);
                if !self.cfg.pd_disaggregation {
                    tokens_used += 1;
                }
            }
        }

        // Prefill admission.
        let budget = self.cfg.max_batch_tokens;
        while let Some(front) = self.waiting.front() {
            if self.running.len() >= self.cfg.max_batch_seqs as usize {
                break;
            }
            let suffix = front.prompt_tokens - front.cached_prefix_tokens;
            let cost = suffix.max(1);
            if tokens_used + cost > budget && tokens_used > 0 {
                break; // batch full; keep FCFS order
            }
            let req = self.waiting.pop_front().unwrap();
            tokens_used += cost;
            plan.prefills.push((req.id, suffix));
            self.running.push(Sequence {
                req,
                phase: Phase::Prefill { suffix },
            });
        }
        plan
    }

    /// Mark a prefill finished: the sequence moves to decode.
    pub fn prefill_done(&mut self, id: RequestId) {
        let s = self
            .running
            .iter_mut()
            .find(|s| s.req.id == id)
            .expect("prefill_done for unknown sequence");
        debug_assert!(matches!(s.phase, Phase::Prefill { .. }));
        s.phase = Phase::Decode { produced: 0 };
    }

    /// Advance a decode by one token; returns true when the sequence
    /// finished and was retired.
    pub fn decode_tick(&mut self, id: RequestId) -> bool {
        let idx = self
            .running
            .iter()
            .position(|s| s.req.id == id)
            .expect("decode_tick for unknown sequence");
        let done = {
            let s = &mut self.running[idx];
            let Phase::Decode { produced } = &mut s.phase else {
                panic!("decode_tick on prefill sequence");
            };
            *produced += 1;
            *produced >= s.req.output_tokens
        };
        if done {
            self.running.swap_remove(idx);
        }
        done
    }

    /// Read access to a running sequence.
    pub fn sequence(&self, id: RequestId) -> Option<&Sequence> {
        self.running.iter().find(|s| s.req.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tokens: u32, seqs: u32, pd: bool) -> ServingConfig {
        ServingConfig {
            max_batch_tokens: tokens,
            max_batch_seqs: seqs,
            pd_disaggregation: pd,
            ..Default::default()
        }
    }

    fn req(id: u64, prompt: u32, cached: u32, out: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: Time::ZERO,
            prompt_tokens: prompt,
            cached_prefix_tokens: cached,
            prefix_key: 0,
            output_tokens: out,
        }
    }

    #[test]
    fn fcfs_admission_under_token_budget() {
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 600, 0, 4));
        s.submit(req(2, 600, 0, 4));
        s.submit(req(3, 100, 0, 4));
        let plan = s.plan_step();
        // 600 fits; +600 exceeds → stop (FCFS: 3 must not jump the queue).
        assert_eq!(plan.prefills, vec![(RequestId(1), 600)]);
        assert_eq!(s.waiting_len(), 2);
        let plan = s.plan_step();
        assert_eq!(plan.prefills[0].0, RequestId(2));
    }

    #[test]
    fn cached_prefix_reduces_prefill_cost() {
        let mut s = Scheduler::new(cfg(1000, 64, true));
        s.submit(req(1, 900, 800, 4)); // suffix 100
        s.submit(req(2, 900, 0, 4)); // suffix 900
        let plan = s.plan_step();
        // Both fit: 100 + 900 = 1000.
        assert_eq!(plan.prefills.len(), 2);
        assert_eq!(plan.prefills[0], (RequestId(1), 100));
    }

    #[test]
    fn decode_priority_in_aggregated_mode() {
        let mut s = Scheduler::new(cfg(100, 64, false));
        s.submit(req(1, 50, 0, 2));
        let p = s.plan_step();
        assert_eq!(p.prefills.len(), 1);
        s.prefill_done(RequestId(1));
        s.submit(req(2, 100, 0, 2));
        let p = s.plan_step();
        // Decode runs; its token counts against the budget, so the
        // 100-token prefill no longer fits (100 + 1 > 100).
        assert_eq!(p.decodes, vec![RequestId(1)]);
        assert!(p.prefills.is_empty());
        // In PD mode the prefill would be admitted.
        let mut s2 = Scheduler::new(cfg(100, 64, true));
        s2.submit(req(1, 50, 0, 2));
        s2.plan_step();
        s2.prefill_done(RequestId(1));
        s2.submit(req(2, 100, 0, 2));
        let p2 = s2.plan_step();
        assert_eq!(p2.decodes.len(), 1);
        assert_eq!(p2.prefills.len(), 1);
    }

    #[test]
    fn sequence_budget_respected() {
        let mut s = Scheduler::new(cfg(10_000, 2, true));
        for i in 0..5 {
            s.submit(req(i, 10, 0, 2));
        }
        let p = s.plan_step();
        assert_eq!(p.prefills.len(), 2);
        assert_eq!(s.running_len(), 2);
    }

    #[test]
    fn decode_until_retirement() {
        let mut s = Scheduler::new(cfg(1000, 8, true));
        s.submit(req(1, 10, 0, 3));
        s.plan_step();
        s.prefill_done(RequestId(1));
        assert!(!s.decode_tick(RequestId(1)));
        assert!(!s.decode_tick(RequestId(1)));
        assert!(s.decode_tick(RequestId(1)), "third token retires");
        assert!(s.is_idle());
    }
}
