//! One per-GPU serving instance: its own KV pool, GPU prefix tier,
//! streams, scheduler, and tagged kernels — the unit a
//! [`crate::serving::ServingFleet`] replicates across GPUs.
//!
//! An instance never owns the clock: every handler takes the shared
//! [`SimWorld`] plus the fleet-shared state ([`FleetShared`]: the host
//! prefix tier) and a read-only view of its sibling instances
//! ([`Peers`]). Request arrivals, transfer completions, and kernel
//! completions are dispatched to it by the fleet's event loop, so N
//! instances' KV fetches genuinely contend for max-min fabric bandwidth
//! on one virtual clock.
//!
//! A prefix miss in the local GPU tier resolves against two further
//! sources: the fleet's shared host tier (fetched host→GPU, the path MMA
//! multipaths) and a *sibling GPU's HBM* (fetched peer-to-peer over the
//! NVLink fabric). Which of the two carries the fetch is a
//! [`crate::policy::TransferPolicy::prefer_peer_fetch`] decision.
//!
//! QoS classes: prefix/KV fetches gate a waiting request's first token
//! and are tagged [`crate::mma::TransferClass::LatencyCritical`] (unless
//! the request carries an explicit class); any other traffic an
//! instance submits rides the `Interactive` default, while registry
//! sleep/wake weight movement is `Bulk` and background loops
//! `Background` — so an on-demand wake routed onto a serving instance can
//! no longer trample its TTFT-critical fetches when QoS is enabled.

use super::kv_cache::{KvCacheManager, SeqId};
use super::prefix_cache::{GpuPrefixTier, HostPrefixPool};
use super::scheduler::{BatchFormer, Phase, Request, RequestId, Scheduler};
use crate::config::{ComputeSource, ServingConfig};
use crate::memory::HbmAllocator;
use crate::metrics::TtftBreakdown;
use crate::mma::{SimWorld, StreamHandle, TransferDesc};
use crate::models::ModelSpec;
use crate::roofline::{h20, GpuRoofline};
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};
use crate::util::fxmap::FxHashMap;
use std::collections::VecDeque;

/// Compute-time provider: roofline for paper-scale models, real PJRT for
/// the live tiny model, fixed for unit tests.
///
/// The two required methods are the seed per-request surface. The two
/// provided methods are the continuous-batching surface; their defaults
/// reduce exactly to the per-request methods, so any provider that does
/// not override them prices batched steps the way the seed scheduler
/// would have run them — which is what keeps `[compute] source =
/// "legacy"` byte-identical to the pre-batching replay output.
pub trait Compute {
    /// Prefill `new_tokens` with `context` total attended tokens.
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64;
    /// One decode step at `context`.
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64;

    /// One decode iteration over a whole continuous batch carrying
    /// `batch_kv_bytes` aggregate resident KV. The default ignores the
    /// aggregate-KV signal and prices the step like a single-sequence
    /// decode at the batch's max context — exactly the seed cost model.
    fn decode_step_secs(
        &mut self,
        m: &ModelSpec,
        _batch_kv_bytes: u64,
        _batch: u32,
        max_context: u64,
        tp: u32,
    ) -> f64 {
        self.decode_secs(m, max_context, tp)
    }

    /// One fused continuous-batching step: a chunked-prefill leg sharing
    /// the iteration with `decode_batch` decode legs. The default
    /// composes the legs serially (prefill kernel, then decode step),
    /// which is what the per-request scheduler would have run
    /// back-to-back — so with one leg per step the fused path is
    /// byte-identical to the seed.
    #[allow(clippy::too_many_arguments)]
    fn step_secs(
        &mut self,
        m: &ModelSpec,
        prefill_tokens: u64,
        prefill_context: u64,
        decode_kv_bytes: u64,
        decode_batch: u32,
        max_decode_context: u64,
        tp: u32,
    ) -> f64 {
        let mut t = 0.0;
        if prefill_tokens > 0 {
            t += self.prefill_secs(m, prefill_tokens, prefill_context, tp);
        }
        if decode_batch > 0 {
            t += self.decode_step_secs(m, decode_kv_bytes, decode_batch, max_decode_context, tp);
        }
        t
    }
}

impl Compute for GpuRoofline {
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        GpuRoofline::prefill_secs(self, m, new_tokens, context, tp)
    }
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        GpuRoofline::decode_secs_per_token(self, m, context, tp)
    }
    fn decode_step_secs(
        &mut self,
        m: &ModelSpec,
        batch_kv_bytes: u64,
        batch: u32,
        max_context: u64,
        tp: u32,
    ) -> f64 {
        GpuRoofline::decode_step_secs(self, m, batch_kv_bytes, batch, max_context, tp)
    }
    fn step_secs(
        &mut self,
        m: &ModelSpec,
        prefill_tokens: u64,
        prefill_context: u64,
        decode_kv_bytes: u64,
        decode_batch: u32,
        max_decode_context: u64,
        tp: u32,
    ) -> f64 {
        GpuRoofline::step_secs(
            self,
            m,
            prefill_tokens,
            prefill_context,
            decode_kv_bytes,
            decode_batch,
            max_decode_context,
            tp,
        )
    }
}

/// Strips a provider's batch-aware overrides so only the per-request
/// `prefill_secs`/`decode_secs` surface remains — the seed cost model.
/// `[compute] source = "legacy"` wraps the roofline in this, making the
/// trait's default-method composition (and therefore byte-identity with
/// the per-request replay output) hold by construction.
pub struct LegacyCosts<C: Compute>(pub C);

impl<C: Compute> Compute for LegacyCosts<C> {
    fn prefill_secs(&mut self, m: &ModelSpec, new_tokens: u64, context: u64, tp: u32) -> f64 {
        self.0.prefill_secs(m, new_tokens, context, tp)
    }
    fn decode_secs(&mut self, m: &ModelSpec, context: u64, tp: u32) -> f64 {
        self.0.decode_secs(m, context, tp)
    }
}

/// Build the compute provider `[compute] source` selects: the raw H20
/// roofline (batch-aware fused steps, the memory-wall regime) or the
/// seed legacy view of it ([`LegacyCosts`]-wrapped, byte-identical to
/// pre-batching output).
pub fn compute_from(source: ComputeSource) -> Box<dyn Compute> {
    match source {
        ComputeSource::Legacy => Box::new(LegacyCosts(h20())),
        ComputeSource::Roofline => Box::new(h20()),
    }
}

/// Fixed per-call compute times (tests).
pub struct FixedCompute {
    /// Prefill seconds per call.
    pub prefill_s: f64,
    /// Decode seconds per step.
    pub decode_s: f64,
}

impl Compute for FixedCompute {
    fn prefill_secs(&mut self, _: &ModelSpec, _: u64, _: u64, _: u32) -> f64 {
        self.prefill_s
    }
    fn decode_secs(&mut self, _: &ModelSpec, _: u64, _: u32) -> f64 {
        self.decode_s
    }
}

/// Final per-request record.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: RequestId,
    /// Arrival time.
    pub arrival: Time,
    /// TTFT decomposition (queue / fetch / prefill component times). With
    /// `fetch_chunks > 1` fetch and prefill overlap, so the components can
    /// sum to more than [`Self::ttft_s`]; without chunking they sum
    /// exactly.
    pub ttft: TtftBreakdown,
    /// First token time (absolute, world clock).
    pub first_token_at: Time,
    /// All output tokens done (absolute, world clock).
    pub finished_at: Option<Time>,
}

impl RequestOutcome {
    /// End-to-end latency if finished.
    pub fn e2e(&self) -> Option<Time> {
        self.finished_at.map(|f| f.since(self.arrival))
    }

    /// Wall-clock time to first token (arrival → first token), seconds.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_at.since(self.arrival).as_secs_f64()
    }
}

/// State every instance shares through the fleet: the pinned-host prefix
/// tier and the fleet-level fetch-path switch.
pub struct FleetShared {
    /// The fleet-shared host prefix tier (byte-accounted).
    pub host: HostPrefixPool,
    /// Peer-NVLink prefix fetches enabled (`[fleet] peer_fetch`).
    pub peer_fetch: bool,
}

/// Read-only view of an instance's siblings, used to find peer-resident
/// prefixes during admission without aliasing the instance itself.
pub struct Peers<'a> {
    left: &'a [ServingInstance],
    right: &'a [ServingInstance],
}

impl<'a> Peers<'a> {
    /// First sibling holding `key` GPU-resident: `(gpu, tokens)`.
    pub fn holder(&self, key: u64) -> Option<(GpuId, u32)> {
        self.left
            .iter()
            .chain(self.right.iter())
            .find_map(|p| p.gpu_tier().peek(key).map(|t| (p.gpu(), t)))
    }
}

/// Split `instances` into instance `i` and a [`Peers`] view of the rest.
pub fn split_peers(
    instances: &mut [ServingInstance],
    i: usize,
) -> (&mut ServingInstance, Peers<'_>) {
    let (left, rest) = instances.split_at_mut(i);
    let (me, right) = rest.split_first_mut().expect("instance index in range");
    (
        me,
        Peers {
            left: &*left,
            right: &*right,
        },
    )
}

/// Kernel-tag layout: `[kind:8][instance:8][rid:48]`. Distinctive kind
/// bytes rather than 1/2 so tags from other consumers of the shared world
/// are unlikely to land in the serving namespace; unknown kinds are
/// ignored, and both arms additionally tolerate tags that merely collide.
const TAG_KIND_MASK: u64 = 0xFF << 56;
const TAG_PREFILL: u64 = 0xE5 << 56;
const TAG_DECODE_STEP: u64 = 0xE6 << 56;
const TAG_STEP: u64 = 0xE7 << 56;
const TAG_INST_SHIFT: u32 = 48;
const TAG_RID_MASK: u64 = (1 << TAG_INST_SHIFT) - 1;

/// Where an admitted prefill's prefix KV is coming from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FetchSource {
    /// Fleet host tier, over the host→GPU path (multipath-eligible).
    Host,
    /// A sibling GPU's HBM, peer-to-peer over NVLink.
    Peer(GpuId),
}

/// Per-admitted-prefill bookkeeping, all timestamps off the world clock.
#[derive(Debug)]
struct PrefillJob {
    /// Tokens to prefill (scheduler suffix — the single source of truth).
    suffix: u32,
    /// Prefix tokens reused from the cache.
    reused: u32,
    /// Admission time (end of arrival queueing).
    sched_at: Time,
    /// First fetch chunk issued.
    fetch_started: Option<Time>,
    /// Last fetch chunk landed.
    fetch_done: Option<Time>,
    /// Outstanding fetch chunks.
    chunks_left: u32,
    /// Compute was released (pushed to the ready queue) already.
    compute_released: bool,
    /// When the job entered the ready queue.
    ready_at: Option<Time>,
    /// Prefill kernel start.
    kernel_start: Option<Time>,
    /// Prefill kernel completion.
    kernel_done: Option<Time>,
    /// Prefill kernel duration, seconds.
    prefill_s: f64,
    /// Prefill tokens already computed by fused steps (batched mode
    /// only; the per-request path runs the whole suffix as one kernel).
    tokens_done: u32,
    /// Stream carrying this job's fetch chunks (returned to the pool when
    /// the last chunk lands).
    fetch_stream: Option<StreamHandle>,
    /// Prefix key this job's own fetch is moving (primary fetcher only).
    fetch_key: Option<u64>,
    /// Full token count of the fetched prefix entry (for promotion).
    fetch_tokens: u32,
}

/// One fused continuous-batching step as it actually ran, recorded by
/// the batched pump path for figures and benches — the raw material of
/// the memory-wall signature (decode step time vs aggregate KV bytes).
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// Step kernel launch time (world clock).
    pub at: Time,
    /// Prefill tokens computed this step (all chunked legs summed).
    pub prefill_tokens: u32,
    /// Decode legs in the step (one output token each).
    pub decode_batch: u32,
    /// Aggregate KV bytes resident for the decode legs, `Σ KV(context_i)`.
    pub decode_kv_bytes: u64,
    /// Step duration, seconds.
    pub secs: f64,
}

/// Legs participating in the in-flight fused step (batched mode).
#[derive(Default)]
struct StepInFlight {
    /// Prefill legs and the tokens each computes this step.
    prefills: Vec<(RequestId, u32)>,
    /// Decode legs (one token each).
    decodes: Vec<RequestId>,
}

/// The event-driven serving state of one GPU (one fleet slot).
pub struct ServingInstance {
    idx: u8,
    /// Serving knobs.
    pub cfg: ServingConfig,
    model: ModelSpec,
    sched: Scheduler,
    gpu_tier: GpuPrefixTier,
    /// Paged GPU KV pool (sized against HBM capacity at construction).
    pub kv: KvCacheManager,
    compute: Box<dyn Compute>,
    gpu: GpuId,
    host_numa: NumaId,
    outcomes: FxHashMap<u64, RequestOutcome>,
    next_seq: u64,
    awake: bool,
    prefill_stream: StreamHandle,
    decode_stream: StreamHandle,
    /// In-flight fetch chunk → owning request.
    inflight_fetch: FxHashMap<u32, RequestId>,
    jobs: FxHashMap<u64, PrefillJob>,
    /// Fetched (or pipeline-released) prefills waiting for the compute lane.
    ready_prefills: VecDeque<RequestId>,
    /// Idle fetch streams, recycled across requests (`StreamId` is a u16:
    /// creating one stream per request would wrap and alias stream 0).
    fetch_streams: Vec<StreamHandle>,
    /// Fetches in flight, by prefix key. A concurrent request hitting the
    /// same key *joins* the in-flight fetch (value = joiners) instead of
    /// seeing a prematurely-promoted GPU tier or re-fetching.
    inflight_prefix: FxHashMap<u64, Vec<RequestId>>,
    /// Suffix tokens of admitted-but-unfinished prefills (budget hold).
    inflight_prefill_tokens: u32,
    prefill_busy: bool,
    decode_busy: bool,
    /// Aggregated mode: alternate decode/prefill so neither lane starves.
    decode_ran_last: bool,
    decode_inflight: Vec<RequestId>,
    /// Batched mode: one fused step outstanding at a time.
    step_busy: bool,
    /// Legs of the in-flight fused step (batched mode).
    step_inflight: StepInFlight,
    /// Every fused step run so far (batched mode only; the per-request
    /// path records nothing, keeping its hot loop allocation-free).
    steps: Vec<StepRecord>,
    /// Requests fully finished since the fleet last drained (router load).
    finished: Vec<RequestId>,
    /// Host-tier fetches issued (joiners excluded).
    pub host_fetches: u64,
    /// Peer-NVLink fetches issued (joiners excluded).
    pub peer_fetches: u64,
    /// Bytes moved by host-tier fetches (the PCIe-crossing traffic).
    pub host_fetch_bytes: u64,
    /// Bytes moved by peer-NVLink fetches.
    pub peer_fetch_bytes: u64,
    /// Admitted prefills that reused a cached prefix (any tier, including
    /// zero-copy local-GPU hits and joined in-flight fetches).
    pub prefix_hits: u64,
    /// Admitted prefills that prefilled cold (no reusable prefix found).
    pub prefix_misses: u64,
    kv_pool_blocks: u32,
}

impl ServingInstance {
    /// Assemble one instance on `gpu`, carving its weights and KV pool out
    /// of `hbm`. The configured `gpu_kv_blocks` is clamped to what the
    /// GPU's HBM can actually hold next to the (TP-sharded) weights, so
    /// pool sizing can no longer bypass capacity accounting.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        idx: u8,
        cfg: ServingConfig,
        model: ModelSpec,
        world: &mut SimWorld,
        hbm: &mut HbmAllocator,
        compute: Box<dyn Compute>,
        gpu: GpuId,
        host_numa: NumaId,
    ) -> ServingInstance {
        let weight = (model.weight_bytes() / cfg.tp.max(1) as u64).max(1);
        hbm.alloc(gpu, weight).unwrap_or_else(|| {
            panic!(
                "model {} weights ({weight} B/gpu) exceed {:?} HBM",
                model.name, gpu
            )
        });
        let block_bytes = model.kv_bytes(cfg.kv_block_tokens as u64).max(1);
        let fit = (hbm.available(gpu) / block_bytes).min(u32::MAX as u64) as u32;
        let blocks = cfg.gpu_kv_blocks.min(fit);
        hbm.alloc(gpu, blocks as u64 * block_bytes)
            .expect("clamped KV pool fits by construction");
        let gpu_tier = GpuPrefixTier::new(
            cfg.kv_block_tokens,
            blocks as u64 * cfg.kv_block_tokens as u64,
        );
        let prefill_stream = world.stream(gpu);
        let decode_stream = world.stream(gpu);
        ServingInstance {
            idx,
            sched: Scheduler::new(cfg.clone()),
            kv: KvCacheManager::new(blocks, cfg.kv_block_tokens),
            gpu_tier,
            model,
            compute,
            gpu,
            host_numa,
            outcomes: FxHashMap::default(),
            next_seq: 0,
            awake: true,
            prefill_stream,
            decode_stream,
            inflight_fetch: FxHashMap::default(),
            jobs: FxHashMap::default(),
            ready_prefills: VecDeque::new(),
            fetch_streams: Vec::new(),
            inflight_prefix: FxHashMap::default(),
            inflight_prefill_tokens: 0,
            prefill_busy: false,
            decode_busy: false,
            decode_ran_last: false,
            decode_inflight: Vec::new(),
            step_busy: false,
            step_inflight: StepInFlight::default(),
            steps: Vec::new(),
            finished: Vec::new(),
            host_fetches: 0,
            peer_fetches: 0,
            host_fetch_bytes: 0,
            peer_fetch_bytes: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            kv_pool_blocks: blocks,
            cfg,
        }
    }

    /// The GPU this instance serves on.
    pub fn gpu(&self) -> GpuId {
        self.gpu
    }

    /// This instance's GPU-resident prefix tier (peers peek through it).
    pub fn gpu_tier(&self) -> &GpuPrefixTier {
        &self.gpu_tier
    }

    /// KV pool size after HBM clamping, in blocks.
    pub fn kv_pool_blocks(&self) -> u32 {
        self.kv_pool_blocks
    }

    /// Weights resident and serving-ready?
    pub fn awake(&self) -> bool {
        self.awake
    }

    /// Flip residency (the fleet drives this off registry sleep/wake).
    pub fn set_awake(&mut self, awake: bool) {
        self.awake = awake;
    }

    /// No queued, running, or in-flight work left?
    pub fn is_idle(&self) -> bool {
        self.sched.is_idle() && self.jobs.is_empty()
    }

    /// Outcome of a request served here.
    pub fn outcome(&self, id: RequestId) -> Option<&RequestOutcome> {
        self.outcomes.get(&id.0)
    }

    /// Requests fully finished since the last drain (router accounting).
    pub fn take_finished(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.finished)
    }

    /// Enqueue a routed arrival. The fleet pumps afterwards.
    pub fn submit(&mut self, req: Request) {
        self.sched.submit(req);
    }

    /// Every fused continuous-batching step run so far (batched mode;
    /// empty under the per-request path).
    pub fn steps(&self) -> &[StepRecord] {
        &self.steps
    }

    /// Event-loop heartbeat: admit what fits, then fill idle compute
    /// lanes. A sleeping instance queues arrivals but does nothing until
    /// its wake completes.
    ///
    /// With `[batching] enabled` (and no prefill/decode disaggregation —
    /// separate GPU groups already keep the lanes independent), each
    /// heartbeat forms one fused step instead of alternating per-request
    /// lanes; join/leave happens at step boundaries because the plan is
    /// re-formed after every step completes.
    pub fn pump(&mut self, world: &mut SimWorld, shared: &mut FleetShared, peers: &Peers) {
        if !self.awake {
            return;
        }
        self.admit(world, shared, peers);
        if self.cfg.batching.enabled && !self.cfg.pd_disaggregation {
            if !self.step_busy {
                self.start_step(world);
            }
            return;
        }
        if self.cfg.pd_disaggregation {
            // Separate GPU groups: both lanes advance independently.
            if !self.decode_busy {
                self.start_decode_step(world);
            }
            if !self.prefill_busy {
                self.start_next_prefill(world);
            }
        } else {
            // One GPU group: decodes and prefills serialize; alternate so
            // decodes keep priority without starving admitted prefills.
            if self.prefill_busy || self.decode_busy {
                return;
            }
            let has_decode = self.sched.decode_count() > 0;
            let has_prefill = !self.ready_prefills.is_empty();
            match (has_decode, has_prefill) {
                (true, true) => {
                    if self.decode_ran_last {
                        self.start_next_prefill(world);
                    } else {
                        self.start_decode_step(world);
                    }
                }
                (true, false) => self.start_decode_step(world),
                (false, true) => self.start_next_prefill(world),
                (false, false) => {}
            }
        }
    }

    /// Admit waiting requests under the in-flight token budget; resolve
    /// each suffix against the prefix tiers (local GPU, then the fleet's
    /// shared host tier, then a sibling GPU's HBM) and issue the KV fetch
    /// as async transfers — host→GPU or peer NVLink per the transfer
    /// policy's [`prefer_peer_fetch`] decision.
    ///
    /// [`prefer_peer_fetch`]: crate::policy::TransferPolicy::prefer_peer_fetch
    fn admit(&mut self, world: &mut SimWorld, shared: &mut FleetShared, peers: &Peers) {
        let now = world.now();
        let decode_hold = if self.cfg.pd_disaggregation {
            0
        } else {
            self.sched.decode_count() as u32
        };
        let busy = self.inflight_prefill_tokens + decode_hold;
        let gpu_tier = &self.gpu_tier;
        let host = &shared.host;
        let peer_ok = shared.peer_fetch;
        let plan = self.sched.plan_prefills(busy, |r| {
            if r.prefix_key == 0 || r.cached_prefix_tokens == 0 {
                return 0;
            }
            // Every tier is indexed by the tenant-tagged key, so one
            // tenant's cached KV is invisible to another's lookups.
            let key = r.cache_key();
            gpu_tier
                .peek(key)
                .or_else(|| host.peek(key))
                .or_else(|| {
                    if peer_ok {
                        peers.holder(key).map(|(_, t)| t)
                    } else {
                        None
                    }
                })
                .map(|tokens| tokens.min(r.cached_prefix_tokens))
                .unwrap_or(0)
        });
        for (rid, suffix) in plan {
            let req = self.sched.sequence(rid).expect("admitted seq").req.clone();
            let key = req.cache_key();
            let reused = req.prompt_tokens - suffix;
            if reused > 0 {
                self.prefix_hits += 1;
            } else {
                self.prefix_misses += 1;
            }
            self.inflight_prefill_tokens += suffix.max(1);
            // KV blocks for the full sequence (best-effort, as the pool
            // model has no eviction path yet).
            let sid = SeqId(self.next_seq);
            self.next_seq += 1;
            let _ = self.kv.alloc_seq(sid, req.prompt_tokens + req.output_tokens);

            let mut job = PrefillJob {
                suffix,
                reused,
                sched_at: now,
                fetch_started: None,
                fetch_done: None,
                chunks_left: 0,
                compute_released: false,
                ready_at: None,
                kernel_start: None,
                kernel_done: None,
                prefill_s: 0.0,
                tokens_done: 0,
                fetch_stream: None,
                fetch_key: None,
                fetch_tokens: 0,
            };
            // Source resolution via non-mutating peeks: local-GPU
            // promotion is deferred to fetch *completion* so a concurrent
            // same-key request cannot observe a GPU tier whose bytes are
            // still in flight.
            let source = if reused == 0 || self.gpu_tier.peek(key).is_some() {
                None // cold, or a zero-copy local-GPU hit
            } else {
                let bytes = self.model.kv_bytes(reused as u64).max(1);
                let peer = if shared.peer_fetch {
                    peers.holder(key)
                } else {
                    None
                };
                let host_tokens = shared.host.peek(key);
                match (peer, host_tokens) {
                    // Both copies exist: the transfer policy decides
                    // host-multipath vs peer-NVLink. Prefix fetches gate a
                    // waiting request's first token → LatencyCritical by
                    // default; trace-driven requests can override it.
                    (Some((pg, pt)), Some(ht)) => {
                        let class = req.fetch_class();
                        if world.prefer_peer_fetch(pg, self.gpu, bytes, class) {
                            Some((FetchSource::Peer(pg), pt))
                        } else {
                            Some((FetchSource::Host, ht))
                        }
                    }
                    (Some((pg, pt)), None) => Some((FetchSource::Peer(pg), pt)),
                    (None, Some(ht)) => Some((FetchSource::Host, ht)),
                    (None, None) => None,
                }
            };
            match source {
                Some((src, entry_tokens)) => {
                    if let Some(waiters) = self.inflight_prefix.get_mut(&key) {
                        // Same prefix already being fetched: join it and
                        // pay only the remaining wait.
                        waiters.push(rid);
                        job.fetch_started = Some(now);
                    } else {
                        // Primary fetcher: move the KV pages, chunked so
                        // later chunks can pipeline with prefill compute.
                        // A dedicated stream per fetch keeps concurrent
                        // requests' DMAs contending in the fabric instead
                        // of serializing on one queue.
                        self.inflight_prefix.insert(key, Vec::new());
                        let bytes = self.model.kv_bytes(reused as u64).max(1);
                        if src == FetchSource::Host {
                            shared.host.touch(key);
                            self.host_fetches += 1;
                            self.host_fetch_bytes += bytes;
                        } else {
                            self.peer_fetches += 1;
                            self.peer_fetch_bytes += bytes;
                        }
                        let chunks = (self.cfg.fetch_chunks.max(1) as u64).min(bytes) as u32;
                        let per = bytes / chunks as u64;
                        let fetch_stream = match self.fetch_streams.pop() {
                            Some(s) => s,
                            None => world.stream(self.gpu),
                        };
                        job.fetch_stream = Some(fetch_stream);
                        job.fetch_key = Some(key);
                        job.fetch_tokens = entry_tokens;
                        job.fetch_started = Some(now);
                        job.chunks_left = chunks;
                        for i in 0..chunks {
                            let sz = if i == chunks - 1 {
                                bytes - per * (chunks as u64 - 1)
                            } else {
                                per
                            };
                            // Fetch chunks default to LatencyCritical:
                            // under QoS they outweigh co-running bulk
                            // wakes on every shared link and issue first
                            // in the engine's class-aware queues. Trace
                            // replay can tag a tenant's requests with a
                            // different class (e.g. a Bulk batch tenant).
                            let class = req.fetch_class();
                            let tid = match src {
                                FetchSource::Host => world.memcpy_async(
                                    fetch_stream,
                                    TransferDesc::new(
                                        Direction::H2D,
                                        self.gpu,
                                        self.host_numa,
                                        sz,
                                    )
                                    .with_class(class),
                                ),
                                FetchSource::Peer(pg) => world.memcpy_async(
                                    fetch_stream,
                                    TransferDesc::p2p(pg, self.gpu, sz).with_class(class),
                                ),
                            };
                            self.inflight_fetch.insert(tid.0, rid);
                        }
                    }
                }
                None => {
                    // Cold prefill, or a resident local hit (refresh LRU,
                    // no bytes move): compute can start right away.
                    if reused > 0 {
                        self.gpu_tier.touch(key);
                    }
                    job.compute_released = true;
                    job.ready_at = Some(now);
                    self.ready_prefills.push_back(rid);
                }
            }
            self.jobs.insert(rid.0, job);
        }
    }

    /// A fetch chunk landed. Returns false for transfers this instance
    /// does not own (sibling fetches, registry / background traffic).
    pub fn on_transfer_done(
        &mut self,
        world: &mut SimWorld,
        shared: &mut FleetShared,
        peers: &Peers,
        tid: u32,
    ) -> bool {
        let Some(rid) = self.inflight_fetch.remove(&tid) else {
            return false;
        };
        let now = world.now();
        let pipelined = self.cfg.fetch_chunks > 1;
        let (all_landed, done_key, entry_tokens) = {
            let job = self.jobs.get_mut(&rid.0).expect("fetch for retired job");
            job.chunks_left -= 1;
            let all_landed = job.chunks_left == 0;
            let mut done_key = None;
            if all_landed {
                job.fetch_done = Some(now);
                done_key = job.fetch_key.take();
                if let Some(s) = job.fetch_stream.take() {
                    self.fetch_streams.push(s);
                }
            }
            // Release compute on the first chunk when pipelining, else
            // only once the whole prefix has landed.
            if !job.compute_released && (all_landed || pipelined) {
                job.compute_released = true;
                job.ready_at = Some(now);
                self.ready_prefills.push_back(rid);
            }
            (all_landed, done_key, job.fetch_tokens)
        };
        if let Some(key) = done_key {
            // The prefix KV is actually resident now: promote into the
            // local GPU tier (the shared host copy stays — siblings may
            // still host- or peer-fetch it) and release every same-key
            // joiner that was waiting on this in-flight fetch.
            self.promote(shared, key, entry_tokens);
            if let Some(waiters) = self.inflight_prefix.remove(&key) {
                for w in waiters {
                    if let Some(job) = self.jobs.get_mut(&w.0) {
                        job.fetch_done = Some(now);
                        job.compute_released = true;
                        job.ready_at = Some(now);
                        self.ready_prefills.push_back(w);
                    }
                }
            }
        }
        if all_landed
            && self
                .jobs
                .get(&rid.0)
                .is_some_and(|j| j.kernel_done.is_some())
        {
            self.finish_prefill(world, shared, rid);
        }
        self.pump(world, shared, peers);
        true
    }

    /// A tagged kernel finished. Returns false for kernels this instance
    /// did not launch (siblings' lanes, foreign consumers of the world).
    pub fn on_kernel_done(
        &mut self,
        world: &mut SimWorld,
        shared: &mut FleetShared,
        peers: &Peers,
        tag: u64,
    ) -> bool {
        match tag & TAG_KIND_MASK {
            TAG_PREFILL => {
                if ((tag >> TAG_INST_SHIFT) & 0xFF) as u8 != self.idx {
                    return false;
                }
                let rid = RequestId(tag & TAG_RID_MASK);
                let now = world.now();
                let Some(job) = self.jobs.get_mut(&rid.0) else {
                    return false; // foreign tag colliding with our namespace
                };
                self.prefill_busy = false;
                job.kernel_done = Some(now);
                if job.chunks_left == 0 {
                    self.finish_prefill(world, shared, rid);
                }
                self.pump(world, shared, peers);
                true
            }
            TAG_DECODE_STEP => {
                if tag != self.decode_tag() || !self.decode_busy {
                    return false;
                }
                self.decode_busy = false;
                let now = world.now();
                let batch = std::mem::take(&mut self.decode_inflight);
                for id in batch {
                    if self.sched.decode_tick(id) {
                        if let Some(o) = self.outcomes.get_mut(&id.0) {
                            o.finished_at = Some(now);
                        }
                        self.finished.push(id);
                    }
                }
                self.pump(world, shared, peers);
                true
            }
            TAG_STEP => {
                if tag != self.step_tag() || !self.step_busy {
                    return false;
                }
                self.step_busy = false;
                let now = world.now();
                let step = std::mem::take(&mut self.step_inflight);
                for id in step.decodes {
                    if self.sched.decode_tick(id) {
                        if let Some(o) = self.outcomes.get_mut(&id.0) {
                            o.finished_at = Some(now);
                        }
                        self.finished.push(id);
                    }
                }
                for (rid, take) in step.prefills {
                    let Some(job) = self.jobs.get_mut(&rid.0) else {
                        continue;
                    };
                    job.tokens_done += take;
                    if job.tokens_done >= job.suffix.max(1) {
                        // Last chunk computed: leave the ready queue and
                        // emit the first token once the fetch has landed
                        // too (same gate as the per-request path).
                        if let Some(pos) = self.ready_prefills.iter().position(|&r| r == rid) {
                            self.ready_prefills.remove(pos);
                        }
                        job.kernel_done = Some(now);
                        if job.chunks_left == 0 {
                            self.finish_prefill(world, shared, rid);
                        }
                    }
                }
                self.pump(world, shared, peers);
                true
            }
            _ => false,
        }
    }

    fn prefill_tag(&self, rid: RequestId) -> u64 {
        TAG_PREFILL | ((self.idx as u64) << TAG_INST_SHIFT) | (rid.0 & TAG_RID_MASK)
    }

    fn decode_tag(&self) -> u64 {
        TAG_DECODE_STEP | ((self.idx as u64) << TAG_INST_SHIFT)
    }

    fn step_tag(&self) -> u64 {
        TAG_STEP | ((self.idx as u64) << TAG_INST_SHIFT)
    }

    /// Insert a prefix into the local GPU tier, demoting evicted LRU
    /// entries to the shared host tier. Returns false when the prefix is
    /// larger than the whole tier and was not inserted (it simply stays
    /// host/peer-resident — for a fetch, the bytes still moved).
    fn promote(&mut self, shared: &mut FleetShared, key: u64, tokens: u32) -> bool {
        let out = self.gpu_tier.insert(key, tokens);
        for (ek, et) in out.evicted {
            shared.host.insert(ek, et);
        }
        out.inserted
    }

    /// Launch the next ready prefill as a kernel on the prefill stream.
    fn start_next_prefill(&mut self, world: &mut SimWorld) {
        let Some(rid) = self.ready_prefills.pop_front() else {
            return;
        };
        let now = world.now();
        let prompt = self
            .sched
            .sequence(rid)
            .expect("ready seq")
            .req
            .prompt_tokens;
        let job = self.jobs.get_mut(&rid.0).expect("ready job");
        let prefill_s = self.compute.prefill_secs(
            &self.model,
            job.suffix.max(1) as u64,
            prompt as u64,
            self.cfg.tp,
        );
        job.kernel_start = Some(now);
        job.prefill_s = prefill_s;
        world.enqueue_kernel_tagged(
            self.prefill_stream,
            Time::from_secs_f64(prefill_s),
            "prefill",
            self.prefill_tag(rid),
        );
        self.prefill_busy = true;
        self.decode_ran_last = false;
    }

    /// Per-sequence decode context right now: prompt + produced so far.
    fn decode_context(&self, id: RequestId) -> u64 {
        self.sched
            .sequence(id)
            .map(|s| {
                let produced = match s.phase {
                    Phase::Decode { produced } => produced,
                    _ => 0,
                };
                s.req.prompt_tokens as u64 + produced as u64
            })
            .unwrap_or(0)
    }

    /// Launch one batched decode step for every running decode sequence.
    /// The duration comes from [`Compute::decode_step_secs`] with the
    /// batch's aggregate KV bytes: batch-aware providers (the raw
    /// roofline) price the memory wall, while legacy/fixed providers fall
    /// back to the seed max-context cost via the trait default.
    fn start_decode_step(&mut self, world: &mut SimWorld) {
        let decodes = self.sched.running_decodes();
        if decodes.is_empty() {
            return;
        }
        // Context grows as sequences generate: prompt + produced so far.
        let mut max_ctx = 0u64;
        let mut agg_kv = 0u64;
        for id in &decodes {
            let ctx = self.decode_context(*id);
            max_ctx = max_ctx.max(ctx);
            agg_kv += self.model.kv_bytes(ctx);
        }
        let decode_s = self.compute.decode_step_secs(
            &self.model,
            agg_kv,
            decodes.len() as u32,
            max_ctx.max(1),
            self.cfg.tp,
        );
        world.enqueue_kernel_tagged(
            self.decode_stream,
            Time::from_secs_f64(decode_s),
            "decode",
            self.decode_tag(),
        );
        self.decode_busy = true;
        self.decode_inflight = decodes;
        self.decode_ran_last = true;
    }

    /// Batched mode: form and launch one fused continuous-batching step —
    /// every running decode leg plus the chunked-prefill legs that fit
    /// the `max_batch_tokens` budget, priced as one roofline kernel.
    ///
    /// Streams mirror the per-request path (prefill stream when a prefill
    /// leg is aboard, decode stream for pure-decode steps) so with one
    /// leg per step the event schedule is byte-identical to the seed.
    fn start_step(&mut self, world: &mut SimWorld) {
        let former = BatchFormer {
            max_batch_tokens: self.cfg.max_batch_tokens,
            chunk_tokens: self.cfg.batching.chunk_tokens,
        };
        let ready: Vec<(RequestId, u32)> = self
            .ready_prefills
            .iter()
            .map(|&rid| {
                let job = &self.jobs[&rid.0];
                (rid, job.suffix.max(1).saturating_sub(job.tokens_done))
            })
            .collect();
        let plan = former.form(self.sched.running_decodes(), ready);
        if plan.is_empty() {
            return;
        }
        let now = world.now();
        let mut max_ctx = 0u64;
        let mut agg_kv = 0u64;
        for id in &plan.decodes {
            let ctx = self.decode_context(*id);
            max_ctx = max_ctx.max(ctx);
            agg_kv += self.model.kv_bytes(ctx);
        }
        // The prefill flops leg attends the largest participating prompt
        // (conservative; exact for the single-leg oracle case).
        let prefill_ctx = plan
            .prefills
            .iter()
            .filter_map(|&(rid, _)| self.sched.sequence(rid))
            .map(|s| s.req.prompt_tokens as u64)
            .max()
            .unwrap_or(0);
        let prefill_tokens = plan.prefill_tokens();
        let secs = self.compute.step_secs(
            &self.model,
            prefill_tokens as u64,
            prefill_ctx,
            agg_kv,
            plan.decodes.len() as u32,
            max_ctx.max(1),
            self.cfg.tp,
        );
        for &(rid, _) in &plan.prefills {
            let job = self.jobs.get_mut(&rid.0).expect("planned job");
            if job.kernel_start.is_none() {
                job.kernel_start = Some(now);
            }
            // The whole fused step gates this leg's first token; for a
            // single-leg step this is exactly the legacy kernel time.
            job.prefill_s += secs;
        }
        let (stream, name) = if plan.prefills.is_empty() {
            (self.decode_stream, "decode")
        } else if plan.decodes.is_empty() {
            (self.prefill_stream, "prefill")
        } else {
            (self.prefill_stream, "step")
        };
        world.enqueue_kernel_tagged(stream, Time::from_secs_f64(secs), name, self.step_tag());
        self.steps.push(StepRecord {
            at: now,
            prefill_tokens,
            decode_batch: plan.decodes.len() as u32,
            decode_kv_bytes: agg_kv,
            secs,
        });
        self.step_inflight = StepInFlight {
            prefills: plan.prefills,
            decodes: plan.decodes,
        };
        self.step_busy = true;
    }

    /// Both the KV fetch and the prefill kernel are done: the first token
    /// exists *now*; record the outcome and move the sequence to decode.
    fn finish_prefill(&mut self, world: &mut SimWorld, shared: &mut FleetShared, rid: RequestId) {
        let now = world.now();
        let job = self.jobs.remove(&rid.0).expect("finishing retired job");
        let req = self.sched.sequence(rid).expect("finished seq").req.clone();
        let fetch_s = match (job.fetch_started, job.fetch_done) {
            (Some(a), Some(b)) => b.since(a).as_secs_f64(),
            _ => 0.0,
        };
        // Queueing = arrival → admission, plus waiting for the compute
        // lane after the fetch released this job.
        let lane_wait = match (job.ready_at, job.kernel_start) {
            (Some(a), Some(b)) => b.since(a).as_secs_f64(),
            _ => 0.0,
        };
        let queue_s = job.sched_at.since(req.arrival).as_secs_f64() + lane_wait;
        self.outcomes.insert(
            rid.0,
            RequestOutcome {
                id: rid,
                arrival: req.arrival,
                ttft: TtftBreakdown {
                    queue_s,
                    fetch_s,
                    prefill_s: job.prefill_s,
                },
                first_token_at: now,
                finished_at: None,
            },
        );
        self.inflight_prefill_tokens -= job.suffix.max(1);
        // Cache the full prompt for future turns (a resident entry only
        // refreshes — inserts never move or resize entries). Under
        // prefill/decode disaggregation (the paper's LMCache setup), the
        // prefill node's KV is offloaded to the shared host tier right
        // away — every later hit pays the fetch.
        if req.prefix_key != 0 {
            let key = req.cache_key();
            if self.gpu_tier.touch(key) || shared.host.touch(key) {
                // Already cached somewhere: refreshed in place.
            } else if !self.promote(shared, key, req.prompt_tokens) {
                // Larger than the GPU tier: cache it host-side instead.
                shared.host.insert(key, req.prompt_tokens);
            }
            if self.cfg.pd_disaggregation {
                if let Some(tokens) = self.gpu_tier.remove(key) {
                    shared.host.insert(key, tokens);
                }
            }
        }
        self.sched.prefill_done(rid);
    }
}
