//! Intra-server interconnect topology.
//!
//! Models the paper's testbed class (Figure 1): a dual-socket host where
//! each NUMA node carries PCIe switches with GPUs behind them, GPUs are
//! fully connected through an NVSwitch fabric, and the two sockets are
//! joined by xGMI links. Every physical resource that can become a
//! bottleneck is a *directional link* with an effective capacity; the
//! [`crate::fabric`] simulator shares each link max-min fairly among the
//! flows crossing it.
//!
//! Capacities are *effective* (measured-equivalent) values, not theoretical
//! peaks — see `presets::h20x8` for the calibration against Table 1 and
//! §5.1 of the paper.

mod presets;

pub use presets::{a100x8, h20x8, single_numa_4gpu, Preset};

use crate::util::SmallPath;
use std::collections::HashMap;
use std::fmt;

/// GPU index within the server.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId(pub u8);

/// NUMA node (socket) index.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NumaId(pub u8);

/// Index of a directional link in [`Topology::links`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub u16);

impl fmt::Debug for GpuId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}
impl fmt::Debug for NumaId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "numa{}", self.0)
    }
}
impl fmt::Debug for LinkId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "link{}", self.0)
    }
}

/// Transfer direction of a host↔GPU copy.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Direction {
    /// Host to device.
    H2D,
    /// Device to host.
    D2H,
}

impl Direction {
    /// The opposite direction.
    pub fn flip(self) -> Direction {
        match self {
            Direction::H2D => Direction::D2H,
            Direction::D2H => Direction::H2D,
        }
    }
    /// Short label ("H2D"/"D2H").
    pub fn label(self) -> &'static str {
        match self {
            Direction::H2D => "H2D",
            Direction::D2H => "D2H",
        }
    }
}

/// Kind of directional link resource.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum LinkKind {
    /// GPU's PCIe lane, host→device direction.
    PcieH2D(GpuId),
    /// GPU's PCIe lane, device→host direction.
    PcieD2H(GpuId),
    /// PCIe switch uplink toward the root complex, host→device direction.
    SwitchH2D(u8),
    /// PCIe switch uplink, device→host direction.
    SwitchD2H(u8),
    /// Per-GPU NVLink egress into the NVSwitch fabric.
    NvOut(GpuId),
    /// Per-GPU NVLink ingress from the NVSwitch fabric.
    NvIn(GpuId),
    /// Host DRAM read bandwidth of a NUMA node.
    DramRd(NumaId),
    /// Host DRAM write bandwidth of a NUMA node.
    DramWr(NumaId),
    /// Inter-socket link, directional (from → to).
    Xgmi(NumaId, NumaId),
    /// Per-GPU cross-socket DMA limit: a single IO agent cannot fill the
    /// xGMI fabric (latency × outstanding-request limits), so each GPU's
    /// remote-socket traffic is individually capped well below the shared
    /// xGMI capacity. This is what makes aggregate bandwidth saturate at
    /// ~6 relays (Fig 8) instead of immediately at the first remote relay.
    XgmiLane(GpuId),
    /// Aggregate DMA copy-engine bandwidth into a GPU's HBM.
    HbmIn(GpuId),
    /// Aggregate DMA copy-engine bandwidth out of a GPU's HBM.
    HbmOut(GpuId),
    /// Relay D2H serialization bottleneck: a relay GPU must interleave
    /// NVLink ingress and PCIe egress on its internal copy engine (§5.1.1),
    /// so its effective D2H forwarding rate sits below the raw PCIe lane.
    RelayD2HCap(GpuId),
}

/// One GPU's placement in the host topology.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// NUMA node whose root complex this GPU hangs off.
    pub numa: NumaId,
    /// PCIe switch index (global) the GPU sits behind.
    pub pcie_switch: u8,
}

/// A directional link with an effective capacity in bytes/second.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// What resource this is.
    pub kind: LinkKind,
    /// Effective capacity, bytes/second.
    pub capacity_bps: f64,
}

/// Latency constants of the host platform (per-operation overheads).
#[derive(Clone, Copy, Debug)]
pub struct LatencySpec {
    /// CPU-side launch + DMA engine setup for one `cudaMemcpyAsync`, ns.
    pub dma_setup_ns: u64,
    /// Same for a GPU-to-GPU P2P copy, ns.
    pub p2p_setup_ns: u64,
    /// One PCIe round trip (mapped-flag store→GPU observe), ns.
    pub pcie_rtt_ns: u64,
    /// DMA engine turnaround between back-to-back queued copies on the
    /// same lane (descriptor already programmed), ns.
    pub dma_turnaround_ns: u64,
    /// `cudaEventSynchronize` wake-up latency after completion, ns.
    pub event_sync_ns: u64,
    /// MMA CPU dispatch cost per micro-task (path selection + queue ops), ns.
    pub dispatch_cpu_ns: u64,
}

/// Full server topology: GPUs, switches, NUMA nodes, and directional links.
pub struct Topology {
    /// Human-readable preset name.
    pub name: String,
    /// Number of NUMA nodes.
    pub numa_count: u8,
    /// Number of PCIe switches (global indices).
    pub switch_count: u8,
    /// Per-GPU placement.
    pub gpus: Vec<GpuSpec>,
    /// All directional links.
    pub links: Vec<LinkSpec>,
    /// Platform latency constants.
    pub lat: LatencySpec,
    /// HBM capacity per GPU in bytes (what weights + KV pools carve from).
    pub hbm_bytes: u64,
    index: HashMap<LinkKind, LinkId>,
}

impl Topology {
    /// Build from parts, creating the link index.
    pub fn new(
        name: &str,
        numa_count: u8,
        switch_count: u8,
        gpus: Vec<GpuSpec>,
        links: Vec<LinkSpec>,
        lat: LatencySpec,
        hbm_bytes: u64,
    ) -> Topology {
        let mut index = HashMap::new();
        for (i, l) in links.iter().enumerate() {
            let prev = index.insert(l.kind, LinkId(i as u16));
            assert!(prev.is_none(), "duplicate link kind {:?}", l.kind);
        }
        Topology {
            name: name.to_string(),
            numa_count,
            switch_count,
            gpus,
            links,
            lat,
            hbm_bytes,
            index,
        }
    }

    /// Number of GPUs.
    pub fn gpu_count(&self) -> usize {
        self.gpus.len()
    }

    /// All GPU ids.
    pub fn gpu_ids(&self) -> impl Iterator<Item = GpuId> + '_ {
        (0..self.gpus.len() as u8).map(GpuId)
    }

    /// NUMA node of a GPU.
    pub fn numa_of(&self, g: GpuId) -> NumaId {
        self.gpus[g.0 as usize].numa
    }

    /// Look up a link id; panics if the preset lacks it.
    pub fn link(&self, kind: LinkKind) -> LinkId {
        *self
            .index
            .get(&kind)
            .unwrap_or_else(|| panic!("topology {:?} has no link {kind:?}", self.name))
    }

    /// Capacity of a link (bytes/sec).
    pub fn capacity(&self, id: LinkId) -> f64 {
        self.links[id.0 as usize].capacity_bps
    }

    /// Effective single-PCIe-lane capacity for a GPU/direction — the native
    /// baseline's asymptotic bandwidth.
    pub fn pcie_capacity(&self, g: GpuId, dir: Direction) -> f64 {
        let kind = match dir {
            Direction::H2D => LinkKind::PcieH2D(g),
            Direction::D2H => LinkKind::PcieD2H(g),
        };
        self.capacity(self.link(kind))
    }

    fn xgmi_hop(&self, from: NumaId, to: NumaId, gpu: GpuId, path: &mut SmallPath) {
        if from != to {
            path.push(self.link(LinkKind::Xgmi(from, to)));
            path.push(self.link(LinkKind::XgmiLane(gpu)));
        }
    }

    /// Direct H2D path: host buffer on `buf_numa` → GPU `dst`.
    ///
    /// DRAM read → (xGMI if crossing sockets) → PCIe switch uplink →
    /// GPU PCIe lane → HBM ingest.
    ///
    /// All path constructors return a [`SmallPath`]: the longest preset
    /// path is 7 links, which fits the inline capacity, so building a
    /// path never touches the heap.
    pub fn h2d_direct(&self, buf_numa: NumaId, dst: GpuId) -> SmallPath {
        let spec = self.gpus[dst.0 as usize];
        let mut p = SmallPath::new();
        p.push(self.link(LinkKind::DramRd(buf_numa)));
        self.xgmi_hop(buf_numa, spec.numa, dst, &mut p);
        p.push(self.link(LinkKind::SwitchH2D(spec.pcie_switch)));
        p.push(self.link(LinkKind::PcieH2D(dst)));
        p.push(self.link(LinkKind::HbmIn(dst)));
        p
    }

    /// H2D relay stage 1: host buffer → relay GPU's HBM (its own PCIe lane).
    pub fn h2d_relay_stage1(&self, buf_numa: NumaId, relay: GpuId) -> SmallPath {
        self.h2d_direct(buf_numa, relay)
    }

    /// H2D relay stage 2: relay GPU → target GPU over NVLink.
    pub fn h2d_relay_stage2(&self, relay: GpuId, dst: GpuId) -> SmallPath {
        SmallPath::from_slice(&[
            self.link(LinkKind::HbmOut(relay)),
            self.link(LinkKind::NvOut(relay)),
            self.link(LinkKind::NvIn(dst)),
            self.link(LinkKind::HbmIn(dst)),
        ])
    }

    /// Direct D2H path: GPU `src` → host buffer on `buf_numa`.
    pub fn d2h_direct(&self, src: GpuId, buf_numa: NumaId) -> SmallPath {
        let spec = self.gpus[src.0 as usize];
        let mut p = SmallPath::from_slice(&[
            self.link(LinkKind::HbmOut(src)),
            self.link(LinkKind::PcieD2H(src)),
            self.link(LinkKind::SwitchD2H(spec.pcie_switch)),
        ]);
        self.xgmi_hop(spec.numa, buf_numa, src, &mut p);
        p.push(self.link(LinkKind::DramWr(buf_numa)));
        p
    }

    /// D2H relay stage 1: target GPU → relay GPU over NVLink.
    pub fn d2h_relay_stage1(&self, src: GpuId, relay: GpuId) -> SmallPath {
        SmallPath::from_slice(&[
            self.link(LinkKind::HbmOut(src)),
            self.link(LinkKind::NvOut(src)),
            self.link(LinkKind::NvIn(relay)),
            self.link(LinkKind::HbmIn(relay)),
        ])
    }

    /// D2H relay stage 2: relay GPU → host buffer over its own PCIe lane.
    /// Includes the relay-serialization cap (§5.1.1: the relay must
    /// interleave NVLink ingress and PCIe egress on its copy engine).
    pub fn d2h_relay_stage2(&self, relay: GpuId, buf_numa: NumaId) -> SmallPath {
        let spec = self.gpus[relay.0 as usize];
        let mut p = SmallPath::from_slice(&[
            self.link(LinkKind::HbmOut(relay)),
            self.link(LinkKind::RelayD2HCap(relay)),
            self.link(LinkKind::PcieD2H(relay)),
            self.link(LinkKind::SwitchD2H(spec.pcie_switch)),
        ]);
        self.xgmi_hop(spec.numa, buf_numa, relay, &mut p);
        p.push(self.link(LinkKind::DramWr(buf_numa)));
        p
    }

    /// GPU↔GPU P2P path over the NVSwitch fabric (used by the Table 2
    /// probe and by NCCL-style background traffic).
    pub fn p2p(&self, src: GpuId, dst: GpuId) -> SmallPath {
        SmallPath::from_slice(&[
            self.link(LinkKind::HbmOut(src)),
            self.link(LinkKind::NvOut(src)),
            self.link(LinkKind::NvIn(dst)),
            self.link(LinkKind::HbmIn(dst)),
        ])
    }

    /// Relay candidates for a target GPU, NUMA-local peers first (the
    /// paper's NVML-driven topology discovery orders by NUMA affinity).
    /// `exclude` removes GPUs busy with their own serving group.
    pub fn relay_order(&self, target: GpuId, exclude: &[GpuId]) -> Vec<GpuId> {
        let tn = self.numa_of(target);
        let mut local: Vec<GpuId> = Vec::new();
        let mut remote: Vec<GpuId> = Vec::new();
        for g in self.gpu_ids() {
            if g == target || exclude.contains(&g) {
                continue;
            }
            if self.numa_of(g) == tn {
                local.push(g);
            } else {
                remote.push(g);
            }
        }
        local.extend(remote);
        local
    }

    /// Render the topology as an indented summary (the `mma topo` command).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "{}: {} GPUs, {} NUMA nodes, {} PCIe switches\n",
            self.name,
            self.gpu_count(),
            self.numa_count,
            self.switch_count
        );
        for n in 0..self.numa_count {
            s.push_str(&format!("  numa{n}:\n"));
            for sw in 0..self.switch_count {
                let gpus: Vec<String> = self
                    .gpus
                    .iter()
                    .enumerate()
                    .filter(|(_, g)| g.numa == NumaId(n) && g.pcie_switch == sw)
                    .map(|(i, _)| format!("gpu{i}"))
                    .collect();
                if !gpus.is_empty() {
                    s.push_str(&format!("    switch{sw}: {}\n", gpus.join(", ")));
                }
            }
        }
        s.push_str("  links (effective):\n");
        for l in &self.links {
            s.push_str(&format!(
                "    {:<22} {:>8.1} GB/s\n",
                format!("{:?}", l.kind),
                l.capacity_bps / 1e9
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h20_preset_shape() {
        let t = h20x8();
        assert_eq!(t.gpu_count(), 8);
        assert_eq!(t.numa_count, 2);
        assert_eq!(t.switch_count, 4);
        // 4 GPUs per socket, 2 per switch.
        for n in 0..2u8 {
            let count = t.gpus.iter().filter(|g| g.numa == NumaId(n)).count();
            assert_eq!(count, 4);
        }
        for sw in 0..4u8 {
            let count = t.gpus.iter().filter(|g| g.pcie_switch == sw).count();
            assert_eq!(count, 2);
        }
    }

    #[test]
    fn direct_path_local_has_no_xgmi() {
        let t = h20x8();
        let p = t.h2d_direct(NumaId(0), GpuId(0));
        let kinds: Vec<LinkKind> = p.iter().map(|l| t.links[l.0 as usize].kind).collect();
        assert!(kinds.contains(&LinkKind::DramRd(NumaId(0))));
        assert!(kinds.contains(&LinkKind::PcieH2D(GpuId(0))));
        assert!(!kinds.iter().any(|k| matches!(k, LinkKind::Xgmi(..))));
    }

    #[test]
    fn direct_path_cross_socket_includes_xgmi() {
        let t = h20x8();
        // GPU 4 lives on numa1; buffer on numa0.
        assert_eq!(t.numa_of(GpuId(4)), NumaId(1));
        let p = t.h2d_direct(NumaId(0), GpuId(4));
        let kinds: Vec<LinkKind> = p.iter().map(|l| t.links[l.0 as usize].kind).collect();
        assert!(kinds.contains(&LinkKind::Xgmi(NumaId(0), NumaId(1))));
    }

    #[test]
    fn d2h_relay_stage2_has_serialization_cap() {
        let t = h20x8();
        let p = t.d2h_relay_stage2(GpuId(1), NumaId(0));
        let kinds: Vec<LinkKind> = p.iter().map(|l| t.links[l.0 as usize].kind).collect();
        assert!(kinds.contains(&LinkKind::RelayD2HCap(GpuId(1))));
        // And the cap is strictly below the raw PCIe lane.
        let cap = t.capacity(t.link(LinkKind::RelayD2HCap(GpuId(1))));
        let pcie = t.capacity(t.link(LinkKind::PcieD2H(GpuId(1))));
        assert!(cap < pcie);
    }

    #[test]
    fn relay_order_prefers_numa_local() {
        let t = h20x8();
        let order = t.relay_order(GpuId(0), &[]);
        assert_eq!(order.len(), 7);
        // First three are the other numa0 GPUs.
        for g in &order[..3] {
            assert_eq!(t.numa_of(*g), NumaId(0));
        }
        for g in &order[3..] {
            assert_eq!(t.numa_of(*g), NumaId(1));
        }
        // Excludes work.
        let order2 = t.relay_order(GpuId(0), &[GpuId(1), GpuId(5)]);
        assert_eq!(order2.len(), 5);
        assert!(!order2.contains(&GpuId(1)));
        assert!(!order2.contains(&GpuId(5)));
    }

    #[test]
    fn pcie_effective_capacity_near_paper_baseline() {
        let t = h20x8();
        let bw = t.pcie_capacity(GpuId(0), Direction::H2D);
        // Paper: native saturates ~53 GB/s on PCIe 5.0 x16.
        assert!((52e9..56e9).contains(&bw), "pcie eff {bw}");
    }

    #[test]
    fn describe_mentions_every_gpu() {
        let t = h20x8();
        let d = t.describe();
        for i in 0..8 {
            assert!(d.contains(&format!("gpu{i}")), "missing gpu{i} in\n{d}");
        }
    }

    #[test]
    fn small_presets_build() {
        let t = single_numa_4gpu();
        assert_eq!(t.gpu_count(), 4);
        assert_eq!(t.numa_count, 1);
        let a = a100x8();
        assert_eq!(a.gpu_count(), 8);
        // A100 is PCIe 4.0: lane capacity well below H20's Gen5.
        assert!(a.pcie_capacity(GpuId(0), Direction::H2D) < 30e9);
    }
}
