//! Topology presets with effective capacities calibrated against the
//! paper's Table 1 and §5.1 measurements.

use super::*;

/// Named preset selector (config/CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// The paper's testbed: 8×H20, PCIe 5.0, NVLink4 + NVSwitch, dual EPYC.
    H20x8,
    /// An A100-class box: PCIe 4.0 lanes, NVLink3-like fabric.
    A100x8,
    /// A small single-socket 4-GPU box (latency-predictable mode, §6).
    SingleNuma4,
}

impl Preset {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Preset> {
        match s.to_ascii_lowercase().as_str() {
            "h20x8" | "h20" => Some(Preset::H20x8),
            "a100x8" | "a100" => Some(Preset::A100x8),
            "single_numa_4gpu" | "4gpu" => Some(Preset::SingleNuma4),
            _ => None,
        }
    }
    /// Build the topology.
    pub fn build(self) -> Topology {
        match self {
            Preset::H20x8 => h20x8(),
            Preset::A100x8 => a100x8(),
            Preset::SingleNuma4 => single_numa_4gpu(),
        }
    }
}

fn gb(x: f64) -> f64 {
    x * 1e9
}

struct Caps {
    pcie: f64,
    switch_uplink: f64,
    nv: f64,
    dram_rd: f64,
    dram_wr: f64,
    xgmi: f64,
    xgmi_lane: f64,
    hbm: f64,
    relay_d2h: f64,
    hbm_bytes: u64,
}

fn build(
    name: &str,
    numa_count: u8,
    switches_per_numa: u8,
    gpus_per_switch: u8,
    caps: Caps,
    lat: LatencySpec,
) -> Topology {
    let switch_count = numa_count * switches_per_numa;
    let mut gpus = Vec::new();
    for n in 0..numa_count {
        for s in 0..switches_per_numa {
            for _ in 0..gpus_per_switch {
                gpus.push(GpuSpec {
                    numa: NumaId(n),
                    pcie_switch: n * switches_per_numa + s,
                });
            }
        }
    }
    let mut links = Vec::new();
    for (i, _) in gpus.iter().enumerate() {
        let g = GpuId(i as u8);
        links.push(LinkSpec { kind: LinkKind::PcieH2D(g), capacity_bps: caps.pcie });
        links.push(LinkSpec { kind: LinkKind::PcieD2H(g), capacity_bps: caps.pcie });
        links.push(LinkSpec { kind: LinkKind::NvOut(g), capacity_bps: caps.nv });
        links.push(LinkSpec { kind: LinkKind::NvIn(g), capacity_bps: caps.nv });
        links.push(LinkSpec { kind: LinkKind::HbmIn(g), capacity_bps: caps.hbm });
        links.push(LinkSpec { kind: LinkKind::HbmOut(g), capacity_bps: caps.hbm });
        links.push(LinkSpec {
            kind: LinkKind::RelayD2HCap(g),
            capacity_bps: caps.relay_d2h,
        });
        links.push(LinkSpec {
            kind: LinkKind::XgmiLane(g),
            capacity_bps: caps.xgmi_lane,
        });
    }
    for sw in 0..switch_count {
        links.push(LinkSpec {
            kind: LinkKind::SwitchH2D(sw),
            capacity_bps: caps.switch_uplink,
        });
        links.push(LinkSpec {
            kind: LinkKind::SwitchD2H(sw),
            capacity_bps: caps.switch_uplink,
        });
    }
    for n in 0..numa_count {
        links.push(LinkSpec {
            kind: LinkKind::DramRd(NumaId(n)),
            capacity_bps: caps.dram_rd,
        });
        links.push(LinkSpec {
            kind: LinkKind::DramWr(NumaId(n)),
            capacity_bps: caps.dram_wr,
        });
        for m in 0..numa_count {
            if n != m {
                links.push(LinkSpec {
                    kind: LinkKind::Xgmi(NumaId(n), NumaId(m)),
                    capacity_bps: caps.xgmi,
                });
            }
        }
    }
    Topology::new(
        name,
        numa_count,
        switch_count,
        gpus,
        links,
        lat,
        caps.hbm_bytes,
    )
}

fn default_lat() -> LatencySpec {
    LatencySpec {
        dma_setup_ns: 9_000,     // cudaMemcpyAsync launch + DMA program
        p2p_setup_ns: 6_000,     // P2P copy launch
        pcie_rtt_ns: 1_500,      // mapped-flag store → __ldcg observe (§4)
        dma_turnaround_ns: 1_200, // queued-descriptor handoff on a lane
        event_sync_ns: 5_000,    // cudaEventSynchronize wake-up
        dispatch_cpu_ns: 3_000,  // MMA micro-task dispatch CPU cost
    }
}

/// The paper's testbed: dual EPYC 9654, 8×H20, PCIe 5.0 ×16, NVLink 4.0
/// through NVSwitch, 4×xGMI3 between sockets, 24-channel DDR5-4800/socket.
///
/// Effective capacities (calibration, see DESIGN.md §6):
/// * PCIe lane 53.6 GB/s — the paper's measured native baseline.
/// * Switch uplink 100 GB/s — two GPUs per switch contend mildly.
/// * NVLink 368 GB/s per GPU — matches Table 2's `P2P_alone` 367.6 GB/s.
/// * DRAM 380 GB/s per direction per socket (~700 aggregate, Table 1).
/// * xGMI 62 GB/s effective per direction for IO-agent DMA traffic — raw
///   4×xGMI3 is ~256 GB/s but remote-socket DMA reads achieve a small
///   fraction; calibrated so aggregate H2D saturates ≈245 GB/s at six
///   relays (Fig 8).
/// * Relay D2H forwarding cap 38 GB/s — NVLink-ingress/PCIe-egress
///   serialization on the relay's copy engine (§5.1.1).
pub fn h20x8() -> Topology {
    build(
        "h20x8",
        2,
        2,
        2,
        Caps {
            pcie: gb(53.6),
            switch_uplink: gb(100.0),
            nv: gb(368.0),
            dram_rd: gb(380.0),
            dram_wr: gb(380.0),
            xgmi: gb(62.0),
            xgmi_lane: gb(28.0),
            hbm: gb(400.0),
            relay_d2h: gb(38.0),
            hbm_bytes: 96_000_000_000, // H20: 96 GB HBM3 per GPU
        },
        default_lat(),
    )
}

/// An A100-class server: PCIe 4.0 ×16 (~25 GB/s effective), NVLink3
/// (~280 GB/s effective per GPU), same dual-socket layout.
pub fn a100x8() -> Topology {
    build(
        "a100x8",
        2,
        2,
        2,
        Caps {
            pcie: gb(25.0),
            switch_uplink: gb(48.0),
            nv: gb(280.0),
            dram_rd: gb(300.0),
            dram_wr: gb(300.0),
            xgmi: gb(55.0),
            xgmi_lane: gb(22.0),
            hbm: gb(360.0),
            relay_d2h: gb(18.0),
            hbm_bytes: 80_000_000_000, // A100 80 GB
        },
        default_lat(),
    )
}

/// Single-socket 4-GPU box: the §6 "latency-predictable" configuration
/// with no xGMI hop anywhere.
pub fn single_numa_4gpu() -> Topology {
    build(
        "single_numa_4gpu",
        1,
        2,
        2,
        Caps {
            pcie: gb(53.6),
            switch_uplink: gb(100.0),
            nv: gb(368.0),
            dram_rd: gb(380.0),
            dram_wr: gb(380.0),
            xgmi: gb(62.0), // unused (one socket) but harmless
            xgmi_lane: gb(28.0),
            hbm: gb(400.0),
            relay_d2h: gb(38.0),
            hbm_bytes: 96_000_000_000,
        },
        default_lat(),
    )
}
