//! Lightweight property-based testing (proptest is unavailable offline).
//!
//! [`check`] runs a property against many deterministic RNG seeds and, on
//! failure, re-raises with the failing seed so the case can be replayed with
//! `MMA_PT_SEED=<seed>`. Generators are free functions over [`Rng`].

use crate::util::rng::Rng;

/// Number of cases per property (override with `MMA_PT_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("MMA_PT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` seeds. `prop` should panic (e.g. via assert!)
/// on violation. If `MMA_PT_SEED` is set, only that seed runs.
pub fn check_named(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    if let Ok(seed) = std::env::var("MMA_PT_SEED") {
        let seed: u64 = seed.parse().expect("MMA_PT_SEED must be u64");
        let mut rng = Rng::seed_from_u64(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        // Derive a well-mixed per-case seed.
        let seed = case
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0xD1B5_4A32_D192_ED03);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case} (replay with MMA_PT_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// [`check_named`] with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Rng)) {
    check_named(name, default_cases(), prop);
}

/// Generate a vector with length in `[0, max_len)` from `gen`.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.range_usize(0, max_len);
    (0..n).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("u64-range", |rng| {
            let x = rng.range_u64(5, 10);
            assert!((5..10).contains(&x));
        });
    }

    #[test]
    fn check_reports_failure() {
        let r = std::panic::catch_unwind(|| {
            check_named("always-fails", 4, |_rng| panic!("boom"));
        });
        assert!(r.is_err());
    }

    #[test]
    fn vec_of_respects_max() {
        check("vec-len", |rng| {
            let v = vec_of(rng, 17, |r| r.next_u64());
            assert!(v.len() < 17);
        });
    }
}
