//! Shared test harness: canonical topology/fleet/request builders plus
//! lightweight property-based testing (proptest is unavailable offline).
//!
//! The builders are the one copy of the setup every serving test used to
//! paste locally: a `qwen-7b-chat` engine/fleet on the simulated H20
//! server, fixed-duration compute stand-ins, and the standard
//! cold/prefix-hit request shapes. Unit tests (`crate::testkit::...`),
//! integration tests, and figure smoke tests (`mma::testkit::...`) all
//! build scenarios through here, so a change to the canonical setup is
//! made exactly once.
//!
//! [`check`] runs a property against many deterministic RNG seeds and, on
//! failure, re-raises with the failing seed so the case can be replayed with
//! `MMA_PT_SEED=<seed>`. Generators are free functions over [`Rng`].

use crate::config::{FleetConfig, ServingConfig};
use crate::mma::{MmaConfig, SimWorld, TransferDesc};
use crate::models::qwen_7b_chat;
use crate::serving::{
    Compute, FixedCompute, Request, RequestId, RoutePolicy, ServingEngine, ServingFleet,
    StepRecord,
};
use crate::sim::Time;
use crate::topology::{h20x8, Direction, GpuId, NumaId};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Canonical builders
// ---------------------------------------------------------------------------

/// A boxed fixed-duration compute model — the standard stand-in when a
/// test cares about scheduling/transfer behavior, not kernel pricing.
pub fn fixed(prefill_s: f64, decode_s: f64) -> Box<dyn Compute> {
    Box::new(FixedCompute {
        prefill_s,
        decode_s,
    })
}

/// `n` identical [`fixed`] compute models — one per fleet instance.
pub fn fixed_computes(n: usize, prefill_s: f64, decode_s: f64) -> Vec<Box<dyn Compute>> {
    (0..n).map(|_| fixed(prefill_s, decode_s)).collect()
}

/// The canonical host→device transfer: `bytes` to `gpu`, staged from
/// NUMA node 0 (where every test scenario parks its host memory).
pub fn h2d(gpu: u8, bytes: u64) -> TransferDesc {
    TransferDesc::new(Direction::H2D, GpuId(gpu), NumaId(0), bytes)
}

/// The canonical fleet shape: `gpus` instances under the round-robin
/// router, no prefix affinity.
pub fn fleet_config(gpus: u32, peer_fetch: bool) -> FleetConfig {
    FleetConfig {
        gpus,
        router: RoutePolicy::RoundRobin,
        peer_fetch,
        prefix_affinity: false,
    }
}

/// The canonical single-GPU engine: `qwen-7b-chat` on GPU 0 / NUMA 0 of
/// the simulated H20 server, with the given serving/transfer config.
pub fn engine(cfg: ServingConfig, mma: MmaConfig, compute: Box<dyn Compute>) -> ServingEngine {
    let world = SimWorld::new(h20x8(), mma);
    ServingEngine::new(cfg, qwen_7b_chat(), world, compute, GpuId(0), NumaId(0))
}

/// The canonical aggregated-mode fleet: `gpus` round-robin instances
/// serving `qwen-7b-chat` with [`fixed`] costs (`prefill_s`, decode
/// 1 ms), PD disaggregation off so promoted prefixes stay GPU-resident,
/// shared host tier on NUMA 0.
pub fn fleet(gpus: u32, peer_fetch: bool, mma: MmaConfig, prefill_s: f64) -> ServingFleet {
    let serving = ServingConfig {
        pd_disaggregation: false,
        ..Default::default()
    };
    let world = SimWorld::new(h20x8(), mma);
    ServingFleet::new(
        fleet_config(gpus, peer_fetch),
        serving,
        qwen_7b_chat(),
        world,
        fixed_computes(gpus as usize, prefill_s, 0.001),
        NumaId(0),
    )
}

/// A request with an explicit prompt/cached-prefix split (2 output
/// tokens, tenant 0, default QoS class).
pub fn request(id: u64, arrival_ms: u64, prompt: u32, cached: u32, key: u64) -> Request {
    Request {
        id: RequestId(id),
        arrival: Time::from_ms(arrival_ms),
        prompt_tokens: prompt,
        cached_prefix_tokens: cached,
        prefix_key: key,
        output_tokens: 2,
        tenant: 0,
        class: None,
    }
}

/// A host-tier prefix hit: `ctx` cached tokens under `key` plus the
/// standard 64-token fresh suffix.
pub fn hit(id: u64, arrival_ms: u64, ctx: u32, key: u64) -> Request {
    request(id, arrival_ms, ctx + 64, ctx, key)
}

/// A cold request: `prompt` tokens, nothing cached.
pub fn cold(id: u64, arrival_ms: u64, prompt: u32) -> Request {
    request(id, arrival_ms, prompt, 0, 0)
}

/// Render a recorded step trace one line per fused step — the
/// comparable/goldenable view of what the continuous-batching scheduler
/// did (see [`crate::serving::ServingInstance::steps`]).
pub fn render_steps(steps: &[StepRecord]) -> String {
    let mut s = String::new();
    for r in steps {
        s.push_str(&format!(
            "t={:.6} prefill={} decode={} kv={} secs={:.6}\n",
            r.at.as_secs_f64(),
            r.prefill_tokens,
            r.decode_batch,
            r.decode_kv_bytes,
            r.secs,
        ));
    }
    s
}

// ---------------------------------------------------------------------------
// Property harness
// ---------------------------------------------------------------------------

/// Number of cases per property (override with `MMA_PT_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("MMA_PT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` seeds. `prop` should panic (e.g. via assert!)
/// on violation. If `MMA_PT_SEED` is set, only that seed runs.
pub fn check_named(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    if let Ok(seed) = std::env::var("MMA_PT_SEED") {
        let seed: u64 = seed.parse().expect("MMA_PT_SEED must be u64");
        let mut rng = Rng::seed_from_u64(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        // Derive a well-mixed per-case seed.
        let seed = case
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0xD1B5_4A32_D192_ED03);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case} (replay with MMA_PT_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// [`check_named`] with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Rng)) {
    check_named(name, default_cases(), prop);
}

/// Generate a vector with length in `[0, max_len)` from `gen`.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.range_usize(0, max_len);
    (0..n).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("u64-range", |rng| {
            let x = rng.range_u64(5, 10);
            assert!((5..10).contains(&x));
        });
    }

    #[test]
    fn check_reports_failure() {
        let r = std::panic::catch_unwind(|| {
            check_named("always-fails", 4, |_rng| panic!("boom"));
        });
        assert!(r.is_err());
    }

    #[test]
    fn vec_of_respects_max() {
        check("vec-len", |rng| {
            let v = vec_of(rng, 17, |r| r.next_u64());
            assert!(v.len() < 17);
        });
    }

    #[test]
    fn canonical_engine_serves_the_canonical_requests() {
        let mut e = engine(
            ServingConfig::default(),
            MmaConfig::native(),
            fixed(0.1, 0.01),
        );
        let out = e.run(vec![cold(1, 0, 1000)]);
        assert_eq!(out.len(), 1);
        assert!((out[0].ttft.prefill_s - 0.1).abs() < 1e-9);
        assert_eq!(out[0].ttft.fetch_s, 0.0, "cold requests fetch nothing");
    }

    #[test]
    fn canonical_fleet_runs_a_prefix_hit() {
        let mut f = fleet(2, false, MmaConfig::native(), 0.05);
        f.seed_host_prefix(7, 4096);
        let out = f.run(vec![hit(1, 0, 4096, 7)]);
        assert!(out[0].ttft.fetch_s > 0.0, "hits fetch from the host tier");
        assert!(out[0].finished_at.is_some());
    }

    #[test]
    fn render_steps_is_one_line_per_step() {
        let steps = [
            StepRecord {
                at: Time::from_ms(1),
                prefill_tokens: 512,
                decode_batch: 0,
                decode_kv_bytes: 0,
                secs: 0.004,
            },
            StepRecord {
                at: Time::from_ms(5),
                prefill_tokens: 0,
                decode_batch: 4,
                decode_kv_bytes: 1 << 30,
                secs: 0.002,
            },
        ];
        let s = render_steps(&steps);
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains("prefill=512") && s.contains("decode=4"));
    }
}
